//! Sweep the full 21-network TorchVision zoo through the `Engine`
//! facade and the paper-device simulators — a compact reproduction of
//! the paper's whole evaluation section in one command:
//!
//!   cargo run --release --example model_zoo
//!
//! Prints, per network: structure (Table 2's left columns), simulated
//! GPU/CPU total speed-ups at batch 128 (Figures 13/14), and the batch-32
//! GPU speed-up (the paper highlights DenseNet-201's 35.7% there).

use brainslug::bench::{self, fmt_pct, Table};
use brainslug::device::DeviceSpec;
use brainslug::memsim::speedup_pct;
use brainslug::zoo;

fn speedup(name: &str, batch: usize, device: &DeviceSpec) -> f64 {
    let engine = bench::paper_engine(name, batch, device).build().unwrap();
    let base = engine.simulate_baseline();
    let bs = engine.simulate_plan().unwrap();
    speedup_pct(base.total_s, bs.total_s)
}

fn main() {
    let gpu = DeviceSpec::paper_gpu();
    let cpu = DeviceSpec::paper_cpu();
    let mut table = Table::new(&[
        "network", "layers", "opt", "stacks", "gpu@128", "cpu@128", "gpu@32",
    ]);
    let mut best = (String::new(), f64::MIN);
    for name in zoo::ALL_NETWORKS {
        let engine = bench::paper_engine(name, 1, &gpu).build().unwrap();
        let plan = engine.plan().unwrap();
        let g128 = speedup(name, 128, &gpu);
        let c128 = speedup(name, 128, &cpu);
        let g32 = speedup(name, 32, &gpu);
        if g32 > best.1 {
            best = (name.to_string(), g32);
        }
        table.row(vec![
            name.to_string(),
            engine.graph().num_layers().to_string(),
            plan.num_optimized_layers().to_string(),
            plan.num_stacks().to_string(),
            fmt_pct(g128),
            fmt_pct(c128),
            fmt_pct(g32),
        ]);
    }
    table.print();
    println!(
        "\nbest GPU speed-up at batch 32: {} ({}) — paper: densenet201 (+35.7%)",
        best.0,
        fmt_pct(best.1)
    );
}
