//! Quickstart: the library's 5-minute tour, mirroring the paper's
//! Listing 3 (`brainslug.optimize(model)`). The whole pipeline is one
//! `Engine` builder:
//!
//!   1. build the engine — network resolution, optimization, plan
//!      validation, and backend selection in a single call,
//!   2. execute baseline and optimized plans,
//!   3. verify both produce identical results.
//!
//! With artifacts (`make artifacts`) this runs the real PJRT backend;
//! without them it transparently falls back to the artifact-free sim
//! backend, so the example always completes:
//!
//!   cargo run --release --example quickstart

use brainslug::bench;
use brainslug::optimizer::Segment;

fn main() -> anyhow::Result<()> {
    // 1. One builder call replaces the old 7-step wiring (zoo lookup,
    //    device spec, optimize, validate, runtime, executor, run).
    //    Fall back to the artifact-free sim backend only when artifacts
    //    are genuinely absent; a broken artifact dir should surface its
    //    real error, not fabricated sim numbers.
    let batch = bench::measured_batches()[0];
    let builder = bench::measured_engine("vgg11_bn", batch);
    let mut engine = if bench::artifacts_present() {
        builder.build()?
    } else {
        println!("(artifacts missing — falling back to the sim backend)");
        builder.sim().build()?
    };
    println!("{}", engine.describe());

    // Peek at the plan the optimizer produced.
    let graph = engine.graph_arc();
    let plan = engine.plan().expect("brainslug mode has a plan");
    for (i, seg) in plan.segments.iter().enumerate().take(8) {
        match seg {
            Segment::Single(id) => {
                println!("  seg {i}: {}", graph.node(*id).name)
            }
            Segment::Stack(st) => println!(
                "  seg {i}: STACK of {} layers -> {} ({} sequence(s))",
                st.nodes.len(),
                st.artifact_name(),
                st.sequences.len()
            ),
            Segment::Branch { arms, join } => println!(
                "  seg {i}: BRANCH of {} arms joining at {} (depth-first arm-by-arm)",
                arms.len(),
                graph.node(*join).name
            ),
        }
    }
    println!("  ...");

    // 2. Execute both modes through the same engine.
    let input = engine.synthetic_input();
    let (out_base, stats_base) = engine.run_baseline(input.clone())?;
    let (out_bs, stats_bs) = engine.run(input)?;

    // 3. Transparent means *same results*.
    let diff = out_base.max_abs_diff(&out_bs);
    println!(
        "baseline {:.1}ms vs brainslug {:.1}ms — max output diff {diff:.2e}",
        stats_base.total_s * 1e3,
        stats_bs.total_s * 1e3
    );
    assert!(out_base.allclose(&out_bs, 1e-4, 1e-4));
    println!("OK: depth-first execution is numerically transparent");
    Ok(())
}
