//! Quickstart: the library's 5-minute tour, mirroring the paper's
//! Listing 3 (`brainslug.optimize(model)`).
//!
//!   1. build a network (VGG-11+BN at reduced scale),
//!   2. run the optimizer — the one-call transparent acceleration,
//!   3. execute baseline and optimized plans on the PJRT runtime,
//!   4. verify both produce identical results.
//!
//! Run after `make artifacts`:
//!   cargo run --release --example quickstart

use brainslug::bench;
use brainslug::optimizer::{optimize, Segment};
use brainslug::runtime::Runtime;
use brainslug::scheduler::Executor;
use brainslug::zoo;

fn main() -> anyhow::Result<()> {
    // 1. Load the model (the paper's `models.__dict__['vgg11_bn']()`).
    let batch = bench::measured_batches()[0];
    let graph = zoo::build("vgg11_bn", zoo::small_config("vgg11_bn", batch));
    println!(
        "vgg11_bn: {} layers, input {}",
        graph.num_layers(),
        graph.input_shape()
    );

    // 2. Optimize — the `brainslug.optimize(model)` call.
    let device = bench::measured_device();
    let plan = optimize(&graph, &device, &bench::measured_opts());
    println!(
        "optimizer: {} of {} layers collapsed into {} stacks ({} unique kernels)",
        plan.num_optimized_layers(),
        graph.num_layers(),
        plan.num_stacks(),
        plan.num_unique_stacks()
    );
    for (i, seg) in plan.segments.iter().enumerate().take(8) {
        match seg {
            Segment::Single(id) => {
                println!("  seg {i}: {}", graph.node(*id).name)
            }
            Segment::Stack(st) => println!(
                "  seg {i}: STACK of {} layers -> {} ({} sequence(s))",
                st.nodes.len(),
                st.artifact_name(),
                st.sequences.len()
            ),
        }
    }
    println!("  ...");

    // 3. Execute both modes on AOT-compiled artifacts.
    let runtime = Runtime::new(std::path::Path::new(bench::ARTIFACT_DIR))?;
    let mut exec = Executor::new(&runtime, &graph, bench::oracle_seed());
    let input = exec.synthetic_input();
    let (out_base, stats_base) = exec.run_baseline(input.clone())?;
    let (out_bs, stats_bs) = exec.run_plan(&plan, input)?;

    // 4. Transparent means *same results*.
    let diff = out_base.max_abs_diff(&out_bs);
    println!(
        "baseline {:.1}ms vs brainslug {:.1}ms — max output diff {diff:.2e}",
        stats_base.total_s * 1e3,
        stats_bs.total_s * 1e3
    );
    assert!(out_base.allclose(&out_bs, 1e-4, 1e-4));
    println!("OK: depth-first execution is numerically transparent");
    Ok(())
}
