//! The Figure-10 scenario as a runnable example: how collapse strategy
//! (1-step / 5-step / unrestricted sequences) changes the generated
//! kernels and measured performance of a pure <MaxPool,BN,ReLU> block
//! network, and where the cache budget forces a sequence spill. All plan
//! inspection and execution goes through the `Engine` facade.
//!
//!   cargo run --release --example stacked_blocks

use brainslug::bench::{self, fmt_pct, fmt_time, Table};
use brainslug::engine::Engine;
use brainslug::memsim::{compare_schedules, speedup_pct};

fn main() -> anyhow::Result<()> {
    let device = bench::measured_device();
    println!(
        "device={} fast_mem={}KiB",
        device.name,
        device.fast_mem_bytes / 1024
    );

    // Collapse structure vs block count: watch the working set grow with
    // the halo until a second sequence appears. The sim backend gives us
    // the validated plan with no artifacts.
    println!("\n## Collapse structure (unrestricted strategy)");
    let mut t = Table::new(&["blocks", "sequences", "tile-rows", "working-set"]);
    for blocks in [1, 2, 4, 8, 16, 24, 32, 40] {
        let engine = Engine::builder()
            .graph_owned(bench::block_net(blocks, 4, 8, 32))
            .device(device.clone())
            .brainslug(bench::measured_opts())
            .sim()
            .build()?;
        let plan = engine.plan().expect("brainslug mode has a plan");
        let stack = plan.stacks().next().unwrap();
        let tiles: Vec<String> = stack
            .sequences
            .iter()
            .map(|s| s.tile_rows.to_string())
            .collect();
        let ws: usize = stack
            .sequences
            .iter()
            .map(|s| s.working_set_bytes(s.tile_rows))
            .max()
            .unwrap();
        t.row(vec![
            blocks.to_string(),
            stack.sequences.len().to_string(),
            tiles.join("/"),
            format!("{}B", ws),
        ]);
    }
    t.print();

    // Cache-simulator evidence, independent of any time model.
    println!("\n## LRU cache simulation (16 KiB cache, 64 KiB plane, depth 6)");
    let (bf, df) = compare_schedules(16384, 6, 512, 16 * 1024);
    println!("breadth-first misses: {bf}\ndepth-first  misses: {df} ({:.1}x fewer)", bf as f64 / df as f64);

    // Measured wall-clock per strategy (needs artifacts). One shared
    // runtime keeps the executable cache warm across engines.
    if let Some(runtime) = bench::measured_runtime() {
        println!("\n## Measured (XLA-CPU, batch=4, 8ch 32x32)");
        let mut t = Table::new(&["blocks", "baseline", "1step", "5step", "unrestr"]);
        for &blocks in bench::fig10_measured_blocks() {
            let mut cells = vec![blocks.to_string()];
            let mut base = f64::NAN;
            for (_, opts) in bench::fig10_strategies() {
                let mut engine =
                    bench::build_measured(bench::block_engine(blocks, 4, 8, 32, opts), &runtime)?;
                let input = engine.synthetic_input();
                if cells.len() == 1 {
                    base = bench::measure(2, 5, || {
                        engine.run_baseline(input.clone()).unwrap();
                    });
                    cells.push(fmt_time(base));
                }
                let tt = bench::measure(2, 5, || {
                    engine.run(input.clone()).unwrap();
                });
                cells.push(format!("{} ({})", fmt_time(tt), fmt_pct(speedup_pct(base, tt))));
            }
            t.row(cells);
        }
        t.print();
    } else {
        println!("\n(measured section skipped: run `make artifacts`)");
    }
    Ok(())
}
