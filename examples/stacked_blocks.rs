//! The Figure-10 scenario as a runnable example: how collapse strategy
//! (1-step / 5-step / unrestricted sequences) changes the generated
//! kernels and measured performance of a pure <MaxPool,BN,ReLU> block
//! network, and where the cache budget forces a sequence spill.
//!
//!   cargo run --release --example stacked_blocks

use brainslug::bench::{self, fmt_pct, fmt_time, Table};
use brainslug::memsim::{compare_schedules, speedup_pct};
use brainslug::optimizer::optimize;
use brainslug::runtime::Runtime;
use brainslug::scheduler::Executor;

fn main() -> anyhow::Result<()> {
    let device = bench::measured_device();
    println!(
        "device={} fast_mem={}KiB",
        device.name,
        device.fast_mem_bytes / 1024
    );

    // Collapse structure vs block count: watch the working set grow with
    // the halo until a second sequence appears.
    println!("\n## Collapse structure (unrestricted strategy)");
    let mut t = Table::new(&["blocks", "sequences", "tile-rows", "working-set"]);
    for blocks in [1, 2, 4, 8, 16, 24, 32, 40] {
        let g = bench::block_net(blocks, 4, 8, 32);
        let plan = optimize(&g, &device, &bench::measured_opts());
        let stack = plan.stacks().next().unwrap();
        let tiles: Vec<String> = stack
            .sequences
            .iter()
            .map(|s| s.tile_rows.to_string())
            .collect();
        let ws: usize = stack
            .sequences
            .iter()
            .map(|s| s.working_set_bytes(s.tile_rows))
            .max()
            .unwrap();
        t.row(vec![
            blocks.to_string(),
            stack.sequences.len().to_string(),
            tiles.join("/"),
            format!("{}B", ws),
        ]);
    }
    t.print();

    // Cache-simulator evidence, independent of any time model.
    println!("\n## LRU cache simulation (16 KiB cache, 64 KiB plane, depth 6)");
    let (bf, df) = compare_schedules(16384, 6, 512, 16 * 1024);
    println!("breadth-first misses: {bf}\ndepth-first  misses: {df} ({:.1}x fewer)", bf as f64 / df as f64);

    // Measured wall-clock per strategy (needs artifacts).
    match Runtime::new(std::path::Path::new(bench::ARTIFACT_DIR)) {
        Ok(runtime) => {
            println!("\n## Measured (XLA-CPU, batch=4, 8ch 32x32)");
            let mut t = Table::new(&["blocks", "baseline", "1step", "5step", "unrestr"]);
            for &blocks in bench::fig10_measured_blocks() {
                let g = bench::block_net(blocks, 4, 8, 32);
                let mut exec = Executor::new(&runtime, &g, bench::oracle_seed());
                let input = exec.synthetic_input();
                let base = bench::measure(2, 5, || {
                    exec.run_baseline(input.clone()).unwrap();
                });
                let mut cells = vec![blocks.to_string(), fmt_time(base)];
                for (_, opts) in bench::fig10_strategies() {
                    let plan = optimize(&g, &device, &opts);
                    let tt = bench::measure(2, 5, || {
                        exec.run_plan(&plan, input.clone()).unwrap();
                    });
                    cells.push(format!("{} ({})", fmt_time(tt), fmt_pct(speedup_pct(base, tt))));
                }
                t.row(cells);
            }
            t.print();
        }
        Err(_) => println!("\n(measured section skipped: run `make artifacts`)"),
    }
    Ok(())
}
