//! End-to-end driver: the full system on a real (small) serving
//! workload, proving all layers compose — rust batching server (worker
//! pool) → `Engine` facade → scheduler → PJRT runtime → AOT-compiled
//! XLA/Pallas artifacts.
//!
//! Loads the reduced-scale VGG-11+BN, serves a synthetic trace of
//! single-image requests through the dynamic batcher in BOTH modes
//! (breadth-first baseline, BrainSlug depth-first plan), reports
//! latency/throughput for each, and cross-checks numerics between modes.
//! The server is configured with a `ServerConfig` over an
//! `EngineBuilder`: each pool worker builds its own engine replica from
//! the shared builder and pulls from one bounded dispatch queue; swap
//! `.artifacts(...)` for `.sim()` to serve without artifacts. Recorded
//! in EXPERIMENTS.md §End-to-end.
//!
//!   cargo run --release --example e2e_serve [-- <num_requests> [<workers>]]

use std::time::Duration;

use brainslug::bench;
use brainslug::engine::Mode;
use brainslug::rng::fill_f32;
use brainslug::server::ServerConfig;

fn serve_trace(
    plan_mode: bool,
    n_requests: usize,
    workers: usize,
) -> anyhow::Result<(f64, f64, f64, Vec<f32>)> {
    let batch = *bench::measured_batches().last().unwrap();
    let engine = bench::measured_engine("vgg11_bn", batch).mode(if plan_mode {
        Mode::BrainSlug(bench::measured_opts())
    } else {
        Mode::Baseline
    });
    let server = ServerConfig::new(engine)
        .workers(workers)
        .queue_depth(4 * batch)
        .max_wait(Duration::from_millis(3))
        .start()?;
    let handle = server.handle();
    let image_elems = handle.image_shape().numel();

    // Warm-up batch so executable compilation is off the trace.
    handle.infer(fill_f32(999, image_elems))?;

    let t0 = std::time::Instant::now();
    let clients: Vec<_> = (0..n_requests)
        .map(|i| {
            let h = handle.clone();
            std::thread::spawn(move || {
                // Poisson-ish arrivals: small deterministic jitter.
                std::thread::sleep(Duration::from_micros((i as u64 % 7) * 300));
                let img = fill_f32(i as u64, image_elems);
                h.infer(img).map(|t| t.data[0])
            })
        })
        .collect();
    let mut firsts = Vec::new();
    for c in clients {
        firsts.push(c.join().unwrap()?);
    }
    let wall = t0.elapsed().as_secs_f64();
    let throughput = n_requests as f64 / wall;
    let latency = server.stats.mean_latency_ms();
    let occupancy = server.occupancy();
    server.stop();
    Ok((throughput, latency, occupancy, firsts))
}

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(48);
    let workers: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    println!(
        "# End-to-end serving: vgg11_bn, {n} requests, dynamic batching, {workers} worker(s)"
    );

    let (thr_b, lat_b, occ_b, out_b) = serve_trace(false, n, workers)?;
    println!(
        "baseline : {thr_b:6.1} req/s, mean latency {lat_b:6.2} ms, occupancy {:.0}%",
        occ_b * 100.0
    );
    let (thr_p, lat_p, occ_p, out_p) = serve_trace(true, n, workers)?;
    println!(
        "brainslug: {thr_p:6.1} req/s, mean latency {lat_p:6.2} ms, occupancy {:.0}%",
        occ_p * 100.0
    );

    // Numerics must agree per request across modes.
    let max_diff = out_b
        .iter()
        .zip(&out_p)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max per-request output diff between modes: {max_diff:.2e}");
    anyhow::ensure!(max_diff < 1e-3, "serving modes diverge numerically");

    println!(
        "throughput gain: {:+.1}%  latency change: {:+.1}%",
        (thr_p / thr_b - 1.0) * 100.0,
        (lat_p / lat_b - 1.0) * 100.0
    );
    println!("OK: full stack (server pool -> engine -> scheduler -> PJRT -> Pallas artifacts) composes");
    Ok(())
}
