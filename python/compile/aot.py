"""AOT path: lower every compile request to an HLO-text artifact.

Reads ``artifacts/requests.json`` (written by ``brainslug
emit-requests``), builds a JAX function per request — per-layer
executables from the L2 layer library, fused per-stack executables from
the L1 Pallas kernel — lowers each to HLO *text* and writes
``artifacts/manifest.json`` plus the numerics oracles.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids that the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Usage:  python -m compile.aot [--requests PATH] [--out DIR] [--only NAME]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import detrng, layers, model
from .kernels import fused_stack


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a 1-tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(dims, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(dims), dtype)


# ---------------------------------------------------------------------------
# Per-layer executables
# ---------------------------------------------------------------------------


def layer_fn_and_specs(req: dict):
    """Build (fn, arg_specs) for a layer request. Argument order matches
    the rust scheduler: activations first, then parameters."""
    kind = req["kind"]
    in_dims = [s["dims"] for s in req["in_shapes"]]
    x = spec(in_dims[0])

    if kind == "conv2d":
        stride = tuple(req["stride"])
        pad = tuple(req["pad"])
        oc = req["out_channels"]
        w = spec((oc, in_dims[0][1], req["kernel"][0], req["kernel"][1]))
        if req["bias"]:
            b = spec((oc,))
            return (
                lambda x, w, b: (layers.conv2d(x, w, b, stride, pad),),
                [x, w, b],
            )
        return (lambda x, w: (layers.conv2d(x, w, None, stride, pad),), [x, w])

    if kind == "linear":
        of = req["out_features"]
        w = spec((in_dims[0][1], of))
        if req["bias"]:
            b = spec((of,))
            return (lambda x, w, b: (layers.linear(x, w, b),), [x, w, b])
        return (lambda x, w: (layers.linear(x, w, None),), [x, w])

    if kind in ("maxpool", "avgpool"):
        kernel = tuple(req["kernel"])
        stride = tuple(req["stride"])
        pad = tuple(req["pad"])
        if req["pool"] == "max":
            ceil = req["ceil_mode"]
            return (
                lambda x: (layers.max_pool2d(x, kernel, stride, pad, ceil),),
                [x],
            )
        cip = req["count_include_pad"]
        return (
            lambda x: (layers.avg_pool2d(x, kernel, stride, pad, cip),),
            [x],
        )

    if kind == "adaptiveavgpool":
        out_hw = tuple(req["out_hw"])
        return (lambda x: (layers.adaptive_avg_pool2d(x, out_hw),), [x])

    if kind == "batchnorm":
        c = in_dims[0][1]
        s = spec((c,))
        return (
            lambda x, scale, shift: (layers.bn_affine(x, scale, shift),),
            [x, s, s],
        )

    if kind == "relu":
        return (lambda x: (layers.relu(x),), [x])

    if kind == "add":
        return (lambda a, b: (a + b,), [spec(in_dims[0]), spec(in_dims[1])])

    if kind == "concat":
        specs = [spec(d) for d in in_dims]
        return (lambda *xs: (jnp.concatenate(xs, axis=1),), specs)

    raise ValueError(f"unknown layer kind {kind}")


def stack_fn_and_specs(req: dict):
    """Build (fn, arg_specs) for a fused stack request."""
    fn = fused_stack.stack_fn(req)
    x = spec(req["in_shape"]["dims"])
    c = req["in_shape"]["dims"][1] if len(req["in_shape"]["dims"]) == 4 else None
    n_bn = sum(
        1
        for seq in req["sequences"]
        for step in seq["steps"]
        for op in step
        if op["op"] == "bn"
    )
    assert n_bn == 0 or c is not None, "bn params require rank-4 stacks"
    params = [spec((c,)) for _ in range(2 * n_bn)]
    return fn, [x] + params


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def shape_manifest(dims) -> dict:
    return {"dims": list(dims), "dtype": "f32"}


def lower_one(name: str, fn, arg_specs, out_dir: str) -> dict:
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(text)
    # Determine the output shape by abstract evaluation.
    out = jax.eval_shape(fn, *arg_specs)
    (out0,) = out  # all executables return 1-tuples
    return {
        "name": name,
        "path": path,
        "inputs": [shape_manifest(s.shape) for s in arg_specs],
        "output": shape_manifest(out0.shape),
    }


def run_oracle(entry: dict, out_dir: str) -> dict:
    graph = entry["graph"]
    seed = entry["seed"]
    tag = entry["tag"]
    params = model.make_params(graph, seed)
    x = model.synthetic_input(graph, seed)
    out = np.asarray(model.run_graph(graph, jnp.asarray(x), params))
    in_path = f"oracle_{tag}_input.f32"
    out_path = f"oracle_{tag}_output.f32"
    x.astype("<f4").tofile(os.path.join(out_dir, in_path))
    out.astype("<f4").tofile(os.path.join(out_dir, out_path))
    return {
        "tag": tag,
        "seed": seed,
        "input_path": in_path,
        "output_path": out_path,
        "input": shape_manifest(x.shape),
        "output": shape_manifest(out.shape),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", default="artifacts/requests.json")
    ap.add_argument("--out", default="artifacts")
    ap.add_argument("--only", default=None, help="lower only this executable")
    args = ap.parse_args()

    with open(args.requests) as f:
        requests = json.load(f)
    os.makedirs(args.out, exist_ok=True)

    entries = []
    t0 = time.time()
    work = [("layer", r) for r in requests["layers"]] + [
        ("stack", r) for r in requests["stacks"]
    ]
    for i, (kind, req) in enumerate(work):
        name = req["name"]
        if args.only and name != args.only:
            continue
        fn, specs = (
            layer_fn_and_specs(req) if kind == "layer" else stack_fn_and_specs(req)
        )
        try:
            entries.append(lower_one(name, fn, specs, args.out))
        except Exception:
            print(f"FAILED lowering {name}", file=sys.stderr)
            raise
        if (i + 1) % 25 == 0:
            print(f"  lowered {i + 1}/{len(work)} ({time.time() - t0:.0f}s)")

    oracles = []
    if not args.only:
        for entry in requests.get("oracles", []):
            oracles.append(run_oracle(entry, args.out))
            print(f"  oracle {entry['tag']} done")

    manifest = {"executables": entries, "oracles": oracles}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(
        f"wrote {len(entries)} executables + {len(oracles)} oracles "
        f"to {args.out} in {time.time() - t0:.0f}s"
    )


if __name__ == "__main__":
    main()
