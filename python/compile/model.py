"""L2: JAX model execution from exported graph JSON.

The rust zoo is the single source of truth for topology; this module
*interprets* an exported graph (``brainslug dot --json`` / the oracle
entries of ``requests.json``) as a JAX computation, with parameters drawn
from the shared deterministic RNG. It is the breadth-first reference the
integration tests compare the rust scheduler against, and it exercises
the same layer library the per-layer executables are lowered from.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import detrng, layers


def param_tags(node: dict) -> list[tuple[str, str, str]]:
    """(tag, kind, role) triples for a node — mirrors rust
    ``node_param_tags`` ordering."""
    kind = node["kind"]
    name = node["name"]
    if kind in ("conv2d", "linear"):
        tags = [(f"{name}:weight", "weight", "weight")]
        if node["bias"]:
            tags.append((f"{name}:bias", "bias", "bias"))
        return tags
    if kind == "batchnorm":
        return [
            (f"{name}:bn_gamma", "bn_gamma", "gamma"),
            (f"{name}:bn_beta", "bn_beta", "beta"),
            (f"{name}:bn_mean", "bn_mean", "mean"),
            (f"{name}:bn_var", "bn_var", "var"),
        ]
    return []


def param_shape(node: dict, in_dims: list[int], role: str) -> tuple[int, ...]:
    kind = node["kind"]
    if kind == "conv2d":
        if role == "weight":
            return (node["out_channels"], in_dims[1], node["kernel"][0], node["kernel"][1])
        return (node["out_channels"],)
    if kind == "linear":
        if role == "weight":
            return (in_dims[1], node["out_features"])
        return (node["out_features"],)
    if kind == "batchnorm":
        return (in_dims[1],)
    raise ValueError(f"{kind} has no params")


def make_params(graph: dict, seed: int) -> dict[str, np.ndarray]:
    """All parameters of a graph, keyed by tag."""
    out: dict[str, np.ndarray] = {}
    nodes = graph["nodes"]
    for node in nodes:
        if not node["inputs"]:
            continue
        in_dims = nodes[node["inputs"][0]]["shape"]["dims"]
        for tag, kind, role in param_tags(node):
            shape = param_shape(node, in_dims, role)
            s = detrng.tensor_seed(seed, tag)
            out[tag] = detrng.fill_param(s, int(np.prod(shape)), kind).reshape(shape)
    return out


def synthetic_input(graph: dict, seed: int) -> np.ndarray:
    """The deterministic input batch (mirrors Executor::synthetic_input)."""
    dims = graph["nodes"][0]["shape"]["dims"]
    s = detrng.tensor_seed(seed, "input")
    return detrng.fill_param(s, int(np.prod(dims)), "activation").reshape(dims)


def apply_node(node: dict, inputs: list, params: dict[str, np.ndarray]):
    """Execute one graph node on already-computed input values."""
    kind = node["kind"]
    name = node["name"]
    if kind == "conv2d":
        w = params[f"{name}:weight"]
        b = params.get(f"{name}:bias") if node["bias"] else None
        return layers.conv2d(
            inputs[0], w, b, stride=tuple(node["stride"]), pad=tuple(node["pad"])
        )
    if kind == "linear":
        w = params[f"{name}:weight"]
        b = params.get(f"{name}:bias") if node["bias"] else None
        return layers.linear(inputs[0], w, b)
    if kind in ("maxpool", "avgpool"):
        kernel = tuple(node["kernel"])
        stride = tuple(node["stride"])
        pad = tuple(node["pad"])
        if node["pool"] == "max":
            return layers.max_pool2d(
                inputs[0], kernel, stride, pad, ceil_mode=node["ceil_mode"]
            )
        assert not node["ceil_mode"]
        return layers.avg_pool2d(
            inputs[0], kernel, stride, pad, count_include_pad=node["count_include_pad"]
        )
    if kind == "adaptiveavgpool":
        return layers.adaptive_avg_pool2d(inputs[0], tuple(node["out_hw"]))
    if kind == "batchnorm":
        scale, shift = layers.fold_bn(
            params[f"{name}:bn_gamma"],
            params[f"{name}:bn_beta"],
            params[f"{name}:bn_mean"],
            params[f"{name}:bn_var"],
            node["eps"],
        )
        return layers.bn_affine(inputs[0], scale, shift)
    if kind == "relu":
        return layers.relu(inputs[0])
    if kind == "dropout":
        return inputs[0]
    if kind == "flatten":
        x = inputs[0]
        return x.reshape(x.shape[0], -1)
    if kind == "add":
        return inputs[0] + inputs[1]
    if kind == "concat":
        return jnp.concatenate(inputs, axis=1)
    raise ValueError(f"unknown node kind {kind}")


def run_graph(graph: dict, x, params: dict[str, np.ndarray]):
    """Breadth-first execution of the whole graph (the oracle)."""
    nodes = graph["nodes"]
    values: dict[int, object] = {0: x}
    for node in nodes[1:]:
        inputs = [values[i] for i in node["inputs"]]
        values[node["id"]] = apply_node(node, inputs, params)
    out = values[graph["output"]]
    expect = tuple(nodes[graph["output"]]["shape"]["dims"])
    assert out.shape == expect, (out.shape, expect)
    return out
