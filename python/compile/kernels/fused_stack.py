"""L1: the fused depth-first stack kernel (Pallas).

This is the paper's generated code (Listing 2) written once,
parametrically: a collapsed stack is a list of *sequences*, each a list
of *steps* (<= 1 pooling op per step, any number of element-wise ops).
One ``pallas_call`` executes one sequence; sequences synchronize through
HBM (the paper's "serialized fashion", §4.2).

Depth-first tiling: one *band* of ``tile_rows`` output rows is pushed
through every step of the sequence before the next band is touched, so
intermediates never materialize at full-tensor size — the band working
set is what the rust collapser budgeted against VMEM. Within a band the
computation is vectorized across batch × channels × width (the SIMD
lanes of §3.2); across bands execution is sequential per core, exactly
the paper's depth-first schedule. Band origins are static (the band loop
unrolls at trace time), so halo regions are static slices plus
pool-identity padding — rows outside the valid image range are never
materialized between pools (they are re-padded at each pool with that
pool's identity, which is what makes BN-after-pool numerically safe).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
version maps a thread block per (batch, channel, patch) with
double-buffered shared memory; here a band plays the role of the patch,
VMEM the role of shared memory, and the (8,128) VPU lanes run the
band's (channel, width) plane. ``interpret=True`` everywhere — the CPU
PJRT runtime cannot execute Mosaic custom-calls, and lowering through
the interpreter emits plain HLO the rust runtime runs.

§Perf iteration log lives in EXPERIMENTS.md: the first version ran a
grid program per (batch, channel) plane with per-plane gathers and was
~64x slower than the jitted jnp reference on XLA:CPU; restructuring to
band-major with full (N, C, ·, W) vectorization (this version) makes
the lowered HLO a short chain of fused slice/pad/reduce-window ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import layers


def _pool_dims_of(op: dict, h: int, w: int) -> tuple[int, int]:
    f = layers.ceil_out_dim if op.get("ceil_mode", False) else layers.conv_out_dim
    return (
        f(h, op["kernel"][0], op["stride"][0], op["pad"][0]),
        f(w, op["kernel"][1], op["stride"][1], op["pad"][1]),
    )


def _step_pool(step: list[dict]):
    """The (at most one) pooling op of a step."""
    pools = [op for op in step if op["op"] == "pool"]
    assert len(pools) <= 1, "a step may contain at most one pooling op"
    return pools[0] if pools else None


def _plan_levels(steps: list[list[dict]], h: int, w: int):
    """Static (H, W) entering each step, plus the final extent."""
    levels = []
    for step in steps:
        levels.append((h, w))
        pool = _step_pool(step)
        if pool is not None:
            h, w = _pool_dims_of(pool, h, w)
    levels.append((h, w))
    return levels


def _row_window(step: list[dict]) -> tuple[int, int, int]:
    pool = _step_pool(step)
    if pool is None:
        return 1, 1, 0
    return pool["kernel"][0], pool["stride"][0], pool["pad"][0]


def _band_ranges(steps, tile: int, out_start: int):
    """Backward pass: requested row range [a_i, a_i + len_i) entering each
    step for a band producing rows [out_start, out_start+tile)."""
    a, length = out_start, tile
    ranges = [(a, length)]
    for step in reversed(steps):
        kh, sh, ph = _row_window(step)
        a = a * sh - ph
        length = (length - 1) * sh + kh
        ranges.append((a, length))
    ranges.reverse()  # ranges[i] = requested input range of step i
    return ranges


def _apply_pool_banded(op: dict, cur, lo: int, a: int, length: int, h: int, w: int):
    """Apply one pooling op to a band of shape (N, C, rows, W).

    ``cur`` holds valid rows [lo, lo+rows) of the level-(h,w) image; the
    backward-computed *requested* row range is [a, a+length). Returns
    (out, out_lo) where out holds only the valid next-level rows.
    """
    kh, kw = op["kernel"]
    sh, sw = op["stride"]
    ph, pw = op["pad"]
    is_max = op["pool"] == "max"
    identity = jnp.float32(jnp.finfo(jnp.float32).min) if is_max else jnp.float32(0.0)

    # Rows: pad the requested halo that lies outside the valid image.
    top = lo - a
    bottom = (a + length) - (lo + cur.shape[2])
    assert top >= 0 and bottom >= 0, (top, bottom)
    # Cols: symmetric pool padding plus ceil-mode right extension.
    out_h, out_w = _pool_dims_of(op, h, w)
    extra_w = max(0, (out_w - 1) * sw + kw - (w + 2 * pw))
    pad_cfg = ((0, 0), (0, 0), (top, bottom), (pw, pw + extra_w))
    padded = jnp.pad(cur, pad_cfg, constant_values=identity)
    reducer = jax.lax.max if is_max else jax.lax.add
    out = jax.lax.reduce_window(
        padded,
        identity,
        reducer,
        window_dimensions=(1, 1, kh, kw),
        window_strides=(1, 1, sh, sw),
        padding="VALID",
    )
    if not is_max:
        if op.get("count_include_pad", True):
            out = out / jnp.float32(kh * kw)
        else:
            counts = jax.lax.reduce_window(
                jnp.pad(jnp.ones_like(cur), pad_cfg),
                jnp.float32(0.0),
                jax.lax.add,
                window_dimensions=(1, 1, kh, kw),
                window_strides=(1, 1, sh, sw),
                padding="VALID",
            )
            out = out / counts
    # Requested output range starts at (a + ph) / sh (exact by
    # construction of the backward ranges).
    assert (a + ph) % sh == 0, "band origin must align with pool stride"
    out_a = (a + ph) // sh
    # Slice away out-of-image rows (they would otherwise leak pool
    # identities into the next element-wise op).
    lo_next = max(out_a, 0)
    hi_next = min(out_a + out.shape[2], out_h)
    out = out[:, :, lo_next - out_a : hi_next - out_a, :]
    return out, lo_next


def _sequence_kernel(x_ref, *refs, steps, levels, tile):
    """Pallas kernel body for one sequence.

    Band-major depth-first: the unrolled band loop pushes each band of
    final-output rows through all steps, vectorized over (N, C, ·, W).
    refs = [scale0, shift0, scale1, shift1, ..., out_ref] with (C,)
    batch-norm parameter vectors.
    """
    out_ref = refs[-1]
    bn_refs = refs[:-1]
    h_in, _w_in = levels[0]
    h_out, _w_out = levels[-1]

    n_bands = -(-h_out // tile)
    for b in range(n_bands):
        out_start = min(b * tile, h_out - tile)
        ranges = _band_ranges(steps, tile, out_start)
        a0, len0 = ranges[0]
        lo = max(a0, 0)
        hi = min(a0 + len0, h_in)
        cur = x_ref[:, :, lo:hi, :]
        bn_i = 0
        for si, step in enumerate(steps):
            h_lvl, w_lvl = levels[si]
            for op in step:
                kind = op["op"]
                if kind == "bn":
                    scale = bn_refs[2 * bn_i][...]
                    shift = bn_refs[2 * bn_i + 1][...]
                    cur = cur * scale[None, :, None, None] + shift[None, :, None, None]
                    bn_i += 1
                elif kind == "relu":
                    cur = jnp.maximum(cur, 0.0)
                elif kind == "id":
                    pass
                elif kind == "pool":
                    a, length = ranges[si]
                    cur, lo = _apply_pool_banded(op, cur, lo, a, length, h_lvl, w_lvl)
                else:
                    raise ValueError(f"unknown op {kind}")
        # cur now holds exactly rows [out_start, out_start + tile).
        assert lo == out_start and cur.shape[2] == tile, (lo, out_start, cur.shape)
        out_ref[:, :, out_start : out_start + tile, :] = cur


def _elementwise_kernel(x_ref, o_ref, *, ops):
    """Rank-2 (N, F) stacks are pure element-wise chains, banded over the
    batch dimension by BlockSpec."""
    cur = x_ref[...]
    for op in ops:
        kind = op["op"]
        if kind == "relu":
            cur = jnp.maximum(cur, 0.0)
        elif kind == "id":
            pass
        else:
            raise ValueError(f"unsupported rank-2 op {kind}")
    o_ref[...] = cur


def sequence_call(seq: dict, in_shape: tuple[int, ...], x, bn_params: list):
    """Run one sequence as a pallas_call; returns (output, consumed_bn)."""
    steps = seq["steps"]
    tile = seq["tile_rows"]
    if len(in_shape) == 2:
        ops = [op for step in steps for op in step]
        n, f = in_shape
        band = min(tile, n)
        grid = (-(-n // band),)
        out = pl.pallas_call(
            functools.partial(_elementwise_kernel, ops=ops),
            grid=grid,
            in_specs=[pl.BlockSpec((band, f), lambda b: (b, 0))],
            out_specs=pl.BlockSpec((band, f), lambda b: (b, 0)),
            out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
            interpret=True,
        )(x)
        return out, 0

    n, c, h, w = in_shape
    levels = _plan_levels(steps, h, w)
    h_out, w_out = levels[-1]
    tile = min(tile, h_out)
    n_bn = sum(1 for step in steps for op in step if op["op"] == "bn")
    consumed = bn_params[: 2 * n_bn]

    if h_out <= tile:
        # Single band covers the whole extent: banding adds only copies.
        # §4.1's special case — "if a sequence only contains a single
        # step, we iterate over the entire input data". Apply the op
        # chain directly; XLA fuses it into one pass.
        from . import ref  # sibling; no circular import

        pairs = iter(list(zip(consumed[0::2], consumed[1::2])))
        out = x
        for step in steps:
            for op in step:
                out = ref.apply_op(op, out, pairs)
        return out, 2 * n_bn

    out = pl.pallas_call(
        functools.partial(_sequence_kernel, steps=steps, levels=levels, tile=tile),
        out_shape=jax.ShapeDtypeStruct((n, c, h_out, w_out), jnp.float32),
        interpret=True,
    )(x, *consumed)
    return out, 2 * n_bn


def run_stack_fused(request: dict, x, bn_param_list):
    """Execute a full stack request: one pallas_call per sequence,
    sequences chained through (conceptual) HBM."""
    shape = tuple(request["in_shape"]["dims"])
    params = list(bn_param_list)
    for seq in request["sequences"]:
        in_shape = tuple(seq["in_shape"]["dims"]) if "in_shape" in seq else shape
        x, used = sequence_call(seq, in_shape, x, params)
        params = params[used:]
        shape = x.shape
    assert not params, "unconsumed bn params"
    return x


def stack_fn(request: dict):
    """Build the jittable stack function f(x, *bn_params) for AOT export."""

    def fn(x, *bn_params):
        return (run_stack_fused(request, x, list(bn_params)),)

    return fn


def vmem_estimate_bytes(request: dict) -> int:
    """Static VMEM working-set estimate of the largest sequence band per
    (batch, channel) plane — the §Perf L1 profile metric (mirrors rust
    working_set_bytes)."""
    worst = 0
    for seq in request["sequences"]:
        dims = tuple(seq["in_shape"]["dims"])
        if len(dims) == 2:
            worst = max(worst, 2 * seq["tile_rows"] * dims[1] * 4)
            continue
        _, _, h, w = dims
        steps = seq["steps"]
        levels = _plan_levels(steps, h, w)
        tile = min(seq["tile_rows"], levels[-1][0])
        ranges = _band_ranges(steps, tile, 0)
        for i in range(len(steps)):
            in_rows = ranges[i][1]
            out_rows = ranges[i + 1][1]
            w_in = levels[i][1]
            w_out = levels[i + 1][1]
            worst = max(worst, (in_rows * w_in + out_rows * w_out) * 4)
    return worst
