"""L1 kernels: fused depth-first stack + pure-jnp oracle."""
