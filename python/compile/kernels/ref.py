"""Pure-jnp oracle for collapsed stacks.

``run_stack_ref`` executes a stack *request* (the JSON the rust optimizer
emits) op by op with the L2 layer library — no fusion, no tiling. The
fused Pallas kernel in ``fused_stack.py`` must match this to float
tolerance for every request; ``python/tests/test_kernel.py`` sweeps both
hand-written and hypothesis-generated requests.
"""

from __future__ import annotations

from .. import layers


def iter_ops(request: dict):
    """All ops of a stack request in execution order."""
    for seq in request["sequences"]:
        for step in seq["steps"]:
            yield from step


def num_bn_ops(request: dict) -> int:
    return sum(1 for op in iter_ops(request) if op["op"] == "bn")


def apply_op(op: dict, x, bn_pairs):
    """Apply one stack op; ``bn_pairs`` is an iterator yielding
    (scale, shift) in op order."""
    kind = op["op"]
    if kind == "bn":
        scale, shift = next(bn_pairs)
        return layers.bn_affine(x, scale, shift)
    if kind == "relu":
        return layers.relu(x)
    if kind == "id":
        return x
    if kind == "pool":
        kernel = tuple(op["kernel"])
        stride = tuple(op["stride"])
        pad = tuple(op["pad"])
        if op["pool"] == "max":
            return layers.max_pool2d(
                x, kernel, stride, pad, ceil_mode=op.get("ceil_mode", False)
            )
        assert not op.get("ceil_mode", False), "ceil avg-pool not used by the zoo"
        return layers.avg_pool2d(
            x, kernel, stride, pad, count_include_pad=op.get("count_include_pad", True)
        )
    raise ValueError(f"unknown stack op {kind}")


def run_stack_ref(request: dict, x, bn_param_list):
    """Execute the whole stack breadth-first (reference semantics).

    ``bn_param_list`` is a flat list [scale0, shift0, scale1, shift1, ...]
    in op order — the same argument convention as the fused executable.
    """
    pairs = iter(list(zip(bn_param_list[0::2], bn_param_list[1::2])))
    for op in iter_ops(request):
        x = apply_op(op, x, pairs)
    return x
