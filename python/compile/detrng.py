"""Deterministic parameter/data generation — python mirror of
``rust/src/rng.rs``.

Both sides generate network parameters and synthetic inputs from the same
SplitMix64 stream -> f32 mapping so the rust scheduler and the python
oracle compute over bit-identical values. Covered by the golden-file test
``python/tests/test_detrng.py`` against vectors pinned in rust.
"""

from __future__ import annotations

import numpy as np

_MASK = (1 << 64) - 1
_GOLDEN = 0x9E3779B97F4A7C15
_MIX1 = 0xBF58476D1CE4E5B9
_MIX2 = 0x94D049BB133111EB
_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3


def splitmix64_at(seed: int, n: int) -> np.ndarray:
    """The first ``n`` outputs of the SplitMix64 stream for ``seed``,
    vectorized: output ``i`` mixes state ``seed + (i+1)*GOLDEN``."""
    idx = np.arange(1, n + 1, dtype=np.uint64)
    z = (np.uint64(seed & _MASK) + idx * np.uint64(_GOLDEN)) & np.uint64(_MASK)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(_MIX1)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(_MIX2)
    return z ^ (z >> np.uint64(31))


def u64_to_f32(x: np.ndarray) -> np.ndarray:
    """Top 24 bits -> fraction of 2^23, offset -1 (uniform [-1, 1))."""
    return (x >> np.uint64(40)).astype(np.float32) / np.float32(1 << 23) - np.float32(1.0)


def fill_f32(seed: int, n: int) -> np.ndarray:
    return u64_to_f32(splitmix64_at(seed, n))


def tensor_seed(base: int, tag: str) -> int:
    """FNV-1a over the tag, XOR rotate_left(base, 17)."""
    h = _FNV_OFFSET
    for b in tag.encode():
        h ^= b
        h = (h * _FNV_PRIME) & _MASK
    rot = ((base << 17) | (base >> 47)) & _MASK
    return h ^ rot


def fill_param(seed: int, n: int, kind: str) -> np.ndarray:
    """Post-processed fills; ``kind`` matches rust's ``ParamKind``."""
    raw = fill_f32(seed, n)
    if kind == "weight":
        return raw * np.float32(0.1)
    if kind == "bias":
        return raw * np.float32(0.01)
    if kind == "bn_gamma":
        return np.float32(1.0) + raw * np.float32(0.1)
    if kind == "bn_beta":
        return raw * np.float32(0.01)
    if kind == "bn_mean":
        return raw * np.float32(0.1)
    if kind == "bn_var":
        return np.float32(0.55) + raw * np.float32(0.45)
    if kind == "activation":
        return raw
    raise ValueError(f"unknown param kind {kind}")
