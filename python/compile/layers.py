"""L2 layer library: JAX implementations of every graph-IR layer.

Semantics mirror PyTorch (and the rust shape inference in
``rust/src/graph``): floor/ceil window arithmetic, max-pool padding with
-inf, avg-pool ``count_include_pad``, inference-mode (folded) batch norm.
These functions are both the breadth-first per-layer executables that
``aot.py`` lowers and the building blocks of the pure-jnp oracle
(``kernels/ref.py`` checks the fused Pallas kernel against them).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def conv_out_dim(inp: int, k: int, s: int, p: int) -> int:
    """floor((in + 2p - k)/s) + 1 — PyTorch default."""
    padded = inp + 2 * p
    assert padded >= k, f"window {k} larger than padded input {padded}"
    return (padded - k) // s + 1


def ceil_out_dim(inp: int, k: int, s: int, p: int) -> int:
    """PyTorch ceil_mode, with the last-window-must-start-inside-input
    correction (mirrors rust ``ceil_out_dim``)."""
    padded = inp + 2 * p
    assert padded >= k
    out = -((padded - k) // -s) + 1
    if p > 0 and (out - 1) * s >= inp + p:
        out -= 1
    return out


def conv2d(x, w, b=None, stride=(1, 1), pad=(0, 0)):
    """NCHW conv with OIHW weights."""
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=stride,
        padding=[(pad[0], pad[0]), (pad[1], pad[1])],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    if b is not None:
        out = out + b[None, :, None, None]
    return out


def linear(x, w, b=None):
    """(N, in) @ (in, out) + bias."""
    out = x @ w
    if b is not None:
        out = out + b[None, :]
    return out


def _pool_dims(h, w, kernel, stride, pad, ceil_mode):
    f = ceil_out_dim if ceil_mode else conv_out_dim
    return (
        f(h, kernel[0], stride[0], pad[0]),
        f(w, kernel[1], stride[1], pad[1]),
    )


def max_pool2d(x, kernel, stride, pad=(0, 0), ceil_mode=False):
    """Max pooling over NCHW with -inf padding (PyTorch semantics)."""
    n, c, h, w = x.shape
    oh, ow = _pool_dims(h, w, kernel, stride, pad, ceil_mode)
    # Right/bottom extension so a VALID reduce emits exactly (oh, ow).
    eh = (oh - 1) * stride[0] + kernel[0] - (h + 2 * pad[0])
    ew = (ow - 1) * stride[1] + kernel[1] - (w + 2 * pad[1])
    neg = jnp.finfo(x.dtype).min
    out = jax.lax.reduce_window(
        x,
        neg,
        jax.lax.max,
        window_dimensions=(1, 1, kernel[0], kernel[1]),
        window_strides=(1, 1, stride[0], stride[1]),
        padding=[(0, 0), (0, 0), (pad[0], pad[0] + max(eh, 0)), (pad[1], pad[1] + max(ew, 0))],
    )
    assert out.shape == (n, c, oh, ow), (out.shape, (n, c, oh, ow))
    return out


def avg_pool2d(x, kernel, stride, pad=(0, 0), count_include_pad=True):
    """Average pooling (floor mode only, as the evaluated networks use)."""
    n, c, h, w = x.shape
    oh, ow = _pool_dims(h, w, kernel, stride, pad, False)
    summed = jax.lax.reduce_window(
        x,
        jnp.array(0.0, x.dtype),
        jax.lax.add,
        window_dimensions=(1, 1, kernel[0], kernel[1]),
        window_strides=(1, 1, stride[0], stride[1]),
        padding=[(0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])],
    )
    if count_include_pad:
        out = summed / np.float32(kernel[0] * kernel[1])
    else:
        ones = jnp.ones_like(x)
        counts = jax.lax.reduce_window(
            ones,
            jnp.array(0.0, x.dtype),
            jax.lax.add,
            window_dimensions=(1, 1, kernel[0], kernel[1]),
            window_strides=(1, 1, stride[0], stride[1]),
            padding=[(0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])],
        )
        out = summed / counts
    assert out.shape == (n, c, oh, ow)
    return out


def adaptive_avg_pool2d(x, out_hw):
    """Adaptive average pooling for dividing extents (as rust enforces)."""
    n, c, h, w = x.shape
    oh, ow = out_hw
    assert h % oh == 0 and w % ow == 0, (x.shape, out_hw)
    kh, kw = h // oh, w // ow
    return x.reshape(n, c, oh, kh, ow, kw).mean(axis=(3, 5))


def bn_affine(x, scale, shift):
    """Folded inference batch-norm: per-channel affine on NCHW."""
    return x * scale[None, :, None, None] + shift[None, :, None, None]


def fold_bn(gamma, beta, mean, var, eps):
    """(gamma, beta, mean, var) -> (scale, shift); mirrors rust
    ``ParamStore::bn_folded``."""
    scale = gamma / np.sqrt(var + np.float32(eps))
    shift = beta - mean * scale
    return scale.astype(np.float32), shift.astype(np.float32)


def relu(x):
    return jnp.maximum(x, 0)
