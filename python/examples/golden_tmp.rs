fn main() {
    let v = brainslug::rng::fill_f32(0x5EED_2026, 8);
    println!("fill_f32: {v:?}");
    let s = brainslug::rng::tensor_seed(0x5EED_2026, "features.0.conv:weight");
    println!("tensor_seed: {s}");
    let w = brainslug::rng::fill_param(s, 4, brainslug::rng::ParamKind::Weight);
    println!("weight: {w:?}");
    let var = brainslug::rng::fill_param(7, 4, brainslug::rng::ParamKind::BnVar);
    println!("var: {var:?}");
}
