"""Layer-library semantics tests: PyTorch-equivalent window arithmetic,
padding values, count_include_pad, ceil_mode — checked against
hand-computed cases."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers


def test_conv_out_dims():
    assert layers.conv_out_dim(32, 3, 1, 1) == 32
    assert layers.conv_out_dim(224, 11, 4, 2) == 55
    assert layers.ceil_out_dim(14, 3, 2, 0) == 7
    # ceil correction: last window must start inside input+pad.
    assert layers.ceil_out_dim(3, 2, 2, 1) == 2
    assert layers.ceil_out_dim(4, 2, 2, 1) == 3


def test_max_pool_padding_is_neg_inf():
    # 2x2 input, 3x3 pool stride 1 pad 1: every output = max of the
    # in-range cells only (padding must never win).
    x = jnp.asarray(np.array([[[[-5.0, -6.0], [-7.0, -8.0]]]], dtype=np.float32))
    out = layers.max_pool2d(x, (3, 3), (1, 1), (1, 1))
    assert out.shape == (1, 1, 2, 2)
    np.testing.assert_array_equal(np.asarray(out)[0, 0], [[-5, -5], [-5, -5]])


def test_max_pool_ceil_mode_shape():
    # 3x3/2 pool on 112: floor -> 55, ceil -> 56.
    x = jnp.zeros((1, 2, 112, 112), dtype=jnp.float32)
    assert layers.max_pool2d(x, (3, 3), (2, 2), (0, 0), ceil_mode=False).shape[2] == 55
    assert layers.max_pool2d(x, (3, 3), (2, 2), (0, 0), ceil_mode=True).shape[2] == 56


def test_avg_pool_count_include_pad():
    x = jnp.ones((1, 1, 2, 2), dtype=jnp.float32)
    # 3x3 pool pad 1: window at corner sees 4 ones + 5 pad zeros.
    cip = layers.avg_pool2d(x, (3, 3), (1, 1), (1, 1), count_include_pad=True)
    np.testing.assert_allclose(np.asarray(cip)[0, 0, 0, 0], 4.0 / 9.0, rtol=1e-6)
    nip = layers.avg_pool2d(x, (3, 3), (1, 1), (1, 1), count_include_pad=False)
    np.testing.assert_allclose(np.asarray(nip)[0, 0, 0, 0], 1.0, rtol=1e-6)


def test_avg_pool_basic():
    x = jnp.asarray(np.arange(16, dtype=np.float32).reshape(1, 1, 4, 4))
    out = layers.avg_pool2d(x, (2, 2), (2, 2))
    np.testing.assert_allclose(
        np.asarray(out)[0, 0], [[2.5, 4.5], [10.5, 12.5]], rtol=1e-6
    )


def test_adaptive_avg_pool():
    x = jnp.asarray(np.arange(16, dtype=np.float32).reshape(1, 1, 4, 4))
    gap = layers.adaptive_avg_pool2d(x, (1, 1))
    np.testing.assert_allclose(np.asarray(gap)[0, 0, 0, 0], 7.5, rtol=1e-6)
    two = layers.adaptive_avg_pool2d(x, (2, 2))
    np.testing.assert_allclose(np.asarray(two)[0, 0], [[2.5, 4.5], [10.5, 12.5]])
    with pytest.raises(AssertionError):
        layers.adaptive_avg_pool2d(x, (3, 3))


def test_conv2d_identity_kernel():
    x = jnp.asarray(np.random.RandomState(0).randn(2, 3, 8, 8).astype(np.float32))
    # 1x1 identity conv: w[o,i] = delta(o,i).
    w = jnp.asarray(np.eye(3, dtype=np.float32).reshape(3, 3, 1, 1))
    out = layers.conv2d(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)


def test_conv2d_stride_pad_shape():
    x = jnp.zeros((1, 3, 32, 32), dtype=jnp.float32)
    w = jnp.zeros((16, 3, 3, 3), dtype=jnp.float32)
    assert layers.conv2d(x, w, stride=(2, 2), pad=(1, 1)).shape == (1, 16, 16, 16)


def test_linear_and_bias():
    x = jnp.asarray([[1.0, 2.0]], dtype=jnp.float32)
    w = jnp.asarray([[1.0, 0.0, 1.0], [0.0, 1.0, 1.0]], dtype=jnp.float32)
    b = jnp.asarray([0.5, -0.5, 0.0], dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(layers.linear(x, w, b)), [[1.5, 1.5, 3.0]], rtol=1e-6
    )


def test_bn_fold_matches_definition():
    rng = np.random.RandomState(1)
    gamma = rng.randn(4).astype(np.float32)
    beta = rng.randn(4).astype(np.float32)
    mean = rng.randn(4).astype(np.float32)
    var = np.abs(rng.randn(4)).astype(np.float32) + 0.5
    eps = 1e-5
    scale, shift = layers.fold_bn(gamma, beta, mean, var, eps)
    x = jnp.asarray(rng.randn(2, 4, 3, 3).astype(np.float32))
    folded = layers.bn_affine(x, jnp.asarray(scale), jnp.asarray(shift))
    direct = (np.asarray(x) - mean[None, :, None, None]) / np.sqrt(
        var[None, :, None, None] + eps
    ) * gamma[None, :, None, None] + beta[None, :, None, None]
    np.testing.assert_allclose(np.asarray(folded), direct, rtol=1e-4, atol=1e-5)


def test_relu():
    x = jnp.asarray([-1.0, 0.0, 2.0], dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(layers.relu(x)), [0.0, 0.0, 2.0])
