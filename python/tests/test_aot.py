"""AOT path tests: request -> HLO lowering works for every request kind
and the emitted text is loadable HLO (contains an ENTRY computation)."""

import json
import os

import pytest

from compile import aot

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _requests():
    path = os.path.join(ARTIFACTS, "requests.json")
    if not os.path.exists(path):
        pytest.skip("artifacts/requests.json not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_layer_fn_builds_for_every_request_kind():
    reqs = _requests()
    seen = set()
    for r in reqs["layers"]:
        if r["kind"] in seen:
            continue
        seen.add(r["kind"])
        fn, specs = aot.layer_fn_and_specs(r)
        assert len(specs) >= 1
    assert "conv2d" in seen and "relu" in seen


def test_stack_fn_builds_and_lowers(tmp_path):
    reqs = _requests()
    stack = reqs["stacks"][0]
    fn, specs = aot.stack_fn_and_specs(stack)
    entry = aot.lower_one(stack["name"], fn, specs, str(tmp_path))
    text = (tmp_path / entry["path"]).read_text()
    assert "ENTRY" in text
    assert entry["output"]["dims"] == stack["out_shape"]["dims"]


def test_lower_one_manifest_entry_shapes(tmp_path):
    reqs = _requests()
    conv = next(r for r in reqs["layers"] if r["kind"] == "conv2d")
    fn, specs = aot.layer_fn_and_specs(conv)
    entry = aot.lower_one(conv["name"], fn, specs, str(tmp_path))
    assert entry["inputs"][0]["dims"] == conv["in_shapes"][0]["dims"]
    assert entry["output"]["dims"] == conv["out_shape"]["dims"]


def test_manifest_covers_all_requests():
    manifest_path = os.path.join(ARTIFACTS, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("manifest not built")
    with open(manifest_path) as f:
        manifest = json.load(f)
    built = {e["name"] for e in manifest["executables"]}
    reqs = _requests()
    wanted = {r["name"] for r in reqs["layers"]} | {r["name"] for r in reqs["stacks"]}
    assert wanted <= built
    # Every artifact file exists.
    for e in manifest["executables"]:
        assert os.path.exists(os.path.join(ARTIFACTS, e["path"])), e["name"]


def test_oracle_files_exist_and_sized():
    manifest_path = os.path.join(ARTIFACTS, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("manifest not built")
    with open(manifest_path) as f:
        manifest = json.load(f)
    assert manifest["oracles"], "no oracles recorded"
    for o in manifest["oracles"]:
        import numpy as np

        for key, path_key in (("input", "input_path"), ("output", "output_path")):
            path = os.path.join(ARTIFACTS, o[path_key])
            n = int(np.prod(o[key]["dims"]))
            assert os.path.getsize(path) == 4 * n, o["tag"]
