"""CORE correctness signal: the fused depth-first Pallas kernel vs the
pure-jnp oracle, across hand-written stack structures and a hypothesis
sweep over shapes/tiles/op-chains."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers
from compile.kernels import fused_stack, ref


def shape_dict(dims):
    return {"dims": list(dims), "dtype": "f32"}


def mk_request(in_dims, sequences):
    """Build a stack request; recomputes each sequence's in_shape."""
    dims = list(in_dims)
    out = {"in_shape": shape_dict(in_dims), "sequences": []}
    for tile, steps in sequences:
        out["sequences"].append(
            {"tile_rows": tile, "in_shape": shape_dict(dims), "steps": steps}
        )
        for step in steps:
            for op in step:
                if op["op"] == "pool":
                    f = (
                        layers.ceil_out_dim
                        if op.get("ceil_mode", False)
                        else layers.conv_out_dim
                    )
                    dims = [
                        dims[0],
                        dims[1],
                        f(dims[2], op["kernel"][0], op["stride"][0], op["pad"][0]),
                        f(dims[3], op["kernel"][1], op["stride"][1], op["pad"][1]),
                    ]
    return out


def pool(kind="max", k=3, s=1, p=1, ceil=False, cip=True):
    return {
        "op": "pool",
        "pool": kind,
        "kernel": [k, k],
        "stride": [s, s],
        "pad": [p, p],
        "ceil_mode": ceil,
        "count_include_pad": cip,
    }


BN = {"op": "bn", "eps": 1e-5}
RELU = {"op": "relu"}
ID = {"op": "id"}


def check(request, seed=0, atol=1e-5):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(*request["in_shape"]["dims"]).astype(np.float32))
    c = request["in_shape"]["dims"][1] if len(request["in_shape"]["dims"]) == 4 else 0
    n_bn = ref.num_bn_ops(request)
    bn = [jnp.asarray(rng.randn(c).astype(np.float32)) for _ in range(2 * n_bn)]
    want = ref.run_stack_ref(request, x, bn)
    got = fused_stack.run_stack_fused(request, x, bn)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=atol, rtol=1e-5)


# ---- hand-written structures -------------------------------------------------


def test_fig10_block():
    # <MaxPool 3x3/1/1, BN, ReLU> — the Figure 10 block.
    req = mk_request((2, 4, 16, 16), [(4, [[pool(), BN, RELU]])])
    check(req)


def test_multi_block_single_sequence():
    steps = [[pool(), BN, RELU] for _ in range(4)]
    req = mk_request((1, 3, 20, 20), [(5, steps)])
    check(req)


def test_multi_sequence_spill():
    req = mk_request(
        (2, 3, 16, 16),
        [
            (3, [[pool(), BN, RELU], [pool("avg", k=2, s=2, p=0), BN]]),
            (2, [[RELU, pool(k=3, s=2, p=0, ceil=True)]]),
        ],
    )
    check(req)


def test_strided_max_pool_vgg():
    req = mk_request((2, 4, 16, 16), [(4, [[BN, RELU, pool(k=2, s=2, p=0)]])])
    check(req)


def test_avg_pool_densenet_transition():
    req = mk_request((1, 6, 12, 12), [(3, [[BN, RELU], [pool("avg", k=2, s=2, p=0)]])])
    check(req)


def test_avg_pool_inception_branch():
    req = mk_request((1, 4, 9, 9), [(3, [[pool("avg", k=3, s=1, p=1)]])])
    check(req)


def test_avg_pool_no_count_include_pad():
    req = mk_request((1, 2, 8, 8), [(2, [[pool("avg", k=3, s=1, p=1, cip=False)]])])
    check(req)


def test_ceil_mode_squeezenet_pool():
    req = mk_request((1, 3, 13, 13), [(2, [[pool(k=3, s=2, p=0, ceil=True)]])])
    check(req)


def test_elementwise_only_rank4():
    req = mk_request((2, 3, 8, 8), [(4, [[BN, RELU, ID, RELU]])])
    check(req)


def test_rank2_elementwise():
    req = {
        "in_shape": shape_dict((6, 32)),
        "sequences": [
            {"tile_rows": 4, "in_shape": shape_dict((6, 32)), "steps": [[RELU, ID]]}
        ],
    }
    check(req)


def test_tile_rows_one():
    req = mk_request((1, 2, 9, 9), [(1, [[pool(), BN, RELU]])])
    check(req)


def test_tile_not_dividing_height():
    # H_out = 7, tile 3: last band recomputes overlap rows.
    req = mk_request((1, 2, 7, 7), [(3, [[pool(), RELU]])])
    check(req)


def test_negative_values_through_max_padding():
    # All-negative input exercises -inf padding correctness at borders.
    req = mk_request((1, 1, 5, 5), [(2, [[pool()]])])
    rng = np.random.RandomState(3)
    x = jnp.asarray(-np.abs(rng.randn(1, 1, 5, 5)).astype(np.float32) - 1.0)
    want = ref.run_stack_ref(req, x, [])
    got = fused_stack.run_stack_fused(req, x, [])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_bn_after_pool_no_inf_leak():
    # BN with negative scale after a max pool: if the kernel leaked -inf
    # padding rows between steps, they would flip to +inf and corrupt the
    # next pool. Construct exactly that chain.
    req = mk_request((1, 2, 10, 10), [(2, [[pool(), BN], [pool(), BN]])])
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(1, 2, 10, 10).astype(np.float32))
    bn = [
        jnp.asarray(np.array([-1.0, -0.5], np.float32)),  # negative scales
        jnp.asarray(np.array([0.1, -0.1], np.float32)),
        jnp.asarray(np.array([-2.0, -1.5], np.float32)),
        jnp.asarray(np.array([0.0, 0.2], np.float32)),
    ]
    want = ref.run_stack_ref(req, x, bn)
    got = fused_stack.run_stack_fused(req, x, bn)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_vmem_estimate_positive_and_monotone():
    shallow = mk_request((1, 4, 32, 32), [(4, [[pool(), BN, RELU]])])
    deep = mk_request(
        (1, 4, 32, 32), [(4, [[pool(), BN, RELU] for _ in range(5)])]
    )
    a = fused_stack.vmem_estimate_bytes(shallow)
    b = fused_stack.vmem_estimate_bytes(deep)
    assert 0 < a <= b


# ---- hypothesis sweep --------------------------------------------------------

op_st = st.sampled_from(
    [
        BN,
        RELU,
        ID,
        pool(),  # max 3x3/1/1
        pool(k=2, s=2, p=0),  # max 2x2/2
        pool("avg", k=2, s=2, p=0),  # avg 2x2/2
        pool("avg", k=3, s=1, p=1),  # avg 3x3/1/1
        pool(k=3, s=2, p=1),  # max 3x3/2/1
    ]
)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 2),
    c=st.integers(1, 4),
    h=st.integers(8, 24),
    tile=st.integers(1, 6),
    ops=st.lists(op_st, min_size=1, max_size=6),
    data=st.data(),
)
def test_hypothesis_stacks(n, c, h, tile, ops, data):
    # Group ops into steps (<=1 pool per step), mirroring the collapser.
    steps, step = [], []
    for op in ops:
        if op["op"] == "pool" and any(o["op"] == "pool" for o in step):
            steps.append(step)
            step = []
        step.append(op)
    if step:
        steps.append(step)
    # Drop structures that shrink below 1 pixel.
    dims = [n, c, h, h]
    for s_ in steps:
        for op in s_:
            if op["op"] == "pool":
                hh = dims[2] + 2 * op["pad"][0]
                if hh < op["kernel"][0]:
                    return  # invalid structure, skip
                dims[2] = layers.conv_out_dim(
                    dims[2], op["kernel"][0], op["stride"][0], op["pad"][0]
                )
                dims[3] = dims[2]
    # Optionally split into two sequences at a random step boundary.
    if len(steps) > 1 and data.draw(st.booleans()):
        cut = data.draw(st.integers(1, len(steps) - 1))
        seqs = [(tile, steps[:cut]), (tile, steps[cut:])]
    else:
        seqs = [(tile, steps)]
    req = mk_request((n, c, h, h), seqs)
    check(req, seed=h * 31 + c)
