"""Graph-JSON interpreter tests: shape agreement with the rust exporter
(via the checked-in requests.json oracles) and basic semantics."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import detrng, model

REQUESTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "requests.json")


def _oracle_graphs():
    if not os.path.exists(REQUESTS):
        pytest.skip("artifacts/requests.json not built (run `make artifacts`)")
    with open(REQUESTS) as f:
        return json.load(f)["oracles"]


def test_oracle_graphs_run_and_match_exported_shapes():
    for entry in _oracle_graphs():
        graph = entry["graph"]
        params = model.make_params(graph, entry["seed"])
        x = model.synthetic_input(graph, entry["seed"])
        out = model.run_graph(graph, jnp.asarray(x), params)
        want = tuple(graph["nodes"][graph["output"]]["shape"]["dims"])
        assert out.shape == want, entry["tag"]


def test_params_deterministic_across_calls():
    graphs = _oracle_graphs()
    g = graphs[0]["graph"]
    p1 = model.make_params(g, 7)
    p2 = model.make_params(g, 7)
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])


def test_param_tags_follow_rust_convention():
    node = {"kind": "conv2d", "name": "features.0.conv", "bias": True}
    tags = [t for t, _, _ in model.param_tags(node)]
    assert tags == ["features.0.conv:weight", "features.0.conv:bias"]
    bn = {"kind": "batchnorm", "name": "bn1"}
    kinds = [k for _, k, _ in model.param_tags(bn)]
    assert kinds == ["bn_gamma", "bn_beta", "bn_mean", "bn_var"]


def test_tiny_handwritten_graph():
    graph = {
        "name": "tiny",
        "output": 3,
        "nodes": [
            {"id": 0, "name": "input", "kind": "input", "inputs": [],
             "shape": {"dims": [1, 2, 4, 4], "dtype": "f32"}},
            {"id": 1, "name": "relu", "kind": "relu", "inputs": [0],
             "shape": {"dims": [1, 2, 4, 4], "dtype": "f32"}},
            {"id": 2, "name": "flat", "kind": "flatten", "inputs": [1],
             "shape": {"dims": [1, 32], "dtype": "f32"}},
            {"id": 3, "name": "fc", "kind": "linear", "inputs": [2], "bias": False,
             "out_features": 3, "shape": {"dims": [1, 3], "dtype": "f32"}},
        ],
    }
    params = model.make_params(graph, 1)
    assert set(params) == {"fc:weight"}
    assert params["fc:weight"].shape == (32, 3)
    x = jnp.asarray(np.full((1, 2, 4, 4), -1.0, np.float32))
    out = model.run_graph(graph, x, params)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((1, 3), np.float32))


def test_synthetic_input_matches_rust_seed_path():
    graphs = _oracle_graphs()
    g = graphs[0]["graph"]
    seed = graphs[0]["seed"]
    x = model.synthetic_input(g, seed)
    # Same derivation as rust Executor::synthetic_input.
    s = detrng.tensor_seed(seed, "input")
    want = detrng.fill_param(s, x.size, "activation").reshape(x.shape)
    np.testing.assert_array_equal(x, want)
