//! Integration: the rust scheduler executing real AOT artifacts must
//! reproduce the python oracle bit-for-bit (same detrng parameters, same
//! XLA backend) in BOTH execution modes, and the two modes must agree
//! with each other — the paper's core "transparent, same results"
//! guarantee (§1: "does not change the actual results").
//!
//! Requires `make artifacts`; tests skip (with a message) if missing.

use std::path::Path;
use std::sync::Arc;

use brainslug::bench;
use brainslug::engine::Engine;
use brainslug::graph::{graph_from_json, Graph};
use brainslug::json::parse;
use brainslug::runtime::{HostTensor, Runtime};

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

struct Oracle {
    tag: String,
    seed: u64,
    graph: Graph,
    input: HostTensor,
    output: HostTensor,
}

fn load_oracles(dir: &Path) -> Vec<Oracle> {
    let requests = parse(&std::fs::read_to_string(dir.join("requests.json")).unwrap()).unwrap();
    let manifest = parse(&std::fs::read_to_string(dir.join("manifest.json")).unwrap()).unwrap();
    let mut out = Vec::new();
    for entry in manifest.arr_field("oracles").unwrap() {
        let tag = entry.str_field("tag").unwrap();
        let req = requests
            .arr_field("oracles")
            .unwrap()
            .iter()
            .find(|o| o.str_field("tag").unwrap() == tag)
            .unwrap_or_else(|| panic!("oracle {tag} not in requests.json"));
        let graph = graph_from_json(req.req("graph").unwrap()).unwrap();
        let in_shape = graph.input_shape().clone();
        let out_shape = graph.output_shape().clone();
        let input = HostTensor::read_f32_file(
            &dir.join(entry.str_field("input_path").unwrap()),
            in_shape,
        )
        .unwrap();
        let output = HostTensor::read_f32_file(
            &dir.join(entry.str_field("output_path").unwrap()),
            out_shape,
        )
        .unwrap();
        out.push(Oracle {
            tag,
            seed: entry.usize_field("seed").unwrap() as u64,
            graph,
            input,
            output,
        });
    }
    assert!(!out.is_empty(), "no oracles recorded");
    out
}

#[test]
fn scheduler_matches_python_oracle_both_modes() {
    let Some(dir) = artifacts() else { return };
    let runtime = bench::measured_runtime().expect("manifest checked above");
    for oracle in load_oracles(dir) {
        // One engine per oracle over a shared runtime: the facade
        // resolves, optimizes, validates, and binds the backend.
        let builder = Engine::builder()
            .graph(Arc::new(oracle.graph.clone()))
            .device(bench::measured_device())
            .brainslug(bench::measured_opts())
            .seed(oracle.seed);
        let mut engine = bench::build_measured(builder, &runtime).unwrap();

        // The deterministic input must match the python-side dump.
        let synth = engine.synthetic_input();
        assert_eq!(
            synth, oracle.input,
            "{}: synthetic input drifted from python",
            oracle.tag
        );

        let (base_out, _) = engine.run_baseline(oracle.input.clone()).unwrap();
        assert!(
            base_out.allclose(&oracle.output, 1e-3, 1e-3),
            "{}: baseline deviates from oracle (max diff {})",
            oracle.tag,
            base_out.max_abs_diff(&oracle.output)
        );

        let (plan_out, _) = engine.run(oracle.input.clone()).unwrap();
        assert!(
            plan_out.allclose(&oracle.output, 1e-3, 1e-3),
            "{}: brainslug deviates from oracle (max diff {})",
            oracle.tag,
            plan_out.max_abs_diff(&oracle.output)
        );
        // And the two modes agree tightly with each other.
        assert!(
            plan_out.allclose(&base_out, 1e-4, 1e-4),
            "{}: modes diverge (max diff {})",
            oracle.tag,
            plan_out.max_abs_diff(&base_out)
        );
        println!(
            "{}: oracle OK (baseline diff {:.1e}, plan diff {:.1e})",
            oracle.tag,
            base_out.max_abs_diff(&oracle.output),
            plan_out.max_abs_diff(&oracle.output)
        );
    }
}

#[test]
fn fig10_strategies_agree_numerically() {
    if artifacts().is_none() {
        return;
    }
    let runtime = bench::measured_runtime().expect("manifest checked above");
    let mut base: Option<HostTensor> = None;
    for (name, opts) in bench::fig10_strategies() {
        let mut engine =
            bench::build_measured(bench::block_engine(2, 4, 8, 32, opts), &runtime).unwrap();
        let input = engine.synthetic_input();
        let base = base.get_or_insert_with(|| engine.run_baseline(input.clone()).unwrap().0);
        let (out, _) = engine.run(input).unwrap();
        assert!(
            out.allclose(base, 1e-4, 1e-4),
            "strategy {name} diverges (max diff {})",
            out.max_abs_diff(base)
        );
    }
}

#[test]
fn missing_artifact_fails_cleanly() {
    let Some(dir) = artifacts() else { return };
    let runtime = Runtime::new(dir).unwrap();
    let err = runtime.execute("does_not_exist", &[]).unwrap_err();
    assert!(err.to_string().contains("not in manifest"), "{err}");
}

#[test]
fn shape_mismatch_fails_cleanly() {
    let Some(dir) = artifacts() else { return };
    let runtime = Runtime::new(dir).unwrap();
    // Grab any manifest entry and call it with a wrong-shaped tensor.
    let name = runtime
        .manifest()
        .entries
        .keys()
        .find(|n| n.starts_with("relu_"))
        .expect("some relu executable")
        .clone();
    let bad = HostTensor::zeros(brainslug::graph::Shape::nf(1, 1));
    let err = runtime.execute(&name, &[&bad]).unwrap_err();
    assert!(err.to_string().contains("shape"), "{err}");
}
