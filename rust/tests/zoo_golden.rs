//! Golden structural pins for the model zoo + optimizer at paper scale:
//! layer counts, optimizable counts, stacks and unique stacks per
//! network. These are this repo's Table-2 structural columns — any
//! unintended topology or analyzer change shows up here.

use brainslug::device::DeviceSpec;
use brainslug::optimizer::{optimize, CollapseOptions};
use brainslug::zoo;

/// (name, layers, optimizable, stacks, unique_stacks) at batch 1,
/// paper-scale inputs, GPU device budget, branch-aware planning.
/// For comparison, the paper's Table 2 reports (layers, opt, stacks):
/// AlexNet 27/12/8, ResNet-18 71/39/21, DenseNet-121 429/247/124,
/// Inception-V3 316/203/103 — our module accounting lands within a few
/// counts of each. "Opt." additionally counts each fused branch join
/// (one per detected region), and the unique-stack counts on ResNets
/// are slightly higher than chain-only planning because branch-arm
/// stacks pack against a skip-reserved budget (different band height →
/// different signature than their outside-arm twins).
const GOLDEN: &[(&str, usize, usize, usize, usize)] = &[
    ("alexnet", 21, 12, 8, 8),
    ("vgg11", 29, 17, 10, 9),
    ("vgg11_bn", 37, 25, 10, 9),
    ("vgg16", 39, 22, 15, 11),
    ("vgg16_bn", 52, 35, 15, 11),
    ("vgg19", 45, 25, 18, 11),
    ("vgg19_bn", 61, 41, 18, 11),
    ("resnet18", 69, 46, 28, 15),
    ("resnet34", 125, 86, 52, 15),
    ("resnet50", 175, 119, 69, 18),
    ("resnet101", 345, 238, 137, 18),
    ("resnet152", 515, 357, 205, 18),
    ("squeezenet1_0", 66, 38, 29, 17),
    ("squeezenet1_1", 66, 38, 29, 13),
    ("densenet121", 427, 304, 124, 68),
    ("densenet161", 567, 404, 164, 88),
    ("densenet169", 595, 424, 172, 92),
    ("densenet201", 707, 504, 204, 108),
    ("inception_v3", 314, 215, 106, 27),
];

/// (name, branch regions, optimized layers, chain-only optimized
/// layers) for the branchy families the branch-aware planner targets.
/// The last column pins the pre-branch-awareness coverage so the
/// "strictly more optimized layers than chain-only planning" guarantee
/// is loud if planning regresses.
const BRANCH_GOLDEN: &[(&str, usize, usize, usize)] = &[
    ("resnet18", 8, 46, 38),
    ("densenet121", 58, 304, 246),
    ("inception_v3", 13, 215, 202),
];

#[test]
fn zoo_structure_matches_golden() {
    let device = DeviceSpec::paper_gpu();
    let mut failures = Vec::new();
    for &(name, layers, opt, stacks, uniq) in GOLDEN {
        let g = zoo::build(name, zoo::paper_config(name, 1));
        let plan = optimize(&g, &device, &CollapseOptions::default());
        plan.validate(&g).unwrap();
        let got = (
            g.num_layers(),
            plan.num_optimized_layers(),
            plan.num_stacks(),
            plan.num_unique_stacks(),
        );
        if got != (layers, opt, stacks, uniq) {
            failures.push(format!(
                "(\"{name}\", {}, {}, {}, {}),",
                got.0, got.1, got.2, got.3
            ));
        }
    }
    assert!(
        failures.is_empty(),
        "zoo structure drifted; updated golden rows:\n{}",
        failures.join("\n")
    );
}

#[test]
fn branchy_networks_match_branch_golden() {
    let device = DeviceSpec::paper_gpu();
    for &(name, branches, opt, chain_only_opt) in BRANCH_GOLDEN {
        let g = zoo::build(name, zoo::paper_config(name, 1));
        let plan = optimize(&g, &device, &CollapseOptions::default());
        plan.validate(&g).unwrap();
        assert_eq!(plan.num_branches(), branches, "{name}: branch regions");
        assert_eq!(plan.num_optimized_layers(), opt, "{name}: optimized layers");
        assert!(
            plan.num_optimized_layers() > chain_only_opt,
            "{name}: branch-aware coverage {} regressed to <= chain-only {}",
            plan.num_optimized_layers(),
            chain_only_opt
        );
    }
}

#[test]
fn optimizable_fraction_in_paper_regime() {
    // Table 2: 44-64% of layers optimizable. Our module accounting
    // differs slightly from the paper's tally and branch-aware planning
    // adds the fused joins on top, so accept a wider band but require
    // every network to be substantially optimizable.
    let device = DeviceSpec::paper_gpu();
    for name in zoo::ALL_NETWORKS {
        let g = zoo::build(name, zoo::paper_config(name, 1));
        let plan = optimize(&g, &device, &CollapseOptions::default());
        let frac = plan.num_optimized_layers() as f64 / g.num_layers() as f64;
        assert!(
            (0.35..0.75).contains(&frac),
            "{name}: optimizable fraction {frac:.2} out of [0.35, 0.75)"
        );
    }
}

#[test]
fn stack_dedup_factor_significant_for_repetitive_nets() {
    // The paper reuses code across identical stacks (§4.3); deep
    // repetitive nets must show strong dedup — including across branch
    // arms, where identical residual blocks share arm-stack signatures.
    let device = DeviceSpec::paper_gpu();
    // ResNets repeat identically-shaped blocks: dedup is strong.
    // DenseNets grow the channel count every layer, so their BN+ReLU
    // stacks differ in shape and dedup is weaker (~2x) — that's
    // inherent, not a bug.
    let factor = |name: &str| {
        let g = zoo::build(name, zoo::paper_config(name, 1));
        let plan = optimize(&g, &device, &CollapseOptions::default());
        plan.num_stacks() as f64 / plan.num_unique_stacks() as f64
    };
    assert!(factor("resnet152") > 8.0);
    assert!(factor("vgg19_bn") > 1.5);
    assert!(factor("densenet201") > 1.5);
}
