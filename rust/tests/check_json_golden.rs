//! Golden test for the `brainslug check --format json` report schema.
//!
//! Downstream CI tooling parses this JSON (the check job uploads it as
//! an artifact), so the shape is a public contract: top-level
//! `diagnostics` / `errors` / `warnings` from `Report::to_json`, plus
//! the `networks` / `device` / `schedules` keys the CLI adds. Each
//! diagnostic carries `code`, `severity`, `subject`, `message`, and —
//! only when present — `node` and `notes`. Keys render in sorted order
//! (the JSON object is a BTreeMap), so the full pretty rendering is
//! deterministic and can be pinned verbatim. If this test breaks, the
//! schema changed: update DESIGN.md §Static Analysis alongside it.

use brainslug::analysis::{DiagCode, Diagnostic, Report};
use brainslug::json::Json;

/// Mirror of the assembly in `cmd_check`: the report body plus the
/// CLI-level context keys.
fn render(report: &Report, networks: &[&str], device: &str, schedules: Option<usize>) -> String {
    let mut j = report.to_json();
    j.set(
        "networks",
        Json::Arr(networks.iter().map(|n| Json::Str((*n).into())).collect()),
    );
    j.set("device", Json::Str(device.into()));
    if let Some(n) = schedules {
        j.set("schedules", Json::Num(n as f64));
    }
    j.to_string_pretty()
}

#[test]
fn clean_report_schema_is_pinned() {
    let report = Report::new();
    let got = render(&report, &["vgg16"], "paper-cpu", None);
    let want = r#"{
  "device": "paper-cpu",
  "diagnostics": [],
  "errors": 0,
  "networks": [
    "vgg16"
  ],
  "warnings": 0
}
"#;
    assert_eq!(got, want);
}

#[test]
fn schedule_finding_schema_is_pinned() {
    // One model-checker error with a counterexample note and one
    // warning: exercises every optional field the schema allows.
    let mut report = Report::new();
    report.push(
        Diagnostic::new(
            DiagCode::GateAfterTokens,
            "schedule model 'server-drain'",
            "shutdown token sent on channel 'dispatch' before gate 'closed' closed",
        )
        .note("counterexample schedule (4 decisions, one tid each): 0 1 1 0")
        .note("replay with ExploreOptions { replay: Some(schedule), .. } to reproduce"),
    );
    report.push(Diagnostic::new(
        DiagCode::BareCondvarWait,
        "schedule model 'server-drain'",
        "condvar waited on without a predicate loop",
    ));
    let got = render(&report, &["vgg16", "resnet18"], "paper-cpu", Some(256));
    let want = r#"{
  "device": "paper-cpu",
  "diagnostics": [
    {
      "code": "BSL055",
      "message": "shutdown token sent on channel 'dispatch' before gate 'closed' closed",
      "notes": [
        "counterexample schedule (4 decisions, one tid each): 0 1 1 0",
        "replay with ExploreOptions { replay: Some(schedule), .. } to reproduce"
      ],
      "severity": "error",
      "subject": "schedule model 'server-drain'"
    },
    {
      "code": "BSL052",
      "message": "condvar waited on without a predicate loop",
      "severity": "warning",
      "subject": "schedule model 'server-drain'"
    }
  ],
  "errors": 1,
  "networks": [
    "vgg16",
    "resnet18"
  ],
  "schedules": 256,
  "warnings": 1
}
"#;
    assert_eq!(got, want);
}
