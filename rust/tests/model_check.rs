//! End-to-end schedule model checking of the shipped runtime protocols.
//!
//! The acceptance bar for the checker is historical: real bugs were
//! fixed in this repo's past — the shutdown-while-queued race in the
//! batch server (tokens could be consumed before the admission gate
//! closed, stranding queued work), the listener drain-ordering bug
//! (pool threads bailing on a stop flag and abandoning accepted
//! connections), and the supervisor lost-restart race (a crashing
//! worker forgetting a shutdown token it had already absorbed, so its
//! reborn replica blocks in `recv` forever and shutdown deadlocks).
//! Each replica exposes a bug switch that re-introduces the pre-fix
//! behavior *in test only*; the checker must find every one with a
//! replayable counterexample schedule, and must find nothing in the
//! shipped (default) configurations.

use brainslug::conc::{explore, report_to_diags, ExploreOptions, Violation};
use brainslug::fault::{supervisor_protocol, SupervisorBugs};
use brainslug::http::listener::{self, ListenerBugs};
use brainslug::obs::{flush_protocol, FlushBugs};
use brainslug::server::{self, DrainBugs};
use std::sync::Arc;

fn opts(dfs: usize) -> ExploreOptions {
    ExploreOptions {
        dfs_executions: dfs,
        ..ExploreOptions::default()
    }
}

// ---------------------------------------------------------------------
// Shipped configurations explore clean.
// ---------------------------------------------------------------------

#[test]
fn shipped_server_drain_explores_clean() {
    let report = explore(
        "server-drain",
        &opts(256),
        Arc::new(|| server::drain_protocol(2, 2, 2, DrainBugs::default())),
    );
    assert!(report.finding.is_none(), "{:?}", report.finding);
    assert!(report.warnings.is_empty(), "{:?}", report.warnings);
}

#[test]
fn shipped_listener_drain_explores_clean() {
    let report = explore(
        "listener-drain",
        &opts(256),
        Arc::new(|| listener::drain_protocol(2, 2, 3, ListenerBugs::default())),
    );
    assert!(report.finding.is_none(), "{:?}", report.finding);
    assert!(report.warnings.is_empty(), "{:?}", report.warnings);
}

#[test]
fn shipped_band_pool_explores_clean() {
    let report = explore(
        "cpu-band-pool",
        &opts(256),
        Arc::new(|| brainslug::cpu::par::pool_protocol(2, 4)),
    );
    assert!(report.finding.is_none(), "{:?}", report.finding);
    assert!(report.warnings.is_empty(), "{:?}", report.warnings);
}

#[test]
fn shipped_fault_supervisor_explores_clean() {
    let report = explore(
        "fault-supervisor",
        &opts(256),
        Arc::new(|| supervisor_protocol(2, 2, 1, 1, SupervisorBugs::default())),
    );
    assert!(report.finding.is_none(), "{:?}", report.finding);
    assert!(report.warnings.is_empty(), "{:?}", report.warnings);
}

#[test]
fn shipped_obs_flush_explores_clean() {
    let report = explore(
        "obs-flush",
        &opts(256),
        Arc::new(|| flush_protocol(2, 2, FlushBugs::default())),
    );
    assert!(report.finding.is_none(), "{:?}", report.finding);
    assert!(report.warnings.is_empty(), "{:?}", report.warnings);
}

// ---------------------------------------------------------------------
// The span-flush drain-before-join bug: the exporter drains shards
// while writer threads are still recording (before the recording gate
// closes and the writers are joined). Under a schedule where a writer
// records after the drain, its span obligation stays open — BSL056.
// ---------------------------------------------------------------------

#[test]
fn obs_flush_drain_before_join_is_found_as_bsl056() {
    let bugs = FlushBugs {
        drain_before_join: true,
    };
    let report = explore(
        "obs-flush-drain-early",
        &opts(512),
        Arc::new(move || flush_protocol(2, 2, bugs)),
    );
    let finding = report.finding.expect("drain-before-join bug must be rediscovered");
    assert!(
        matches!(finding.violation, Violation::NonQuiescent { .. }),
        "wrong classification: {:?}",
        finding.violation
    );
    assert!(
        !finding.counterexample.schedule.is_empty(),
        "counterexample must carry a replayable schedule"
    );
    let diags = report_to_diags(&report);
    assert!(diags.iter().any(|d| d.code.as_str() == "BSL056"), "{diags:?}");
}

// ---------------------------------------------------------------------
// Reverting the shutdown-gate fix: shutdown tokens sent *before* the
// admission gate closes. The channel is bound to the gate, so the model
// flags the first token that races the close — BSL055.
// ---------------------------------------------------------------------

#[test]
fn reverted_shutdown_gate_fix_is_found_as_bsl055() {
    let bugs = DrainBugs {
        tokens_before_gate: true,
        ..DrainBugs::default()
    };
    let report = explore(
        "server-drain-reverted-gate",
        &opts(512),
        Arc::new(move || server::drain_protocol(2, 2, 2, bugs)),
    );
    let finding = report.finding.expect("pre-fix bug must be rediscovered");
    assert!(
        matches!(finding.violation, Violation::GateAfterTokens { .. }),
        "wrong classification: {:?}",
        finding.violation
    );
    assert!(
        !finding.counterexample.schedule.is_empty(),
        "counterexample must carry a replayable schedule"
    );

    // The diagnostic surface agrees: BSL055, with the schedule in a note.
    let diags = report_to_diags(&report);
    assert!(diags.iter().any(|d| d.code.as_str() == "BSL055"), "{diags:?}");
    let d = diags.iter().find(|d| d.code.as_str() == "BSL055").unwrap();
    assert!(
        d.notes.iter().any(|n| n.contains("counterexample schedule")),
        "{:?}",
        d.notes
    );
    assert!(
        d.notes.iter().any(|n| n.contains("replay with")),
        "{:?}",
        d.notes
    );
}

// ---------------------------------------------------------------------
// Reverting the admission-gate entirely (clients send without holding a
// gate guard): under the schedule where workers consume both shutdown
// tokens before the late client sends, the queued request is stranded —
// its obligation stays open at join time. BSL056.
// ---------------------------------------------------------------------

#[test]
fn reverted_admission_gate_is_found_as_bsl056() {
    let bugs = DrainBugs {
        ungated: true,
        ..DrainBugs::default()
    };
    let report = explore(
        "server-drain-ungated",
        &opts(512),
        Arc::new(move || server::drain_protocol(2, 2, 2, bugs)),
    );
    let finding = report.finding.expect("pre-fix bug must be rediscovered");
    assert!(
        matches!(finding.violation, Violation::NonQuiescent { .. }),
        "wrong classification: {:?}",
        finding.violation
    );
    let diags = report_to_diags(&report);
    assert!(diags.iter().any(|d| d.code.as_str() == "BSL056"), "{diags:?}");
}

// ---------------------------------------------------------------------
// Reverting the listener drain fix: pool threads check the stop flag
// after dequeuing and abandon the connection instead of answering it.
// The accepted connection's obligation stays open — BSL056.
// ---------------------------------------------------------------------

#[test]
fn reverted_listener_drain_fix_is_found_as_bsl056() {
    let bugs = ListenerBugs {
        abandon_queue_on_stop: true,
    };
    let report = explore(
        "listener-drain-reverted",
        &opts(512),
        Arc::new(move || listener::drain_protocol(2, 2, 3, bugs)),
    );
    let finding = report.finding.expect("pre-fix bug must be rediscovered");
    assert!(
        matches!(finding.violation, Violation::NonQuiescent { .. }),
        "wrong classification: {:?}",
        finding.violation
    );
    let diags = report_to_diags(&report);
    assert!(diags.iter().any(|d| d.code.as_str() == "BSL056"), "{diags:?}");
}

// ---------------------------------------------------------------------
// The supervisor lost-restart race: a worker that crashes after its
// gather absorbed a shutdown token "forgets" the token across the
// restart (the bug the real supervisor avoids by carrying
// `shutdown_pending` through `LoopExit::Crashed`). The reborn worker
// blocks in recv with no token left for it — join deadlocks. BSL050.
// ---------------------------------------------------------------------

#[test]
fn supervisor_lost_restart_race_is_found_as_bsl050() {
    let bugs = SupervisorBugs {
        lose_shutdown_on_crash: true,
        ..SupervisorBugs::default()
    };
    let report = explore(
        "fault-supervisor-lost-restart",
        &opts(512),
        Arc::new(move || supervisor_protocol(2, 2, 1, 1, bugs)),
    );
    let finding = report.finding.expect("lost-restart race must be rediscovered");
    assert!(
        matches!(finding.violation, Violation::Deadlock { .. }),
        "wrong classification: {:?}",
        finding.violation
    );
    assert!(
        !finding.counterexample.schedule.is_empty(),
        "counterexample must carry a replayable schedule"
    );
    let diags = report_to_diags(&report);
    assert!(diags.iter().any(|d| d.code.as_str() == "BSL050"), "{diags:?}");
    let d = diags.iter().find(|d| d.code.as_str() == "BSL050").unwrap();
    assert!(
        d.notes.iter().any(|n| n.contains("counterexample schedule")),
        "{:?}",
        d.notes
    );
    assert!(
        d.notes.iter().any(|n| n.contains("replay with")),
        "{:?}",
        d.notes
    );
}

// ---------------------------------------------------------------------
// The supervisor in-flight-drop bug: a crashing worker drops the batch
// it had gathered instead of answering every request with a typed
// error. The dropped requests' obligations stay open — BSL056.
// ---------------------------------------------------------------------

#[test]
fn supervisor_dropped_inflight_is_found_as_bsl056() {
    let bugs = SupervisorBugs {
        drop_inflight_on_crash: true,
        ..SupervisorBugs::default()
    };
    let report = explore(
        "fault-supervisor-dropped-inflight",
        &opts(512),
        Arc::new(move || supervisor_protocol(2, 2, 1, 1, bugs)),
    );
    let finding = report.finding.expect("dropped-inflight bug must be rediscovered");
    assert!(
        matches!(finding.violation, Violation::NonQuiescent { .. }),
        "wrong classification: {:?}",
        finding.violation
    );
    let diags = report_to_diags(&report);
    assert!(diags.iter().any(|d| d.code.as_str() == "BSL056"), "{diags:?}");
}

// ---------------------------------------------------------------------
// Counterexamples replay: pinning the violating schedule reproduces the
// same violation class deterministically, with no search.
// ---------------------------------------------------------------------

#[test]
fn counterexample_schedule_replays_deterministically() {
    let bugs = DrainBugs {
        tokens_before_gate: true,
        ..DrainBugs::default()
    };
    let report = explore(
        "server-drain-replay-src",
        &opts(512),
        Arc::new(move || server::drain_protocol(2, 2, 2, bugs)),
    );
    let finding = report.finding.expect("need a finding to replay");
    let schedule = finding.counterexample.schedule.clone();

    for round in 0..3 {
        let replay_opts = ExploreOptions {
            replay: Some(schedule.clone()),
            ..ExploreOptions::default()
        };
        let replayed = explore(
            "server-drain-replay",
            &replay_opts,
            Arc::new(move || server::drain_protocol(2, 2, 2, bugs)),
        );
        assert_eq!(replayed.executions, 1, "replay runs exactly one schedule");
        let f = replayed
            .finding
            .unwrap_or_else(|| panic!("replay round {round} lost the violation"));
        assert!(
            matches!(f.violation, Violation::GateAfterTokens { .. }),
            "replay round {round} reclassified: {:?}",
            f.violation
        );
    }
}

#[test]
fn supervisor_counterexample_replays_deterministically() {
    let bugs = SupervisorBugs {
        lose_shutdown_on_crash: true,
        ..SupervisorBugs::default()
    };
    let report = explore(
        "fault-supervisor-replay-src",
        &opts(512),
        Arc::new(move || supervisor_protocol(2, 2, 1, 1, bugs)),
    );
    let finding = report.finding.expect("need a finding to replay");
    let schedule = finding.counterexample.schedule.clone();

    for round in 0..3 {
        let replay_opts = ExploreOptions {
            replay: Some(schedule.clone()),
            ..ExploreOptions::default()
        };
        let replayed = explore(
            "fault-supervisor-replay",
            &replay_opts,
            Arc::new(move || supervisor_protocol(2, 2, 1, 1, bugs)),
        );
        assert_eq!(replayed.executions, 1, "replay runs exactly one schedule");
        let f = replayed
            .finding
            .unwrap_or_else(|| panic!("replay round {round} lost the violation"));
        assert!(
            matches!(f.violation, Violation::Deadlock { .. }),
            "replay round {round} reclassified: {:?}",
            f.violation
        );
    }
}
