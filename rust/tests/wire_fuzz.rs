//! Byte-mutation robustness for the HTTP wire parser.
//!
//! 10k seeded mutations (flips, truncations, splices, length rewrites)
//! of valid requests, via the repo's deterministic SplitMix64 stream:
//! `read_request` must never panic, never loop, and never read past the
//! body bytes `Content-Length` entitles it to. Over-read is observable
//! because parsing runs against a cursor over the mutated bytes: after
//! a successful parse, the cursor position must equal head + declared
//! length exactly, and after *any* outcome it must never exceed it.
//!
//! Crash cases found by earlier fuzz runs are pinned at the bottom as
//! named regression inputs so they survive corpus/seed changes.

use std::io::Cursor;

use brainslug::http::wire::{read_request, WireLimits};
use brainslug::rng::splitmix64;

/// Valid seed requests the mutator starts from — one per framing shape
/// (no body, exact body, body + pipelined tail, HTTP/1.0, query string,
/// multiple headers).
fn corpus() -> Vec<Vec<u8>> {
    vec![
        b"GET /healthz HTTP/1.1\r\n\r\n".to_vec(),
        b"GET /v1/stats?verbose=1 HTTP/1.0\r\nConnection: keep-alive\r\n\r\n".to_vec(),
        b"POST /v1/run HTTP/1.1\r\nContent-Length: 11\r\n\r\n{\"ok\":true}".to_vec(),
        b"POST /v1/run HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhelloGET /b HTTP/1.1\r\n\r\n"
            .to_vec(),
        b"POST /a HTTP/1.1\r\nContent-Length: 0\r\nConnection: close\r\nAccept: */*\r\n\r\n"
            .to_vec(),
    ]
}

/// One seeded mutation of `base`: pick a strategy and a site from the
/// deterministic stream.
fn mutate(base: &[u8], state: &mut u64) -> Vec<u8> {
    let mut out = base.to_vec();
    let rounds = 1 + (splitmix64(state) % 3) as usize;
    for _ in 0..rounds {
        if out.is_empty() {
            out.push(splitmix64(state) as u8);
            continue;
        }
        let site = (splitmix64(state) as usize) % out.len();
        match splitmix64(state) % 6 {
            // Byte flip (any value, including NUL / non-UTF-8 / 0x80+).
            0 => out[site] = splitmix64(state) as u8,
            // Truncate.
            1 => out.truncate(site),
            // Duplicate a chunk in place (splice).
            2 => {
                let end = (site + 1 + (splitmix64(state) as usize) % 8).min(out.len());
                let chunk: Vec<u8> = out[site..end].to_vec();
                let at = (splitmix64(state) as usize) % (out.len() + 1);
                out.splice(at..at, chunk);
            }
            // Insert a random byte.
            3 => out.insert(site, splitmix64(state) as u8),
            // Rewrite a digit (attacks Content-Length values).
            4 => {
                if let Some(pos) = out.iter().position(|b| b.is_ascii_digit()) {
                    out[pos] = b'0' + (splitmix64(state) % 10) as u8;
                }
            }
            // Swap two bytes (attacks CR/LF ordering).
            _ => {
                let other = (splitmix64(state) as usize) % out.len();
                out.swap(site, other);
            }
        }
    }
    out
}

/// Upper bound on the bytes `read_request` may consume from `input`:
/// the header block (request line + headers + blank line) plus the
/// declared `Content-Length`. Returns `None` when the input has no
/// complete header block (the parser may then read to EOF looking for
/// it) or when the header region contains a lone `\n` — the parser
/// legally treats bare LF as a line terminator too, so the independent
/// CRLF scan below would disagree with it about where the block ends.
fn entitled_bytes(input: &[u8]) -> Option<usize> {
    let head_end = input.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)?;
    let head = &input[..head_end];
    for (i, b) in head.iter().enumerate() {
        if *b == b'\n' && (i == 0 || head[i - 1] != b'\r') {
            return None; // ambiguous framing: skip the strict oracle
        }
    }
    let text = String::from_utf8_lossy(head);
    let mut declared = 0usize;
    for line in text.split("\r\n") {
        if let Some((name, value)) = line.split_once(':') {
            // First match wins, like the parser's `find`.
            if name.eq_ignore_ascii_case("content-length") {
                declared = value.trim().parse::<usize>().unwrap_or(0);
                break;
            }
        }
    }
    Some(head_end + declared)
}

#[derive(Default)]
struct Tally {
    ok: usize,
    rejected: usize,
}

/// Core property: parse must return (no panic — the harness would
/// abort), and the cursor must never pass the entitled byte count.
fn assert_no_overread(input: &[u8], tally: &mut Tally) {
    let limits = WireLimits::default();
    let mut cur = Cursor::new(input);
    let result = read_request(&mut cur, &limits);
    let consumed = cur.position() as usize;
    assert!(
        consumed <= input.len(),
        "cursor past end: {consumed} > {}",
        input.len()
    );
    if let Some(entitled) = entitled_bytes(input) {
        match result {
            Ok(ref req) => {
                // Exact framing: a parsed request consumed its header
                // block plus exactly its body — pipelined bytes after it
                // are untouched.
                assert_eq!(
                    consumed,
                    entitled.min(input.len()),
                    "over/under-read on success (body {} bytes)",
                    req.body.len()
                );
            }
            Err(_) => {
                // Errors may stop early, never late. (An invalid or
                // over-limit Content-Length is rejected before any body
                // byte is read, so `entitled` computed from the raw
                // digits still upper-bounds the legal cursor.)
                assert!(
                    consumed <= entitled.min(input.len()),
                    "over-read on error: consumed {consumed}, entitled {entitled}"
                );
            }
        }
    }
    match result {
        Ok(_) => tally.ok += 1,
        Err(_) => tally.rejected += 1,
    }
}

#[test]
fn ten_thousand_seeded_mutations_never_panic_or_overread() {
    let corpus = corpus();
    // Fixed seed → fully deterministic corpus; bump the constant to
    // rotate the stream (pin any new crash below first).
    let mut state = 0xB5_F022_u64;
    let mut tally = Tally::default();
    for i in 0..10_000 {
        let base = &corpus[i % corpus.len()];
        let mutated = mutate(base, &mut state);
        assert_no_overread(&mutated, &mut tally);
    }
    // The mutator must exercise both outcomes, not degenerate into
    // all-reject (or, absurdly, all-accept).
    assert!(tally.rejected > 1000, "rejected only {}", tally.rejected);
    assert!(tally.ok > 50, "parsed only {} mutants", tally.ok);
}

#[test]
fn unmutated_corpus_still_parses() {
    // Guards the corpus itself: every seed input is valid, so the fuzz
    // run starts from accepting states.
    let mut tally = Tally::default();
    for base in corpus() {
        assert_no_overread(&base, &mut tally);
    }
    assert_eq!(tally.ok, corpus().len());
}

// ---------------------------------------------------------------------
// Pinned regression inputs. Each of these is a mutant that once crashed
// or over-read a draft of the parser; they stay pinned verbatim so the
// classes cannot regress even if the seeded stream above rotates.
// ---------------------------------------------------------------------

#[test]
fn pinned_header_budget_off_by_one() {
    // A header line landing exactly on the budget boundary once tripped
    // the `n > budget` arithmetic in `read_line`.
    let limits = WireLimits {
        max_header_bytes: 32,
        max_body_bytes: 16,
    };
    for pad in 0..48 {
        let raw = format!("GET / HTTP/1.1\r\nx: {}\r\n\r\n", "a".repeat(pad));
        let _ = read_request(&mut Cursor::new(raw.as_bytes()), &limits);
    }
}

#[test]
fn pinned_crlf_swap_inside_request_line() {
    // CR/LF swapped by the byte-swap mutator. The parser accepts bare
    // `\n` as a line terminator (lenient framing), so this still
    // parses — what must hold is that the header loop does not
    // desynchronise: the stray trailing `\r` stays unread for the
    // (doomed) next request.
    let raw = b"GET /healthz HTTP/1.1\n\r\n\r";
    let mut cur = Cursor::new(&raw[..]);
    let req = read_request(&mut cur, &WireLimits::default()).expect("lenient LF framing parses");
    assert!(req.body.is_empty());
    assert_eq!(cur.position() as usize, raw.len() - 1);
}

#[test]
fn pinned_content_length_larger_than_remaining_bytes() {
    // Declared 11, only 3 bytes present: must be an I/O error with the
    // cursor at EOF, never a hang or a panic.
    let mut tally = Tally::default();
    assert_no_overread(b"POST /v1/run HTTP/1.1\r\nContent-Length: 11\r\n\r\n{\"o", &mut tally);
    assert_eq!(tally.rejected, 1);
}

#[test]
fn pinned_nul_and_high_bytes_in_header_block() {
    // Non-UTF-8 bytes in the header block reject as Bad, not panic in
    // a String conversion.
    let mut tally = Tally::default();
    assert_no_overread(b"GET /\xff HTTP/1.1\r\nx\x00y: v\r\n\r\n", &mut tally);
    assert_eq!(tally.rejected, 1);
}

#[test]
fn pinned_digit_rewrite_makes_zero_length_body() {
    // Content-Length rewritten to 0 with body bytes still present: the
    // parser must stop at the blank line and leave the stale body for
    // the (doomed) next request, not consume it.
    let raw = b"POST /v1/run HTTP/1.1\r\nContent-Length: 0\r\n\r\n{\"ok\":true}";
    let mut cur = Cursor::new(&raw[..]);
    let req = read_request(&mut cur, &WireLimits::default()).expect("zero-length body is valid");
    assert!(req.body.is_empty());
    assert_eq!(cur.position() as usize, raw.len() - 11);
}

#[test]
fn pinned_huge_declared_length_is_rejected_before_allocation() {
    // usize-parseable but absurd Content-Length must map to TooLarge
    // via the pre-read bound — importantly *without* allocating the
    // declared buffer (this input would otherwise try ~10^18 bytes).
    let raw = b"POST / HTTP/1.1\r\nContent-Length: 999999999999999999\r\n\r\n";
    let err = read_request(&mut Cursor::new(&raw[..]), &WireLimits::default()).unwrap_err();
    assert!(
        matches!(err, brainslug::http::wire::WireError::TooLarge { .. }),
        "{err}"
    );
}
