//! Property-based tests over the optimizer and memory model.
//!
//! The offline build has no proptest crate, so these are seeded
//! randomized sweeps driven by the in-tree SplitMix64 generator: 200+
//! random network structures per property, deterministic across runs
//! (failures reproduce by seed, printed on panic).

use brainslug::device::DeviceSpec;
use brainslug::engine::Engine;
use brainslug::graph::{Graph, Layer, PoolKind, Shape, Window2d};
use brainslug::memsim::{graph_cost_bf, sequence_cost_df, simulate_baseline, simulate_plan};
use brainslug::optimizer::{optimize, CollapseOptions, Segment};
use brainslug::rng::splitmix64;
use brainslug::runtime::{HostTensor, ParamStore};

/// Deterministic random usize in [lo, hi].
fn rand_in(state: &mut u64, lo: usize, hi: usize) -> usize {
    lo + (splitmix64(state) as usize) % (hi - lo + 1)
}

/// Generate a random single-chain network of optimizable + conv layers.
fn random_chain(seed: u64) -> Graph {
    let mut st = seed;
    let c = rand_in(&mut st, 1, 16);
    let h = rand_in(&mut st, 8, 48);
    let mut g = Graph::new(format!("rand{seed}"), Shape::nchw(rand_in(&mut st, 1, 4), c, h, h));
    let n_layers = rand_in(&mut st, 1, 24);
    for i in 0..n_layers {
        let cur_h = g.output_shape().height();
        match rand_in(&mut st, 0, 5) {
            0 => {
                g.push(format!("bn{i}"), Layer::BatchNorm2d { eps: 1e-5 });
            }
            1 => {
                g.push(format!("relu{i}"), Layer::Relu);
            }
            2 => {
                g.push(format!("drop{i}"), Layer::Dropout { p: 0.5 });
            }
            3 if cur_h >= 4 => {
                let k = rand_in(&mut st, 2, 3);
                let s = rand_in(&mut st, 1, 2);
                let p = rand_in(&mut st, 0, k / 2);
                g.push(
                    format!("pool{i}"),
                    Layer::Pool2d {
                        kind: if rand_in(&mut st, 0, 1) == 0 {
                            PoolKind::Max
                        } else {
                            PoolKind::Avg
                        },
                        window: Window2d::square(k, s, p),
                        ceil_mode: false,
                        count_include_pad: true,
                    },
                );
            }
            4 if cur_h >= 3 => {
                g.push(
                    format!("conv{i}"),
                    Layer::Conv2d {
                        out_channels: rand_in(&mut st, 1, 16),
                        window: Window2d::square(3, 1, 1),
                        bias: rand_in(&mut st, 0, 1) == 0,
                    },
                );
            }
            _ => {
                g.push(format!("relu_b{i}"), Layer::Relu);
            }
        }
    }
    g
}

/// Generate a random *branchy* DAG: optimizable runs interleaved with
/// fan-out/join regions (`Add` with 2 arms or `Concat` with 2-3 arms;
/// arm bodies hold 0-3 shape-preserving layers, 0 = identity skip).
/// Returns the graph and the number of join regions built — every
/// generated join is a well-formed single-entry/single-exit region, so
/// the branch-aware planner must emit exactly that many branch segments.
fn random_branchy(seed: u64) -> (Graph, usize) {
    let mut st = seed ^ 0xB17A9C;
    let c = rand_in(&mut st, 2, 10);
    let h = rand_in(&mut st, 6, 24);
    let batch = rand_in(&mut st, 1, 2);
    let mut g = Graph::new(format!("branchy{seed}"), Shape::nchw(batch, c, h, h));
    let blocks = rand_in(&mut st, 1, 5);
    for b in 0..blocks {
        for i in 0..rand_in(&mut st, 0, 2) {
            match rand_in(&mut st, 0, 1) {
                0 => g.push(format!("b{b}.pre{i}"), Layer::BatchNorm2d { eps: 1e-5 }),
                _ => g.push(format!("b{b}.pre{i}"), Layer::Relu),
            };
        }
        let entry = g.output;
        let channels = g.output_shape().channels();
        let concat = rand_in(&mut st, 0, 1) == 1;
        let n_arms = if concat { rand_in(&mut st, 2, 3) } else { 2 };
        let mut outs = Vec::new();
        for a in 0..n_arms {
            let mut cur = entry;
            for l in 0..rand_in(&mut st, 0, 3) {
                let name = format!("b{b}.a{a}.l{l}");
                cur = match rand_in(&mut st, 0, 2) {
                    0 => g.add(name, Layer::BatchNorm2d { eps: 1e-5 }, &[cur]),
                    1 => g.add(name, Layer::Relu, &[cur]),
                    _ => g.add(
                        name,
                        Layer::Conv2d {
                            out_channels: channels,
                            window: Window2d::square(3, 1, 1),
                            bias: false,
                        },
                        &[cur],
                    ),
                };
            }
            outs.push(cur);
        }
        if concat {
            g.add(format!("b{b}.cat"), Layer::Concat, &outs);
        } else {
            g.add(format!("b{b}.add"), Layer::Add, &outs);
        }
        if rand_in(&mut st, 0, 1) == 1 {
            g.push(format!("b{b}.post"), Layer::Relu);
        }
    }
    (g, blocks)
}

fn random_device(seed: u64) -> DeviceSpec {
    let mut st = seed ^ 0xDEAD;
    let mut d = match rand_in(&mut st, 0, 2) {
        0 => DeviceSpec::paper_cpu(),
        1 => DeviceSpec::paper_gpu(),
        _ => DeviceSpec::tpu_core(),
    };
    d.fast_mem_bytes = 1usize << rand_in(&mut st, 10, 20);
    d
}

#[test]
fn plan_partitions_every_node_exactly_once() {
    for seed in 0..250 {
        let g = random_chain(seed);
        let device = random_device(seed);
        let plan = optimize(&g, &device, &CollapseOptions::default());
        plan.validate(&g)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

#[test]
fn stack_ops_preserve_topological_order() {
    for seed in 0..250 {
        let g = random_chain(seed);
        let plan = optimize(&g, &random_device(seed), &CollapseOptions::default());
        for stack in plan.stacks() {
            let flat: Vec<usize> = stack
                .sequences
                .iter()
                .flat_map(|s| &s.steps)
                .flat_map(|st| &st.ops)
                .map(|o| o.node)
                .collect();
            assert_eq!(flat, stack.nodes, "seed {seed}: op order != node order");
        }
    }
}

#[test]
fn multi_step_sequences_respect_budget() {
    // Sequences with >1 step fit the budget at their chosen tile; a
    // single-step sequence may exceed it (degenerate whole-input case).
    for seed in 0..250 {
        let g = random_chain(seed);
        let device = random_device(seed);
        let plan = optimize(&g, &device, &CollapseOptions::default());
        for stack in plan.stacks() {
            for seq in &stack.sequences {
                if seq.steps.len() > 1 {
                    let ws = seq.working_set_bytes(1);
                    assert!(
                        ws <= device.resource_limit(),
                        "seed {seed}: min working set {ws} > budget {}",
                        device.resource_limit()
                    );
                }
                assert!(seq.tile_rows >= 1, "seed {seed}");
            }
        }
    }
}

#[test]
fn depth_first_never_moves_more_main_bytes() {
    // Holds for realistic fast-memory budgets (>= 16 KiB). With
    // pathologically small budgets the band height collapses to a few
    // rows and the pooling halo redundancy can exceed the intermediate
    // savings — the same effect the paper documents for convolutions
    // (§7 Limitations) and that Figure 10's "unrestricted" curve shows
    // when sequences outgrow the cache.
    for seed in 0..250 {
        let g = random_chain(seed);
        let mut device = random_device(seed);
        device.fast_mem_bytes = device.fast_mem_bytes.max(16 * 1024);
        let plan = optimize(&g, &device, &CollapseOptions::default());
        let bf = graph_cost_bf(&g);
        let mut df_main = 0.0;
        for seg in &plan.segments {
            match seg {
                Segment::Stack(st) => {
                    for seq in &st.sequences {
                        df_main += sequence_cost_df(&g, seq).main_bytes;
                    }
                }
                Segment::Single(id) => {
                    df_main += brainslug::memsim::layer_cost_bf(&g, g.node(*id)).main_bytes;
                }
                Segment::Branch { .. } => unreachable!("random chains have no branches"),
            }
        }
        // Halo redundancy can add bytes, but removing intermediates must
        // dominate: allow 5% slack for degenerate tiny stacks.
        assert!(
            df_main <= bf.main_bytes * 1.05,
            "seed {seed}: df {df_main} > bf {}",
            bf.main_bytes
        );
    }
}

#[test]
fn identical_signatures_imply_identical_structure() {
    use std::collections::HashMap;
    for seed in 0..120 {
        let g = random_chain(seed);
        let plan = optimize(&g, &random_device(seed), &CollapseOptions::default());
        let mut by_sig: HashMap<&str, (usize, usize, Vec<usize>)> = HashMap::new();
        for stack in plan.stacks() {
            let key = stack.signature.as_str();
            let shape = (
                stack.sequences.len(),
                stack.num_ops(),
                stack.sequences.iter().map(|s| s.tile_rows).collect(),
            );
            if let Some(prev) = by_sig.get(key) {
                assert_eq!(prev, &shape, "seed {seed}: signature collision");
            } else {
                by_sig.insert(key, shape);
            }
        }
    }
}

#[test]
fn strategy_restriction_never_reduces_sequence_count() {
    for seed in 0..120 {
        let g = random_chain(seed);
        let device = random_device(seed);
        let count = |max: Option<usize>| -> usize {
            let plan = optimize(
                &g,
                &device,
                &CollapseOptions {
                    max_steps_per_sequence: max,
                    ..Default::default()
                },
            );
            plan.stacks().map(|s| s.sequences.len()).sum()
        };
        let one = count(Some(1));
        let five = count(Some(5));
        let unrestricted = count(None);
        assert!(one >= five, "seed {seed}");
        assert!(five >= unrestricted, "seed {seed}");
    }
}

#[test]
fn simulated_plan_time_is_finite_and_positive() {
    for seed in 0..120 {
        let g = random_chain(seed);
        let device = random_device(seed);
        let plan = optimize(&g, &device, &CollapseOptions::default());
        let base = simulate_baseline(&g, &device);
        let bs = simulate_plan(&g, &plan, &device);
        assert!(base.total_s.is_finite() && base.total_s > 0.0, "seed {seed}");
        assert!(bs.total_s.is_finite() && bs.total_s > 0.0, "seed {seed}");
    }
}

#[test]
fn batch_rebuild_preserves_plan_structure() {
    for seed in 0..60 {
        let g = random_chain(seed);
        let device = random_device(seed);
        let p1 = optimize(&g, &device, &CollapseOptions::default());
        let p2 = optimize(&g.with_batch(7), &device, &CollapseOptions::default());
        assert_eq!(p1.num_stacks(), p2.num_stacks(), "seed {seed}");
        assert_eq!(
            p1.num_optimized_layers(),
            p2.num_optimized_layers(),
            "seed {seed}"
        );
    }
}

#[test]
fn branchy_plans_partition_and_count_regions() {
    for seed in 0..200 {
        let (g, blocks) = random_branchy(seed);
        g.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let device = random_device(seed);
        let plan = optimize(&g, &device, &CollapseOptions::default());
        plan.validate(&g).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(plan.num_branches(), blocks, "seed {seed}");
        // Every optimizable layer stacks (inside or outside an arm), and
        // every join is fused: the optimized-layer count is exact.
        let n_opt = g
            .nodes
            .iter()
            .skip(1)
            .filter(|n| n.layer.is_optimizable())
            .count();
        assert_eq!(plan.num_optimized_layers(), n_opt + blocks, "seed {seed}");
    }
}

#[test]
fn branchy_plan_structure_is_batch_invariant() {
    for seed in 0..60 {
        let (g, _) = random_branchy(seed);
        let device = random_device(seed);
        let p1 = optimize(&g, &device, &CollapseOptions::default());
        let p7 = optimize(&g.with_batch(7), &device, &CollapseOptions::default());
        assert_eq!(p1.num_branches(), p7.num_branches(), "seed {seed}");
        assert_eq!(p1.num_stacks(), p7.num_stacks(), "seed {seed}");
        assert_eq!(
            p1.num_optimized_layers(),
            p7.num_optimized_layers(),
            "seed {seed}"
        );
    }
}

#[test]
fn branchy_plans_execute_on_sim_with_oracle_parity() {
    // Oracle parity for Segment::Branch on the artifact-free backend:
    // baseline and plan runs must complete and produce identical
    // outputs, and the plan stats must show one fused join per region.
    for seed in 0..40 {
        let (g, blocks) = random_branchy(seed);
        let mut eng = Engine::builder()
            .graph_owned(g)
            .device(random_device(seed))
            .sim()
            .seed(seed)
            .build()
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(eng.plan().unwrap().num_branches(), blocks, "seed {seed}");
        let input = eng.synthetic_input();
        let (out_base, stats_base) = eng.run_baseline(input.clone()).unwrap();
        let (out_plan, stats_plan) = eng.run(input).unwrap();
        assert_eq!(out_base, out_plan, "seed {seed}: modes diverge");
        assert!(stats_base.total_s > 0.0 && stats_base.total_s.is_finite());
        assert!(stats_plan.total_s > 0.0 && stats_plan.total_s.is_finite());
        let joins = stats_plan
            .segments
            .iter()
            .filter(|s| s.kind == "join")
            .count();
        assert_eq!(joins, blocks, "seed {seed}");
    }
}

// ---------------------------------------------------------------------
// Native CPU backend: numeric parity between the breadth-first baseline
// and the depth-first band walker.
//
// Tolerances: both schedules share the pooling / affine arithmetic
// (`cpu::kernels::pool_window`, same per-element expressions) and the
// non-stacked segments execute the very same kernels, so they are
// expected to agree *bitwise*; atol = rtol = 1e-6 only leaves headroom
// for a future reassociating (e.g. SIMD-blocked) kernel rewrite.
const CPU_ATOL: f32 = 1e-6;
const CPU_RTOL: f32 = 1e-6;

/// Small random chains for the CPU-backend parity sweep. The shapes are
/// deliberately tiny (real convolutions in debug builds); the structure
/// space matches `random_chain`: bn / relu / dropout / max+avg pools /
/// 3x3 convs in any order.
fn random_small_chain(seed: u64) -> Graph {
    let mut st = seed ^ 0xC4;
    let c = rand_in(&mut st, 1, 6);
    let h = rand_in(&mut st, 8, 18);
    let mut g = Graph::new(
        format!("cpu{seed}"),
        Shape::nchw(rand_in(&mut st, 1, 2), c, h, h),
    );
    let n_layers = rand_in(&mut st, 2, 9);
    for i in 0..n_layers {
        let cur_h = g.output_shape().height();
        match rand_in(&mut st, 0, 5) {
            0 => {
                g.push(format!("bn{i}"), Layer::BatchNorm2d { eps: 1e-5 });
            }
            1 => {
                g.push(format!("relu{i}"), Layer::Relu);
            }
            2 => {
                g.push(format!("drop{i}"), Layer::Dropout { p: 0.5 });
            }
            3 if cur_h >= 4 => {
                let k = rand_in(&mut st, 2, 3);
                let s = rand_in(&mut st, 1, 2);
                let p = rand_in(&mut st, 0, k / 2);
                g.push(
                    format!("pool{i}"),
                    Layer::Pool2d {
                        kind: if rand_in(&mut st, 0, 1) == 0 {
                            PoolKind::Max
                        } else {
                            PoolKind::Avg
                        },
                        window: Window2d::square(k, s, p),
                        ceil_mode: false,
                        count_include_pad: true,
                    },
                );
            }
            4 if cur_h >= 3 => {
                g.push(
                    format!("conv{i}"),
                    Layer::Conv2d {
                        out_channels: rand_in(&mut st, 1, 6),
                        window: Window2d::square(3, 1, 1),
                        bias: rand_in(&mut st, 0, 1) == 0,
                    },
                );
            }
            _ => {
                g.push(format!("relu_b{i}"), Layer::Relu);
            }
        }
    }
    g
}

fn cpu_engine(g: Graph, seed: u64, threads: usize) -> Engine {
    Engine::builder()
        .graph_owned(g)
        .device(DeviceSpec::host_cpu())
        .cpu(threads)
        .seed(seed)
        .build()
        .unwrap()
}

#[test]
fn cpu_depth_first_matches_breadth_first_on_random_chains() {
    for seed in 0..10 {
        let g = random_small_chain(seed);
        let mut eng = cpu_engine(g, seed, 2);
        let input = eng.synthetic_input();
        let (base, _) = eng.run_baseline(input.clone()).unwrap();
        let (df, stats) = eng.run(input).unwrap();
        assert!(
            base.allclose(&df, CPU_ATOL, CPU_RTOL),
            "seed {seed}: schedules diverge, max |diff| = {:.3e}",
            base.max_abs_diff(&df)
        );
        // Plans with stacks must actually have exercised the walker.
        if eng.plan().unwrap().num_stacks() > 0 {
            assert!(
                stats.segments.iter().any(|s| s.kind == "stack"),
                "seed {seed}: no stack segment executed"
            );
        }
    }
}

#[test]
fn cpu_backend_parity_on_random_branchy_dags() {
    // Residual adds and concats: skip planes are Arc-shared across the
    // arms, branch arms execute depth-first, and the two schedules must
    // still agree.
    for seed in 0..8 {
        let (g, blocks) = random_branchy(seed);
        let mut eng = cpu_engine(g, seed, 2);
        assert_eq!(eng.plan().unwrap().num_branches(), blocks, "seed {seed}");
        let input = eng.synthetic_input();
        let (base, _) = eng.run_baseline(input.clone()).unwrap();
        let (df, _) = eng.run(input).unwrap();
        assert!(
            base.allclose(&df, CPU_ATOL, CPU_RTOL),
            "seed {seed}: schedules diverge, max |diff| = {:.3e}",
            base.max_abs_diff(&df)
        );
    }
}

#[test]
fn cpu_parity_at_walker_edge_tile_configs() {
    // The autotuner explores degenerate band geometries; the walker
    // must stay *bit-identical* to the breadth-first baseline at both
    // extremes: forced single-row bands (`max_tile_rows = 1`, maximal
    // halo redundancy) and whole-plane bands (`min_tile_rows` far above
    // any output height, `tile_rows >= out_h` after clamping), plus a
    // mid cap for good measure. Non-stacked segments run the same
    // kernels on both schedules, so exact equality is the bar.
    let configs: &[(&str, CollapseOptions)] = &[
        (
            "tile_rows=1",
            CollapseOptions {
                max_tile_rows: Some(1),
                ..Default::default()
            },
        ),
        (
            "tile_rows<=2",
            CollapseOptions {
                max_tile_rows: Some(2),
                ..Default::default()
            },
        ),
        (
            "tile_rows>=out_h",
            CollapseOptions {
                min_tile_rows: 1 << 20,
                ..Default::default()
            },
        ),
    ];
    for seed in 0..6 {
        let g = random_small_chain(seed ^ 0x71E5);
        for (label, opts) in configs {
            let mut eng = Engine::builder()
                .graph_owned(g.clone())
                .device(DeviceSpec::host_cpu())
                .brainslug(*opts)
                .cpu(2)
                .seed(seed)
                .build()
                .unwrap();
            let input = eng.synthetic_input();
            let (base, _) = eng.run_baseline(input.clone()).unwrap();
            let (df, _) = eng.run(input).unwrap();
            assert_eq!(
                base, df,
                "seed {seed} {label}: walker diverges at an edge tile config"
            );
            // The forced geometry really bit: every sequence honours it.
            for stack in eng.plan().unwrap().stacks() {
                for seq in &stack.sequences {
                    match *label {
                        "tile_rows=1" => assert_eq!(seq.tile_rows, 1, "seed {seed}"),
                        "tile_rows<=2" => assert!(seq.tile_rows <= 2, "seed {seed}"),
                        _ => {
                            // Whole-plane bands: tile_rows clamps to the
                            // sequence's own output height.
                            let out = seq.out_shape();
                            let out_h = if out.rank() == 4 {
                                out.height()
                            } else {
                                out.batch()
                            };
                            assert_eq!(seq.tile_rows, out_h, "seed {seed}");
                        }
                    }
                }
            }
        }
    }
}

/// Fixed-seed golden for one vgg16 block
/// (conv3x3 → relu → conv3x3 → relu → maxpool2x2s2) at reduced width:
/// the native backend must match an *independent* naive reference
/// (different loop nest, f64 accumulation) within atol = rtol = 1e-4 —
/// the tolerance covers f32-vs-f64 accumulation-order divergence; the
/// two native schedules themselves must agree bitwise, and the whole
/// pipeline must be deterministic across backend instances.
#[test]
fn cpu_vgg16_block_golden() {
    fn conv3x3_ref(x: &HostTensor, w: &HostTensor, b: &HostTensor) -> HostTensor {
        let (n, ci, h, wd) = (
            x.shape.batch(),
            x.shape.channels(),
            x.shape.height(),
            x.shape.width(),
        );
        let oc = w.shape.dims[0];
        let mut out = HostTensor::zeros(Shape::nchw(n, oc, h, wd));
        for bi in 0..n {
            for o in 0..oc {
                for y in 0..h {
                    for x0 in 0..wd {
                        let mut acc = b.data[o] as f64;
                        for c in 0..ci {
                            for ky in 0..3usize {
                                for kx in 0..3usize {
                                    let iy = y as isize + ky as isize - 1;
                                    let ix = x0 as isize + kx as isize - 1;
                                    if iy < 0
                                        || ix < 0
                                        || iy >= h as isize
                                        || ix >= wd as isize
                                    {
                                        continue;
                                    }
                                    let xv = x.data
                                        [((bi * ci + c) * h + iy as usize) * wd + ix as usize];
                                    let wv = w.data[((o * ci + c) * 3 + ky) * 3 + kx];
                                    acc += xv as f64 * wv as f64;
                                }
                            }
                        }
                        out.data[((bi * oc + o) * h + y) * wd + x0] = acc as f32;
                    }
                }
            }
        }
        out
    }
    fn relu_ref(x: &HostTensor) -> HostTensor {
        HostTensor::new(
            x.shape.clone(),
            x.data.iter().map(|v| if *v > 0.0 { *v } else { 0.0 }).collect(),
        )
    }
    fn maxpool2x2_ref(x: &HostTensor) -> HostTensor {
        let (n, c, h, w) = (
            x.shape.batch(),
            x.shape.channels(),
            x.shape.height(),
            x.shape.width(),
        );
        let (oh, ow) = (h / 2, w / 2);
        let mut out = HostTensor::zeros(Shape::nchw(n, c, oh, ow));
        for p in 0..n * c {
            for y in 0..oh {
                for x0 in 0..ow {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..2usize {
                        for dx in 0..2usize {
                            m = m.max(x.data[(p * h + 2 * y + dy) * w + 2 * x0 + dx]);
                        }
                    }
                    out.data[(p * oh + y) * ow + x0] = m;
                }
            }
        }
        out
    }

    let mut g = Graph::new("vgg16_block", Shape::nchw(2, 3, 12, 12));
    let conv = |oc: usize| Layer::Conv2d {
        out_channels: oc,
        window: Window2d::square(3, 1, 1),
        bias: true,
    };
    g.push("conv1", conv(8));
    g.push("relu1", Layer::Relu);
    g.push("conv2", conv(8));
    g.push("relu2", Layer::Relu);
    g.push(
        "pool",
        Layer::Pool2d {
            kind: PoolKind::Max,
            window: Window2d::square(2, 2, 0),
            ceil_mode: false,
            count_include_pad: true,
        },
    );
    let seed = 42u64;

    // Independent reference over the same deterministic param streams.
    let shared = std::sync::Arc::new(g.clone());
    let mut params = ParamStore::new(shared, seed);
    let input = HostTensor::from_seed(
        g.input_shape().clone(),
        brainslug::rng::tensor_seed(seed, "input"),
        brainslug::rng::ParamKind::Activation,
    );
    let mut want = conv3x3_ref(&input, &params.raw(1, "weight"), &params.raw(1, "bias"));
    want = relu_ref(&want);
    want = conv3x3_ref(&want, &params.raw(3, "weight"), &params.raw(3, "bias"));
    want = relu_ref(&want);
    want = maxpool2x2_ref(&want);

    let mut eng = cpu_engine(g.clone(), seed, 2);
    let eng_input = eng.synthetic_input();
    assert_eq!(eng_input, input, "engine input drifts from the rng stream");
    let (base, _) = eng.run_baseline(input.clone()).unwrap();
    let (df, _) = eng.run(input.clone()).unwrap();
    assert_eq!(base, df, "native schedules must agree bitwise here");
    assert_eq!(base.shape, want.shape);
    assert!(
        base.allclose(&want, 1e-4, 1e-4),
        "native backend diverges from the reference: max |diff| = {:.3e}",
        base.max_abs_diff(&want)
    );
    // Determinism: a fresh engine reproduces the outputs bit-for-bit.
    let mut eng2 = cpu_engine(g, seed, 1);
    let (df2, _) = eng2.run(input).unwrap();
    assert_eq!(df, df2, "cpu backend is not deterministic across instances");
}

#[test]
fn cache_sim_df_never_worse_across_random_configs() {
    use brainslug::memsim::compare_schedules;
    for seed in 0..60 {
        let mut st = seed;
        let elems = 256 << rand_in(&mut st, 0, 6);
        let depth = rand_in(&mut st, 1, 8);
        let band = 64 << rand_in(&mut st, 0, 3);
        let cache = 1024 << rand_in(&mut st, 0, 6);
        let (bf, df) = compare_schedules(elems, depth, band, cache);
        assert!(
            df <= bf,
            "seed {seed}: df {df} > bf {bf} (elems {elems} depth {depth} band {band} cache {cache})"
        );
    }
}

#[test]
fn static_checker_passes_every_random_valid_dag() {
    use brainslug::analysis::{self, Severity};
    for seed in 0..150 {
        let g = random_chain(seed);
        let device = random_device(seed);
        let opts = CollapseOptions::default();
        let plan = optimize(&g, &device, &opts);
        let mut diags = analysis::lint_graph(&g);
        diags.extend(analysis::verify_plan(&g, &plan, &device, &opts));
        let errors: Vec<_> = diags
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .collect();
        assert!(errors.is_empty(), "seed {seed}: {errors:?}");
    }
    for seed in 0..100 {
        let (g, _) = random_branchy(seed);
        let device = random_device(seed);
        let opts = CollapseOptions::default();
        let plan = optimize(&g, &device, &opts);
        let mut diags = analysis::lint_graph(&g);
        diags.extend(analysis::verify_plan(&g, &plan, &device, &opts));
        let errors: Vec<_> = diags
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .collect();
        assert!(errors.is_empty(), "branchy seed {seed}: {errors:?}");
    }
}
