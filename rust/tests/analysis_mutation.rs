//! Mutation tests for the static verifier: build *valid* graphs and
//! plans, seed a specific corruption, and assert the verifier rejects
//! it with the expected stable `BSL0xx` code. Each test is one
//! corruption class; together they pin the verifier's contract (a
//! refactor that silently stops catching one of these fails here, not
//! in production).

use brainslug::analysis::{self, DiagCode, Severity};
use brainslug::device::DeviceSpec;
use brainslug::graph::{Graph, Layer, PoolKind, Shape, Window2d};
use brainslug::optimizer::{optimize, CollapseOptions, Plan, Segment};

fn pool3() -> Layer {
    Layer::Pool2d {
        kind: PoolKind::Max,
        window: Window2d::square(3, 1, 1),
        ceil_mode: false,
        count_include_pad: true,
    }
}

/// conv → bn → relu → pool: plans as [Single(conv), Stack(bn,relu,pool)].
fn conv_chain() -> Graph {
    let mut g = Graph::new("conv_chain", Shape::nchw(1, 8, 32, 32));
    g.push(
        "conv",
        Layer::Conv2d {
            out_channels: 8,
            window: Window2d::square(3, 1, 1),
            bias: true,
        },
    );
    g.push("bn", Layer::BatchNorm2d { eps: 1e-5 });
    g.push("relu", Layer::Relu);
    g.push("pool", pool3());
    g
}

/// 4 shape-preserving pools at c=32, h=224 — on a 4 KiB budget the
/// packer must split them into several sequences (mirrors the
/// `memory_budget_splits_sequences` collapse test).
fn pool_tower() -> (Graph, DeviceSpec) {
    let mut g = Graph::new("pool_tower", Shape::nchw(1, 32, 224, 224));
    for i in 0..4 {
        g.push(format!("p{i}"), pool3());
    }
    let dev = DeviceSpec {
        fast_mem_bytes: 4 * 1024,
        ..DeviceSpec::paper_gpu()
    };
    (g, dev)
}

/// input → bn(entry) → [pool, pool | identity] → add → relu.
/// On paper_cpu the 128×128 entry plane's skip reservation floors the
/// arm budget to 2 KiB, which forces the two arm pools into separate
/// single-step sequences.
fn residual_pools() -> Graph {
    let mut g = Graph::new("residual_pools", Shape::nchw(1, 8, 128, 128));
    let entry = g.push("bn_in", Layer::BatchNorm2d { eps: 1e-5 });
    let p1 = g.add("p1", pool3(), &[entry]);
    let p2 = g.add("p2", pool3(), &[p1]);
    g.add("add", Layer::Add, &[p2, entry]);
    g.push("relu_out", Layer::Relu);
    g
}

fn default_plan(g: &Graph, dev: &DeviceSpec) -> Plan {
    let plan = optimize(g, dev, &CollapseOptions::default());
    // Sanity: the uncorrupted plan must verify clean — otherwise the
    // corruption assertions below prove nothing.
    let diags = analysis::verify_plan(g, &plan, dev, &CollapseOptions::default());
    assert!(
        diags.iter().all(|d| d.severity != Severity::Error),
        "valid plan produced errors: {diags:?}"
    );
    plan
}

fn codes(diags: &[analysis::Diagnostic]) -> Vec<DiagCode> {
    diags.iter().map(|d| d.code).collect()
}

// ---------------------------------------------------------------- plan

#[test]
fn bsl020_deleted_segment_breaks_coverage() {
    let g = conv_chain();
    let dev = DeviceSpec::paper_cpu();
    let mut plan = default_plan(&g, &dev);
    let removed = plan.segments.remove(0);
    assert!(matches!(removed, Segment::Single(_)), "{removed:?}");
    let diags = analysis::verify_structure(&g, &plan);
    assert!(codes(&diags).contains(&DiagCode::PlanCoverage), "{diags:?}");
}

#[test]
fn bsl020_duplicated_segment_is_double_coverage() {
    let g = conv_chain();
    let dev = DeviceSpec::paper_cpu();
    let mut plan = default_plan(&g, &dev);
    let dup = plan.segments[0].clone();
    plan.segments.push(dup);
    let diags = analysis::verify_structure(&g, &plan);
    assert!(codes(&diags).contains(&DiagCode::PlanCoverage), "{diags:?}");
}

#[test]
fn bsl021_swapped_stack_nodes_break_the_chain() {
    let g = conv_chain();
    let dev = DeviceSpec::paper_cpu();
    let mut plan = default_plan(&g, &dev);
    let mut swapped = false;
    for seg in &mut plan.segments {
        if let Segment::Stack(st) = seg {
            if st.nodes.len() >= 2 {
                st.nodes.swap(0, 1);
                swapped = true;
            }
        }
    }
    assert!(swapped, "expected a multi-node stack");
    let diags = analysis::verify_structure(&g, &plan);
    assert!(
        codes(&diags).contains(&DiagCode::StackChainBroken),
        "{diags:?}"
    );
}

#[test]
fn bsl022_join_retarget_is_malformed_branch() {
    let g = residual_pools();
    let dev = DeviceSpec::paper_cpu();
    let mut plan = default_plan(&g, &dev);
    let mut hit = false;
    for seg in &mut plan.segments {
        if let Segment::Branch { join, .. } = seg {
            *join -= 1; // now points at a pool, not the add
            hit = true;
        }
    }
    assert!(hit, "expected a branch segment");
    let diags = analysis::verify_structure(&g, &plan);
    assert!(
        codes(&diags).contains(&DiagCode::BranchJoinMalformed),
        "{diags:?}"
    );
}

#[test]
fn bsl023_truncated_arm_misses_join_input() {
    let g = residual_pools();
    let dev = DeviceSpec::paper_cpu();
    let mut plan = default_plan(&g, &dev);
    let mut hit = false;
    for seg in &mut plan.segments {
        if let Segment::Branch { arms, .. } = seg {
            for arm in arms.iter_mut() {
                if !arm.is_empty() {
                    arm.pop();
                    hit = true;
                    break;
                }
            }
        }
    }
    assert!(hit, "expected a non-empty branch arm");
    let diags = analysis::verify_structure(&g, &plan);
    assert!(
        codes(&diags).contains(&DiagCode::BranchArmMismatch),
        "{diags:?}"
    );
}

#[test]
fn bsl024_merged_sequences_overrun_the_budget() {
    let (g, dev) = pool_tower();
    let mut plan = default_plan(&g, &dev);
    let mut merged = false;
    for seg in &mut plan.segments {
        if let Segment::Stack(st) = seg {
            assert!(
                st.sequences.len() > 1,
                "4 KiB budget must split the tower; got {} sequence(s)",
                st.sequences.len()
            );
            // Undo the packer's split: cram every step into the first
            // sequence, as if the budget accounting had been skipped.
            let mut seqs = std::mem::take(&mut st.sequences);
            let mut first = seqs.remove(0);
            for s in seqs {
                first.steps.extend(s.steps);
            }
            st.sequences = vec![first];
            merged = true;
        }
    }
    assert!(merged);
    let diags = analysis::verify_resources(&g, &plan, &dev, &CollapseOptions::default());
    assert!(codes(&diags).contains(&DiagCode::BudgetOverrun), "{diags:?}");
}

#[test]
fn bsl025_zero_tile_rows_is_halo_underflow() {
    let g = conv_chain();
    let dev = DeviceSpec::paper_cpu();
    let mut plan = default_plan(&g, &dev);
    for seg in &mut plan.segments {
        if let Segment::Stack(st) = seg {
            st.sequences[0].tile_rows = 0;
        }
    }
    let diags = analysis::verify_resources(&g, &plan, &dev, &CollapseOptions::default());
    assert!(
        codes(&diags).contains(&DiagCode::HaloUnderflow),
        "{diags:?}"
    );
}

#[test]
fn bsl026_merged_arm_sequences_break_the_skip_reservation() {
    let g = residual_pools();
    let dev = DeviceSpec::paper_cpu();
    let mut plan = default_plan(&g, &dev);
    let mut merged = false;
    for seg in &mut plan.segments {
        if let Segment::Branch { arms, .. } = seg {
            for arm in arms.iter_mut() {
                for arm_seg in arm.iter_mut() {
                    if let Segment::Stack(st) = arm_seg {
                        if st.sequences.len() > 1 {
                            let mut seqs = std::mem::take(&mut st.sequences);
                            let mut first = seqs.remove(0);
                            for s in seqs {
                                first.steps.extend(s.steps);
                            }
                            st.sequences = vec![first];
                            merged = true;
                        }
                    }
                }
            }
        }
    }
    assert!(
        merged,
        "expected the skip reservation to split the arm pools into >1 sequences"
    );
    let diags = analysis::verify_resources(&g, &plan, &dev, &CollapseOptions::default());
    assert!(
        codes(&diags).contains(&DiagCode::SkipReservationBroken),
        "{diags:?}"
    );
}

#[test]
fn bsl027_swapped_steps_break_the_band_shape_chain() {
    let (g, dev) = pool_tower();
    let mut plan = default_plan(&g, &dev);
    let mut hit = false;
    for seg in &mut plan.segments {
        if let Segment::Stack(st) = seg {
            if st.sequences.len() >= 2 {
                // Swap whole sequences: ops order no longer matches the
                // stack's node list (an undersized/mis-sized band
                // buffer at run time).
                st.sequences.swap(0, 1);
                hit = true;
            }
        }
    }
    assert!(hit);
    let diags = analysis::verify_structure(&g, &plan);
    assert!(
        codes(&diags).contains(&DiagCode::BandShapeChain),
        "{diags:?}"
    );
}

#[test]
fn bsl028_unfusable_node_in_stack_has_no_fallback() {
    let g = conv_chain();
    let dev = DeviceSpec::paper_cpu();
    let mut plan = default_plan(&g, &dev);
    // Pretend the conv was fused into the stack: conv has no
    // depth-first kernel, so the stack would have no way to execute it.
    let conv_id = 1;
    let mut hit = false;
    plan.segments.retain(|s| !matches!(s, Segment::Single(id) if *id == conv_id));
    for seg in &mut plan.segments {
        if let Segment::Stack(st) = seg {
            st.nodes.insert(0, conv_id);
            hit = true;
        }
    }
    assert!(hit);
    let diags = analysis::verify_structure(&g, &plan);
    assert!(codes(&diags).contains(&DiagCode::NoFallback), "{diags:?}");
}

#[test]
fn bsl029_oversized_tile_rows_is_a_warning() {
    let g = conv_chain();
    let dev = DeviceSpec::paper_cpu();
    let mut plan = default_plan(&g, &dev);
    for seg in &mut plan.segments {
        if let Segment::Stack(st) = seg {
            let out_h = st.sequences[0].out_shape().height();
            st.sequences[0].tile_rows = out_h + 5;
        }
    }
    let diags = analysis::verify_resources(&g, &plan, &dev, &CollapseOptions::default());
    let d = diags
        .iter()
        .find(|d| d.code == DiagCode::TileRowsExceedHeight)
        .unwrap_or_else(|| panic!("no BSL029 in {diags:?}"));
    assert_eq!(d.severity, Severity::Warning);
}

// --------------------------------------------------------------- graph

#[test]
fn bsl008_stored_shape_drift_is_caught() {
    let mut g = conv_chain();
    g.nodes[2].shape = Shape::nchw(1, 8, 7, 7);
    let diags = analysis::lint_graph(&g);
    assert!(
        codes(&diags).contains(&DiagCode::StoredShapeMismatch),
        "{diags:?}"
    );
}

#[test]
fn bsl003_forward_edge_is_rejected() {
    let mut g = conv_chain();
    g.nodes[2].inputs = vec![3]; // bn now "consumes" relu: a cycle
    let diags = analysis::lint_graph(&g);
    assert!(
        codes(&diags).contains(&DiagCode::NonTopologicalEdge),
        "{diags:?}"
    );
}

#[test]
fn bsl010_out_of_range_output_is_rejected() {
    let mut g = conv_chain();
    g.output = 999;
    let diags = analysis::lint_graph(&g);
    assert!(codes(&diags).contains(&DiagCode::BadOutput), "{diags:?}");
}

// ------------------------------------------------------------ topology

#[test]
fn bsl041_tokens_before_gate_close() {
    let mut t = brainslug::server::topology(4, 64);
    t.shutdown.swap(0, 1);
    let diags = analysis::check_topology(&t);
    assert!(
        codes(&diags).contains(&DiagCode::SendBeforeGateClose),
        "{diags:?}"
    );
}

#[test]
fn bsl042_dropped_join_leaks_workers() {
    let mut t = brainslug::server::topology(4, 64);
    t.shutdown.pop();
    let diags = analysis::check_topology(&t);
    assert!(
        codes(&diags).contains(&DiagCode::UnjoinedThread),
        "{diags:?}"
    );
}

#[test]
fn bsl044_conn_join_before_acceptor_join_can_block() {
    let mut t = brainslug::http::listener::topology(8, 64);
    // Join the conn pool before the acceptor: the conns channel never
    // disconnects (its sole sender is still alive), so the join blocks.
    let (a, c) = (1, 2);
    assert!(matches!(
        (&t.shutdown[a], &t.shutdown[c]),
        (
            analysis::ShutdownStep::Join(x),
            analysis::ShutdownStep::Join(y)
        ) if x == "acceptor" && y == "conn"
    ));
    t.shutdown.swap(a, c);
    let diags = analysis::check_topology(&t);
    assert!(
        codes(&diags).contains(&DiagCode::JoinWithoutTermination),
        "{diags:?}"
    );
}

// ------------------------------------------------------ whole pipeline

#[test]
fn shipped_zoo_and_topologies_pass_with_deny_warnings() {
    use brainslug::zoo;
    let dev = DeviceSpec::paper_cpu();
    let opts = CollapseOptions::default();
    let mut report = analysis::Report::new();
    for name in zoo::ALL_NETWORKS {
        let g = zoo::build(name, zoo::paper_config(name, 1));
        report.extend(analysis::lint_graph(&g));
        let plan = optimize(&g, &dev, &opts);
        report.extend(analysis::verify_plan(&g, &plan, &dev, &opts));
    }
    for t in analysis::standard_topologies() {
        report.extend(analysis::check_topology(&t));
    }
    assert!(
        report.is_clean(true),
        "shipped zoo must pass --deny warnings: {}",
        report.render_text()
    );
}
