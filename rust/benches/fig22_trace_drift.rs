//! Figure 22: the cost of looking — tracing overhead and the
//! predicted-vs-measured drift report, on the native CPU backend.
//!
//! For vgg16 / resnet18 / densenet121 at reduced scale the depth-first
//! schedule runs three ways: with no [`brainslug::obs::Obs`] attached
//! (the default — the hot path must not pay for observability it never
//! asked for), with a recorder armed and a fresh trace id per run, and
//! then untraced again on the same engine to show arming a *different*
//! engine left no residue. Outputs are asserted `allclose` between the
//! untraced and traced engines before any timing — spans must never
//! perturb numerics.
//!
//! The armed run's segment spans then feed
//! [`brainslug::obs::drift_report`] against
//! [`brainslug::memsim::predicted_segments`] for the same graph /
//! plan / device: every top-level segment of every network must match a
//! measured span (`unmatched == 0`), and the Spearman rank correlation
//! between the analytic model and reality is reported per network.
//!
//! Acceptance: traced wall-clock within 3% of untraced (plus a small
//! absolute noise floor), the untraced re-measurement within 1% of the
//! first, zero dropped spans, and full drift coverage on all three
//! networks.

use std::sync::atomic::AtomicU64;
use std::sync::Arc;

use brainslug::bench::{self, fmt_pct, fmt_time, Table};
use brainslug::device::DeviceSpec;
use brainslug::engine::Engine;
use brainslug::json::Json;
use brainslug::memsim::predicted_segments;
use brainslug::obs::{self, Obs};

const NETS: [&str; 3] = ["vgg16", "resnet18", "densenet121"];
/// Timed iterations per leg (`bench::measure` keeps the minimum).
const RUNS: usize = 3;
/// Absolute slack added to every relative timing bound: min-of-3 on a
/// shared CI runner still jitters by a couple of scheduler quanta, and
/// a pure percentage bound would make sub-10ms rows flaky.
const SLACK_S: f64 = 0.002;

fn engine_for(name: &str, obs: Option<Arc<Obs>>) -> Engine {
    let mut b = Engine::builder()
        .zoo_small(name, 1)
        .device(DeviceSpec::host_cpu())
        .brainslug(Default::default())
        .cpu(1)
        .no_profile()
        .seed(bench::oracle_seed());
    if let Some(o) = obs {
        b = b.obs(o);
    }
    b.build().unwrap()
}

fn main() {
    println!("# Figure 22 — tracing overhead & memsim drift, native CPU backend");
    println!("reduced scale (64^2, quarter width), batch 1, single thread, min of {RUNS} runs\n");
    let mut table = Table::new(&[
        "network",
        "untraced",
        "traced",
        "overhead",
        "segments",
        "rank-corr",
    ]);
    let mut rows = Vec::new();
    for &name in &NETS {
        let mut eng_off = engine_for(name, None);
        let input = eng_off.synthetic_input();
        let obs = Arc::new(Obs::default());
        let mut eng_on = engine_for(name, Some(obs.clone()));
        let ids = AtomicU64::new(0xF16_2200);

        // Parity first: an armed recorder must not change a single
        // output value.
        let (out_off, _) = eng_off.run(input.clone()).unwrap();
        let (out_on, _) = eng_on
            .run_traced(input.clone(), obs::next_trace_id(&ids))
            .unwrap();
        assert!(
            out_off.allclose(&out_on, 1e-6, 1e-6),
            "{name}: tracing perturbed the output, max |diff| = {:.3e}",
            out_off.max_abs_diff(&out_on)
        );

        let t_off = bench::measure(1, RUNS, || {
            eng_off.run(input.clone()).unwrap();
        });
        let t_on = bench::measure(1, RUNS, || {
            eng_on
                .run_traced(input.clone(), obs::next_trace_id(&ids))
                .unwrap();
        });
        // Same untraced engine again: arming a *different* engine's
        // recorder must leave this one's hot path untouched.
        let t_off2 = bench::measure(1, RUNS, || {
            eng_off.run(input.clone()).unwrap();
        });

        let overhead = (t_on / t_off - 1.0) * 100.0;
        assert!(
            t_on <= t_off * 1.03 + SLACK_S,
            "{name}: traced run {} vs untraced {} exceeds the 3% overhead budget",
            fmt_time(t_on),
            fmt_time(t_off)
        );
        assert!(
            (t_off2 - t_off).abs() <= t_off * 0.01 + SLACK_S,
            "{name}: untraced re-measurement drifted: {} vs {}",
            fmt_time(t_off2),
            fmt_time(t_off)
        );

        let spans = obs.spans.drain();
        assert_eq!(obs.spans.dropped(), 0, "{name}: recorder dropped spans");
        let plan = eng_on.plan().expect("brainslug mode always has a plan");
        let predicted = predicted_segments(eng_on.graph(), plan, eng_on.device());
        let report = obs::drift_report(name, &predicted, &spans);
        assert!(!report.rows.is_empty(), "{name}: empty drift report");
        assert_eq!(
            report.unmatched, 0,
            "{name}: {} predicted segment(s) never measured:\n{}",
            report.unmatched,
            report.to_json().to_string_pretty()
        );
        for row in &report.rows {
            assert!(
                row.measured_s > 0.0 && row.ratio.is_finite() && row.ratio > 0.0,
                "{name} {}: degenerate drift row (measured {} ratio {})",
                row.segment,
                row.measured_s,
                row.ratio
            );
        }
        assert!(
            (-1.0..=1.0).contains(&report.rank_correlation),
            "{name}: rank correlation {} out of range",
            report.rank_correlation
        );

        table.row(vec![
            name.to_string(),
            fmt_time(t_off),
            fmt_time(t_on),
            fmt_pct(overhead),
            report.rows.len().to_string(),
            format!("{:+.2}", report.rank_correlation),
        ]);
        let mut row = Json::object();
        row.set("bench", Json::Str("fig22_trace_drift".into()));
        row.set("net", Json::Str(name.into()));
        row.set("backend", Json::Str("cpu".into()));
        row.set("untraced_s", Json::Num(t_off));
        row.set("traced_s", Json::Num(t_on));
        row.set("retrace_untraced_s", Json::Num(t_off2));
        row.set("overhead_pct", Json::Num(overhead));
        row.set("spans", Json::from_usize(spans.len()));
        row.set("segments", Json::from_usize(report.rows.len()));
        row.set("unmatched", Json::from_usize(report.unmatched));
        row.set("rank_correlation", Json::Num(report.rank_correlation));
        rows.push(row);
    }
    table.print();
    println!(
        "\nall {} networks: traced within 3% of untraced, full segment coverage \
         in the drift report",
        NETS.len()
    );
    bench::emit_bench_json("fig22_trace_drift", rows);
}
