//! Figure 18 (repro extension): HTTP serving tail latency — the
//! experiment behind `brainslug bench-serve`.
//!
//! Unlike Figure 16 (in-process `ServerHandle::infer` calls), every
//! request here crosses a real socket: HTTP/1.1 keep-alive framing,
//! lazy JSON body parsing, the bounded connection pool, the dispatch
//! queue, and the reply serialisation all sit on the measured path.
//!
//! Two load shapes per worker count:
//! * **closed loop** (Block policy) — fixed client concurrency, every
//!   request eventually served; queue wait surfaces in p95/p99.
//! * **open loop** (Reject policy) — paced arrivals at ~1.5x the
//!   pool's estimated capacity; the server must shed the excess as
//!   503 + Retry-After, and latency is measured from each request's
//!   *scheduled* arrival (no coordinated omission).
//!
//! Expected shape: closed-loop p50 stays near the batch cost while p99
//! grows with concurrency; the overload point reports a non-zero
//! reject rate at every pool size (offered load is scaled with the
//! pool, so it is always ~1.5x capacity).
//!
//! Each closed-loop point also scrapes the server's own `GET
//! /v1/stats` p50 — a histogram-midpoint estimate, within 12.5% of the
//! true sample by construction (DESIGN.md §Observability) — and
//! asserts it agrees with the client's raw-sample p50 up to that error
//! plus queue-exit skew, tying the two latency provenances together.

use std::time::Duration;

use brainslug::bench::{self, Table};
use brainslug::http::{self, HttpConfig, HttpServer};
use brainslug::json::{self, Json};
use brainslug::rng::fill_f32;
use brainslug::server::{QueuePolicy, ServerConfig};

/// Compiled batch size of every served engine.
const BATCH: usize = 8;
/// Wall-clock cost of one batch after pacing calibration.
const TARGET_BATCH_S: f64 = 8e-3;
const WORKER_COUNTS: [usize; 3] = [1, 2, 4];
const CONCURRENCIES: [usize; 3] = [1, 4, 16];
const REQS_PER_CLIENT: usize = 4;
/// Open-loop overload: offered load vs estimated capacity, duration.
const OVERLOAD_FACTOR: f64 = 1.5;
const OVERLOAD_DURATION_S: f64 = 0.4;
const OVERLOAD_POOL: usize = 16;

fn start_http(scale: f64, workers: usize, policy: QueuePolicy, depth: usize) -> HttpServer {
    let server = ServerConfig::new(bench::serving_engine(BATCH, scale))
        .workers(workers)
        .queue_depth(depth)
        .queue_policy(policy)
        .max_wait(Duration::from_millis(2))
        .start()
        .expect("server start");
    let mut cfg = HttpConfig::new("127.0.0.1:0");
    // Enough connection threads that the dispatch queue — not the
    // connection pool — is the bottleneck under every load point.
    cfg.conn_threads = CONCURRENCIES.iter().max().copied().unwrap().max(OVERLOAD_POOL) + 4;
    HttpServer::start(server, cfg).expect("http start")
}

fn main() -> anyhow::Result<()> {
    // Calibrate pacing against the unpaced model time (fig16 scheme).
    let mut probe = bench::serving_engine(BATCH, 0.0).build()?;
    let input = probe.synthetic_input();
    let (_, stats) = probe.run(input)?;
    let scale = TARGET_BATCH_S / stats.total_s.max(1e-12);

    println!("# Figure 18 — HTTP serving tail latency (paced sim over real sockets)");
    println!(
        "batch={BATCH} batch-cost={:.0}ms reqs/client={REQS_PER_CLIENT} overload={OVERLOAD_FACTOR}x capacity",
        TARGET_BATCH_S * 1e3
    );
    let mut table = Table::new(&[
        "mode", "workers", "load", "sent", "ok", "rejected", "req/s", "p50-ms", "p95-ms",
        "p99-ms",
    ]);
    let mut rows = Vec::new();
    for &workers in &WORKER_COUNTS {
        for &clients in &CONCURRENCIES {
            let http = start_http(scale, workers, QueuePolicy::Block, 4 * BATCH);
            let state = http.state().clone();
            let body = run_body(&state.model, state.image_elems);
            let report = http::closed_loop(
                &http.addr().to_string(),
                clients,
                REQS_PER_CLIENT,
                body.as_bytes(),
            );
            let stats_resp = http::one_shot(&http.addr().to_string(), "GET", "/v1/stats", None)
                .expect("stats scrape");
            let parsed = json::parse(std::str::from_utf8(&stats_resp.body).unwrap()).unwrap();
            http.shutdown();
            assert_eq!(
                report.ok, report.sent,
                "closed loop w={workers} c={clients}: {} errors, {} rejected",
                report.errors, report.rejected
            );
            assert!(
                report.p99_ms() >= report.p50_ms(),
                "percentiles out of order"
            );
            assert_eq!(
                parsed.str_field("percentile_source").unwrap(),
                "histogram-midpoint"
            );
            let server_p50 = parsed.f64_field("p50_ms").unwrap();
            let band = server_p50 * brainslug::obs::MIDPOINT_REL_ERROR + 3.0;
            assert!(
                (report.p50_ms() - server_p50).abs() <= band,
                "w={workers} c={clients}: client p50 {:.3} ms vs server p50 \
                 {server_p50:.3} ms (band {band:.3} ms)",
                report.p50_ms()
            );
            table.row(vec![
                "closed".into(),
                workers.to_string(),
                format!("c={clients}"),
                report.sent.to_string(),
                report.ok.to_string(),
                report.rejected.to_string(),
                format!("{:.0}", report.throughput_rps()),
                format!("{:.2}", report.p50_ms()),
                format!("{:.2}", report.p95_ms()),
                format!("{:.2}", report.p99_ms()),
            ]);
            let mut row = base_row("closed", workers, &report);
            row.set("concurrency", Json::from_usize(clients));
            row.set("server_p50_ms", Json::Num(server_p50));
            rows.push(row);
        }

        let capacity_rps = workers as f64 * BATCH as f64 / TARGET_BATCH_S;
        let rate_rps = OVERLOAD_FACTOR * capacity_rps;
        let http = start_http(scale, workers, QueuePolicy::Reject, BATCH);
        let state = http.state().clone();
        let body = run_body(&state.model, state.image_elems);
        let report = http::open_loop(
            &http.addr().to_string(),
            rate_rps,
            OVERLOAD_DURATION_S,
            OVERLOAD_POOL,
            body.as_bytes(),
        );
        // The shed must be visible both to the client (503s) and in
        // the server's own counters.
        let rejected_stat = state
            .stats
            .rejected
            .load(std::sync::atomic::Ordering::Relaxed);
        http.shutdown();
        assert!(
            report.rejected > 0 && rejected_stat > 0,
            "overload w={workers} at {rate_rps:.0}/s shed nothing (ok={} errors={})",
            report.ok,
            report.errors
        );
        table.row(vec![
            "open".into(),
            workers.to_string(),
            format!("{rate_rps:.0}/s"),
            report.sent.to_string(),
            report.ok.to_string(),
            report.rejected.to_string(),
            format!("{:.0}", report.throughput_rps()),
            format!("{:.2}", report.p50_ms()),
            format!("{:.2}", report.p95_ms()),
            format!("{:.2}", report.p99_ms()),
        ]);
        let mut row = base_row("open", workers, &report);
        row.set("rate_rps", Json::Num(rate_rps));
        row.set("pool", Json::from_usize(OVERLOAD_POOL));
        rows.push(row);
    }
    table.print();
    bench::emit_bench_json("fig18_http_serving", rows);
    Ok(())
}

fn run_body(model: &str, elems: usize) -> String {
    let mut o = Json::object();
    o.set("model", Json::Str(model.to_string()));
    o.set(
        "input",
        Json::Arr(
            fill_f32(18, elems)
                .into_iter()
                .map(|v| Json::Num(v as f64))
                .collect(),
        ),
    );
    o.to_string_compact()
}

fn base_row(mode: &str, workers: usize, report: &http::LoadReport) -> Json {
    let mut row = Json::object();
    row.set("bench", Json::Str("fig18_http_serving".into()));
    row.set("mode", Json::Str(mode.into()));
    row.set("workers", Json::from_usize(workers));
    row.set("batch", Json::from_usize(BATCH));
    row.set("sent", Json::Num(report.sent as f64));
    row.set("ok", Json::Num(report.ok as f64));
    row.set("rejected", Json::Num(report.rejected as f64));
    row.set("reject_rate", Json::Num(report.reject_rate()));
    row.set("throughput_rps", Json::Num(report.throughput_rps()));
    row.set("mean_ms", Json::Num(report.mean_ms()));
    row.set("p50_ms", Json::Num(report.p50_ms()));
    row.set("p95_ms", Json::Num(report.p95_ms()));
    row.set("p99_ms", Json::Num(report.p99_ms()));
    row
}
