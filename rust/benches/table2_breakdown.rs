//! Table 2: per-network breakdown at batch 128 — layer counts, how many
//! BrainSlug optimizes, stack counts, optimizable-layer speed-up, the
//! optimizable fraction of total time, and total speed-up.
//!
//! The structural columns (layers/opt/stacks) come straight from the
//! optimizer; the timing columns from the memsim model on both paper
//! devices. A measured section reports the same breakdown from actual
//! per-segment wall-clock on the PJRT runtime.

use brainslug::bench::{self, fmt_pct, Table};
use brainslug::device::DeviceSpec;
use brainslug::memsim::{simulate_baseline, simulate_plan, speedup_pct};
use brainslug::optimizer::{optimize, CollapseOptions};
use brainslug::runtime::Runtime;
use brainslug::scheduler::Executor;
use brainslug::zoo;

fn simulated(device: &DeviceSpec) {
    println!("\n## Table 2 — device={}, batch=128 (simulated)", device.name);
    let mut table = Table::new(&[
        "network",
        "layers",
        "opt",
        "stacks",
        "uniq",
        "opt-speedup",
        "%-of-time",
        "total-speedup",
    ]);
    for name in zoo::ALL_NETWORKS {
        let g = zoo::build(name, zoo::paper_config(name, 128));
        let plan = optimize(&g, device, &CollapseOptions::default());
        let base = simulate_baseline(&g, device);
        let bs = simulate_plan(&g, &plan, device);
        table.row(vec![
            name.to_string(),
            g.num_layers().to_string(),
            plan.num_optimized_layers().to_string(),
            plan.num_stacks().to_string(),
            plan.num_unique_stacks().to_string(),
            fmt_pct(speedup_pct(base.optimizable_s, bs.stack_s)),
            format!("{:.1}", base.optimizable_s / base.total_s * 100.0),
            fmt_pct(speedup_pct(base.total_s, bs.total_s)),
        ]);
    }
    table.print();
}

fn measured() {
    let Ok(runtime) = Runtime::new(std::path::Path::new(bench::ARTIFACT_DIR)) else {
        println!("\n(measured section skipped: run `make artifacts`)");
        return;
    };
    let batch = *bench::measured_batches().last().unwrap();
    println!("\n## Table 2 (measured, XLA-CPU, reduced scale, batch={batch})");
    let device = bench::measured_device();
    let mut table = Table::new(&[
        "network", "layers", "opt", "stacks", "opt-speedup", "%-of-time", "total-speedup",
    ]);
    for &name in bench::measured_networks() {
        let g = zoo::build(name, zoo::small_config(name, batch));
        let plan = optimize(&g, &device, &bench::measured_opts());
        let mut exec = Executor::new(&runtime, &g, bench::oracle_seed());
        let input = exec.synthetic_input();
        // Warm, then take per-segment stats from the best run.
        exec.run_baseline(input.clone()).unwrap();
        exec.run_plan(&plan, input.clone()).unwrap();
        let (_, base) = exec.run_baseline(input.clone()).unwrap();
        let (_, bs) = exec.run_plan(&plan, input.clone()).unwrap();
        table.row(vec![
            name.to_string(),
            g.num_layers().to_string(),
            plan.num_optimized_layers().to_string(),
            plan.num_stacks().to_string(),
            fmt_pct(speedup_pct(base.optimizable_s(), bs.optimizable_s())),
            format!("{:.1}", base.optimizable_s() / base.total_s * 100.0),
            fmt_pct(speedup_pct(base.total_s, bs.total_s)),
        ]);
    }
    table.print();
}

fn main() {
    println!("# Table 2 — Detailed Performance Analysis");
    simulated(&DeviceSpec::paper_cpu());
    simulated(&DeviceSpec::paper_gpu());
    measured();
}
