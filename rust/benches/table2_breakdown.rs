//! Table 2: per-network breakdown at batch 128 — layer counts, how many
//! BrainSlug optimizes, stack counts, optimizable-layer speed-up, the
//! optimizable fraction of total time, and total speed-up.
//!
//! The structural columns (layers/opt/stacks) come straight from the
//! engine's validated plan; the timing columns from the memsim model on
//! both paper devices. A measured section reports the same breakdown
//! from actual per-segment wall-clock on the PJRT backend.

use brainslug::bench::{self, fmt_pct, Table};
use brainslug::device::DeviceSpec;
use brainslug::json::Json;
use brainslug::memsim::{baseline_optimized_time, speedup_pct};
use brainslug::zoo;

fn simulated(device: &DeviceSpec, rows: &mut Vec<Json>) {
    println!("\n## Table 2 — device={}, batch=128 (simulated)", device.name);
    let mut table = Table::new(&[
        "network",
        "layers",
        "opt",
        "stacks",
        "uniq",
        "opt-speedup",
        "%-of-time",
        "total-speedup",
    ]);
    for name in zoo::ALL_NETWORKS {
        let engine = bench::paper_engine(name, 128, device).build().unwrap();
        let plan = engine.plan().unwrap();
        let base = engine.simulate_baseline();
        let bs = engine.simulate_plan().unwrap();
        // Like-for-like optimized-portion comparison: `stack_s` includes
        // fused branch joins, so its baseline side must too.
        let opt_base_s = baseline_optimized_time(engine.graph(), plan, engine.device());
        table.row(vec![
            name.to_string(),
            engine.graph().num_layers().to_string(),
            plan.num_optimized_layers().to_string(),
            plan.num_stacks().to_string(),
            plan.num_unique_stacks().to_string(),
            fmt_pct(speedup_pct(opt_base_s, bs.stack_s)),
            format!("{:.1}", opt_base_s / base.total_s * 100.0),
            fmt_pct(speedup_pct(base.total_s, bs.total_s)),
        ]);
        let mut row = Json::object();
        row.set("bench", Json::Str("table2_breakdown".into()));
        row.set("device", Json::Str(device.name.clone()));
        row.set("net", Json::Str((*name).into()));
        row.set("layers", Json::from_usize(engine.graph().num_layers()));
        row.set("opt_layers", Json::from_usize(plan.num_optimized_layers()));
        row.set("stacks", Json::from_usize(plan.num_stacks()));
        row.set("unique_stacks", Json::from_usize(plan.num_unique_stacks()));
        row.set(
            "opt_speedup_pct",
            Json::Num(speedup_pct(opt_base_s, bs.stack_s)),
        );
        row.set("opt_time_pct", Json::Num(opt_base_s / base.total_s * 100.0));
        row.set(
            "total_speedup_pct",
            Json::Num(speedup_pct(base.total_s, bs.total_s)),
        );
        rows.push(row);
    }
    table.print();
}

fn measured() {
    let Some(runtime) = bench::measured_runtime() else {
        println!("\n(measured section skipped: run `make artifacts`)");
        return;
    };
    let batch = *bench::measured_batches().last().unwrap();
    println!("\n## Table 2 (measured, XLA-CPU, reduced scale, batch={batch})");
    let mut table = Table::new(&[
        "network", "layers", "opt", "stacks", "opt-speedup", "%-of-time", "total-speedup",
    ]);
    for &name in bench::measured_networks() {
        let mut engine =
            bench::build_measured(bench::measured_engine(name, batch), &runtime).unwrap();
        let input = engine.synthetic_input();
        // Warm, then take per-segment stats from the best run.
        engine.run_baseline(input.clone()).unwrap();
        engine.run(input.clone()).unwrap();
        let (_, base) = engine.run_baseline(input.clone()).unwrap();
        let (_, bs) = engine.run(input).unwrap();
        let plan = engine.plan().unwrap();
        table.row(vec![
            name.to_string(),
            engine.graph().num_layers().to_string(),
            plan.num_optimized_layers().to_string(),
            plan.num_stacks().to_string(),
            fmt_pct(speedup_pct(base.optimizable_s(), bs.optimizable_s())),
            format!("{:.1}", base.optimizable_s() / base.total_s * 100.0),
            fmt_pct(speedup_pct(base.total_s, bs.total_s)),
        ]);
    }
    table.print();
}

fn main() {
    println!("# Table 2 — Detailed Performance Analysis");
    let mut rows = Vec::new();
    simulated(&DeviceSpec::paper_cpu(), &mut rows);
    simulated(&DeviceSpec::paper_gpu(), &mut rows);
    measured();
    bench::emit_bench_json("table2_breakdown", rows);
}
