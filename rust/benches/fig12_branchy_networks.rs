//! Figure 12 (repro extension): branchy-network acceleration under
//! branch-aware depth-first planning.
//!
//! Chain-only planning (the paper's Listing 1) fragments ResNet,
//! DenseNet, and Inception into tiny stacks at every `Add`/`Concat`
//! junction — exactly the workloads Table 2 shows the least headroom on.
//! This bench sweeps the branchy zoo families baseline-vs-BrainSlug on
//! the paper device models (sim backend, batch 128) so the stacking
//! gain from `Segment::Branch` (arms depth-first, joins fused) is
//! measurable, and emits one machine-readable `BENCH {json}` row per
//! network for trend tracking.
//!
//! A parity section drives one engine per zoo family through both
//! execution modes on the sim backend and checks baseline output ==
//! BrainSlug output — the paper's transparency guarantee extended to
//! branch segments.

use brainslug::bench::{self, fmt_pct, fmt_time, Table};
use brainslug::device::DeviceSpec;
use brainslug::json::Json;
use brainslug::memsim::speedup_pct;

/// The branchy networks the branch-aware planner targets (plus their
/// deeper siblings, to show the effect scales with depth).
const BRANCHY: &[&str] = &[
    "resnet18",
    "resnet50",
    "densenet121",
    "densenet201",
    "inception_v3",
    "squeezenet1_1",
];

/// One representative per zoo family for the oracle-parity section.
const FAMILIES: &[&str] = &[
    "alexnet",
    "vgg16_bn",
    "resnet18",
    "densenet121",
    "inception_v3",
    "squeezenet1_1",
];

fn simulated(device: &DeviceSpec, rows: &mut Vec<Json>) {
    println!(
        "\n## Branchy networks — device={}, batch=128 (simulated)",
        device.name
    );
    let mut table = Table::new(&[
        "network", "layers", "opt", "branches", "baseline", "brainslug", "speedup",
    ]);
    for &name in BRANCHY {
        let engine = bench::paper_engine(name, 128, device).build().unwrap();
        let plan = engine.plan().expect("paper engines plan");
        let base = engine.simulate_baseline();
        let bs = engine.simulate_plan().unwrap();
        let speedup = speedup_pct(base.total_s, bs.total_s);
        table.row(vec![
            name.to_string(),
            engine.graph().num_layers().to_string(),
            plan.num_optimized_layers().to_string(),
            plan.num_branches().to_string(),
            fmt_time(base.total_s),
            fmt_time(bs.total_s),
            fmt_pct(speedup),
        ]);
        let mut row = Json::object();
        row.set("bench", Json::Str("fig12_branchy_networks".into()));
        row.set("device", Json::Str(device.name.clone()));
        row.set("net", Json::Str(name.into()));
        row.set("batch", Json::from_usize(128));
        row.set("layers", Json::from_usize(engine.graph().num_layers()));
        row.set("opt_layers", Json::from_usize(plan.num_optimized_layers()));
        row.set("branches", Json::from_usize(plan.num_branches()));
        row.set("stacks", Json::from_usize(plan.num_stacks()));
        row.set("baseline_s", Json::Num(base.total_s));
        row.set("brainslug_s", Json::Num(bs.total_s));
        row.set("speedup_pct", Json::Num(speedup));
        rows.push(row);
    }
    table.print();
}

fn oracle_parity() {
    println!("\n## Oracle parity (sim backend, both modes, one engine per family)");
    for &name in FAMILIES {
        let mut engine = bench::paper_engine(name, 1, &DeviceSpec::paper_gpu())
            .build()
            .unwrap();
        let input = engine.synthetic_input();
        let (out_base, _) = engine.run_baseline(input.clone()).unwrap();
        let (out_bs, stats) = engine.run(input).unwrap();
        assert_eq!(
            out_base, out_bs,
            "{name}: baseline and BrainSlug outputs diverge"
        );
        let joins = stats.segments.iter().filter(|s| s.kind == "join").count();
        println!(
            "  {name}: outputs identical, {} fused join(s), model time {}",
            joins,
            fmt_time(stats.total_s)
        );
    }
}

fn main() {
    println!("# Figure 12 (extension) — Branch-Aware Depth-First Planning");
    let mut rows = Vec::new();
    simulated(&DeviceSpec::paper_cpu(), &mut rows);
    simulated(&DeviceSpec::paper_gpu(), &mut rows);
    oracle_parity();
    bench::emit_bench_json("fig12_branchy_networks", rows);
}
