//! Figures 11–14: full-network execution time (11: CPU, 12: GPU) and
//! relative speed-up over the baseline (13: CPU, 14: GPU) for all 21
//! TorchVision networks at batch 128.
//!
//! Paper scale via the memsim time model; a measured wall-clock section
//! covers the reduced-scale subset on the PJRT runtime. Both sections go
//! through the `Engine` facade (`bench::paper_engine` /
//! `bench::measured_engine`).

use brainslug::bench::{self, fmt_pct, fmt_time, Table};
use brainslug::device::DeviceSpec;
use brainslug::json::Json;
use brainslug::memsim::speedup_pct;
use brainslug::zoo;

fn simulated(device: &DeviceSpec, rows: &mut Vec<Json>) {
    println!(
        "\n## Fig {} (times) + Fig {} (speedups) — device={}, batch=128 (simulated)",
        if device.name.contains("xeon") { 11 } else { 12 },
        if device.name.contains("xeon") { 13 } else { 14 },
        device.name
    );
    let mut table = Table::new(&["network", "baseline", "brainslug", "speedup"]);
    for name in zoo::ALL_NETWORKS {
        let engine = bench::paper_engine(name, 128, device).build().unwrap();
        let base = engine.simulate_baseline();
        let bs = engine.simulate_plan().unwrap();
        table.row(vec![
            name.to_string(),
            fmt_time(base.total_s),
            fmt_time(bs.total_s),
            fmt_pct(speedup_pct(base.total_s, bs.total_s)),
        ]);
        let mut row = Json::object();
        row.set("bench", Json::Str("fig11_full_networks".into()));
        row.set("device", Json::Str(device.name.clone()));
        row.set("net", Json::Str((*name).into()));
        row.set("batch", Json::from_usize(128));
        row.set("baseline_s", Json::Num(base.total_s));
        row.set("brainslug_s", Json::Num(bs.total_s));
        row.set(
            "speedup_pct",
            Json::Num(speedup_pct(base.total_s, bs.total_s)),
        );
        rows.push(row);
    }
    table.print();
}

fn measured() {
    let Some(runtime) = bench::measured_runtime() else {
        println!("\n(measured section skipped: run `make artifacts`)");
        return;
    };
    let batch = *bench::measured_batches().last().unwrap();
    println!("\n## Measured wall-clock (XLA-CPU, reduced scale, batch={batch})");
    let mut table = Table::new(&["network", "baseline", "brainslug", "speedup"]);
    for &name in bench::measured_networks() {
        let mut engine =
            bench::build_measured(bench::measured_engine(name, batch), &runtime).unwrap();
        let input = engine.synthetic_input();
        let t_base = bench::measure(2, 9, || {
            engine.run_baseline(input.clone()).unwrap();
        });
        let t_bs = bench::measure(2, 9, || {
            engine.run(input.clone()).unwrap();
        });
        table.row(vec![
            name.to_string(),
            fmt_time(t_base),
            fmt_time(t_bs),
            fmt_pct(speedup_pct(t_base, t_bs)),
        ]);
    }
    table.print();
}

fn main() {
    println!("# Figures 11-14 — Full Network Acceleration");
    let mut rows = Vec::new();
    simulated(&DeviceSpec::paper_cpu(), &mut rows);
    simulated(&DeviceSpec::paper_gpu(), &mut rows);
    measured();
    bench::emit_bench_json("fig11_full_networks", rows);
}
