//! Figures 11–14: full-network execution time (11: CPU, 12: GPU) and
//! relative speed-up over the baseline (13: CPU, 14: GPU) for all 21
//! TorchVision networks at batch 128.
//!
//! Paper scale via the memsim time model; a measured wall-clock section
//! covers the reduced-scale subset on the PJRT runtime.

use brainslug::bench::{self, fmt_pct, fmt_time, Table};
use brainslug::device::DeviceSpec;
use brainslug::memsim::{simulate_baseline, simulate_plan, speedup_pct};
use brainslug::optimizer::{optimize, CollapseOptions};
use brainslug::runtime::Runtime;
use brainslug::scheduler::Executor;
use brainslug::zoo;

fn simulated(device: &DeviceSpec) {
    println!(
        "\n## Fig {} (times) + Fig {} (speedups) — device={}, batch=128 (simulated)",
        if device.name.contains("xeon") { 11 } else { 12 },
        if device.name.contains("xeon") { 13 } else { 14 },
        device.name
    );
    let mut table = Table::new(&["network", "baseline", "brainslug", "speedup"]);
    for name in zoo::ALL_NETWORKS {
        let g = zoo::build(name, zoo::paper_config(name, 128));
        let plan = optimize(&g, device, &CollapseOptions::default());
        let base = simulate_baseline(&g, device);
        let bs = simulate_plan(&g, &plan, device);
        table.row(vec![
            name.to_string(),
            fmt_time(base.total_s),
            fmt_time(bs.total_s),
            fmt_pct(speedup_pct(base.total_s, bs.total_s)),
        ]);
    }
    table.print();
}

fn measured() {
    let Ok(runtime) = Runtime::new(std::path::Path::new(bench::ARTIFACT_DIR)) else {
        println!("\n(measured section skipped: run `make artifacts`)");
        return;
    };
    let batch = *bench::measured_batches().last().unwrap();
    println!("\n## Measured wall-clock (XLA-CPU, reduced scale, batch={batch})");
    let device = bench::measured_device();
    let mut table = Table::new(&["network", "baseline", "brainslug", "speedup"]);
    for &name in bench::measured_networks() {
        let g = zoo::build(name, zoo::small_config(name, batch));
        let plan = optimize(&g, &device, &bench::measured_opts());
        let mut exec = Executor::new(&runtime, &g, bench::oracle_seed());
        let input = exec.synthetic_input();
        let t_base = bench::measure(2, 9, || {
            exec.run_baseline(input.clone()).unwrap();
        });
        let t_bs = bench::measure(2, 9, || {
            exec.run_plan(&plan, input.clone()).unwrap();
        });
        table.row(vec![
            name.to_string(),
            fmt_time(t_base),
            fmt_time(t_bs),
            fmt_pct(speedup_pct(t_base, t_bs)),
        ]);
    }
    table.print();
}

fn main() {
    println!("# Figures 11-14 — Full Network Acceleration");
    simulated(&DeviceSpec::paper_cpu());
    simulated(&DeviceSpec::paper_gpu());
    measured();
}
