//! Figure 13 (real measurement): depth-first vs. breadth-first
//! wall-clock on the native CPU backend — the repo's first *measured*
//! speedup numbers, no artifacts, no simulation.
//!
//! For vgg16 / resnet18 / densenet121 at reduced scale and several
//! batch sizes, both schedules run on [`brainslug::cpu::CpuBackend`]:
//! the baseline executes every layer as a whole-tensor kernel (eager
//! PyTorch-style, every intermediate through main memory), the
//! depth-first path streams cache-sized bands through collapsed stacks
//! (branch arms depth-first, same thread budget for both sides, so the
//! gap is pure scheduling). Outputs are asserted `allclose` before any
//! timing — transparency first, speed second.
//!
//! Each row also reports what the `memsim` analytic model *predicts*
//! for the same graph on the host-cpu device profile, so measured
//! reality and the model that generated Tables 1–2 sit side by side.
//!
//! The acceptance assertion (> 0% somewhere) only considers the
//! `--threads 1` points: there both schedules run fully inline (zero
//! scoped-thread spawns on either side), so the gap is pure scheduling.
//! Multi-thread rows are still reported, but the baseline spawns one
//! scoped worker set per *layer* while depth-first spawns one per
//! *sequence*, so their gap includes a small spawn-overhead asymmetry.

use brainslug::bench::{self, fmt_pct, fmt_time, Table};
use brainslug::device::DeviceSpec;
use brainslug::engine::Engine;
use brainslug::json::Json;
use brainslug::memsim::speedup_pct;

const NETS: [&str; 3] = ["vgg16", "resnet18", "densenet121"];
const BATCHES: [usize; 2] = [1, 4];
const THREADS: [usize; 2] = [1, 2];

fn main() {
    println!("# Figure 13 (real) — measured depth-first speedup, native CPU backend");
    println!("reduced scale (64^2, quarter width), min of 3 timed runs\n");
    let mut table = Table::new(&[
        "network",
        "batch",
        "threads",
        "baseline",
        "depth-first",
        "measured",
        "memsim-pred",
    ]);
    let mut rows = Vec::new();
    let mut best = f64::NEG_INFINITY;
    let mut best_serial = f64::NEG_INFINITY;
    for &name in &NETS {
        for &batch in &BATCHES {
            for &threads in &THREADS {
                // `no_profile`: this bench measures the *default preset*
                // schedule; a previously tuned profile cache must not
                // silently change what the rows mean (fig17 covers the
                // tuned-vs-default comparison).
                let mut eng = Engine::builder()
                    .zoo_small(name, batch)
                    .device(DeviceSpec::host_cpu())
                    .brainslug(Default::default())
                    .cpu(threads)
                    .no_profile()
                    .seed(bench::oracle_seed())
                    .build()
                    .unwrap();
                let input = eng.synthetic_input();
                // Numeric parity is the correctness oracle: the two
                // schedules must agree before their times mean anything.
                let (out_base, _) = eng.run_baseline(input.clone()).unwrap();
                let (out_df, _) = eng.run(input.clone()).unwrap();
                assert!(
                    out_base.allclose(&out_df, 1e-4, 1e-4),
                    "{name} b{batch}: schedules diverge, max |diff| = {:.3e}",
                    out_base.max_abs_diff(&out_df)
                );
                let t_base = bench::measure(1, 3, || {
                    eng.run_baseline(input.clone()).unwrap();
                });
                let t_df = bench::measure(1, 3, || {
                    eng.run(input.clone()).unwrap();
                });
                let measured = speedup_pct(t_base, t_df);
                best = best.max(measured);
                if threads == 1 {
                    best_serial = best_serial.max(measured);
                }
                let predicted = speedup_pct(
                    eng.simulate_baseline().total_s,
                    eng.simulate_plan().unwrap().total_s,
                );
                table.row(vec![
                    name.to_string(),
                    batch.to_string(),
                    threads.to_string(),
                    fmt_time(t_base),
                    fmt_time(t_df),
                    fmt_pct(measured),
                    fmt_pct(predicted),
                ]);
                let mut row = Json::object();
                row.set("bench", Json::Str("fig13_real_speedup".into()));
                row.set("net", Json::Str(name.into()));
                row.set("batch", Json::from_usize(batch));
                row.set("threads", Json::from_usize(threads));
                row.set("backend", Json::Str("cpu".into()));
                row.set("baseline_s", Json::Num(t_base));
                row.set("depth_first_s", Json::Num(t_df));
                row.set("measured_speedup_pct", Json::Num(measured));
                row.set("predicted_speedup_pct", Json::Num(predicted));
                rows.push(row);
            }
        }
    }
    table.print();
    println!(
        "\nbest measured depth-first speedup: {} (memsim predictions above are \
         host-cpu profile, same graphs)",
        fmt_pct(best)
    );
    bench::emit_bench_json("fig13_real_speedup", rows);
    assert!(
        best_serial > 0.0,
        "acceptance: depth-first must beat the breadth-first CPU baseline \
         on at least one single-threaded network/batch point \
         (best serial {best_serial:+.1}%)"
    );
}
