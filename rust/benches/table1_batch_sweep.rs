//! Table 1: BrainSlug total speed-up vs the baseline for all 21 networks
//! across batch sizes 1..256, GPU (left half) and CPU (right half).
//!
//! Reproduction targets (shape, not absolute values): GPU negative at
//! batch 1-4 for several networks, positive from batch >= 8 except
//! ResNet-101/152; CPU positive everywhere with the largest values for
//! SqueezeNets at small batch (the Listing-4 pooling-parallelism bug).
//! Each cell is one `bench::paper_engine` build + simulation.

use brainslug::bench::{self, fmt_pct, Table};
use brainslug::device::DeviceSpec;
use brainslug::json::Json;
use brainslug::memsim::speedup_pct;
use brainslug::zoo;

const BATCHES: [usize; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];

fn sweep(device: &DeviceSpec, rows: &mut Vec<Json>) {
    println!("\n## Table 1 — device={} (simulated)", device.name);
    let mut table = Table::new(&[
        "network", "1", "2", "4", "8", "16", "32", "64", "128", "256",
    ]);
    for name in zoo::ALL_NETWORKS {
        let mut cells = vec![name.to_string()];
        let mut row = Json::object();
        row.set("bench", Json::Str("table1_batch_sweep".into()));
        row.set("device", Json::Str(device.name.clone()));
        row.set("net", Json::Str((*name).into()));
        for &b in &BATCHES {
            let engine = bench::paper_engine(name, b, device).build().unwrap();
            let base = engine.simulate_baseline();
            let bs = engine.simulate_plan().unwrap();
            let speedup = speedup_pct(base.total_s, bs.total_s);
            cells.push(fmt_pct(speedup));
            row.set(&format!("speedup_pct_b{b}"), Json::Num(speedup));
        }
        rows.push(row);
        table.row(cells);
    }
    table.print();
}

fn main() {
    println!("# Table 1 — Full speed-up grid");
    let mut rows = Vec::new();
    sweep(&DeviceSpec::paper_gpu(), &mut rows);
    sweep(&DeviceSpec::paper_cpu(), &mut rows);
    bench::emit_bench_json("table1_batch_sweep", rows);
}
