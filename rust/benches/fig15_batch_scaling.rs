//! Figure 15: scaling behaviour vs batch size — absolute execution time
//! of baseline (Py) and BrainSlug (BS) for three selected networks.
//! Both must scale with batch size, BrainSlug always below the baseline
//! with the gap widening at larger batches. All sections drive the
//! `Engine` facade.

use brainslug::bench::{self, fmt_time, Table};
use brainslug::device::DeviceSpec;
use brainslug::json::Json;

const NETS: [&str; 3] = ["resnet18", "densenet121", "vgg16_bn"];
const BATCHES: [usize; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];

fn simulated(device: &DeviceSpec, rows: &mut Vec<Json>) {
    println!("\n## Figure 15 — device={} (simulated)", device.name);
    let mut table = Table::new(&[
        "batch",
        "resnet18-Py",
        "resnet18-BS",
        "densenet121-Py",
        "densenet121-BS",
        "vgg16_bn-Py",
        "vgg16_bn-BS",
    ]);
    for &b in &BATCHES {
        let mut cells = vec![b.to_string()];
        for name in NETS {
            let engine = bench::paper_engine(name, b, device).build().unwrap();
            let base = engine.simulate_baseline();
            let bs = engine.simulate_plan().unwrap();
            cells.push(fmt_time(base.total_s));
            cells.push(fmt_time(bs.total_s));
            let mut row = Json::object();
            row.set("bench", Json::Str("fig15_batch_scaling".into()));
            row.set("device", Json::Str(device.name.clone()));
            row.set("net", Json::Str(name.into()));
            row.set("batch", Json::from_usize(b));
            row.set("baseline_s", Json::Num(base.total_s));
            row.set("brainslug_s", Json::Num(bs.total_s));
            rows.push(row);
        }
        table.row(cells);
    }
    table.print();
}

fn measured() {
    let Some(runtime) = bench::measured_runtime() else {
        println!("\n(measured section skipped: run `make artifacts`)");
        return;
    };
    println!("\n## Figure 15 (measured, XLA-CPU, resnet18 reduced scale)");
    let mut table = Table::new(&["batch", "baseline", "brainslug"]);
    for &b in bench::measured_batches() {
        let mut engine =
            bench::build_measured(bench::measured_engine("resnet18", b), &runtime).unwrap();
        let input = engine.synthetic_input();
        let t_base = bench::measure(2, 9, || {
            engine.run_baseline(input.clone()).unwrap();
        });
        let t_bs = bench::measure(2, 9, || {
            engine.run(input.clone()).unwrap();
        });
        table.row(vec![b.to_string(), fmt_time(t_base), fmt_time(t_bs)]);
    }
    table.print();
}

fn main() {
    println!("# Figure 15 — Batch Size Scaling Behavior");
    let mut rows = Vec::new();
    simulated(&DeviceSpec::paper_gpu(), &mut rows);
    simulated(&DeviceSpec::paper_cpu(), &mut rows);
    measured();
    bench::emit_bench_json("fig15_batch_scaling", rows);
}
