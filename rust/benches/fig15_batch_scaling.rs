//! Figure 15: scaling behaviour vs batch size — absolute execution time
//! of baseline (Py) and BrainSlug (BS) for three selected networks.
//! Both must scale with batch size, BrainSlug always below the baseline
//! with the gap widening at larger batches.

use brainslug::bench::{self, fmt_time, Table};
use brainslug::device::DeviceSpec;
use brainslug::memsim::{simulate_baseline, simulate_plan};
use brainslug::optimizer::{optimize, CollapseOptions};
use brainslug::runtime::Runtime;
use brainslug::scheduler::Executor;
use brainslug::zoo;

const NETS: [&str; 3] = ["resnet18", "densenet121", "vgg16_bn"];
const BATCHES: [usize; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];

fn simulated(device: &DeviceSpec) {
    println!("\n## Figure 15 — device={} (simulated)", device.name);
    let mut table = Table::new(&[
        "batch",
        "resnet18-Py",
        "resnet18-BS",
        "densenet121-Py",
        "densenet121-BS",
        "vgg16_bn-Py",
        "vgg16_bn-BS",
    ]);
    for &b in &BATCHES {
        let mut cells = vec![b.to_string()];
        for name in NETS {
            let g = zoo::build(name, zoo::paper_config(name, b));
            let plan = optimize(&g, device, &CollapseOptions::default());
            let base = simulate_baseline(&g, device);
            let bs = simulate_plan(&g, &plan, device);
            cells.push(fmt_time(base.total_s));
            cells.push(fmt_time(bs.total_s));
        }
        table.row(cells);
    }
    table.print();
}

fn measured() {
    let Ok(runtime) = Runtime::new(std::path::Path::new(bench::ARTIFACT_DIR)) else {
        println!("\n(measured section skipped: run `make artifacts`)");
        return;
    };
    println!("\n## Figure 15 (measured, XLA-CPU, resnet18 reduced scale)");
    let device = bench::measured_device();
    let mut table = Table::new(&["batch", "baseline", "brainslug"]);
    for &b in bench::measured_batches() {
        let g = zoo::build("resnet18", zoo::small_config("resnet18", b));
        let plan = optimize(&g, &device, &bench::measured_opts());
        let mut exec = Executor::new(&runtime, &g, bench::oracle_seed());
        let input = exec.synthetic_input();
        let t_base = bench::measure(2, 9, || {
            exec.run_baseline(input.clone()).unwrap();
        });
        let t_bs = bench::measure(2, 9, || {
            exec.run_plan(&plan, input.clone()).unwrap();
        });
        table.row(vec![b.to_string(), fmt_time(t_base), fmt_time(t_bs)]);
    }
    table.print();
}

fn main() {
    println!("# Figure 15 — Batch Size Scaling Behavior");
    simulated(&DeviceSpec::paper_gpu());
    simulated(&DeviceSpec::paper_cpu());
    measured();
}
