//! Figure 16 (repro extension): serving throughput scaling with engine
//! replicas — the experiment behind `brainslug serve --workers N`.
//!
//! A closed-loop client population drives the batching server while the
//! worker pool is swept over {1, 2, 4, 8} replicas at a fixed compiled
//! batch size. The paced `SimBackend` sleeps the model time per batch
//! (calibrated below so one batch ≈ 4 ms of wall-clock), which makes
//! queueing and overlap *genuine*: with instantaneous sim runs every
//! configuration would report the same near-infinite throughput.
//!
//! Expected shape: throughput scales near-linearly with workers while
//! the client population keeps all replicas fed (≥2× at 4 workers vs 1
//! is the acceptance bar), mean latency drops as queue wait shrinks,
//! and occupancy stays high until the pool outruns the offered load.

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use brainslug::bench::{self, Table};
use brainslug::json::Json;
use brainslug::rng::fill_f32;
use brainslug::server::{QueuePolicy, ServerConfig};

/// Compiled batch size of every served engine.
const BATCH: usize = 8;
/// Closed-loop clients; 2× the slots of the largest pool (8 × BATCH)
/// would idle it, so the sweep's tail shows occupancy rolling off.
const CLIENTS: usize = 64;
const REQS_PER_CLIENT: usize = 4;
/// Wall-clock cost of one batch after pacing calibration.
const TARGET_BATCH_S: f64 = 4e-3;

fn main() -> anyhow::Result<()> {
    // Calibrate the pacing scale against the unpaced model time so the
    // batch cost is ~TARGET_BATCH_S regardless of the device model.
    let mut probe = bench::serving_engine(BATCH, 0.0).build()?;
    let input = probe.synthetic_input();
    let (_, stats) = probe.run(input)?;
    let scale = TARGET_BATCH_S / stats.total_s.max(1e-12);

    println!("# Figure 16 — serving throughput vs worker-pool size (paced sim)");
    println!(
        "batch={BATCH} clients={CLIENTS} reqs/client={REQS_PER_CLIENT} batch-cost={:.1}ms queue=block",
        TARGET_BATCH_S * 1e3
    );
    let mut table = Table::new(&[
        "workers",
        "req/s",
        "vs-1",
        "mean-lat-ms",
        "p50-ms",
        "p95-ms",
        "p99-ms",
        "occupancy",
        "peak-queue",
    ]);
    let mut base_throughput = None;
    let mut rows = Vec::new();
    for &workers in bench::fig16_worker_counts() {
        let server = ServerConfig::new(bench::serving_engine(BATCH, scale))
            .workers(workers)
            .queue_depth(4 * BATCH)
            .queue_policy(QueuePolicy::Block)
            .max_wait(Duration::from_millis(2))
            .start()?;
        let handle = server.handle();
        let elems = handle.image_shape().numel();
        let t0 = Instant::now();
        let clients: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    for i in 0..REQS_PER_CLIENT {
                        h.infer(fill_f32((c * REQS_PER_CLIENT + i) as u64, elems))
                            .expect("serving request failed");
                    }
                })
            })
            .collect();
        for c in clients {
            c.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let served = server.stats.requests.load(Ordering::Relaxed);
        let throughput = served as f64 / wall;
        let vs_one = base_throughput.map_or(1.0, |b: f64| throughput / b);
        if base_throughput.is_none() {
            base_throughput = Some(throughput);
        }
        // Histogram-midpoint estimates (within 12.5% by construction,
        // see DESIGN.md §Observability) — the tail columns the mean
        // hides: queue wait under load lives in p95/p99.
        let (p50, p95, p99) = server.stats.latency_percentiles_ms();
        table.row(vec![
            workers.to_string(),
            format!("{throughput:.0}"),
            format!("{vs_one:.2}x"),
            format!("{:.2}", server.stats.mean_latency_ms()),
            format!("{p50:.2}"),
            format!("{p95:.2}"),
            format!("{p99:.2}"),
            format!("{:.2}", server.occupancy()),
            server.stats.queue_peak.load(Ordering::Relaxed).to_string(),
        ]);
        let mut row = Json::object();
        row.set("bench", Json::Str("fig16_serving_scaling".into()));
        row.set("workers", Json::from_usize(workers));
        row.set("batch", Json::from_usize(BATCH));
        row.set("req_per_s", Json::Num(throughput));
        row.set("scaling_vs_one", Json::Num(vs_one));
        row.set("mean_latency_ms", Json::Num(server.stats.mean_latency_ms()));
        row.set("p50_ms", Json::Num(p50));
        row.set("p95_ms", Json::Num(p95));
        row.set("p99_ms", Json::Num(p99));
        row.set("occupancy", Json::Num(server.occupancy()));
        row.set(
            "queue_peak",
            Json::Num(server.stats.queue_peak.load(Ordering::Relaxed) as f64),
        );
        rows.push(row);
        server.stop();
    }
    table.print();
    bench::emit_bench_json("fig16_serving_scaling", rows);
    Ok(())
}
