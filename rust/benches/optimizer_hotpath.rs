//! L3 hot-path microbenchmarks (§Perf): the optimizer itself (graph walk
//! + collapse) on the largest networks, graph construction, and the full
//! `Engine` compile phase (resolve → optimize → validate → sim backend).
//! The paper's compile phase runs once per network, but a dynamic-graph
//! front-end (PyTorch, §4.3) re-optimizes on graph changes, so both
//! `optimize` latency and end-to-end `EngineBuilder::build` latency
//! matter.

use std::sync::Arc;

use brainslug::bench::{self, fmt_time, Table};
use brainslug::device::DeviceSpec;
use brainslug::graph::Layer;
use brainslug::json::Json;
use brainslug::optimizer::{optimize, CollapseOptions};
use brainslug::runtime::ParamStore;
use brainslug::zoo;

fn main() {
    println!("# Optimizer hot path");
    let mut rows = Vec::new();
    let device = DeviceSpec::paper_gpu();
    let mut table = Table::new(&["network", "build-graph", "optimize", "engine-build", "stacks"]);
    for name in ["alexnet", "resnet152", "densenet201", "inception_v3"] {
        let cfg = zoo::paper_config(name, 128);
        let t_build = bench::measure(3, 10, || {
            let g = zoo::build(name, cfg);
            std::hint::black_box(&g);
        });
        let g = zoo::build(name, cfg);
        let t_opt = bench::measure(3, 10, || {
            let plan = optimize(&g, &device, &CollapseOptions::default());
            std::hint::black_box(&plan);
        });
        // The facade's whole compile phase, artifact-free.
        let t_engine = bench::measure(3, 10, || {
            let engine = bench::paper_engine(name, 128, &device).build().unwrap();
            std::hint::black_box(&engine);
        });
        let engine = bench::paper_engine(name, 128, &device).build().unwrap();
        table.row(vec![
            name.to_string(),
            fmt_time(t_build),
            fmt_time(t_opt),
            fmt_time(t_engine),
            engine.plan().unwrap().num_stacks().to_string(),
        ]);
        let mut row = Json::object();
        row.set("bench", Json::Str("optimizer_hotpath".into()));
        row.set("net", Json::Str(name.into()));
        row.set("build_graph_s", Json::Num(t_build));
        row.set("optimize_s", Json::Num(t_opt));
        row.set("engine_build_s", Json::Num(t_engine));
        rows.push(row);
    }
    table.print();

    // Collapse-only microbench on a deep synthetic chain.
    let g = bench::block_net(40, 128, 32, 112);
    let t = bench::measure(3, 20, || {
        let plan = optimize(&g, &device, &CollapseOptions::default());
        std::hint::black_box(&plan);
    });
    println!("\nblock_net(40) optimize: {}", fmt_time(t));

    // Consumer-map microbench: a planning pass needs consumer info in
    // two places (the chain walk and branch-region detection), and the
    // graph validator plus the executor each need it again. One
    // `consumer_map` derivation is threaded through per pass instead of
    // one per site; this measures what each avoided derivation costs on
    // the largest zoo graph.
    let g = zoo::build("densenet201", zoo::paper_config("densenet201", 128));
    let t_map = bench::measure(3, 20, || {
        let m = g.consumer_map();
        std::hint::black_box(&m);
    });
    println!(
        "densenet201 consumer_map: {} per derivation (computed once per \
         planning pass and threaded through chain walk + region detection)",
        fmt_time(t_map)
    );

    // Folded-BN gather microbench: every `run_stack` invocation gathers
    // the folded (scale, shift) pair of every bn op in the stack. The
    // ParamStore caches the fold per node, so only the first gather pays
    // for generation + folding; steady-state gathers are map lookups.
    // densenet201 is the bn-heaviest zoo graph.
    let g = Arc::new(zoo::build("densenet201", zoo::paper_config("densenet201", 1)));
    let bn_nodes: Vec<usize> = g
        .nodes
        .iter()
        .filter(|n| matches!(n.layer, Layer::BatchNorm2d { .. }))
        .map(|n| n.id)
        .collect();
    let t_cold = bench::measure(1, 5, || {
        let mut store = ParamStore::new(g.clone(), 7);
        for &id in &bn_nodes {
            std::hint::black_box(store.bn_folded(id));
        }
    });
    let mut store = ParamStore::new(g.clone(), 7);
    for &id in &bn_nodes {
        store.bn_folded(id); // warm the fold cache
    }
    let t_hot = bench::measure(1, 5, || {
        for &id in &bn_nodes {
            std::hint::black_box(store.bn_folded(id));
        }
    });
    println!(
        "densenet201 bn_folded gather x{}: cold {} -> cached {} per pass",
        bn_nodes.len(),
        fmt_time(t_cold),
        fmt_time(t_hot)
    );
    let mut row = Json::object();
    row.set("bench", Json::Str("optimizer_hotpath".into()));
    row.set("net", Json::Str("densenet201".into()));
    row.set("bn_nodes", Json::from_usize(bn_nodes.len()));
    row.set("bn_gather_cold_s", Json::Num(t_cold));
    row.set("bn_gather_cached_s", Json::Num(t_hot));
    rows.push(row);
    bench::emit_bench_json("optimizer_hotpath", rows);
}
