//! L3 hot-path microbenchmarks (§Perf): the optimizer itself (graph walk
//! + collapse) on the largest networks, graph construction, and the full
//! `Engine` compile phase (resolve → optimize → validate → sim backend).
//! The paper's compile phase runs once per network, but a dynamic-graph
//! front-end (PyTorch, §4.3) re-optimizes on graph changes, so both
//! `optimize` latency and end-to-end `EngineBuilder::build` latency
//! matter.

use brainslug::bench::{self, fmt_time, Table};
use brainslug::device::DeviceSpec;
use brainslug::optimizer::{optimize, CollapseOptions};
use brainslug::zoo;

fn main() {
    println!("# Optimizer hot path");
    let device = DeviceSpec::paper_gpu();
    let mut table = Table::new(&["network", "build-graph", "optimize", "engine-build", "stacks"]);
    for name in ["alexnet", "resnet152", "densenet201", "inception_v3"] {
        let cfg = zoo::paper_config(name, 128);
        let t_build = bench::measure(3, 10, || {
            let g = zoo::build(name, cfg);
            std::hint::black_box(&g);
        });
        let g = zoo::build(name, cfg);
        let t_opt = bench::measure(3, 10, || {
            let plan = optimize(&g, &device, &CollapseOptions::default());
            std::hint::black_box(&plan);
        });
        // The facade's whole compile phase, artifact-free.
        let t_engine = bench::measure(3, 10, || {
            let engine = bench::paper_engine(name, 128, &device).build().unwrap();
            std::hint::black_box(&engine);
        });
        let engine = bench::paper_engine(name, 128, &device).build().unwrap();
        table.row(vec![
            name.to_string(),
            fmt_time(t_build),
            fmt_time(t_opt),
            fmt_time(t_engine),
            engine.plan().unwrap().num_stacks().to_string(),
        ]);
    }
    table.print();

    // Collapse-only microbench on a deep synthetic chain.
    let g = bench::block_net(40, 128, 32, 112);
    let t = bench::measure(3, 20, || {
        let plan = optimize(&g, &device, &CollapseOptions::default());
        std::hint::black_box(&plan);
    });
    println!("\nblock_net(40) optimize: {}", fmt_time(t));

    // Consumer-map microbench: a planning pass needs consumer info in
    // two places (the chain walk and branch-region detection), and the
    // graph validator plus the executor each need it again. One
    // `consumer_map` derivation is threaded through per pass instead of
    // one per site; this measures what each avoided derivation costs on
    // the largest zoo graph.
    let g = zoo::build("densenet201", zoo::paper_config("densenet201", 128));
    let t_map = bench::measure(3, 20, || {
        let m = g.consumer_map();
        std::hint::black_box(&m);
    });
    println!(
        "densenet201 consumer_map: {} per derivation (computed once per \
         planning pass and threaded through chain walk + region detection)",
        fmt_time(t_map)
    );
}
