//! Figure 21 (repro extension): availability and tail latency under a
//! seeded fault storm — the experiment behind the fault-injection and
//! worker-supervision layer.
//!
//! Three phases against one HTTP-served engine:
//!
//! 1. **baseline** — closed-loop load with injection armed but all
//!    rates at zero: proves the disarmed layer costs nothing visible
//!    and every request succeeds.
//! 2. **storm** — every injection point hot at once (worker panics,
//!    slow batches, queue stalls, socket resets, partial writes) plus
//!    one guaranteed panic trigger. Clients retry with jittered
//!    backoff. The bar: every logical request ends in a *reply or a
//!    typed error* — nothing hangs — and the server's restart counter
//!    matches the injector's fired-panic count exactly.
//! 3. **recovery** — rates back to zero, wait for `/healthz` to report
//!    `ready` again, rerun the baseline load: throughput must be back
//!    within 10% of the pre-storm baseline.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use brainslug::bench::{self, Table};
use brainslug::fault::{FaultInjector, FaultPoint};
use brainslug::http::{self, HttpConfig, HttpServer, LoadReport, RetryPolicy};
use brainslug::json::{self, Json};
use brainslug::rng::fill_f32;
use brainslug::server::{QueuePolicy, ServerConfig};

/// Compiled batch size of the served engine.
const BATCH: usize = 4;
/// Wall-clock cost of one batch after pacing calibration.
const TARGET_BATCH_S: f64 = 4e-3;
const WORKERS: usize = 2;
const CLIENTS: usize = 4;
const REQS_PER_CLIENT: usize = 12;
/// Injector seed; override with BRAINSLUG_FAULT_SEED (the CI fault
/// matrix sweeps it).
const FAULT_SEED: u64 = 21;

/// Storm-phase rates per injection point (per draw).
const STORM_RATES: [(FaultPoint, f64); 5] = [
    (FaultPoint::WorkerPanic, 0.05),
    (FaultPoint::SlowExec, 0.08),
    (FaultPoint::QueueStall, 0.05),
    (FaultPoint::SocketReset, 0.04),
    (FaultPoint::PartialWrite, 0.20),
];

fn start_http(scale: f64, inj: Arc<FaultInjector>) -> HttpServer {
    let server = ServerConfig::new(bench::serving_engine(BATCH, scale))
        .workers(WORKERS)
        .queue_depth(4 * BATCH)
        .queue_policy(QueuePolicy::Block)
        .max_wait(Duration::from_millis(2))
        .faults(inj)
        .start()
        .expect("server start");
    let mut cfg = HttpConfig::new("127.0.0.1:0");
    cfg.conn_threads = CLIENTS + 4;
    HttpServer::start(server, cfg).expect("http start")
}

fn main() -> anyhow::Result<()> {
    // Calibrate pacing against the unpaced model time (fig16 scheme).
    let mut probe = bench::serving_engine(BATCH, 0.0).build()?;
    let input = probe.synthetic_input();
    let (_, stats) = probe.run(input)?;
    let scale = TARGET_BATCH_S / stats.total_s.max(1e-12);

    let seed = brainslug::fault::seed_from_env(FAULT_SEED);
    let inj = Arc::new(FaultInjector::new(seed));
    let http = start_http(scale, inj.clone());
    let addr = http.addr().to_string();
    let state = http.state().clone();
    let body = run_body(&state.model, state.image_elems);

    println!("# Figure 21 — availability and p99 under a seeded fault storm");
    println!(
        "batch={BATCH} batch-cost={:.0}ms workers={WORKERS} clients={CLIENTS} \
         reqs/client={REQS_PER_CLIENT} fault-seed={seed}",
        TARGET_BATCH_S * 1e3
    );
    let mut table = Table::new(&[
        "phase", "sent", "ok", "rejected", "expired", "errors", "retries", "req/s", "p50-ms",
        "p99-ms",
    ]);
    let mut rows = Vec::new();

    // Phase 1: baseline (injection armed, every rate zero).
    let baseline = http::closed_loop(&addr, CLIENTS, REQS_PER_CLIENT, body.as_bytes());
    assert_eq!(
        baseline.ok, baseline.sent,
        "baseline: {} errors, {} rejected",
        baseline.errors, baseline.rejected
    );
    emit(&mut table, &mut rows, "baseline", &baseline);

    // Phase 2: the storm. Rates on everywhere, plus one guaranteed
    // panic so the supervision path is exercised at every seed.
    for (point, rate) in STORM_RATES {
        inj.set_rate(point, rate);
    }
    inj.trigger(FaultPoint::WorkerPanic);
    let retry = RetryPolicy {
        max_attempts: 5,
        base_ms: 5,
        cap_ms: 500,
        budget: 200,
        seed,
    };
    let storm = http::closed_loop_with(&addr, CLIENTS, REQS_PER_CLIENT, body.as_bytes(), Some(retry));
    // Availability bar: every logical request was *answered* — by a
    // 200, a typed shed (503/504), or a transport error the client
    // observed. Nothing may hang (closed_loop would still be blocked).
    assert_eq!(
        storm.sent as usize,
        CLIENTS * REQS_PER_CLIENT,
        "storm lost track of requests"
    );
    assert!(
        storm.ok as f64 >= 0.75 * storm.sent as f64,
        "storm availability collapsed: ok={} of {} (errors={} rejected={} expired={})",
        storm.ok,
        storm.sent,
        storm.errors,
        storm.rejected,
        storm.expired
    );
    let panics = inj.fired(FaultPoint::WorkerPanic);
    assert!(panics >= 1, "the triggered panic must have fired");
    let restarts = state.stats.restarts.load(Ordering::Relaxed);
    assert_eq!(
        restarts, panics,
        "every injected panic must surface as exactly one supervised restart"
    );
    emit(&mut table, &mut rows, "storm", &storm);

    // Phase 3: recovery. Disarm, wait for Ready, rerun the baseline.
    for (point, _) in STORM_RATES {
        inj.set_rate(point, 0.0);
    }
    wait_ready(&addr);
    let recovery = http::closed_loop(&addr, CLIENTS, REQS_PER_CLIENT, body.as_bytes());
    assert_eq!(
        recovery.ok, recovery.sent,
        "recovery: {} errors, {} rejected",
        recovery.errors, recovery.rejected
    );
    assert!(
        recovery.throughput_rps() >= 0.9 * baseline.throughput_rps(),
        "post-storm throughput {:.0}/s fell more than 10% below baseline {:.0}/s",
        recovery.throughput_rps(),
        baseline.throughput_rps()
    );
    emit(&mut table, &mut rows, "recovery", &recovery);

    // The server's own accounting agrees with the injector's.
    let stats_resp = http::one_shot(&addr, "GET", "/v1/stats", None)?;
    let parsed = json::parse(std::str::from_utf8(&stats_resp.body)?)?;
    assert_eq!(
        parsed.usize_field("restarts").expect("restarts field") as u64,
        panics,
        "/v1/stats restarts disagrees with the injector"
    );
    assert_eq!(
        parsed.str_field("health").expect("health field"),
        "ready",
        "server must end the experiment Ready"
    );
    http.shutdown();

    table.print();
    for row in &mut rows {
        row.set("fault_seed", Json::Num(seed as f64));
        row.set("restarts", Json::Num(restarts as f64));
        row.set("panics_fired", Json::Num(panics as f64));
    }
    bench::emit_bench_json("fig21_fault_recovery", rows);
    Ok(())
}

/// Poll `/healthz` until the state machine reports `ready` again (the
/// last replica rebuild finished), bounded at 5 s.
fn wait_ready(addr: &str) {
    for _ in 0..100 {
        if let Ok(resp) = http::one_shot(addr, "GET", "/healthz", None) {
            if resp.status == 200 {
                if let Ok(parsed) = json::parse(&String::from_utf8_lossy(&resp.body)) {
                    if parsed.str_field("state").is_ok_and(|s| s == "ready") {
                        return;
                    }
                }
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    panic!("server did not return to Ready within 5 s of the storm ending");
}

fn emit(table: &mut Table, rows: &mut Vec<Json>, phase: &str, report: &LoadReport) {
    table.row(vec![
        phase.into(),
        report.sent.to_string(),
        report.ok.to_string(),
        report.rejected.to_string(),
        report.expired.to_string(),
        report.errors.to_string(),
        report.retries.to_string(),
        format!("{:.0}", report.throughput_rps()),
        format!("{:.2}", report.p50_ms()),
        format!("{:.2}", report.p99_ms()),
    ]);
    let mut row = Json::object();
    row.set("bench", Json::Str("fig21_fault_recovery".into()));
    row.set("phase", Json::Str(phase.into()));
    row.set("workers", Json::from_usize(WORKERS));
    row.set("batch", Json::from_usize(BATCH));
    row.set("sent", Json::Num(report.sent as f64));
    row.set("ok", Json::Num(report.ok as f64));
    row.set("rejected", Json::Num(report.rejected as f64));
    row.set("expired", Json::Num(report.expired as f64));
    row.set("errors", Json::Num(report.errors as f64));
    row.set("retries", Json::Num(report.retries as f64));
    row.set(
        "availability",
        Json::Num(if report.sent == 0 {
            1.0
        } else {
            report.ok as f64 / report.sent as f64
        }),
    );
    row.set("throughput_rps", Json::Num(report.throughput_rps()));
    row.set("mean_ms", Json::Num(report.mean_ms()));
    row.set("p50_ms", Json::Num(report.p50_ms()));
    row.set("p99_ms", Json::Num(report.p99_ms()));
    rows.push(row);
}

fn run_body(model: &str, elems: usize) -> String {
    let mut o = Json::object();
    o.set("model", Json::Str(model.to_string()));
    o.set(
        "input",
        Json::Arr(
            fill_f32(21, elems)
                .into_iter()
                .map(|v| Json::Num(v as f64))
                .collect(),
        ),
    );
    o.to_string_compact()
}
