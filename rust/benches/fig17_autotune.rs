//! Figure 17 (repro extension): autotuned vs default-preset collapse
//! configuration, measured on the native CPU backend.
//!
//! For each zoo network swept, the autotuner runs its full pipeline —
//! memsim cost-model pre-pass over the candidate space, timed runs
//! (warmup + median-of-N with early-exit pruning) on
//! [`brainslug::cpu::CpuBackend`], then an interleaved head-to-head
//! re-match of the sweep winner against the device-preset default. The
//! default preset is always fully measured and wins ties/lost
//! re-matches, so `tuned <= default` holds per point by construction;
//! the interesting output is *how much* the preset leaves on the table
//! per network and thread count. Baseline-schedule parity is asserted
//! on every winning config inside `autotune::tune` (transparency
//! first, speed second — same contract as fig13).
//!
//! Acceptance: tuned measured time <= default measured time on every
//! swept point, and strictly faster on at least one (a search over
//! budget scale × band caps on real hardware should beat a static
//! preset somewhere; if it never does, the tuner is broken).

use std::sync::Arc;

use brainslug::autotune::{self, TuneLevel};
use brainslug::bench::{self, fmt_pct, fmt_time, Table};
use brainslug::device::DeviceSpec;
use brainslug::json::Json;
use brainslug::zoo;

const NETS: [&str; 4] = ["vgg16", "resnet18", "densenet121", "squeezenet1_1"];
const THREADS: [usize; 2] = [1, 2];

fn main() {
    println!("# Figure 17 — autotuned vs default-preset collapse config, native CPU backend");
    println!("reduced scale (64^2, quarter width), batch 1, tune level fast\n");
    let device = DeviceSpec::host_cpu();
    let mut table = Table::new(&[
        "network", "threads", "default", "tuned", "gain", "winner", "measured", "pruned",
    ]);
    let mut rows = Vec::new();
    let mut best_gain = f64::NEG_INFINITY;
    for &name in &NETS {
        let graph = Arc::new(
            zoo::try_build(name, zoo::small_config(name, 1)).expect("zoo network"),
        );
        let outcome =
            autotune::tune(&graph, &device, bench::oracle_seed(), TuneLevel::Fast, &THREADS)
                .expect("tuning must succeed (parity is asserted inside)");
        let pruned = outcome.measured.iter().filter(|m| m.pruned).count();
        for tr in &outcome.per_thread {
            let gain = tr.gain_pct();
            best_gain = best_gain.max(gain);
            // Per-point acceptance: tuning never regresses.
            assert!(
                tr.tuned_s <= tr.default_s,
                "{name} t{}: tuned {} > default {}",
                tr.threads,
                tr.tuned_s,
                tr.default_s
            );
            table.row(vec![
                name.to_string(),
                tr.threads.to_string(),
                fmt_time(tr.default_s),
                fmt_time(tr.tuned_s),
                fmt_pct(gain),
                tr.winner.label.clone(),
                outcome.candidates_measured.to_string(),
                pruned.to_string(),
            ]);
            let mut row = Json::object();
            row.set("bench", Json::Str("fig17_autotune".into()));
            row.set("net", Json::Str(name.into()));
            row.set("batch", Json::from_usize(1));
            row.set("threads", Json::from_usize(tr.threads));
            row.set("backend", Json::Str("cpu".into()));
            row.set("device", Json::Str(device.name.clone()));
            row.set("default_s", Json::Num(tr.default_s));
            row.set("tuned_s", Json::Num(tr.tuned_s));
            row.set("gain_pct", Json::Num(gain));
            row.set("winner", Json::Str(tr.winner.label.clone()));
            row.set(
                "candidates_total",
                Json::from_usize(outcome.candidates_total),
            );
            row.set(
                "candidates_measured",
                Json::from_usize(outcome.candidates_measured),
            );
            row.set("candidates_pruned", Json::from_usize(pruned));
            rows.push(row);
        }
    }
    table.print();
    println!(
        "\nbest measured tuning gain over the device preset: {}",
        fmt_pct(best_gain)
    );
    bench::emit_bench_json("fig17_autotune", rows);
    assert!(
        best_gain > 0.0,
        "acceptance: the tuner must beat the default preset on at least one \
         network × thread point (best gain {best_gain:+.1}%)"
    );
}
