//! Figure 10: stacked-layer acceleration on synthetic
//! <MaxPool 3×3/1/1, BN, ReLU> block networks, 1..40 blocks, under the
//! three collapse strategies (1 step/seq, 5 steps/seq, unrestricted).
//!
//! Paper-scale sweep runs on the memsim time model for both paper
//! devices (the paper's absolute hardware is unavailable; the *shape* —
//! BrainSlug ≫ baseline, 5-step > 1-step, unrestricted degrading past
//! the cache limit with spill artifacts — is the reproduction target).
//! A measured wall-clock section runs the same structures end-to-end on
//! the PJRT runtime when artifacts are present.

use brainslug::bench::{self, fmt_pct, fmt_time, Table};
use brainslug::device::DeviceSpec;
use brainslug::memsim::{simulate_baseline, simulate_plan, speedup_pct};
use brainslug::optimizer::optimize;
use brainslug::runtime::Runtime;
use brainslug::scheduler::Executor;

fn simulated(device: &DeviceSpec) {
    println!("\n## Figure 10 (simulated) — device={}, batch=32, 32ch 112x112", device.name);
    let mut table = Table::new(&[
        "blocks", "baseline", "1step", "5step", "unrestr", "seqs-unr", "speedup-5step",
    ]);
    let mut prev_seqs = 0usize;
    for blocks in [1, 2, 4, 6, 8, 12, 16, 20, 24, 28, 32, 36, 40] {
        let g = bench::block_net(blocks, 32, 32, 112);
        let base = simulate_baseline(&g, device);
        let mut cells = vec![blocks.to_string(), fmt_time(base.total_s)];
        let mut t5 = f64::NAN;
        let mut seqs_unr = 0;
        for (name, opts) in bench::fig10_strategies() {
            let plan = optimize(&g, device, &opts);
            let sim = simulate_plan(&g, &plan, device);
            cells.push(fmt_time(sim.total_s));
            if name == "5step" {
                t5 = sim.total_s;
            }
            if name == "unrestricted" {
                seqs_unr = sim.num_sequences;
            }
        }
        let artifact = if seqs_unr > prev_seqs && prev_seqs > 0 {
            format!("{seqs_unr} (spill!)")
        } else {
            seqs_unr.to_string()
        };
        prev_seqs = seqs_unr;
        cells.push(artifact);
        cells.push(fmt_pct(speedup_pct(base.total_s, t5)));
        table.row(cells);
    }
    table.print();
}

fn measured() {
    let Ok(runtime) = Runtime::new(std::path::Path::new(bench::ARTIFACT_DIR)) else {
        println!("\n(measured section skipped: run `make artifacts`)");
        return;
    };
    println!("\n## Figure 10 (measured wall-clock, XLA-CPU, batch=4, 8ch 32x32)");
    let device = bench::measured_device();
    let mut table = Table::new(&["blocks", "baseline", "1step", "5step", "unrestr", "best-speedup"]);
    for &blocks in bench::fig10_measured_blocks() {
        let g = bench::block_net(blocks, 4, 8, 32);
        let mut exec = Executor::new(&runtime, &g, bench::oracle_seed());
        let input = exec.synthetic_input();
        let t_base = bench::measure(2, 5, || {
            exec.run_baseline(input.clone()).unwrap();
        });
        let mut cells = vec![blocks.to_string(), fmt_time(t_base)];
        let mut best = f64::INFINITY;
        for (_, opts) in bench::fig10_strategies() {
            let plan = optimize(&g, &device, &opts);
            let t = bench::measure(2, 5, || {
                exec.run_plan(&plan, input.clone()).unwrap();
            });
            best = best.min(t);
            cells.push(fmt_time(t));
        }
        cells.push(fmt_pct(speedup_pct(t_base, best)));
        table.row(cells);
    }
    table.print();
}

fn main() {
    println!("# Figure 10 — Stacked Layers Acceleration");
    simulated(&DeviceSpec::paper_gpu());
    simulated(&DeviceSpec::paper_cpu());
    measured();
}
