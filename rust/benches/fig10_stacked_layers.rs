//! Figure 10: stacked-layer acceleration on synthetic
//! <MaxPool 3×3/1/1, BN, ReLU> block networks, 1..40 blocks, under the
//! three collapse strategies (1 step/seq, 5 steps/seq, unrestricted).
//!
//! Paper-scale sweep runs on the memsim time model for both paper
//! devices (the paper's absolute hardware is unavailable; the *shape* —
//! BrainSlug ≫ baseline, 5-step > 1-step, unrestricted degrading past
//! the cache limit with spill artifacts — is the reproduction target).
//! A measured wall-clock section runs the same structures end-to-end
//! through the `Engine` facade on the PJRT runtime when artifacts are
//! present.

use brainslug::bench::{self, fmt_pct, fmt_time, Table};
use brainslug::device::DeviceSpec;
use brainslug::engine::Engine;
use brainslug::json::Json;
use brainslug::memsim::speedup_pct;

fn simulated(device: &DeviceSpec, rows: &mut Vec<Json>) {
    println!("\n## Figure 10 (simulated) — device={}, batch=32, 32ch 112x112", device.name);
    let mut table = Table::new(&[
        "blocks", "baseline", "1step", "5step", "unrestr", "seqs-unr", "speedup-5step",
    ]);
    let mut prev_seqs = 0usize;
    for blocks in [1, 2, 4, 6, 8, 12, 16, 20, 24, 28, 32, 36, 40] {
        let mut cells = vec![blocks.to_string()];
        let mut t5 = f64::NAN;
        let mut seqs_unr = 0;
        let mut base_s = f64::NAN;
        let mut row = Json::object();
        row.set("bench", Json::Str("fig10_stacked_layers".into()));
        row.set("device", Json::Str(device.name.clone()));
        row.set("blocks", Json::from_usize(blocks));
        for (name, opts) in bench::fig10_strategies() {
            let engine = Engine::builder()
                .graph_owned(bench::block_net(blocks, 32, 32, 112))
                .device(device.clone())
                .brainslug(opts)
                .sim()
                .build()
                .unwrap();
            if cells.len() == 1 {
                base_s = engine.simulate_baseline().total_s;
                cells.push(fmt_time(base_s));
                row.set("baseline_s", Json::Num(base_s));
            }
            let sim = engine.simulate_plan().unwrap();
            cells.push(fmt_time(sim.total_s));
            row.set(&format!("{name}_s"), Json::Num(sim.total_s));
            if name == "5step" {
                t5 = sim.total_s;
            }
            if name == "unrestricted" {
                seqs_unr = sim.num_sequences;
            }
        }
        let artifact = if seqs_unr > prev_seqs && prev_seqs > 0 {
            format!("{seqs_unr} (spill!)")
        } else {
            seqs_unr.to_string()
        };
        prev_seqs = seqs_unr;
        cells.push(artifact);
        cells.push(fmt_pct(speedup_pct(base_s, t5)));
        row.set("unrestricted_sequences", Json::from_usize(seqs_unr));
        row.set("speedup_5step_pct", Json::Num(speedup_pct(base_s, t5)));
        rows.push(row);
        table.row(cells);
    }
    table.print();
}

fn measured() {
    let Some(runtime) = bench::measured_runtime() else {
        println!("\n(measured section skipped: run `make artifacts`)");
        return;
    };
    println!("\n## Figure 10 (measured wall-clock, XLA-CPU, batch=4, 8ch 32x32)");
    let mut table = Table::new(&["blocks", "baseline", "1step", "5step", "unrestr", "best-speedup"]);
    for &blocks in bench::fig10_measured_blocks() {
        let mut cells = vec![blocks.to_string()];
        let mut t_base = f64::NAN;
        let mut best = f64::INFINITY;
        for (_, opts) in bench::fig10_strategies() {
            let mut engine =
                bench::build_measured(bench::block_engine(blocks, 4, 8, 32, opts), &runtime)
                    .unwrap();
            let input = engine.synthetic_input();
            if cells.len() == 1 {
                t_base = bench::measure(2, 5, || {
                    engine.run_baseline(input.clone()).unwrap();
                });
                cells.push(fmt_time(t_base));
            }
            let t = bench::measure(2, 5, || {
                engine.run(input.clone()).unwrap();
            });
            best = best.min(t);
            cells.push(fmt_time(t));
        }
        cells.push(fmt_pct(speedup_pct(t_base, best)));
        table.row(cells);
    }
    table.print();
}

fn main() {
    println!("# Figure 10 — Stacked Layers Acceleration");
    let mut rows = Vec::new();
    simulated(&DeviceSpec::paper_gpu(), &mut rows);
    simulated(&DeviceSpec::paper_cpu(), &mut rows);
    measured();
    bench::emit_bench_json("fig10_stacked_layers", rows);
}
