//! Memory-hierarchy simulation substrate.
//!
//! The paper's evaluation ran on a Xeon E5-2690v4 and a GTX 1080 Ti;
//! neither is available here, so per DESIGN.md §Substitutions the
//! paper-scale experiments (Figures 10–15, Tables 1–2) are regenerated
//! on an analytic model of exactly the quantity the paper's speed-ups
//! derive from — bytes moved between main memory and the fast tier —
//! plus the documented baseline pathologies (un-vectorized CPU kernels,
//! the Listing-4 pooling parallelism bug, per-kernel launch overheads).
//!
//! * [`traffic`] — FLOP and byte accounting per layer (breadth-first)
//!   and per collapsed sequence (depth-first, halo-aware).
//! * [`perfmodel`] — the time model and plan simulation.
//! * [`cache`] — a set-associative LRU cache simulator that validates
//!   the locality claim on raw address traces, independent of the
//!   analytic model's calibration.

pub mod cache;
pub mod perfmodel;
pub mod traffic;

pub use cache::{compare_schedules, Cache};
pub use perfmodel::{
    baseline_layer_time, baseline_optimized_time, branch_join_time, predicted_segments,
    segment_times, simulate_baseline, simulate_plan, speedup_pct, stack_time, BaselineSim,
    LayerTime, ModelParams, PlanSim, SegmentPrediction,
};
pub use traffic::{graph_cost_bf, layer_cost_bf, layer_flops, sequence_cost_df, UnitCost};
