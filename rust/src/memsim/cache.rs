//! Set-associative LRU cache simulator.
//!
//! A fine-grained substrate below the analytic traffic model: we generate
//! the actual address streams of breadth-first vs depth-first execution
//! of a stack and count cache misses, validating the paper's core claim
//! (depth-first keeps intermediates cache-resident) independently of the
//! time model's calibration constants. Used by unit/property tests and
//! the `memsim_ablation` example.

/// A set-associative cache with LRU replacement.
#[derive(Debug)]
pub struct Cache {
    sets: Vec<Vec<u64>>, // per set: tags, most-recent last
    assoc: usize,
    line: usize,
    set_count: usize,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    /// `size` bytes, `assoc`-way, `line`-byte lines. `size` must be a
    /// multiple of `assoc * line`.
    pub fn new(size: usize, assoc: usize, line: usize) -> Self {
        assert!(size % (assoc * line) == 0, "size not divisible");
        let set_count = size / (assoc * line);
        Cache {
            sets: vec![Vec::with_capacity(assoc); set_count],
            assoc,
            line,
            set_count,
            hits: 0,
            misses: 0,
        }
    }

    /// Access one byte address (read or write — write-allocate).
    pub fn access(&mut self, addr: u64) {
        let lineno = addr / self.line as u64;
        let set = (lineno % self.set_count as u64) as usize;
        let tag = lineno / self.set_count as u64;
        let ways = &mut self.sets[set];
        if let Some(pos) = ways.iter().position(|&t| t == tag) {
            ways.remove(pos);
            ways.push(tag);
            self.hits += 1;
        } else {
            if ways.len() == self.assoc {
                ways.remove(0);
            }
            ways.push(tag);
            self.misses += 1;
        }
    }

    /// Access a contiguous f32 range [start_elem, start_elem+len).
    pub fn access_range(&mut self, base: u64, start_elem: usize, len: usize) {
        for i in 0..len {
            self.access(base + (start_elem + i) as u64 * 4);
        }
    }

    pub fn miss_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.misses as f64 / total as f64
        }
    }

    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

/// A simplified stack of `depth` element-wise layers over a plane of
/// `elems` f32 values: generate the BF and DF access traces and return
/// (bf_misses, df_misses).
///
/// * breadth-first: layer by layer — read the whole input plane from a
///   full-size buffer, write the whole output plane to the next one (what
///   a framework's per-layer kernels do).
/// * depth-first: band by band of `band` elements — push one band through
///   all layers before the next band, with the intermediates held in two
///   small ping-pong scratch buffers (Listing 2's `cached_data`), reading
///   from the input plane and writing to the output plane only.
pub fn compare_schedules(elems: usize, depth: usize, band: usize, cache_bytes: usize) -> (u64, u64) {
    let plane = (elems * 4) as u64;
    // Distinct buffer per layer boundary, placed far apart.
    let buf = |i: usize| i as u64 * plane.next_power_of_two().max(64) * 2;

    let mut bf = Cache::new(cache_bytes, 8, 64);
    for layer in 0..depth {
        for e in 0..elems {
            bf.access(buf(layer) + e as u64 * 4); // read
            bf.access(buf(layer + 1) + e as u64 * 4); // write
        }
    }

    let mut df = Cache::new(cache_bytes, 8, 64);
    // Two band-sized scratch buffers, placed after the planes.
    let scratch_base = buf(depth + 1);
    let scratch = |i: usize| scratch_base + (i % 2) as u64 * (band as u64 * 4 + 64);
    let mut start = 0;
    while start < elems {
        let len = band.min(elems - start);
        for layer in 0..depth {
            // read source
            if layer == 0 {
                df.access_range(buf(0), start, len);
            } else {
                df.access_range(scratch(layer - 1), 0, len);
            }
            // write destination
            if layer == depth - 1 {
                df.access_range(buf(depth), start, len);
            } else {
                df.access_range(scratch(layer), 0, len);
            }
        }
        start += len;
    }

    (bf.misses, df.misses)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_hit_miss() {
        let mut c = Cache::new(1024, 2, 64);
        c.access(0);
        assert_eq!((c.hits, c.misses), (0, 1));
        c.access(4); // same line
        assert_eq!((c.hits, c.misses), (1, 1));
        c.access(64); // next line
        assert_eq!((c.hits, c.misses), (1, 2));
    }

    #[test]
    fn lru_eviction() {
        // 2-way, line 64, 2 sets => size 256.
        let mut c = Cache::new(256, 2, 64);
        // Three lines mapping to set 0: lines 0, 2, 4.
        c.access(0);
        c.access(2 * 64);
        c.access(4 * 64); // evicts line 0
        c.access(0); // miss again
        assert_eq!(c.misses, 4);
        assert_eq!(c.hits, 0);
        // line 4 is still resident (was MRU before line 0 refill).
        c.access(4 * 64);
        assert_eq!(c.hits, 1);
    }

    #[test]
    fn depth_first_has_fewer_misses_when_working_set_exceeds_cache() {
        // Plane 64 KiB (16384 f32) with a 16 KiB cache and 4 layers:
        // breadth-first thrashes; a 1 KiB band stays resident.
        let (bf, df) = compare_schedules(16384, 4, 256, 16 * 1024);
        assert!(
            (df as f64) < (bf as f64) * 0.5,
            "df misses {df} not < half of bf {bf}"
        );
    }

    #[test]
    fn compulsory_misses_only_when_everything_fits() {
        // Tiny plane entirely cache-resident: both schedules take only
        // compulsory misses; DF touches fewer distinct bytes (scratch
        // reuse), so it can only be <= BF.
        let (bf, df) = compare_schedules(512, 3, 128, 64 * 1024);
        assert!(df <= bf, "df {df} > bf {bf}");
        // All BF misses are compulsory: 4 planes of 512 f32 = 128 lines.
        assert_eq!(bf, 128);
    }

    #[test]
    fn miss_rate_sane() {
        let mut c = Cache::new(4096, 4, 64);
        for i in 0..1000u64 {
            c.access(i * 4);
        }
        assert!(c.miss_rate() > 0.0 && c.miss_rate() < 0.2);
    }
}
