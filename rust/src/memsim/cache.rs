//! Set-associative LRU cache simulator.
//!
//! A fine-grained substrate below the analytic traffic model: we generate
//! the actual address streams of breadth-first vs depth-first execution
//! of a stack and count cache misses, validating the paper's core claim
//! (depth-first keeps intermediates cache-resident) independently of the
//! time model's calibration constants. Used by unit/property tests and
//! the `memsim_ablation` example.

/// A set-associative cache with LRU replacement.
#[derive(Debug)]
pub struct Cache {
    sets: Vec<Vec<u64>>, // per set: tags, most-recent last
    assoc: usize,
    line: usize,
    set_count: usize,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    /// `size` bytes, `assoc`-way, `line`-byte lines.
    ///
    /// Policy: a `size` that is not a multiple of `assoc * line` is
    /// rounded **down** to a whole number of sets (modelling the usable
    /// capacity of an odd budget). Geometry that yields no set at all —
    /// zero `assoc`/`line`, or `size < assoc * line` — is an `Err`
    /// rather than a panic or a zero-set modulo downstream.
    pub fn new(size: usize, assoc: usize, line: usize) -> Result<Self, String> {
        if assoc == 0 || line == 0 {
            return Err(format!(
                "cache geometry: assoc={assoc} and line={line} must be nonzero"
            ));
        }
        let set_count = size / (assoc * line);
        if set_count == 0 {
            return Err(format!(
                "cache size {size} smaller than one set ({} bytes)",
                assoc * line
            ));
        }
        Ok(Cache {
            sets: vec![Vec::with_capacity(assoc); set_count],
            assoc,
            line,
            set_count,
            hits: 0,
            misses: 0,
        })
    }

    /// Access one byte address (read or write — write-allocate).
    pub fn access(&mut self, addr: u64) {
        let lineno = addr / self.line as u64;
        let set = (lineno % self.set_count as u64) as usize;
        let tag = lineno / self.set_count as u64;
        let ways = &mut self.sets[set];
        if let Some(pos) = ways.iter().position(|&t| t == tag) {
            ways.remove(pos);
            ways.push(tag);
            self.hits += 1;
        } else {
            if ways.len() == self.assoc {
                ways.remove(0);
            }
            ways.push(tag);
            self.misses += 1;
        }
    }

    /// Access a contiguous element range `[start_elem, start_elem+len)`
    /// of `elem_bytes`-wide elements (4 for f32, 2 for bf16, ...).
    pub fn access_range(&mut self, base: u64, start_elem: usize, len: usize, elem_bytes: usize) {
        for i in 0..len {
            self.access(base + ((start_elem + i) * elem_bytes) as u64);
        }
    }

    pub fn miss_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.misses as f64 / total as f64
        }
    }

    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

/// A simplified stack of `depth` element-wise layers over a plane of
/// `elems` f32 values: generate the BF and DF access traces and return
/// (bf_misses, df_misses).
///
/// * breadth-first: layer by layer — read the whole input plane from a
///   full-size buffer, write the whole output plane to the next one (what
///   a framework's per-layer kernels do).
/// * depth-first: band by band of `band` elements — push one band through
///   all layers before the next band, with the intermediates held in two
///   small ping-pong scratch buffers (Listing 2's `cached_data`), reading
///   from the input plane and writing to the output plane only.
pub fn compare_schedules(elems: usize, depth: usize, band: usize, cache_bytes: usize) -> (u64, u64) {
    let plane = (elems * 4) as u64;
    // Distinct buffer per layer boundary, placed far apart.
    let buf = |i: usize| i as u64 * plane.next_power_of_two().max(64) * 2;

    let mut bf = Cache::new(cache_bytes, 8, 64).expect("compare_schedules cache geometry");
    for layer in 0..depth {
        for e in 0..elems {
            bf.access(buf(layer) + e as u64 * 4); // read
            bf.access(buf(layer + 1) + e as u64 * 4); // write
        }
    }

    let mut df = Cache::new(cache_bytes, 8, 64).expect("compare_schedules cache geometry");
    // Two band-sized scratch buffers, placed after the planes.
    let scratch_base = buf(depth + 1);
    let scratch = |i: usize| scratch_base + (i % 2) as u64 * (band as u64 * 4 + 64);
    let mut start = 0;
    while start < elems {
        let len = band.min(elems - start);
        for layer in 0..depth {
            // read source
            if layer == 0 {
                df.access_range(buf(0), start, len, 4);
            } else {
                df.access_range(scratch(layer - 1), 0, len, 4);
            }
            // write destination
            if layer == depth - 1 {
                df.access_range(buf(depth), start, len, 4);
            } else {
                df.access_range(scratch(layer), 0, len, 4);
            }
        }
        start += len;
    }

    (bf.misses, df.misses)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_hit_miss() {
        let mut c = Cache::new(1024, 2, 64).unwrap();
        c.access(0);
        assert_eq!((c.hits, c.misses), (0, 1));
        c.access(4); // same line
        assert_eq!((c.hits, c.misses), (1, 1));
        c.access(64); // next line
        assert_eq!((c.hits, c.misses), (1, 2));
    }

    #[test]
    fn lru_eviction() {
        // 2-way, line 64, 2 sets => size 256.
        let mut c = Cache::new(256, 2, 64).unwrap();
        // Three lines mapping to set 0: lines 0, 2, 4.
        c.access(0);
        c.access(2 * 64);
        c.access(4 * 64); // evicts line 0
        c.access(0); // miss again
        assert_eq!(c.misses, 4);
        assert_eq!(c.hits, 0);
        // line 4 is still resident (was MRU before line 0 refill).
        c.access(4 * 64);
        assert_eq!(c.hits, 1);
    }

    #[test]
    fn depth_first_has_fewer_misses_when_working_set_exceeds_cache() {
        // Plane 64 KiB (16384 f32) with a 16 KiB cache and 4 layers:
        // breadth-first thrashes; a 1 KiB band stays resident.
        let (bf, df) = compare_schedules(16384, 4, 256, 16 * 1024);
        assert!(
            (df as f64) < (bf as f64) * 0.5,
            "df misses {df} not < half of bf {bf}"
        );
    }

    #[test]
    fn compulsory_misses_only_when_everything_fits() {
        // Tiny plane entirely cache-resident: both schedules take only
        // compulsory misses; DF touches fewer distinct bytes (scratch
        // reuse), so it can only be <= BF.
        let (bf, df) = compare_schedules(512, 3, 128, 64 * 1024);
        assert!(df <= bf, "df {df} > bf {bf}");
        // All BF misses are compulsory: 4 planes of 512 f32 = 128 lines.
        assert_eq!(bf, 128);
    }

    #[test]
    fn miss_rate_sane() {
        let mut c = Cache::new(4096, 4, 64).unwrap();
        for i in 0..1000u64 {
            c.access(i * 4);
        }
        assert!(c.miss_rate() > 0.0 && c.miss_rate() < 0.2);
    }

    #[test]
    fn non_divisible_size_rounds_down() {
        // 1000 B / (2-way * 64 B) = 7 whole sets (896 B usable) — used
        // to assert-panic. The zero-set modulo path is an error instead
        // of a divide-by-zero.
        let mut c = Cache::new(1000, 2, 64).unwrap();
        for i in 0..32u64 {
            c.access(i * 64);
        }
        assert_eq!(c.misses, 32);
    }

    #[test]
    fn degenerate_geometry_is_an_error() {
        assert!(Cache::new(63, 2, 64).is_err()); // below one set
        assert!(Cache::new(0, 8, 64).is_err());
        assert!(Cache::new(1024, 0, 64).is_err());
        assert!(Cache::new(1024, 8, 0).is_err());
    }

    #[test]
    fn access_range_is_dtype_aware() {
        // 32 elements: f64-wide spans 4 lines, f32 2 lines, bf16 1 line.
        for (elem_bytes, lines) in [(8usize, 4u64), (4, 2), (2, 1)] {
            let mut c = Cache::new(4096, 4, 64).unwrap();
            c.access_range(0, 0, 32, elem_bytes);
            assert_eq!(c.misses, lines, "elem_bytes {elem_bytes}");
            assert_eq!(c.hits, 32 - lines, "elem_bytes {elem_bytes}");
        }
    }
}
