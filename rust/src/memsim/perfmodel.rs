//! Analytic execution-time model: PyTorch-style breadth-first baseline
//! vs. BrainSlug depth-first plans on the paper's device models.
//!
//! The model is deliberately simple — `time = launch_overhead +
//! max(compute, memory)` per executed unit — plus the three *documented*
//! behaviours of the paper's baseline that drive its results:
//!
//! 1. **CPU element-wise/pooling kernels are not vectorized** (§5.1:
//!    "the current PyTorch implementation ... does not use any explicit
//!    vector processing instructions"), so their compute rate is the
//!    scalar rate. BrainSlug's ISPC kernels run vectorized.
//! 2. **CPU pooling parallelizes only over the batch dimension**
//!    (Listing 4's nested `omp parallel for` bug), so at batch < cores
//!    the baseline pooling uses `batch` cores. BrainSlug iterates over
//!    `batch × channels` and always uses all cores (§5.2).
//! 3. **Every baseline layer is a separate kernel launch**, while a
//!    collapsed sequence is one launch; BrainSlug adds a fixed per-stack
//!    scheduling overhead (gathering tensors, allocating outputs through
//!    the framework, §4.2), which is what makes small GPU batches
//!    slightly *slower* — exactly the paper's Table 1 left columns.
//!
//! Calibration constants live in [`ModelParams`]; EXPERIMENTS.md compares
//! the resulting table/figure shapes against the paper.

use crate::device::{DeviceKind, DeviceSpec};
use crate::graph::{Graph, Layer, Node, NodeId};
use crate::optimizer::{Plan, Segment, Stack};

use super::traffic::{layer_cost_bf, layer_flops, sequence_cost_df};

/// Calibration constants of the time model.
#[derive(Debug, Clone)]
pub struct ModelParams {
    /// Compute efficiency of tuned GEMM/conv libraries (cuDNN/MKL).
    pub conv_eff: f64,
    /// Compute efficiency of baseline element-wise/pool kernels.
    pub simple_eff: f64,
    /// Compute efficiency of BrainSlug generated kernels.
    pub stack_eff: f64,
    /// Fixed per-stack scheduler overhead (gather, allocate, dispatch).
    pub stack_overhead_s: f64,
    /// Fraction of peak memory bandwidth tuned kernels (GEMM libraries,
    /// BrainSlug's generated vectorized kernels) achieve.
    pub mem_eff: f64,
    /// Fraction of peak memory bandwidth the *baseline's* element-wise /
    /// pooling kernels achieve. On the paper's PyTorch 0.3 CPU path these
    /// are scalar, non-streaming loops (§5.1) — far off the roofline; on
    /// GPU they are ordinary CUDA kernels that stream reasonably well.
    pub simple_mem_eff: f64,
}

impl ModelParams {
    pub fn for_device(device: &DeviceSpec) -> Self {
        match device.kind {
            DeviceKind::Cpu => ModelParams {
                // PyTorch-0.3-era CPU convolutions were im2col+GEMM
                // (THNN), far below MKL's roofline.
                conv_eff: 0.22,
                simple_eff: 0.9,
                stack_eff: 0.5,
                stack_overhead_s: 4.0e-6,
                mem_eff: 0.85,
                simple_mem_eff: 0.18,
            },
            DeviceKind::Gpu => ModelParams {
                conv_eff: 0.60,
                simple_eff: 0.30,
                stack_eff: 0.30,
                // The paper's scheduler goes through the framework for
                // gathering/allocation on every stack execution.
                stack_overhead_s: 22.0e-6,
                mem_eff: 0.80,
                simple_mem_eff: 0.62,
            },
            DeviceKind::Tpu => ModelParams {
                conv_eff: 0.55,
                simple_eff: 0.30,
                stack_eff: 0.40,
                stack_overhead_s: 3.0e-6,
                mem_eff: 0.90,
                simple_mem_eff: 0.70,
            },
        }
    }
}

/// Simulated time of one baseline layer.
#[derive(Debug, Clone)]
pub struct LayerTime {
    pub node: usize,
    pub name: String,
    pub kind: &'static str,
    pub seconds: f64,
    pub optimizable: bool,
}

/// Baseline (breadth-first, PyTorch-style) simulation result.
#[derive(Debug, Clone)]
pub struct BaselineSim {
    pub per_layer: Vec<LayerTime>,
    pub total_s: f64,
    /// Time spent in optimizable layers.
    pub optimizable_s: f64,
}

/// BrainSlug plan simulation result.
#[derive(Debug, Clone)]
pub struct PlanSim {
    pub total_s: f64,
    /// Time spent in the depth-first schedule: collapsed stacks (incl.
    /// stack overheads) plus fused branch joins.
    pub stack_s: f64,
    /// Time spent in untouched layers.
    pub rest_s: f64,
    pub num_stacks: usize,
    pub num_sequences: usize,
    /// Branch regions executed arm-by-arm.
    pub num_branches: usize,
}

/// Is this layer served by a tuned GEMM library in the baseline?
fn is_gemm(layer: &Layer) -> bool {
    matches!(layer, Layer::Conv2d { .. } | Layer::Linear { .. })
}

/// Baseline time of a single layer on `device`.
pub fn baseline_layer_time(
    graph: &Graph,
    node: &Node,
    device: &DeviceSpec,
    p: &ModelParams,
) -> f64 {
    if matches!(node.layer, Layer::Input { .. } | Layer::Flatten) {
        return 0.0;
    }
    let cost = layer_cost_bf(graph, node);
    let flops = layer_flops(graph, node);

    let (compute_rate, mem_rate) = match device.kind {
        DeviceKind::Cpu => {
            let scalar_peak = device.peak_flops / device.simd_lanes as f64;
            if is_gemm(&node.layer) {
                (device.peak_flops * p.conv_eff, device.mem_bw * p.mem_eff)
            } else if matches!(node.layer, Layer::Pool2d { .. } | Layer::AdaptiveAvgPool { .. }) {
                // Listing 4: pooling parallelises over batch only.
                let batch = node.shape.batch().min(device.parallel_units);
                let frac = batch as f64 / device.parallel_units as f64;
                (
                    scalar_peak * p.simple_eff * frac,
                    device.mem_bw * p.simple_mem_eff
                        * frac.max(1.0 / device.parallel_units as f64),
                )
            } else {
                // Element-wise: parallel over all cores but scalar code.
                (scalar_peak * p.simple_eff, device.mem_bw * p.simple_mem_eff)
            }
        }
        DeviceKind::Gpu | DeviceKind::Tpu => {
            if is_gemm(&node.layer) {
                (device.peak_flops * p.conv_eff, device.mem_bw * p.mem_eff)
            } else {
                (
                    device.peak_flops * p.simple_eff,
                    device.mem_bw * p.simple_mem_eff,
                )
            }
        }
    };

    let t_compute = if flops > 0.0 { flops / compute_rate } else { 0.0 };
    let t_mem = cost.main_bytes / mem_rate;
    device.launch_overhead_s + t_compute.max(t_mem)
}

/// Simulate the whole network breadth-first.
pub fn simulate_baseline(graph: &Graph, device: &DeviceSpec) -> BaselineSim {
    let p = ModelParams::for_device(device);
    let mut per_layer = Vec::with_capacity(graph.nodes.len());
    let mut total = 0.0;
    let mut opt = 0.0;
    for node in graph.nodes.iter().skip(1) {
        let t = baseline_layer_time(graph, node, device, &p);
        total += t;
        if node.layer.is_optimizable() {
            opt += t;
        }
        per_layer.push(LayerTime {
            node: node.id,
            name: node.name.clone(),
            kind: node.layer.kind_name(),
            seconds: t,
            optimizable: node.layer.is_optimizable(),
        });
    }
    BaselineSim {
        per_layer,
        total_s: total,
        optimizable_s: opt,
    }
}

/// Time of one collapsed stack (all its sequences + stack overhead).
pub fn stack_time(graph: &Graph, stack: &Stack, device: &DeviceSpec, p: &ModelParams) -> f64 {
    let mut t = p.stack_overhead_s;
    for seq in &stack.sequences {
        let cost = sequence_cost_df(graph, seq);
        // BrainSlug kernels: vectorized, full parallelism (batch×channels
        // ×bands on every device).
        let t_compute = cost.flops / (device.peak_flops * p.stack_eff);
        let t_main = cost.main_bytes / (device.mem_bw * p.mem_eff);
        let t_cache = cost.cache_bytes / device.cache_bw;
        t += device.launch_overhead_s + t_compute.max(t_main).max(t_cache);
    }
    t
}

/// Join inputs a [`Segment::Branch`]'s depth-first schedule leaves in
/// the fast tier: the final arm's output (just produced band-wise),
/// plus each identity-skip read of the entry plane *when the arm
/// reservation actually held* (the shared
/// [`crate::optimizer::collapse::reservation_holds`] policy — a floored
/// reservation means the skip spilled and is re-read from main memory).
///
/// The pin check assumes the plan was built with the default zero base
/// [`crate::optimizer::CollapseOptions::reserved_bytes`] — the only
/// mode the in-tree planner uses; a caller-supplied base reservation is
/// not recoverable from the plan itself.
fn branch_resident_inputs(
    graph: &Graph,
    arms: &[Vec<Segment>],
    join: NodeId,
    device: &DeviceSpec,
) -> Vec<NodeId> {
    let mut resident = Vec::new();
    if let Some(out) = arms
        .iter()
        .rev()
        .find_map(|arm| arm.last())
        .and_then(|seg| seg.output_node())
    {
        resident.push(out);
    }
    let jn = graph.node(join);
    for (arm, &input) in arms.iter().zip(&jn.inputs) {
        if arm.is_empty() {
            let plane = crate::optimizer::plan::live_plane_bytes(&graph.node(input).shape);
            if crate::optimizer::collapse::reservation_holds(device, plane) {
                resident.push(input);
            }
        }
    }
    resident
}

/// Simulated time of a fused branch join (`Add`/`Concat` executed
/// band-wise as the tail of a [`Segment::Branch`]'s depth-first
/// schedule): no standalone kernel launch; `resident` inputs (the final
/// arm's output and any successfully pinned skip plane, one occurrence
/// each) are consumed from the fast tier, while the remaining arm
/// outputs stream from main memory at the depth-first kernels'
/// bandwidth efficiency.
pub fn branch_join_time(
    graph: &Graph,
    join: NodeId,
    resident: &[NodeId],
    device: &DeviceSpec,
    p: &ModelParams,
) -> f64 {
    let node = graph.node(join);
    let flops = layer_flops(graph, node);
    let mut main = node.shape.bytes() as f64; // write the join output
    let mut cache = 0.0;
    let mut resident = resident.to_vec();
    for &i in &node.inputs {
        let bytes = graph.node(i).shape.bytes() as f64;
        if let Some(pos) = resident.iter().position(|&r| r == i) {
            resident.swap_remove(pos);
            cache += bytes;
        } else {
            main += bytes;
        }
    }
    let t_compute = if flops > 0.0 {
        flops / (device.peak_flops * p.stack_eff)
    } else {
        0.0
    };
    let t_main = main / (device.mem_bw * p.mem_eff);
    let t_cache = cache / device.cache_bw;
    t_compute.max(t_main).max(t_cache)
}

/// Flattened per-unit simulated times of one plan segment. Branch
/// segments contribute their arm members in depth-first order followed
/// by the fused join (kind `"join"`). Shared by [`simulate_plan`] and
/// the sim backend so reported stats and simulated totals agree.
pub fn segment_times(
    graph: &Graph,
    seg: &Segment,
    device: &DeviceSpec,
    p: &ModelParams,
    out: &mut Vec<LayerTime>,
) {
    match seg {
        Segment::Single(id) => {
            let node = graph.node(*id);
            let name = crate::runtime::layer_exec_name(graph, node)
                .unwrap_or_else(|| format!("native:{}", node.name));
            out.push(LayerTime {
                node: *id,
                name,
                kind: node.layer.kind_name(),
                seconds: baseline_layer_time(graph, node, device, p),
                optimizable: node.layer.is_optimizable(),
            });
        }
        Segment::Stack(st) => {
            out.push(LayerTime {
                node: st.nodes[0],
                name: st.artifact_name(),
                kind: "stack",
                seconds: stack_time(graph, st, device, p),
                optimizable: true,
            });
        }
        Segment::Branch { arms, join } => {
            for arm in arms {
                for seg in arm {
                    segment_times(graph, seg, device, p, out);
                }
            }
            let resident = branch_resident_inputs(graph, arms, *join, device);
            out.push(LayerTime {
                node: *join,
                name: format!("branch_join:{}", graph.node(*join).name),
                kind: "join",
                seconds: branch_join_time(graph, *join, &resident, device, p),
                optimizable: true,
            });
        }
    }
}

/// Predicted time of one *top-level* plan segment — the unit the
/// tracing layer measures ([`crate::obs::SpanKind::Segment`] spans are
/// emitted per top-level segment), so drift reports can join predicted
/// against measured rows by label.
#[derive(Debug, Clone)]
pub struct SegmentPrediction {
    /// Stable join key: `seg{i}` for the i-th top-level plan segment —
    /// the prefix of the backend's `seg{i}:{kind}` span labels.
    pub label: String,
    /// Segment flavor: the layer kind for `Single`, `"stack"`,
    /// `"branch"`.
    pub kind: &'static str,
    /// Total modeled time of the segment (arms and join included for
    /// branches).
    pub seconds: f64,
}

/// Per-top-level-segment predictions for a whole plan — the memsim
/// side of the predicted-vs-measured drift report
/// ([`crate::obs::drift`], `brainslug trace --drift`, fig22).
pub fn predicted_segments(
    graph: &Graph,
    plan: &Plan,
    device: &DeviceSpec,
) -> Vec<SegmentPrediction> {
    let p = ModelParams::for_device(device);
    let mut scratch = Vec::new();
    plan.segments
        .iter()
        .enumerate()
        .map(|(i, seg)| {
            scratch.clear();
            segment_times(graph, seg, device, &p, &mut scratch);
            let kind = match seg {
                Segment::Single(id) => graph.node(*id).layer.kind_name(),
                Segment::Stack(_) => "stack",
                Segment::Branch { .. } => "branch",
            };
            SegmentPrediction {
                label: format!("seg{i}"),
                kind,
                seconds: scratch.iter().map(|lt| lt.seconds).sum(),
            }
        })
        .collect()
}

/// Baseline (breadth-first) time of exactly the layers the plan's
/// depth-first schedule absorbs: stack members everywhere plus each
/// fused branch join. This is the like-for-like baseline side for
/// [`PlanSim::stack_s`] in Table-2 style opt-speedup columns —
/// [`BaselineSim::optimizable_s`] excludes `Add`/`Concat` joins (they
/// are not `is_optimizable`), so comparing it against a `stack_s` that
/// includes fused-join time would mix mismatched sets.
pub fn baseline_optimized_time(graph: &Graph, plan: &Plan, device: &DeviceSpec) -> f64 {
    let p = ModelParams::for_device(device);
    fn seg_time(graph: &Graph, seg: &Segment, device: &DeviceSpec, p: &ModelParams) -> f64 {
        match seg {
            Segment::Single(_) => 0.0,
            Segment::Stack(st) => st
                .nodes
                .iter()
                .map(|&id| baseline_layer_time(graph, graph.node(id), device, p))
                .sum(),
            Segment::Branch { arms, join } => {
                let arms_s: f64 = arms
                    .iter()
                    .flatten()
                    .map(|seg| seg_time(graph, seg, device, p))
                    .sum();
                arms_s + baseline_layer_time(graph, graph.node(*join), device, p)
            }
        }
    }
    plan.segments
        .iter()
        .map(|seg| seg_time(graph, seg, device, &p))
        .sum()
}

/// Simulate a BrainSlug plan: stacks depth-first, branch regions
/// arm-by-arm with fused joins, the rest unchanged.
pub fn simulate_plan(graph: &Graph, plan: &Plan, device: &DeviceSpec) -> PlanSim {
    let p = ModelParams::for_device(device);
    let mut times = Vec::new();
    for seg in &plan.segments {
        segment_times(graph, seg, device, &p, &mut times);
    }
    let mut stack_s = 0.0;
    let mut rest_s = 0.0;
    for lt in &times {
        if lt.kind == "stack" || lt.kind == "join" {
            stack_s += lt.seconds;
        } else {
            rest_s += lt.seconds;
        }
    }
    let mut num_stacks = 0;
    let mut num_sequences = 0;
    for st in plan.stacks() {
        num_stacks += 1;
        num_sequences += st.sequences.len();
    }
    PlanSim {
        total_s: stack_s + rest_s,
        stack_s,
        rest_s,
        num_stacks,
        num_sequences,
        num_branches: plan.num_branches(),
    }
}

/// Speed-up in the paper's convention: `(t_base / t_bs - 1) * 100%`.
pub fn speedup_pct(t_base: f64, t_bs: f64) -> f64 {
    (t_base / t_bs - 1.0) * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::{optimize, CollapseOptions};
    use crate::zoo;

    fn sim(name: &str, batch: usize, device: &DeviceSpec) -> (f64, f64) {
        let g = zoo::build(name, zoo::paper_config(name, batch));
        let base = simulate_baseline(&g, device);
        let plan = optimize(&g, device, &CollapseOptions::default());
        let bs = simulate_plan(&g, &plan, device);
        (base.total_s, bs.total_s)
    }

    #[test]
    fn cpu_always_wins_at_batch_128() {
        let cpu = DeviceSpec::paper_cpu();
        for name in ["alexnet", "resnet18", "vgg16_bn", "squeezenet1_0", "densenet121"] {
            let (b, s) = sim(name, 128, &cpu);
            assert!(
                speedup_pct(b, s) > 0.0,
                "{name}: cpu speedup {:.1}% not positive",
                speedup_pct(b, s)
            );
        }
    }

    #[test]
    fn gpu_small_batch_can_regress_large_batch_wins() {
        let gpu = DeviceSpec::paper_gpu();
        // Table 1 (GPU): ResNet-18 at batch 1 is negative, at 32 positive.
        let (b1, s1) = sim("resnet18", 1, &gpu);
        let (b32, s32) = sim("resnet18", 32, &gpu);
        assert!(
            speedup_pct(b1, s1) < speedup_pct(b32, s32),
            "gpu speedup must grow with batch"
        );
        assert!(speedup_pct(b32, s32) > 0.0);
    }

    #[test]
    fn bn_vgg_gains_more_than_plain_vgg() {
        // Figure 13/14: VGG+BN gains exceed plain VGG because the BN
        // layers collapse for free.
        for device in [DeviceSpec::paper_cpu(), DeviceSpec::paper_gpu()] {
            let (b, s) = sim("vgg16", 128, &device);
            let (bb, sb) = sim("vgg16_bn", 128, &device);
            assert!(
                speedup_pct(bb, sb) > speedup_pct(b, s),
                "{}: vgg16_bn {:.1}% <= vgg16 {:.1}%",
                device.name,
                speedup_pct(bb, sb),
                speedup_pct(b, s)
            );
        }
    }

    #[test]
    fn densenet_among_top_gainers_on_gpu() {
        let gpu = DeviceSpec::paper_gpu();
        let (bd, sd) = sim("densenet121", 128, &gpu);
        let (br, sr) = sim("resnet152", 128, &gpu);
        assert!(
            speedup_pct(bd, sd) > speedup_pct(br, sr),
            "densenet121 {:.1}% should beat resnet152 {:.1}%",
            speedup_pct(bd, sd),
            speedup_pct(br, sr)
        );
    }

    #[test]
    fn cpu_batch1_pooling_bug_gives_large_gains() {
        // §5.2: the Listing-4 bug makes baseline pooling single-core at
        // batch 1, so SqueezeNet (pool-heavy) shows large CPU gains.
        let cpu = DeviceSpec::paper_cpu();
        let (b, s) = sim("squeezenet1_0", 1, &cpu);
        let pct = speedup_pct(b, s);
        assert!(pct > 15.0, "squeezenet1_0 cpu batch1 speedup {pct:.1}% too low");
    }

    #[test]
    fn optimizable_fraction_larger_on_gpu_than_cpu() {
        // Table 2: % of total time for optimizable layers is much larger
        // on GPU (13.7-47.4%) than on CPU (2.5-16.9%)? Note: CPU numbers
        // are lower because un-vectorized pooling inflates ... actually
        // the paper's CPU % is lower because convs are relatively slower
        // on CPU. Verify the GPU fraction exceeds the CPU fraction for
        // densenets.
        let g = zoo::build("densenet121", zoo::paper_config("densenet121", 128));
        let cpu = simulate_baseline(&g, &DeviceSpec::paper_cpu());
        let gpu = simulate_baseline(&g, &DeviceSpec::paper_gpu());
        let cpu_frac = cpu.optimizable_s / cpu.total_s;
        let gpu_frac = gpu.optimizable_s / gpu.total_s;
        assert!(
            gpu_frac > cpu_frac,
            "gpu opt fraction {gpu_frac:.2} <= cpu {cpu_frac:.2}"
        );
    }

    #[test]
    fn speedup_pct_convention() {
        assert!((speedup_pct(2.0, 1.0) - 100.0).abs() < 1e-12);
        assert!((speedup_pct(1.0, 2.0) + 50.0).abs() < 1e-12);
    }

    #[test]
    fn fused_branch_join_beats_standalone_add() {
        // Every resnet18 residual join: fused (no launch, last arm
        // resident) must be cheaper than the baseline standalone kernel.
        let gpu = DeviceSpec::paper_gpu();
        let p = ModelParams::for_device(&gpu);
        let g = zoo::build("resnet18", zoo::paper_config("resnet18", 1));
        let plan = optimize(&g, &gpu, &CollapseOptions::default());
        assert!(plan.num_branches() > 0);
        let mut checked = 0;
        for seg in &plan.segments {
            if let crate::optimizer::Segment::Branch { arms, join } = seg {
                let resident = branch_resident_inputs(&g, arms, *join, &gpu);
                // Every join consumes at least the final arm's output
                // from the fast tier; identity-skip blocks (no
                // downsample projection) additionally pin the skip
                // plane, which fits the reservation floor at every
                // resnet18 stage.
                let has_identity_skip = arms.iter().any(|a| a.is_empty());
                assert_eq!(resident.len(), 1 + usize::from(has_identity_skip));
                let fused = branch_join_time(&g, *join, &resident, &gpu, &p);
                let standalone = baseline_layer_time(&g, g.node(*join), &gpu, &p);
                assert!(
                    fused < standalone,
                    "join {join}: fused {fused:.3e} !< standalone {standalone:.3e}"
                );
                checked += 1;
            }
        }
        assert_eq!(checked, 8); // one per basic block
    }

    #[test]
    fn baseline_optimized_time_covers_stacks_and_joins() {
        let gpu = DeviceSpec::paper_gpu();
        let g = zoo::build("resnet18", zoo::paper_config("resnet18", 1));
        let plan = optimize(&g, &gpu, &CollapseOptions::default());
        let opt_base = baseline_optimized_time(&g, &plan, &gpu);
        let base = simulate_baseline(&g, &gpu);
        // Strictly more than the optimizable-layer time (the 8 fused
        // joins are in the optimized set), strictly less than the whole
        // network (convs and the classifier stay out).
        assert!(opt_base > base.optimizable_s);
        assert!(opt_base < base.total_s);
    }

    #[test]
    fn branchy_plan_sim_reports_branches() {
        let gpu = DeviceSpec::paper_gpu();
        let g = zoo::build("densenet121", zoo::paper_config("densenet121", 1));
        let plan = optimize(&g, &gpu, &CollapseOptions::default());
        let sim = simulate_plan(&g, &plan, &gpu);
        assert_eq!(sim.num_branches, 58); // one per dense layer
        assert!(sim.total_s.is_finite() && sim.total_s > 0.0);
        assert!((sim.total_s - sim.stack_s - sim.rest_s).abs() <= 1e-12 * sim.total_s);
    }
}
