//! Per-layer FLOP and main-memory traffic accounting.
//!
//! Breadth-first execution materializes every layer's output: each layer
//! reads its inputs and parameters from main memory and writes its output
//! back. Depth-first execution of a collapsed sequence reads the sequence
//! input once (times the halo redundancy factor) and writes only the
//! sequence output; all intermediates stay in the fast tier. These byte
//! counts are the quantity the paper's speed-ups derive from, and they
//! feed the [`super::perfmodel`] time model.

use crate::graph::{Graph, Layer, Node, PoolKind, Shape};
use crate::optimizer::Sequence;

/// FLOPs and byte movement of one executed unit (layer or sequence).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct UnitCost {
    /// Floating-point operations (multiply-accumulate counted as 2).
    pub flops: f64,
    /// Bytes read from + written to main memory.
    pub main_bytes: f64,
    /// Bytes moved through the fast tier (cache/smem/VMEM) beyond the
    /// main-memory traffic (depth-first intermediates).
    pub cache_bytes: f64,
}

impl UnitCost {
    pub fn add(&mut self, other: &UnitCost) {
        self.flops += other.flops;
        self.main_bytes += other.main_bytes;
        self.cache_bytes += other.cache_bytes;
    }
}

/// Parameter bytes of a node (conv weights, BN stats, ...).
fn param_bytes(graph: &Graph, node: &Node) -> f64 {
    let input = match node.inputs.first() {
        Some(&i) => &graph.node(i).shape,
        None => return 0.0,
    };
    node.layer
        .param_shapes(input)
        .iter()
        .map(|s| s.bytes() as f64)
        .sum()
}

/// FLOPs of one layer.
pub fn layer_flops(graph: &Graph, node: &Node) -> f64 {
    let out = &node.shape;
    let input = node.inputs.first().map(|&i| &graph.node(i).shape);
    match &node.layer {
        Layer::Input { .. } => 0.0,
        Layer::Conv2d { window, bias, .. } => {
            let cin = input.expect("conv input").channels() as f64;
            let mac = out.numel() as f64 * cin * (window.kernel.0 * window.kernel.1) as f64;
            2.0 * mac + if *bias { out.numel() as f64 } else { 0.0 }
        }
        Layer::Linear { bias, .. } => {
            let cin = input.expect("linear input").channels() as f64;
            2.0 * out.numel() as f64 * cin + if *bias { out.numel() as f64 } else { 0.0 }
        }
        Layer::Pool2d { window, kind, .. } => {
            let per_out = (window.kernel.0 * window.kernel.1) as f64
                + if matches!(kind, PoolKind::Avg) { 1.0 } else { 0.0 };
            out.numel() as f64 * per_out
        }
        Layer::AdaptiveAvgPool { .. } => {
            input.map_or(0.0, |i| i.numel() as f64) + out.numel() as f64
        }
        // Folded inference BN: one multiply + one add per element.
        Layer::BatchNorm2d { .. } => 2.0 * out.numel() as f64,
        Layer::Relu => out.numel() as f64,
        Layer::Add => out.numel() as f64,
        Layer::Dropout { .. } | Layer::Flatten | Layer::Concat => 0.0,
    }
}

/// Breadth-first cost of one layer: read inputs + params, write output.
pub fn layer_cost_bf(graph: &Graph, node: &Node) -> UnitCost {
    if matches!(node.layer, Layer::Input { .. }) {
        return UnitCost::default();
    }
    let in_bytes: f64 = node
        .inputs
        .iter()
        .map(|&i| graph.node(i).shape.bytes() as f64)
        .sum();
    // Flatten is a metadata-only reshape in every framework.
    if matches!(node.layer, Layer::Flatten) {
        return UnitCost::default();
    }
    UnitCost {
        flops: layer_flops(graph, node),
        main_bytes: in_bytes + node.shape.bytes() as f64 + param_bytes(graph, node),
        cache_bytes: 0.0,
    }
}

/// Depth-first cost of one collapsed sequence: input (with halo
/// redundancy) + params in, output out; intermediates through the fast
/// tier only. FLOPs also scale with the halo factor — overlapping bands
/// recompute halo values (§7 Limitations discusses exactly this
/// redundancy).
pub fn sequence_cost_df(graph: &Graph, seq: &Sequence) -> UnitCost {
    let halo = seq.halo_overlap_factor();
    let in_bytes = seq.in_shape().bytes() as f64;
    let out_bytes = seq.out_shape().bytes() as f64;

    let mut flops = 0.0;
    let mut params = 0.0;
    let mut inter_bytes = 0.0;
    let all_ops: Vec<_> = seq.steps.iter().flat_map(|s| &s.ops).collect();
    for (i, op) in all_ops.iter().enumerate() {
        let node = graph.node(op.node);
        flops += layer_flops(graph, node);
        params += param_bytes(graph, node);
        // Every op boundary except the last writes an intermediate into
        // the fast tier (and the next op reads it back).
        if i + 1 < all_ops.len() {
            inter_bytes += 2.0 * op.out_shape.bytes() as f64;
        }
    }
    UnitCost {
        flops: flops * halo,
        main_bytes: in_bytes * halo + out_bytes + params,
        cache_bytes: inter_bytes * halo + (in_bytes + out_bytes) * halo.max(1.0),
    }
}

/// Whole-network breadth-first totals.
pub fn graph_cost_bf(graph: &Graph) -> UnitCost {
    let mut total = UnitCost::default();
    for node in graph.nodes.iter().skip(1) {
        total.add(&layer_cost_bf(graph, node));
    }
    total
}

/// Shape helper used by reports.
pub fn activation_bytes(shape: &Shape) -> f64 {
    shape.bytes() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceSpec;
    use crate::graph::{Layer, Window2d};
    use crate::optimizer::{optimize, CollapseOptions, Segment};

    fn stacked_net(blocks: usize, c: usize, h: usize) -> Graph {
        let mut g = Graph::new("blocks", Shape::nchw(1, c, h, h));
        for i in 0..blocks {
            g.push(
                format!("b{i}.pool"),
                Layer::Pool2d {
                    kind: PoolKind::Max,
                    window: Window2d::square(3, 1, 1),
                    ceil_mode: false,
                    count_include_pad: true,
                },
            );
            g.push(format!("b{i}.bn"), Layer::BatchNorm2d { eps: 1e-5 });
            g.push(format!("b{i}.relu"), Layer::Relu);
        }
        g
    }

    #[test]
    fn conv_flops_formula() {
        let mut g = Graph::new("c", Shape::nchw(1, 3, 8, 8));
        g.push(
            "conv",
            Layer::Conv2d {
                out_channels: 16,
                window: Window2d::square(3, 1, 1),
                bias: false,
            },
        );
        let node = g.node(1);
        // 2 * (1*16*8*8) * 3 * 9
        assert_eq!(layer_flops(&g, node), 2.0 * 1024.0 * 27.0);
    }

    #[test]
    fn df_moves_fewer_main_bytes_than_bf() {
        let g = stacked_net(5, 16, 64);
        let plan = optimize(&g, &DeviceSpec::paper_gpu(), &CollapseOptions::default());
        let bf = graph_cost_bf(&g);
        let mut df = UnitCost::default();
        for seg in &plan.segments {
            match seg {
                Segment::Stack(st) => {
                    for seq in &st.sequences {
                        df.add(&sequence_cost_df(&g, seq));
                    }
                }
                Segment::Single(id) => df.add(&layer_cost_bf(&g, g.node(*id))),
                Segment::Branch { .. } => unreachable!("linear net has no branches"),
            }
        }
        assert!(
            df.main_bytes < bf.main_bytes * 0.5,
            "df {} vs bf {}",
            df.main_bytes,
            bf.main_bytes
        );
        // But the intermediates now travel through the fast tier.
        assert!(df.cache_bytes > 0.0);
    }

    #[test]
    fn bf_totals_scale_with_batch() {
        let g1 = stacked_net(2, 8, 32);
        let g4 = g1.with_batch(4);
        let c1 = graph_cost_bf(&g1);
        let c4 = graph_cost_bf(&g4);
        assert!((c4.flops / c1.flops - 4.0).abs() < 1e-9);
        // bytes scale slightly sub-4x because params are batch-invariant.
        assert!(c4.main_bytes < 4.0 * c1.main_bytes);
        assert!(c4.main_bytes > 3.5 * c1.main_bytes);
    }

    #[test]
    fn flatten_and_dropout_are_free() {
        let mut g = Graph::new("f", Shape::nchw(1, 4, 4, 4));
        g.push("flatten", Layer::Flatten);
        let n = g.node(1);
        assert_eq!(layer_cost_bf(&g, n), UnitCost::default());
    }
}
