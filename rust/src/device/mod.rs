//! Device models: the hardware parameters the collapser packs sequences
//! against (§4.1 step 3 — "the Collapser retrieves device specs from the
//! back-end(s), e.g. cache sizes") and the cost parameters the
//! memory-traffic simulator uses.
//!
//! Three presets mirror the paper's testbed plus the TPU adaptation:
//! * [`DeviceSpec::paper_cpu`] — Intel Xeon E5-2690v4 (Broadwell, 14C,
//!   AVX2, 32 KiB L1d per core).
//! * [`DeviceSpec::paper_gpu`] — NVIDIA GTX 1080 Ti (28 SMs; the paper
//!   deliberately budgets only 16 KiB of the 96 KiB shared memory and 128
//!   threads per block, §4.4).
//! * [`DeviceSpec::tpu_core`] — a TPU-v4-like core for the Pallas/VMEM
//!   sizing (§Hardware-Adaptation in DESIGN.md).

/// Kind of device, selecting cost-model behaviours in `memsim`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    Cpu,
    Gpu,
    Tpu,
}

/// Hardware description consumed by the collapser and the cost models.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub name: String,
    pub kind: DeviceKind,
    /// Fast-memory budget *per concurrent work unit* in bytes: usable L1d
    /// on CPU, the shared-memory budget per thread block on GPU, the VMEM
    /// tile budget on TPU. This is the paper's `device.resourceLimit()`.
    pub fast_mem_bytes: usize,
    /// SIMD lanes that share one fast memory (8 for AVX2 f32, 128 CUDA
    /// threads per block, 8×128 VPU sublanes×lanes on TPU).
    pub simd_lanes: usize,
    /// Independent work units (cores / resident blocks / cores).
    pub parallel_units: usize,
    /// Peak main-memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Fast-tier (cache/smem/VMEM) bandwidth, bytes/s (aggregate).
    pub cache_bw: f64,
    /// Peak f32 FLOP/s.
    pub peak_flops: f64,
    /// Fixed overhead per kernel/executable launch, seconds.
    pub launch_overhead_s: f64,
}

impl DeviceSpec {
    /// The paper's CPU testbed: Xeon E5-2690v4 — 14 cores @ 2.6 GHz,
    /// AVX2 (8-wide f32 FMA), 32 KiB L1d, ~76 GB/s DDR4-2400.
    pub fn paper_cpu() -> Self {
        DeviceSpec {
            name: "xeon-e5-2690v4".into(),
            kind: DeviceKind::Cpu,
            // Half of L1d usable for the working set (rest: code, stack,
            // streaming buffers) — the collapser's budget.
            fast_mem_bytes: 16 * 1024,
            simd_lanes: 8,
            parallel_units: 14,
            mem_bw: 76.8e9,
            cache_bw: 14.0 * 100.0e9, // ~100 GB/s L1 per core
            peak_flops: 14.0 * 2.6e9 * 8.0 * 2.0, // FMA
            launch_overhead_s: 2.0e-6,
        }
    }

    /// The paper's GPU testbed: GTX 1080 Ti — 28 SMs, 484 GB/s GDDR5X,
    /// ~11.3 TFLOP/s f32. The paper limits each block to 16 KiB shared
    /// memory and 128 threads (§4.4).
    pub fn paper_gpu() -> Self {
        DeviceSpec {
            name: "gtx-1080ti".into(),
            kind: DeviceKind::Gpu,
            fast_mem_bytes: 16 * 1024,
            simd_lanes: 128,
            parallel_units: 28 * 4, // resident blocks for latency hiding
            mem_bw: 484.0e9,
            cache_bw: 28.0 * 128.0e9, // aggregate smem bandwidth
            peak_flops: 11.3e12,
            launch_overhead_s: 5.0e-6,
        }
    }

    /// TPU-like core used for the Pallas/VMEM adaptation: ~16 MiB VMEM,
    /// 8×128 VPU lanes; budget a 128 KiB working tile so many tiles are
    /// in flight (double-buffering + pipelining).
    pub fn tpu_core() -> Self {
        DeviceSpec {
            name: "tpu-core".into(),
            kind: DeviceKind::Tpu,
            fast_mem_bytes: 128 * 1024,
            simd_lanes: 8 * 128,
            parallel_units: 2,
            mem_bw: 1.2e12,
            cache_bw: 8.0e12,
            peak_flops: 275.0e12 / 2.0, // MXU bf16; VPU f32 far lower
            launch_overhead_s: 1.0e-6,
        }
    }

    /// The host this repo actually measures on (container CPU, XLA:CPU
    /// backend). Used by the measured-mode harness for tile sizing.
    pub fn host_cpu() -> Self {
        let cores = std::thread::available_parallelism().map_or(4, |n| n.get());
        DeviceSpec {
            name: "host-cpu".into(),
            kind: DeviceKind::Cpu,
            fast_mem_bytes: 16 * 1024,
            simd_lanes: 8,
            parallel_units: cores,
            mem_bw: 20.0e9,
            cache_bw: cores as f64 * 80.0e9,
            peak_flops: cores as f64 * 3.0e9 * 8.0 * 2.0,
            launch_overhead_s: 10.0e-6,
        }
    }

    /// Look up a preset by name (CLI `--device`). Canonical device
    /// names (`DeviceSpec::name`, e.g. "gtx-1080ti") also resolve, so
    /// a spec can round-trip through its own name — `simulate --exp
    /// table2` forwards `device.name` back into this lookup.
    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "paper-cpu" | "cpu" | "xeon-e5-2690v4" => Some(Self::paper_cpu()),
            "paper-gpu" | "gpu" | "gtx-1080ti" => Some(Self::paper_gpu()),
            "tpu" | "tpu-core" => Some(Self::tpu_core()),
            "host" | "host-cpu" => Some(Self::host_cpu()),
            _ => None,
        }
    }

    /// The valid [`Self::preset`] names, for actionable CLI errors —
    /// every `preset()` miss should surface this list, not a bare
    /// "unknown preset".
    pub fn preset_names() -> &'static str {
        "paper-cpu (alias: cpu), paper-gpu (alias: gpu), tpu, host"
    }

    /// `resourceLimit()` of Listing 1: bytes one work unit may keep
    /// resident in the fast tier.
    pub fn resource_limit(&self) -> usize {
        self.fast_mem_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for n in ["paper-cpu", "paper-gpu", "tpu", "host", "cpu", "gpu"] {
            assert!(DeviceSpec::preset(n).is_some(), "{n}");
        }
        assert!(DeviceSpec::preset("fpga").is_none());
    }

    #[test]
    fn preset_names_list_every_canonical_preset() {
        let names = DeviceSpec::preset_names();
        for n in ["paper-cpu", "paper-gpu", "tpu", "host"] {
            assert!(names.contains(n), "{n} missing from preset_names()");
        }
    }

    #[test]
    fn presets_roundtrip_through_their_own_names() {
        // `simulate --exp table2` forwards device.name back into
        // preset(); every spec must resolve to itself.
        for key in ["paper-cpu", "paper-gpu", "tpu", "host"] {
            let spec = DeviceSpec::preset(key).unwrap();
            let again = DeviceSpec::preset(&spec.name).unwrap();
            assert_eq!(spec.name, again.name, "{key}");
        }
    }

    #[test]
    fn paper_budgets_match_section_4_4() {
        let gpu = DeviceSpec::paper_gpu();
        assert_eq!(gpu.fast_mem_bytes, 16 * 1024);
        assert_eq!(gpu.simd_lanes, 128);
        let cpu = DeviceSpec::paper_cpu();
        assert_eq!(cpu.simd_lanes, 8); // AVX2 f32
    }

    #[test]
    fn sane_magnitudes() {
        for d in [
            DeviceSpec::paper_cpu(),
            DeviceSpec::paper_gpu(),
            DeviceSpec::tpu_core(),
            DeviceSpec::host_cpu(),
        ] {
            assert!(d.mem_bw > 1e9 && d.mem_bw < 1e13, "{}", d.name);
            assert!(d.cache_bw > d.mem_bw, "{}", d.name);
            assert!(d.peak_flops > 1e10, "{}", d.name);
            assert!(d.fast_mem_bytes >= 4096, "{}", d.name);
        }
    }
}
