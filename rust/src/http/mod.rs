//! HTTP/JSON serving front door: the wire face of [`crate::server`].
//!
//! BrainSlug's serving story so far ended at an in-process Rust API;
//! this module puts the batching worker pool behind a zero-dependency
//! HTTP/1.1 endpoint so the "millions of users" traffic the ROADMAP
//! targets has a protocol to arrive on:
//!
//! * [`wire`] — request parsing / response serialisation (keep-alive,
//!   `Content-Length` framing, bounded header and body sizes),
//! * [`router`] — `POST /v1/run`, `GET /v1/stats`, `GET /v1/metrics`
//!   (Prometheus text exposition), `GET /healthz`, with lazy JSON
//!   field extraction ([`crate::json::scan_str_field`] and friends) so
//!   the hot path never builds a document tree,
//! * [`listener`] — `TcpListener` accept loop plus a bounded
//!   connection-thread pool,
//! * [`load`] — the closed/open-loop load generator behind
//!   `brainslug bench-serve`.
//!
//! Backpressure is end-to-end: a full connection channel sheds at the
//! accept stage with 503, and a full dispatch queue (under
//! [`crate::server::QueuePolicy::Reject`]) surfaces as 503 + a
//! queue-depth-aware `Retry-After` per request. Shutdown is graceful by
//! construction — see [`listener`] for the ordering contract.
//!
//! The whole path is stormable under [`crate::fault`]: worker panics
//! surface as transient 503s while the replica rebuilds, expired
//! per-request deadlines (`x-brainslug-deadline-ms`) as 504, slow-loris
//! clients as 408, and injected socket resets / partial writes exercise
//! the reconnect and [`wire::write_full`] retry paths. See DESIGN.md
//! §Fault Injection & Recovery.
//!
//! Observability (DESIGN.md §Observability): every routed response
//! echoes an `x-brainslug-trace` id (client-supplied or minted), and
//! `GET /v1/metrics` exposes the serving counters plus per-segment
//! execution histograms in the Prometheus text format.

pub mod listener;
pub mod load;
pub mod router;
pub mod wire;

pub use listener::{HttpConfig, HttpServer};
pub use load::{
    closed_loop, closed_loop_with, one_shot, one_shot_with, open_loop, ClientConn, ClientResponse,
    LoadReport, RetryPolicy,
};
pub use router::AppState;
pub use wire::{Request, Response, WireError, WireLimits};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench;
    use crate::device::DeviceSpec;
    use crate::engine::{Engine, EngineBuilder};
    use crate::json::{self, Json};
    use crate::optimizer::CollapseOptions;
    use crate::server::{QueuePolicy, ServerConfig};
    use std::io::{Read, Write};
    use std::net::TcpStream;

    /// Builder for a sim-backed engine over a tiny block network with
    /// batch `b` (unpaced).
    fn sim_builder(b: usize) -> EngineBuilder {
        Engine::builder()
            .graph_owned(bench::block_net(1, b, 2, 8))
            .device(DeviceSpec::tpu_core())
            .brainslug(CollapseOptions::default())
            .sim()
            .seed(11)
    }

    /// Pacing scale that makes one batch cost roughly `target` seconds
    /// of wall-clock (same calibration as the server tests).
    fn pace_scale_for(b: usize, target: f64) -> f64 {
        let mut probe = sim_builder(b).build().unwrap();
        let input = probe.synthetic_input();
        let (_, st) = probe.run(input).unwrap();
        target / st.total_s.max(1e-12)
    }

    fn start_http(config: ServerConfig) -> HttpServer {
        let server = config.start().unwrap();
        HttpServer::start(server, HttpConfig::new("127.0.0.1:0")).unwrap()
    }

    fn run_body(state: &AppState, input: &[f32]) -> String {
        let mut o = Json::object();
        o.set("model", Json::Str(state.model.clone()));
        o.set(
            "input",
            Json::Arr(input.iter().map(|v| Json::Num(*v as f64)).collect()),
        );
        o.to_string_compact()
    }

    #[test]
    fn http_output_matches_in_process_run() {
        let http = start_http(ServerConfig::new(sim_builder(1)));
        let addr = http.addr().to_string();
        let state = http.state().clone();
        let input = crate::rng::fill_f32(3, state.image_elems);
        let body = run_body(&state, &input);
        let resp = one_shot(&addr, "POST", "/v1/run", Some(body.as_bytes())).unwrap();
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let parsed = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let wire_out: Vec<f32> = parsed
            .arr_field("output")
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let direct = state.handle.infer(input).unwrap();
        assert_eq!(wire_out, direct.data, "wire output diverges from engine.run");
        http.shutdown();
    }

    #[test]
    fn healthz_stats_and_errors_over_the_wire() {
        let http = start_http(ServerConfig::new(sim_builder(1)));
        let addr = http.addr().to_string();
        assert_ne!(http.addr().port(), 0, "ephemeral port resolved");

        let resp = one_shot(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(resp.status, 200);
        let parsed = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert!(parsed.bool_field("ok").unwrap());
        assert_eq!(parsed.str_field("state").unwrap(), "ready");

        let resp = one_shot(&addr, "GET", "/v1/stats", None).unwrap();
        assert_eq!(resp.status, 200);
        let parsed = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(parsed.str_field("model").unwrap(), http.state().model);
        assert!(parsed.usize_field("image_elems").unwrap() > 0);

        assert_eq!(one_shot(&addr, "GET", "/nope", None).unwrap().status, 404);
        let resp = one_shot(&addr, "GET", "/v1/run", None).unwrap();
        assert_eq!(resp.status, 405);
        assert_eq!(resp.header("allow"), Some("POST"));
        let resp = one_shot(&addr, "POST", "/v1/run", Some(b"not json")).unwrap();
        assert_eq!(resp.status, 400);
        http.shutdown();
    }

    /// Satellite: the `x-brainslug-trace` header round-trips over a
    /// real socket — client ids are echoed verbatim (zero-padded to 16
    /// hex digits), absent ids are minted, and error responses carry
    /// the echo too.
    #[test]
    fn trace_header_round_trips_over_the_wire() {
        let http = start_http(ServerConfig::new(sim_builder(1)));
        let addr = http.addr().to_string();
        let resp = one_shot_with(
            &addr,
            "GET",
            "/healthz",
            &[("x-brainslug-trace", "deadbeef")],
            None,
        )
        .unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("x-brainslug-trace"), Some("00000000deadbeef"));
        // No client id: the server mints one — 16 hex digits, non-zero.
        let resp = one_shot(&addr, "GET", "/healthz", None).unwrap();
        let minted = resp.header("x-brainslug-trace").expect("minted id");
        assert_eq!(minted.len(), 16, "{minted}");
        assert!(u64::from_str_radix(minted, 16).is_ok_and(|t| t != 0), "{minted}");
        // Error paths echo too (404 and 405 here).
        let resp = one_shot_with(
            &addr,
            "GET",
            "/nope",
            &[("x-brainslug-trace", "17")],
            None,
        )
        .unwrap();
        assert_eq!(resp.status, 404);
        assert_eq!(resp.header("x-brainslug-trace"), Some("0000000000000017"));
        let resp = one_shot_with(
            &addr,
            "GET",
            "/v1/run",
            &[("x-brainslug-trace", "17")],
            None,
        )
        .unwrap();
        assert_eq!(resp.status, 405);
        assert_eq!(resp.header("x-brainslug-trace"), Some("0000000000000017"));
        http.shutdown();
    }

    /// Satellite: the `/v1/stats` percentiles come from histogram
    /// bucket midpoints ([`crate::obs::MIDPOINT_REL_ERROR`]); the load
    /// harness measures raw client-side samples. The two views of the
    /// same traffic must agree to within the documented band (plus a
    /// small absolute allowance for the client's connection overhead).
    #[test]
    fn client_and_server_p50_agree_within_midpoint_error() {
        let scale = pace_scale_for(1, 0.010);
        let http = start_http(
            ServerConfig::new(sim_builder(1).sim_paced(scale))
                .workers(1)
                .queue_depth(16),
        );
        let addr = http.addr().to_string();
        let state = http.state().clone();
        let body = run_body(&state, &vec![0.5; state.image_elems]);
        let report = closed_loop(&addr, 1, 20, body.as_bytes());
        assert_eq!(report.ok, 20, "errors={}", report.errors);
        let resp = one_shot(&addr, "GET", "/v1/stats", None).unwrap();
        let parsed = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(
            parsed.str_field("percentile_source").unwrap(),
            "histogram-midpoint"
        );
        let server_p50 = parsed.f64_field("p50_ms").unwrap();
        let client_p50 = report.p50_ms();
        assert!(server_p50 > 0.0 && client_p50 > 0.0);
        let band = server_p50 * crate::obs::MIDPOINT_REL_ERROR + 3.0;
        assert!(
            (client_p50 - server_p50).abs() <= band,
            "client p50 {client_p50:.3} ms vs server p50 {server_p50:.3} ms \
             (band {band:.3} ms)"
        );
        http.shutdown();
    }

    #[test]
    fn malformed_request_line_gets_400_and_close() {
        let http = start_http(ServerConfig::new(sim_builder(1)));
        let mut stream = TcpStream::connect(http.addr()).unwrap();
        stream.write_all(b"BOGUS\r\n\r\n").unwrap();
        let mut raw = String::new();
        stream.read_to_string(&mut raw).unwrap(); // server closes → EOF
        assert!(raw.starts_with("HTTP/1.1 400 "), "{raw}");
        assert!(raw.contains("connection: close"), "{raw}");
        http.shutdown();
    }

    #[test]
    fn oversized_body_gets_413_and_close() {
        let server = ServerConfig::new(sim_builder(1)).start().unwrap();
        let mut cfg = HttpConfig::new("127.0.0.1:0");
        cfg.limits.max_body_bytes = 64;
        let http = HttpServer::start(server, cfg).unwrap();
        let mut stream = TcpStream::connect(http.addr()).unwrap();
        // Declared length over the limit; body never sent.
        stream
            .write_all(b"POST /v1/run HTTP/1.1\r\ncontent-length: 65\r\n\r\n")
            .unwrap();
        let mut raw = String::new();
        stream.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 413 "), "{raw}");
        assert!(raw.contains("connection: close"), "{raw}");
        http.shutdown();
    }

    #[test]
    fn pipelined_keep_alive_requests_both_answered() {
        let http = start_http(ServerConfig::new(sim_builder(1)));
        let mut stream = TcpStream::connect(http.addr()).unwrap();
        stream
            .write_all(
                b"GET /healthz HTTP/1.1\r\n\r\nGET /healthz HTTP/1.1\r\nconnection: close\r\n\r\n",
            )
            .unwrap();
        let mut raw = String::new();
        stream.read_to_string(&mut raw).unwrap();
        assert_eq!(raw.matches("HTTP/1.1 200 OK").count(), 2, "{raw}");
        assert_eq!(raw.matches("\"ok\":true").count(), 2, "{raw}");
        http.shutdown();
    }

    #[test]
    fn concurrent_clients_against_paced_engine() {
        let scale = pace_scale_for(2, 0.004);
        let http = start_http(
            ServerConfig::new(sim_builder(2).sim_paced(scale))
                .workers(2)
                .queue_depth(16),
        );
        let addr = http.addr().to_string();
        let state = http.state().clone();
        let input = crate::rng::fill_f32(5, state.image_elems);
        let body = run_body(&state, &input);
        let report = closed_loop(&addr, 4, 5, body.as_bytes());
        assert_eq!(report.sent, 20);
        assert_eq!(report.ok, 20, "errors={} rejected={}", report.errors, report.rejected);
        assert!(report.p99_ms() >= report.p50_ms());
        assert_eq!(
            http.state().stats.requests.load(std::sync::atomic::Ordering::Relaxed),
            20
        );
        http.shutdown();
    }

    #[test]
    fn overload_sheds_as_503_with_retry_after() {
        // One slow worker (≈80 ms/batch), a one-deep queue, Reject
        // policy: a burst of 8 must shed most of itself.
        let scale = pace_scale_for(1, 0.08);
        let http = start_http(
            ServerConfig::new(sim_builder(1).sim_paced(scale))
                .workers(1)
                .queue_depth(1)
                .queue_policy(QueuePolicy::Reject),
        );
        let addr = http.addr().to_string();
        let state = http.state().clone();
        let body = run_body(&state, &vec![0.5; state.image_elems]);
        let joins: Vec<_> = (0..8)
            .map(|_| {
                let addr = addr.clone();
                let body = body.clone();
                std::thread::spawn(move || one_shot(&addr, "POST", "/v1/run", Some(body.as_bytes())))
            })
            .collect();
        let mut saw_503_with_retry_after = false;
        let mut ok = 0;
        for j in joins {
            let resp = j.join().unwrap().unwrap();
            match resp.status {
                200 => ok += 1,
                503 => {
                    // Queue-depth-aware hint: always present, 1–8 s.
                    let ra: u32 = resp.header("retry-after").unwrap().parse().unwrap();
                    assert!((1..=8).contains(&ra), "retry-after {ra}");
                    saw_503_with_retry_after = true;
                }
                s => panic!("unexpected status {s}"),
            }
        }
        assert!(ok >= 1, "at least the first request must be served");
        assert!(saw_503_with_retry_after, "burst of 8 onto capacity 2 must shed");
        // The shed shows up in /v1/stats as a non-zero rejected count.
        let resp = one_shot(&addr, "GET", "/v1/stats", None).unwrap();
        let parsed = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert!(parsed.usize_field("rejected").unwrap() > 0);
        http.shutdown();
    }

    #[test]
    fn idle_keep_alive_connection_is_closed_after_timeout() {
        use std::time::{Duration, Instant};
        let server = ServerConfig::new(sim_builder(1)).start().unwrap();
        let mut cfg = HttpConfig::new("127.0.0.1:0");
        // One 250 ms read-timeout tick passes without tripping it, the
        // second exceeds it — the connection must close well under 10 s.
        cfg.idle_timeout = Duration::from_millis(300);
        let http = HttpServer::start(server, cfg).unwrap();
        let mut stream = TcpStream::connect(http.addr()).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        stream.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        // Read the keep-alive response (connection stays open).
        let mut raw = Vec::new();
        let mut buf = [0u8; 512];
        while !String::from_utf8_lossy(&raw).contains("{\"ok\":true}") {
            let n = stream.read(&mut buf).unwrap();
            assert!(n > 0, "server closed before answering: {:?}", raw);
            raw.extend_from_slice(&buf[..n]);
        }
        assert!(String::from_utf8_lossy(&raw).starts_with("HTTP/1.1 200"));
        // Now go idle: the server must close the socket (EOF), not hold
        // it for the default 30 s.
        let t0 = Instant::now();
        let mut rest = String::new();
        stream.read_to_string(&mut rest).unwrap(); // EOF, not timeout
        assert!(rest.is_empty(), "unexpected extra bytes: {rest}");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "idle close took {:?}",
            t0.elapsed()
        );
        http.shutdown();
    }

    #[test]
    fn saturated_conn_pool_sheds_at_the_door_with_503() {
        use std::time::Duration;
        let server = ServerConfig::new(sim_builder(1)).start().unwrap();
        let mut cfg = HttpConfig::new("127.0.0.1:0");
        // One conn thread, one queue slot: the third concurrent
        // connection must be shed by the acceptor.
        cfg.conn_threads = 1;
        cfg.conn_queue = 1;
        let http = HttpServer::start(server, cfg).unwrap();

        // Connection A: served, then parked in the keep-alive idle wait
        // — this pins the only conn thread.
        let mut a = TcpStream::connect(http.addr()).unwrap();
        a.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        a.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        let mut raw = Vec::new();
        let mut buf = [0u8; 512];
        while !String::from_utf8_lossy(&raw).contains("{\"ok\":true}") {
            let n = a.read(&mut buf).unwrap();
            assert!(n > 0, "server closed A early");
            raw.extend_from_slice(&buf[..n]);
        }

        // Connection B: accepted into the one queue slot, never served
        // while A pins the thread.
        let _b = TcpStream::connect(http.addr()).unwrap();
        // Let the acceptor move B into the channel before C arrives.
        std::thread::sleep(Duration::from_millis(200));

        // Connection C: pool and queue full → shed with 503 + Retry-After.
        let mut c = TcpStream::connect(http.addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let _ = c.write_all(b"GET /healthz HTTP/1.1\r\n\r\n");
        let mut shed_raw = String::new();
        c.read_to_string(&mut shed_raw).unwrap(); // shed closes the socket
        assert!(shed_raw.starts_with("HTTP/1.1 503 "), "{shed_raw}");
        assert!(shed_raw.contains("retry-after: 1"), "{shed_raw}");
        assert!(shed_raw.contains("connection: close"), "{shed_raw}");

        http.shutdown();
    }

    #[test]
    fn fault_slow_loris_header_trickle_gets_408_and_close() {
        use std::time::{Duration, Instant};
        let server = ServerConfig::new(sim_builder(1)).start().unwrap();
        let mut cfg = HttpConfig::new("127.0.0.1:0");
        cfg.header_deadline = Duration::from_millis(400);
        let http = HttpServer::start(server, cfg).unwrap();
        let mut stream = TcpStream::connect(http.addr()).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        // Trickle header bytes at 150 ms intervals — fast enough that
        // the 250 ms socket timeout never fires, so only the request
        // deadline can end this. Writes stop before the deadline so the
        // 408 is not lost to a TCP reset.
        let t0 = Instant::now();
        for chunk in [b"GET /hea".as_slice(), b"lthz HTT", b"P/1.1\r\nx"] {
            stream.write_all(chunk).unwrap();
            std::thread::sleep(Duration::from_millis(150));
        }
        let mut raw = String::new();
        stream.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 408 "), "{raw}");
        assert!(raw.contains("connection: close"), "{raw}");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "loris held the thread for {:?}",
            t0.elapsed()
        );
        http.shutdown();
    }

    #[test]
    fn fault_injected_partial_write_still_delivers_full_response() {
        use crate::fault::{FaultInjector, FaultPoint};
        let inj = std::sync::Arc::new(FaultInjector::new(33));
        let server = ServerConfig::new(sim_builder(1))
            .faults(inj.clone())
            .start()
            .unwrap();
        let http = HttpServer::start(server, HttpConfig::new("127.0.0.1:0")).unwrap();
        let addr = http.addr().to_string();
        // The next response is chopped into 1–7 byte slices with
        // injected Interrupteds; write_full must still deliver it all.
        inj.trigger(FaultPoint::PartialWrite);
        let resp = one_shot(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(resp.status, 200);
        let parsed = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert!(parsed.bool_field("ok").unwrap());
        assert_eq!(inj.fired(FaultPoint::PartialWrite), 1);
        http.shutdown();
    }

    #[test]
    fn fault_injected_socket_reset_drops_one_connection_only() {
        use crate::fault::{FaultInjector, FaultPoint};
        let inj = std::sync::Arc::new(FaultInjector::new(34));
        let server = ServerConfig::new(sim_builder(1))
            .faults(inj.clone())
            .start()
            .unwrap();
        let http = HttpServer::start(server, HttpConfig::new("127.0.0.1:0")).unwrap();
        let addr = http.addr().to_string();
        inj.trigger(FaultPoint::SocketReset);
        // The victim connection is dropped without a reply…
        assert!(one_shot(&addr, "GET", "/healthz", None).is_err());
        // …and the server keeps serving everyone else.
        let resp = one_shot(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(inj.fired(FaultPoint::SocketReset), 1);
        http.shutdown();
    }

    #[test]
    fn shutdown_closes_the_listener() {
        let http = start_http(ServerConfig::new(sim_builder(1)));
        let addr = http.addr();
        assert_eq!(one_shot(&addr.to_string(), "GET", "/healthz", None).unwrap().status, 200);
        http.shutdown();
        // The port is released: new connections are refused (or, if the
        // OS raced a final accept, the stream yields no response).
        match TcpStream::connect(addr) {
            Err(_) => {}
            Ok(mut stream) => {
                let _ = stream.write_all(b"GET /healthz HTTP/1.1\r\n\r\n");
                let mut raw = String::new();
                let _ = stream.read_to_string(&mut raw);
                assert!(raw.is_empty(), "served after shutdown: {raw}");
            }
        }
    }
}
