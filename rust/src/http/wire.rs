//! HTTP/1.1 wire format: request parsing and response serialisation.
//!
//! This is a deliberately small subset of RFC 9112, sized for a JSON
//! inference API behind a trusted load balancer:
//!
//! * request line `METHOD SP PATH SP HTTP/1.x`,
//! * header block terminated by an empty line, total size bounded by
//!   [`WireLimits::max_header_bytes`],
//! * bodies framed by `Content-Length` only (chunked transfer encoding
//!   is rejected with 400), bounded by [`WireLimits::max_body_bytes`]
//!   — the bound is enforced *before* the body is read, so an
//!   oversized declaration costs no memory and maps to 413,
//! * keep-alive by default for HTTP/1.1, opt-in via
//!   `Connection: keep-alive` for HTTP/1.0, opt-out via
//!   `Connection: close`.
//!
//! Parsing never allocates proportionally to anything the client did
//! not send: header names/values are stored as owned strings but their
//! cumulative size is capped first.

use std::io::{BufRead, Read, Write};

/// Size bounds applied while parsing a request.
#[derive(Debug, Clone, Copy)]
pub struct WireLimits {
    /// Cap on the request line plus all header lines, in bytes.
    pub max_header_bytes: usize,
    /// Cap on the declared `Content-Length`.
    pub max_body_bytes: usize,
}

impl Default for WireLimits {
    fn default() -> Self {
        WireLimits {
            max_header_bytes: 8 * 1024,
            max_body_bytes: 1024 * 1024,
        }
    }
}

/// Why a request could not be read. Each variant carries enough for the
/// listener to pick a status code: `Bad` → 400, `TooLarge` → 413 (and
/// close, since the unread body would desynchronise the stream), `Io` /
/// `Eof` → close without a response.
#[derive(Debug)]
pub enum WireError {
    /// Malformed request: bad request line, bad header, bad framing.
    Bad(String),
    /// Declared body exceeds [`WireLimits::max_body_bytes`].
    TooLarge { declared: usize, limit: usize },
    /// Transport error (includes read timeouts).
    Io(std::io::Error),
    /// Clean end of stream before any request byte (keep-alive close).
    Eof,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Bad(msg) => write!(f, "bad request: {msg}"),
            WireError::TooLarge { declared, limit } => {
                write!(f, "body of {declared} bytes exceeds limit of {limit}")
            }
            WireError::Io(e) => write!(f, "i/o error: {e}"),
            WireError::Eof => write!(f, "connection closed"),
        }
    }
}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

/// One parsed request. Header names are lower-cased at parse time so
/// lookups are case-insensitive; the query string (everything from `?`)
/// is stripped from `path` — no endpoint takes query parameters.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Whether the connection may carry another request after this one.
    pub keep_alive: bool,
}

impl Request {
    /// First header value for `name` (ASCII case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Read one line terminated by `\n`, stripping the `\r\n` / `\n`
/// terminator. `budget` is the remaining header-byte allowance and is
/// decremented by the raw line length (terminator included) — a line
/// that would overrun it is an oversized header block.
fn read_line<R: BufRead>(r: &mut R, budget: &mut usize) -> Result<String, WireError> {
    let mut raw = Vec::new();
    let mut limited = r.take(*budget as u64 + 1);
    let n = limited.read_until(b'\n', &mut raw)?;
    if n == 0 {
        return Err(WireError::Eof);
    }
    if raw.last() != Some(&b'\n') {
        if n > *budget {
            return Err(WireError::Bad("header block too large".into()));
        }
        return Err(WireError::Io(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            "connection closed mid-line",
        )));
    }
    *budget -= n.min(*budget);
    raw.pop();
    if raw.last() == Some(&b'\r') {
        raw.pop();
    }
    String::from_utf8(raw).map_err(|_| WireError::Bad("header line is not valid UTF-8".into()))
}

/// Read and validate one request from `r`.
pub fn read_request<R: BufRead>(r: &mut R, limits: &WireLimits) -> Result<Request, WireError> {
    let mut budget = limits.max_header_bytes;
    let request_line = read_line(r, &mut budget)?;
    let mut parts = request_line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => {
            return Err(WireError::Bad(format!(
                "malformed request line {request_line:?}"
            )))
        }
    };
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => {
            return Err(WireError::Bad(format!(
                "unsupported protocol version {version:?}"
            )))
        }
    };
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(WireError::Bad(format!("malformed method {method:?}")));
    }
    if !target.starts_with('/') {
        return Err(WireError::Bad(format!(
            "request target {target:?} is not an absolute path"
        )));
    }
    let path = target.split('?').next().unwrap_or(target).to_string();

    let mut headers = Vec::new();
    loop {
        let line = match read_line(r, &mut budget) {
            Ok(line) => line,
            // EOF inside the header block is a framing error, not a
            // clean close — the peer sent a partial request.
            Err(WireError::Eof) => {
                return Err(WireError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection closed inside header block",
                )))
            }
            Err(e) => return Err(e),
        };
        if line.is_empty() {
            break;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(WireError::Bad(format!("malformed header line {line:?}")));
        };
        if name.is_empty() || name.contains(' ') {
            return Err(WireError::Bad(format!("malformed header name {name:?}")));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }

    if headers.iter().any(|(k, _)| k == "transfer-encoding") {
        return Err(WireError::Bad(
            "transfer-encoding is not supported; use content-length".into(),
        ));
    }
    let content_length = match headers.iter().find(|(k, _)| k == "content-length") {
        Some((_, v)) => v
            .parse::<usize>()
            .map_err(|_| WireError::Bad(format!("invalid content-length {v:?}")))?,
        None => 0,
    };
    // Enforce the body bound *before* reading: the caller must close
    // the connection after a 413 because the body bytes stay unread.
    if content_length > limits.max_body_bytes {
        return Err(WireError::TooLarge {
            declared: content_length,
            limit: limits.max_body_bytes,
        });
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body)?;

    let connection = headers
        .iter()
        .find(|(k, _)| k == "connection")
        .map(|(_, v)| v.to_ascii_lowercase());
    let keep_alive = match connection.as_deref() {
        Some("close") => false,
        Some("keep-alive") => true,
        _ => http11,
    };
    Ok(Request {
        method: method.to_string(),
        path,
        headers,
        body,
        keep_alive,
    })
}

/// One response to serialise. Built by the router; the listener owns
/// the final `Connection` decision (it may force `close` on shutdown).
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub body: Vec<u8>,
    pub content_type: &'static str,
    /// Emitted as a `Retry-After` header (seconds) — set on 503s so
    /// well-behaved clients back off instead of hammering a full queue.
    pub retry_after: Option<u32>,
    /// Emitted as an `Allow` header — required on 405 responses.
    pub allow: Option<&'static str>,
    /// Close the connection after this response regardless of what the
    /// request asked for (parse errors, 413, server shutdown).
    pub close: bool,
    /// Emitted as an `x-brainslug-trace` header (16 lowercase hex
    /// digits). The router sets it on *every* routed response — success
    /// and error paths alike — echoing the client's header or the
    /// freshly minted id, so a client can always correlate a response
    /// (even a 503) with recorded spans.
    pub trace: Option<u64>,
}

impl Response {
    /// Response with an arbitrary (static) content type — the escape
    /// hatch for non-JSON bodies like the Prometheus text exposition.
    pub fn text(status: u16, content_type: &'static str, body: String) -> Response {
        Response {
            status,
            body: body.into_bytes(),
            content_type,
            retry_after: None,
            allow: None,
            close: false,
            trace: None,
        }
    }

    /// JSON response with the given status.
    pub fn json(status: u16, body: String) -> Response {
        Response::text(status, "application/json", body)
    }

    /// Standard error body `{"error": msg}`.
    pub fn error(status: u16, msg: &str) -> Response {
        let mut o = crate::json::Json::object();
        o.set("error", crate::json::Json::Str(msg.to_string()));
        Response::json(status, o.to_string_compact())
    }
}

/// Reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Content Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// `write_all` replacement that keeps its promise on fault-injected
/// (and real) sockets: `ErrorKind::Interrupted` is retried, a short
/// `write` return advances the cursor and continues, and a zero-length
/// accept is surfaced as `WriteZero` instead of spinning. Plain
/// `write_all` already loops over short writes, but its `Interrupted`
/// handling is the library's choice, not a tested contract of ours —
/// and the listener's partial-write fault adapter exists precisely to
/// pin this loop's behavior.
pub fn write_full<W: Write>(w: &mut W, mut buf: &[u8]) -> std::io::Result<()> {
    while !buf.is_empty() {
        match w.write(buf) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "stream refused further bytes",
                ))
            }
            Ok(n) => buf = &buf[n..],
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Serialise `resp`. `close` forces `Connection: close` (the listener
/// ors it with `resp.close` and the request's own keep-alive choice).
pub fn write_response<W: Write>(w: &mut W, resp: &Response, close: bool) -> std::io::Result<()> {
    let close = close || resp.close;
    let mut head = format!(
        "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\n",
        resp.status,
        reason(resp.status),
        resp.content_type,
        resp.body.len()
    );
    if let Some(secs) = resp.retry_after {
        head.push_str(&format!("retry-after: {secs}\r\n"));
    }
    if let Some(allow) = resp.allow {
        head.push_str(&format!("allow: {allow}\r\n"));
    }
    if let Some(trace) = resp.trace {
        head.push_str(&format!("x-brainslug-trace: {trace:016x}\r\n"));
    }
    head.push_str(if close {
        "connection: close\r\n\r\n"
    } else {
        "connection: keep-alive\r\n\r\n"
    });
    write_full(w, head.as_bytes())?;
    write_full(w, &resp.body)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Request, WireError> {
        read_request(&mut Cursor::new(raw.as_bytes()), &WireLimits::default())
    }

    #[test]
    fn parses_minimal_get() {
        let req = parse("GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert!(req.body.is_empty());
        assert!(req.keep_alive);
    }

    #[test]
    fn frames_body_by_content_length() {
        let req =
            parse("POST /v1/run HTTP/1.1\r\nContent-Length: 5\r\n\r\nhelloEXTRA").unwrap();
        assert_eq!(req.body, b"hello");
    }

    #[test]
    fn pipelined_requests_parse_back_to_back() {
        let raw = "POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiGET /b HTTP/1.1\r\n\r\n";
        let mut cur = Cursor::new(raw.as_bytes());
        let limits = WireLimits::default();
        let first = read_request(&mut cur, &limits).unwrap();
        assert_eq!((first.path.as_str(), first.body.as_slice()), ("/a", &b"hi"[..]));
        let second = read_request(&mut cur, &limits).unwrap();
        assert_eq!(second.path, "/b");
        assert!(matches!(
            read_request(&mut cur, &limits),
            Err(WireError::Eof)
        ));
    }

    #[test]
    fn malformed_request_lines_rejected() {
        for raw in [
            "GET\r\n\r\n",
            "GET /x\r\n\r\n",
            "GET /x HTTP/1.1 extra\r\n\r\n",
            "get /x HTTP/1.1\r\n\r\n",
            "GET x HTTP/1.1\r\n\r\n",
            "GET /x HTTP/2\r\n\r\n",
            " /x HTTP/1.1\r\n\r\n",
        ] {
            assert!(
                matches!(parse(raw), Err(WireError::Bad(_))),
                "accepted {raw:?}"
            );
        }
    }

    #[test]
    fn malformed_headers_rejected() {
        assert!(matches!(
            parse("GET / HTTP/1.1\r\nno-colon-here\r\n\r\n"),
            Err(WireError::Bad(_))
        ));
        assert!(matches!(
            parse("GET / HTTP/1.1\r\nbad name: v\r\n\r\n"),
            Err(WireError::Bad(_))
        ));
        assert!(matches!(
            parse("GET / HTTP/1.1\r\nContent-Length: banana\r\n\r\n"),
            Err(WireError::Bad(_))
        ));
    }

    #[test]
    fn transfer_encoding_rejected() {
        assert!(matches!(
            parse("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            Err(WireError::Bad(_))
        ));
    }

    #[test]
    fn header_block_size_is_bounded() {
        let limits = WireLimits {
            max_header_bytes: 64,
            max_body_bytes: 1024,
        };
        let raw = format!("GET / HTTP/1.1\r\nx: {}\r\n\r\n", "a".repeat(256));
        let err = read_request(&mut Cursor::new(raw.as_bytes()), &limits).unwrap_err();
        assert!(matches!(err, WireError::Bad(ref m) if m.contains("too large")), "{err}");
    }

    #[test]
    fn oversized_body_maps_to_too_large_without_reading_it() {
        let limits = WireLimits {
            max_header_bytes: 8 * 1024,
            max_body_bytes: 8,
        };
        // Body bytes deliberately absent: the check fires on the
        // declared length alone.
        let raw = "POST / HTTP/1.1\r\nContent-Length: 9\r\n\r\n";
        match read_request(&mut Cursor::new(raw.as_bytes()), &limits) {
            Err(WireError::TooLarge { declared: 9, limit: 8 }) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn keep_alive_defaults_per_version() {
        assert!(parse("GET / HTTP/1.1\r\n\r\n").unwrap().keep_alive);
        assert!(!parse("GET / HTTP/1.0\r\n\r\n").unwrap().keep_alive);
        assert!(!parse("GET / HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap()
            .keep_alive);
        assert!(parse("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n")
            .unwrap()
            .keep_alive);
    }

    #[test]
    fn truncated_body_is_io_error() {
        assert!(matches!(
            parse("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort"),
            Err(WireError::Io(_))
        ));
    }

    #[test]
    fn query_string_is_stripped() {
        let req = parse("GET /v1/stats?verbose=1 HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.path, "/v1/stats");
    }

    #[test]
    fn empty_stream_is_eof() {
        assert!(matches!(parse(""), Err(WireError::Eof)));
    }

    /// Mock stream that accepts at most `max_chunk` bytes per `write`
    /// and fails every third call with `ErrorKind::Interrupted` first —
    /// the short-write behavior a real socket shows under memory
    /// pressure (and the listener's partial-write fault injection).
    struct ShortWriter {
        out: Vec<u8>,
        max_chunk: usize,
        calls: usize,
    }

    impl Write for ShortWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.calls += 1;
            if self.calls % 3 == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::Interrupted,
                    "interrupted",
                ));
            }
            let n = buf.len().min(self.max_chunk);
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn short_writes_and_interrupts_still_deliver_the_full_response() {
        // Regression: the writer used to assume `write_all` semantics;
        // a short-writing stream must still receive a byte-identical
        // response.
        let mut resp = Response::json(200, "{\"ok\":true,\"state\":\"ready\"}".to_string());
        resp.retry_after = Some(2);
        let mut reference = Vec::new();
        write_response(&mut reference, &resp, false).unwrap();

        for max_chunk in [1usize, 3, 7] {
            let mut w = ShortWriter {
                out: Vec::new(),
                max_chunk,
                calls: 0,
            };
            write_response(&mut w, &resp, false).unwrap();
            assert_eq!(
                w.out, reference,
                "chunk size {max_chunk} corrupted the response"
            );
        }
    }

    #[test]
    fn write_zero_surfaces_as_write_zero_error() {
        struct DeadWriter;
        impl Write for DeadWriter {
            fn write(&mut self, _buf: &[u8]) -> std::io::Result<usize> {
                Ok(0)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let err = write_full(&mut DeadWriter, b"abc").unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::WriteZero);
        // And a genuine transport error passes straight through.
        struct BrokenWriter;
        impl Write for BrokenWriter {
            fn write(&mut self, _buf: &[u8]) -> std::io::Result<usize> {
                Err(std::io::Error::new(
                    std::io::ErrorKind::BrokenPipe,
                    "peer gone",
                ))
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let err = write_full(&mut BrokenWriter, b"abc").unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::BrokenPipe);
    }

    #[test]
    fn reason_covers_the_fault_statuses() {
        assert_eq!(reason(408), "Request Timeout");
        assert_eq!(reason(504), "Gateway Timeout");
    }

    #[test]
    fn response_serialisation_round_trip() {
        let mut resp = Response::json(200, "{\"ok\":true}".to_string());
        resp.retry_after = Some(1);
        resp.trace = Some(0xDEAD_BEEF);
        let mut out = Vec::new();
        write_response(&mut out, &resp, false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("content-length: 11\r\n"), "{text}");
        assert!(text.contains("retry-after: 1\r\n"), "{text}");
        assert!(text.contains("x-brainslug-trace: 00000000deadbeef\r\n"), "{text}");
        assert!(text.contains("connection: keep-alive\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{\"ok\":true}"), "{text}");

        let mut out = Vec::new();
        write_response(&mut out, &Response::error(405, "nope"), true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 405 Method Not Allowed\r\n"), "{text}");
        assert!(text.contains("connection: close\r\n"), "{text}");
        assert!(text.ends_with("{\"error\":\"nope\"}"), "{text}");
    }
}
