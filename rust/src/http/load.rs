//! Minimal HTTP/1.1 client and load generators for `bench-serve`.
//!
//! Two harness shapes, matching the serving-benchmark literature:
//!
//! * **closed loop** — `C` clients, each with one keep-alive
//!   connection, issuing the next request the moment the previous
//!   reply lands. Measures per-request latency under a fixed
//!   concurrency; throughput is demand-limited by `C`.
//! * **open loop** — requests arrive on a fixed schedule (`rate` per
//!   second) regardless of how fast replies come back. Latency is
//!   measured from the *scheduled* arrival time, not from the moment a
//!   connection became free, so a stalled server inflates the tail
//!   instead of silently pausing the clock (no coordinated omission).
//!
//! Both count 503 replies as `rejected` — load the server shed on
//! purpose — separately from transport `errors`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::sync_channel;
use std::time::{Duration, Instant};

/// One reply as seen by the client.
#[derive(Debug)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    /// First header value for `name` (ASCII case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// A keep-alive client connection.
pub struct ClientConn {
    r: BufReader<TcpStream>,
}

impl ClientConn {
    pub fn connect(addr: &str) -> std::io::Result<ClientConn> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        // Generous bound so a wedged server fails the harness instead
        // of hanging it.
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(ClientConn {
            r: BufReader::new(stream),
        })
    }

    /// Issue one request and read the full reply. JSON content type is
    /// assumed for bodies — that is all this API speaks.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&[u8]>,
    ) -> std::io::Result<ClientResponse> {
        self.request_with(method, path, &[], body)
    }

    /// [`ClientConn::request`] with extra request headers (e.g.
    /// `x-brainslug-deadline-ms`, `x-brainslug-fault`).
    pub fn request_with(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: Option<&[u8]>,
    ) -> std::io::Result<ClientResponse> {
        let mut head = format!("{method} {path} HTTP/1.1\r\nhost: brainslug\r\n");
        for (name, value) in headers {
            head.push_str(&format!("{name}: {value}\r\n"));
        }
        if let Some(body) = body {
            head.push_str(&format!(
                "content-type: application/json\r\ncontent-length: {}\r\n",
                body.len()
            ));
        }
        head.push_str("\r\n");
        let w = self.r.get_mut();
        w.write_all(head.as_bytes())?;
        if let Some(body) = body {
            w.write_all(body)?;
        }
        w.flush()?;
        self.read_response()
    }

    fn read_line(&mut self) -> std::io::Result<String> {
        let mut line = String::new();
        if self.r.read_line(&mut line)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            ));
        }
        while line.ends_with('\n') || line.ends_with('\r') {
            line.pop();
        }
        Ok(line)
    }

    fn read_response(&mut self) -> std::io::Result<ClientResponse> {
        let bad = |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
        let status_line = self.read_line()?;
        let status = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| bad(format!("malformed status line {status_line:?}")))?;
        let mut headers = Vec::new();
        loop {
            let line = self.read_line()?;
            if line.is_empty() {
                break;
            }
            let (name, value) = line
                .split_once(':')
                .ok_or_else(|| bad(format!("malformed header {line:?}")))?;
            headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
        }
        let len = headers
            .iter()
            .find(|(k, _)| k == "content-length")
            .and_then(|(_, v)| v.parse::<usize>().ok())
            .unwrap_or(0);
        let mut body = vec![0u8; len];
        self.r.read_exact(&mut body)?;
        Ok(ClientResponse {
            status,
            headers,
            body,
        })
    }
}

/// Connect, issue one request, disconnect. The CI smoke path.
pub fn one_shot(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
) -> std::io::Result<ClientResponse> {
    ClientConn::connect(addr)?.request(method, path, body)
}

/// [`one_shot`] with extra request headers.
pub fn one_shot_with(
    addr: &str,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: Option<&[u8]>,
) -> std::io::Result<ClientResponse> {
    ClientConn::connect(addr)?.request_with(method, path, headers, body)
}

/// Client-side retry discipline for [`closed_loop_with`]: retry shed
/// (503) and transport-failed requests with full-jitter exponential
/// backoff, honoring the server's `Retry-After` hint, spending from a
/// bounded per-client budget so a dying server exhausts the harness in
/// bounded time instead of amplifying load forever. 504 (deadline
/// exceeded) is deliberately *not* retried — the request's time budget
/// is spent, and blind retry would double-charge the server.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Attempts per logical request, including the first.
    pub max_attempts: u32,
    /// Backoff ceiling doubles from here per attempt.
    pub base_ms: u64,
    /// Hard cap on any single backoff sleep.
    pub cap_ms: u64,
    /// Total retries one client thread may spend across its whole run.
    pub budget: u64,
    /// Jitter seed (deterministic per client: mixed with the client
    /// index).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base_ms: 10,
            cap_ms: 2_000,
            budget: 100,
            seed: 0x5EED_4E74,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `attempt` (1-based): full jitter in
    /// `[0, min(cap, base·2^attempt)]`, floored by the server's
    /// `Retry-After` (seconds), re-capped at `cap_ms`.
    fn backoff_ms(&self, attempt: u32, retry_after_s: Option<u64>, rng: &mut u64) -> u64 {
        let ceil = self.cap_ms.min(self.base_ms.saturating_mul(1 << attempt.min(16)));
        let jittered = if ceil == 0 {
            0
        } else {
            crate::rng::splitmix64(rng) % (ceil + 1)
        };
        jittered
            .max(retry_after_s.unwrap_or(0).saturating_mul(1000))
            .min(self.cap_ms)
    }
}

/// Aggregated result of one load-generation run.
#[derive(Debug, Default)]
pub struct LoadReport {
    pub sent: u64,
    pub ok: u64,
    /// 503 replies — load the server shed deliberately.
    pub rejected: u64,
    /// 504 replies — requests shed because their deadline passed.
    pub expired: u64,
    /// Transport failures and non-200/503/504 statuses.
    pub errors: u64,
    /// Extra attempts spent by [`RetryPolicy`] (0 without one).
    pub retries: u64,
    pub wall_s: f64,
    /// Latency of every reply (ok + rejected), milliseconds, sorted.
    pub latencies_ms: Vec<f64>,
}

impl LoadReport {
    pub fn p50_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 0.50)
    }
    pub fn p95_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 0.95)
    }
    pub fn p99_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 0.99)
    }
    pub fn mean_ms(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        self.latencies_ms.iter().sum::<f64>() / self.latencies_ms.len() as f64
    }
    /// Successful replies per wall-clock second.
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.ok as f64 / self.wall_s
    }
    pub fn reject_rate(&self) -> f64 {
        if self.sent == 0 {
            return 0.0;
        }
        self.rejected as f64 / self.sent as f64
    }

    fn absorb(&mut self, status: Option<u16>, latency_ms: f64) {
        self.sent += 1;
        match status {
            Some(200) => {
                self.ok += 1;
                self.latencies_ms.push(latency_ms);
            }
            Some(503) => {
                self.rejected += 1;
                self.latencies_ms.push(latency_ms);
            }
            Some(504) => {
                self.expired += 1;
                self.latencies_ms.push(latency_ms);
            }
            _ => self.errors += 1,
        }
    }

    fn merge(&mut self, other: LoadReport) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.rejected += other.rejected;
        self.expired += other.expired;
        self.errors += other.errors;
        self.retries += other.retries;
        self.latencies_ms.extend(other.latencies_ms);
    }

    fn finish(&mut self, wall: Duration) {
        self.wall_s = wall.as_secs_f64();
        self.latencies_ms.sort_by(|a, b| a.total_cmp(b));
    }
}

/// Nearest-rank percentile over a sorted slice; `0.0` when empty.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Closed loop: `clients` threads × `reqs_per_client` sequential
/// `POST /v1/run` requests with `body`, one keep-alive connection per
/// client (re-established after transport errors or server-initiated
/// closes).
pub fn closed_loop(addr: &str, clients: usize, reqs_per_client: usize, body: &[u8]) -> LoadReport {
    closed_loop_with(addr, clients, reqs_per_client, body, None)
}

/// [`closed_loop`] with an optional client-side [`RetryPolicy`]. With a
/// policy, a logical request that is shed (503) or fails in transport
/// is retried after backoff, and only the *final* attempt's outcome is
/// absorbed into the report (intermediate 503s become `retries`, not
/// `rejected`); latency runs from the first attempt to the final
/// reply, so retries inflate the tail honestly.
pub fn closed_loop_with(
    addr: &str,
    clients: usize,
    reqs_per_client: usize,
    body: &[u8],
    retry: Option<RetryPolicy>,
) -> LoadReport {
    let started = Instant::now();
    let joins: Vec<_> = (0..clients.max(1))
        .map(|client| {
            let addr = addr.to_string();
            let body = body.to_vec();
            std::thread::spawn(move || {
                let mut local = LoadReport::default();
                let mut conn = ClientConn::connect(&addr).ok();
                let mut budget = retry.map_or(0, |p| p.budget);
                let mut rng = retry
                    .map_or(0, |p| p.seed)
                    .wrapping_add((client as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                for _ in 0..reqs_per_client {
                    let t0 = Instant::now();
                    let mut attempt: u32 = 0;
                    loop {
                        let result = match conn.as_mut() {
                            Some(c) => c.request("POST", "/v1/run", Some(&body)),
                            None => Err(std::io::Error::new(
                                std::io::ErrorKind::NotConnected,
                                "connect failed",
                            )),
                        };
                        let (status, retry_after_s) = match result {
                            Ok(resp) => {
                                // The server closes the stream after some
                                // statuses (shutdown, 413); reconnect lazily.
                                if resp.header("connection") == Some("close") {
                                    conn = None;
                                }
                                let ra = resp
                                    .header("retry-after")
                                    .and_then(|v| v.parse::<u64>().ok());
                                (Some(resp.status), ra)
                            }
                            Err(_) => {
                                conn = None;
                                (None, None)
                            }
                        };
                        attempt += 1;
                        let retriable = matches!(status, Some(503) | None);
                        if let Some(p) = retry {
                            if retriable && attempt < p.max_attempts && budget > 0 {
                                budget -= 1;
                                local.retries += 1;
                                let wait = p.backoff_ms(attempt, retry_after_s, &mut rng);
                                std::thread::sleep(Duration::from_millis(wait));
                                if conn.is_none() {
                                    conn = ClientConn::connect(&addr).ok();
                                }
                                continue;
                            }
                        }
                        local.absorb(status, ms_since(t0));
                        break;
                    }
                    if conn.is_none() {
                        conn = ClientConn::connect(&addr).ok();
                    }
                }
                local
            })
        })
        .collect();
    let mut report = LoadReport::default();
    for j in joins {
        if let Ok(local) = j.join() {
            report.merge(local);
        }
    }
    report.finish(started.elapsed());
    report
}

/// Open loop: `rate_rps` scheduled arrivals per second for
/// `duration_s`, executed by a pool of `pool` connections. Latency is
/// measured from each request's *scheduled* time.
pub fn open_loop(
    addr: &str,
    rate_rps: f64,
    duration_s: f64,
    pool: usize,
    body: &[u8],
) -> LoadReport {
    let total = (rate_rps * duration_s).round().max(1.0) as usize;
    let interval = Duration::from_secs_f64(1.0 / rate_rps.max(1e-9));
    // Deep ticket queue: a slow server must find backed-up tickets, not
    // a blocked pacer (that would re-introduce coordinated omission).
    let (tx, rx) = sync_channel::<Instant>(total);
    let rx = std::sync::Arc::new(std::sync::Mutex::new(rx));
    let started = Instant::now();
    let joins: Vec<_> = (0..pool.max(1))
        .map(|_| {
            let addr = addr.to_string();
            let body = body.to_vec();
            let rx = rx.clone();
            std::thread::spawn(move || {
                let mut local = LoadReport::default();
                let mut conn = ClientConn::connect(&addr).ok();
                loop {
                    let scheduled = match rx.lock().unwrap_or_else(|p| p.into_inner()).recv() {
                        Ok(t) => t,
                        Err(_) => return local,
                    };
                    let result = match conn.as_mut() {
                        Some(c) => c.request("POST", "/v1/run", Some(&body)),
                        None => Err(std::io::Error::new(
                            std::io::ErrorKind::NotConnected,
                            "connect failed",
                        )),
                    };
                    match result {
                        Ok(resp) => {
                            if resp.header("connection") == Some("close") {
                                conn = None;
                            }
                            local.absorb(Some(resp.status), ms_since(scheduled));
                        }
                        Err(_) => {
                            local.absorb(None, ms_since(scheduled));
                            conn = None;
                        }
                    }
                    if conn.is_none() {
                        conn = ClientConn::connect(&addr).ok();
                    }
                }
            })
        })
        .collect();
    // Pace on this thread: emit each ticket at its scheduled instant.
    for i in 0..total {
        let target = started + interval.mul_f64(i as f64);
        if let Some(wait) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        if tx.send(target).is_err() {
            break;
        }
    }
    drop(tx);
    let mut report = LoadReport::default();
    for j in joins {
        if let Ok(local) = j.join() {
            report.merge(local);
        }
    }
    report.finish(started.elapsed());
    report
}

/// Milliseconds elapsed since `t0`, clamped at zero.
fn ms_since(t0: Instant) -> f64 {
    Instant::now()
        .checked_duration_since(t0)
        .unwrap_or(Duration::ZERO)
        .as_secs_f64()
        * 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.95), 95.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&[7.5], 0.99), 7.5);
        assert_eq!(percentile(&[], 0.5), 0.0);
        // Tiny q still picks the first element, never index -1.
        assert_eq!(percentile(&v, 0.0001), 1.0);
    }

    #[test]
    fn report_accounting() {
        let mut r = LoadReport::default();
        r.absorb(Some(200), 2.0);
        r.absorb(Some(200), 4.0);
        r.absorb(Some(503), 1.0);
        r.absorb(Some(504), 3.0);
        r.absorb(None, 9.0);
        r.finish(Duration::from_secs(2));
        assert_eq!(
            (r.sent, r.ok, r.rejected, r.expired, r.errors),
            (5, 2, 1, 1, 1)
        );
        assert_eq!(r.latencies_ms, vec![1.0, 2.0, 3.0, 4.0]);
        assert!((r.throughput_rps() - 1.0).abs() < 1e-9);
        assert!((r.reject_rate() - 0.2).abs() < 1e-9);
        assert!((r.mean_ms() - 10.0 / 4.0).abs() < 1e-9);
    }

    #[test]
    fn fault_retry_backoff_is_bounded_and_honors_retry_after() {
        let p = RetryPolicy {
            max_attempts: 5,
            base_ms: 10,
            cap_ms: 500,
            budget: 10,
            seed: 7,
        };
        let mut rng = 42u64;
        for attempt in 1..=8 {
            // Jitter-only: never above the per-attempt ceiling or cap.
            let w = p.backoff_ms(attempt, None, &mut rng);
            assert!(w <= p.cap_ms.min(p.base_ms * (1 << attempt.min(16))));
            // A server hint floors the wait, but the cap still wins.
            let w = p.backoff_ms(attempt, Some(3), &mut rng);
            assert_eq!(w, p.cap_ms, "3 s hint > 500 ms cap");
        }
        // Determinism: same seed state → same sequence.
        let (mut a, mut b) = (9u64, 9u64);
        let sa: Vec<u64> = (1..6).map(|i| p.backoff_ms(i, None, &mut a)).collect();
        let sb: Vec<u64> = (1..6).map(|i| p.backoff_ms(i, None, &mut b)).collect();
        assert_eq!(sa, sb);
    }

    #[test]
    fn empty_report_is_nan_free() {
        let r = LoadReport::default();
        assert_eq!(r.p50_ms(), 0.0);
        assert_eq!(r.mean_ms(), 0.0);
        assert_eq!(r.throughput_rps(), 0.0);
        assert_eq!(r.reject_rate(), 0.0);
    }
}
