//! Minimal HTTP/1.1 client and load generators for `bench-serve`.
//!
//! Two harness shapes, matching the serving-benchmark literature:
//!
//! * **closed loop** — `C` clients, each with one keep-alive
//!   connection, issuing the next request the moment the previous
//!   reply lands. Measures per-request latency under a fixed
//!   concurrency; throughput is demand-limited by `C`.
//! * **open loop** — requests arrive on a fixed schedule (`rate` per
//!   second) regardless of how fast replies come back. Latency is
//!   measured from the *scheduled* arrival time, not from the moment a
//!   connection became free, so a stalled server inflates the tail
//!   instead of silently pausing the clock (no coordinated omission).
//!
//! Both count 503 replies as `rejected` — load the server shed on
//! purpose — separately from transport `errors`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::sync_channel;
use std::time::{Duration, Instant};

/// One reply as seen by the client.
#[derive(Debug)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    /// First header value for `name` (ASCII case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// A keep-alive client connection.
pub struct ClientConn {
    r: BufReader<TcpStream>,
}

impl ClientConn {
    pub fn connect(addr: &str) -> std::io::Result<ClientConn> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        // Generous bound so a wedged server fails the harness instead
        // of hanging it.
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(ClientConn {
            r: BufReader::new(stream),
        })
    }

    /// Issue one request and read the full reply. JSON content type is
    /// assumed for bodies — that is all this API speaks.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&[u8]>,
    ) -> std::io::Result<ClientResponse> {
        let mut head = format!("{method} {path} HTTP/1.1\r\nhost: brainslug\r\n");
        if let Some(body) = body {
            head.push_str(&format!(
                "content-type: application/json\r\ncontent-length: {}\r\n",
                body.len()
            ));
        }
        head.push_str("\r\n");
        let w = self.r.get_mut();
        w.write_all(head.as_bytes())?;
        if let Some(body) = body {
            w.write_all(body)?;
        }
        w.flush()?;
        self.read_response()
    }

    fn read_line(&mut self) -> std::io::Result<String> {
        let mut line = String::new();
        if self.r.read_line(&mut line)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            ));
        }
        while line.ends_with('\n') || line.ends_with('\r') {
            line.pop();
        }
        Ok(line)
    }

    fn read_response(&mut self) -> std::io::Result<ClientResponse> {
        let bad = |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
        let status_line = self.read_line()?;
        let status = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| bad(format!("malformed status line {status_line:?}")))?;
        let mut headers = Vec::new();
        loop {
            let line = self.read_line()?;
            if line.is_empty() {
                break;
            }
            let (name, value) = line
                .split_once(':')
                .ok_or_else(|| bad(format!("malformed header {line:?}")))?;
            headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
        }
        let len = headers
            .iter()
            .find(|(k, _)| k == "content-length")
            .and_then(|(_, v)| v.parse::<usize>().ok())
            .unwrap_or(0);
        let mut body = vec![0u8; len];
        self.r.read_exact(&mut body)?;
        Ok(ClientResponse {
            status,
            headers,
            body,
        })
    }
}

/// Connect, issue one request, disconnect. The CI smoke path.
pub fn one_shot(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
) -> std::io::Result<ClientResponse> {
    ClientConn::connect(addr)?.request(method, path, body)
}

/// Aggregated result of one load-generation run.
#[derive(Debug, Default)]
pub struct LoadReport {
    pub sent: u64,
    pub ok: u64,
    /// 503 replies — load the server shed deliberately.
    pub rejected: u64,
    /// Transport failures and non-200/503 statuses.
    pub errors: u64,
    pub wall_s: f64,
    /// Latency of every reply (ok + rejected), milliseconds, sorted.
    pub latencies_ms: Vec<f64>,
}

impl LoadReport {
    pub fn p50_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 0.50)
    }
    pub fn p95_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 0.95)
    }
    pub fn p99_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 0.99)
    }
    pub fn mean_ms(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        self.latencies_ms.iter().sum::<f64>() / self.latencies_ms.len() as f64
    }
    /// Successful replies per wall-clock second.
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.ok as f64 / self.wall_s
    }
    pub fn reject_rate(&self) -> f64 {
        if self.sent == 0 {
            return 0.0;
        }
        self.rejected as f64 / self.sent as f64
    }

    fn absorb(&mut self, status: Option<u16>, latency_ms: f64) {
        self.sent += 1;
        match status {
            Some(200) => {
                self.ok += 1;
                self.latencies_ms.push(latency_ms);
            }
            Some(503) => {
                self.rejected += 1;
                self.latencies_ms.push(latency_ms);
            }
            _ => self.errors += 1,
        }
    }

    fn merge(&mut self, other: LoadReport) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.rejected += other.rejected;
        self.errors += other.errors;
        self.latencies_ms.extend(other.latencies_ms);
    }

    fn finish(&mut self, wall: Duration) {
        self.wall_s = wall.as_secs_f64();
        self.latencies_ms.sort_by(|a, b| a.total_cmp(b));
    }
}

/// Nearest-rank percentile over a sorted slice; `0.0` when empty.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Closed loop: `clients` threads × `reqs_per_client` sequential
/// `POST /v1/run` requests with `body`, one keep-alive connection per
/// client (re-established after transport errors or server-initiated
/// closes).
pub fn closed_loop(addr: &str, clients: usize, reqs_per_client: usize, body: &[u8]) -> LoadReport {
    let started = Instant::now();
    let joins: Vec<_> = (0..clients.max(1))
        .map(|_| {
            let addr = addr.to_string();
            let body = body.to_vec();
            std::thread::spawn(move || {
                let mut local = LoadReport::default();
                let mut conn = ClientConn::connect(&addr).ok();
                for _ in 0..reqs_per_client {
                    let t0 = Instant::now();
                    let result = match conn.as_mut() {
                        Some(c) => c.request("POST", "/v1/run", Some(&body)),
                        None => Err(std::io::Error::new(
                            std::io::ErrorKind::NotConnected,
                            "connect failed",
                        )),
                    };
                    match result {
                        Ok(resp) => {
                            // The server closes the stream after some
                            // statuses (shutdown, 413); reconnect lazily.
                            if resp.header("connection") == Some("close") {
                                conn = None;
                            }
                            local.absorb(Some(resp.status), ms_since(t0));
                        }
                        Err(_) => {
                            local.absorb(None, ms_since(t0));
                            conn = None;
                        }
                    }
                    if conn.is_none() {
                        conn = ClientConn::connect(&addr).ok();
                    }
                }
                local
            })
        })
        .collect();
    let mut report = LoadReport::default();
    for j in joins {
        if let Ok(local) = j.join() {
            report.merge(local);
        }
    }
    report.finish(started.elapsed());
    report
}

/// Open loop: `rate_rps` scheduled arrivals per second for
/// `duration_s`, executed by a pool of `pool` connections. Latency is
/// measured from each request's *scheduled* time.
pub fn open_loop(
    addr: &str,
    rate_rps: f64,
    duration_s: f64,
    pool: usize,
    body: &[u8],
) -> LoadReport {
    let total = (rate_rps * duration_s).round().max(1.0) as usize;
    let interval = Duration::from_secs_f64(1.0 / rate_rps.max(1e-9));
    // Deep ticket queue: a slow server must find backed-up tickets, not
    // a blocked pacer (that would re-introduce coordinated omission).
    let (tx, rx) = sync_channel::<Instant>(total);
    let rx = std::sync::Arc::new(std::sync::Mutex::new(rx));
    let started = Instant::now();
    let joins: Vec<_> = (0..pool.max(1))
        .map(|_| {
            let addr = addr.to_string();
            let body = body.to_vec();
            let rx = rx.clone();
            std::thread::spawn(move || {
                let mut local = LoadReport::default();
                let mut conn = ClientConn::connect(&addr).ok();
                loop {
                    let scheduled = match rx.lock().unwrap_or_else(|p| p.into_inner()).recv() {
                        Ok(t) => t,
                        Err(_) => return local,
                    };
                    let result = match conn.as_mut() {
                        Some(c) => c.request("POST", "/v1/run", Some(&body)),
                        None => Err(std::io::Error::new(
                            std::io::ErrorKind::NotConnected,
                            "connect failed",
                        )),
                    };
                    match result {
                        Ok(resp) => {
                            if resp.header("connection") == Some("close") {
                                conn = None;
                            }
                            local.absorb(Some(resp.status), ms_since(scheduled));
                        }
                        Err(_) => {
                            local.absorb(None, ms_since(scheduled));
                            conn = None;
                        }
                    }
                    if conn.is_none() {
                        conn = ClientConn::connect(&addr).ok();
                    }
                }
            })
        })
        .collect();
    // Pace on this thread: emit each ticket at its scheduled instant.
    for i in 0..total {
        let target = started + interval.mul_f64(i as f64);
        if let Some(wait) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        if tx.send(target).is_err() {
            break;
        }
    }
    drop(tx);
    let mut report = LoadReport::default();
    for j in joins {
        if let Ok(local) = j.join() {
            report.merge(local);
        }
    }
    report.finish(started.elapsed());
    report
}

/// Milliseconds elapsed since `t0`, clamped at zero.
fn ms_since(t0: Instant) -> f64 {
    Instant::now()
        .checked_duration_since(t0)
        .unwrap_or(Duration::ZERO)
        .as_secs_f64()
        * 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.95), 95.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&[7.5], 0.99), 7.5);
        assert_eq!(percentile(&[], 0.5), 0.0);
        // Tiny q still picks the first element, never index -1.
        assert_eq!(percentile(&v, 0.0001), 1.0);
    }

    #[test]
    fn report_accounting() {
        let mut r = LoadReport::default();
        r.absorb(Some(200), 2.0);
        r.absorb(Some(200), 4.0);
        r.absorb(Some(503), 1.0);
        r.absorb(None, 9.0);
        r.finish(Duration::from_secs(2));
        assert_eq!((r.sent, r.ok, r.rejected, r.errors), (4, 2, 1, 1));
        assert_eq!(r.latencies_ms, vec![1.0, 2.0, 4.0]);
        assert!((r.throughput_rps() - 1.0).abs() < 1e-9);
        assert!((r.reject_rate() - 0.25).abs() < 1e-9);
        assert!((r.mean_ms() - 7.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_report_is_nan_free() {
        let r = LoadReport::default();
        assert_eq!(r.p50_ms(), 0.0);
        assert_eq!(r.mean_ms(), 0.0);
        assert_eq!(r.throughput_rps(), 0.0);
        assert_eq!(r.reject_rate(), 0.0);
    }
}
