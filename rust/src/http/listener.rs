//! TCP listener and connection-thread pool.
//!
//! ```text
//!  accept loop (nonblocking poll)      K connection threads
//!  ┌───────────────┐  bounded channel  ┌──────────────────┐   try_infer   ┌──────────────┐
//!  │ TcpListener   │ ───────────────▶  │ read_request     │ ────────────▶ │ dispatch     │
//!  │ (1 thread)    │   full → 503      │ route / respond  │  full → 503   │ queue + pool │
//!  └───────────────┘                   │ keep-alive loop  │               └──────────────┘
//!                                      └──────────────────┘
//! ```
//!
//! Two bounded hand-offs stand between a socket and an engine: the
//! connection channel (here) and the dispatch queue (in
//! [`crate::server`]). Both shed load as 503 + `Retry-After` instead of
//! queueing without bound.
//!
//! Shutdown ordering (the graceful-drain contract): flip the stop flag
//! → acceptor exits (no new connections) → connection threads answer
//! their in-flight request with `Connection: close` and exit →
//! [`crate::server::Server::stop`] drains every queued request to a
//! real reply → workers join.

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::TrySendError;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

// The accept → pool handoff goes through the `conc::sync` facade:
// `std::sync` in production, schedule-explored via [`drain_protocol`]
// under the model checker.
use crate::conc::sync::{sync_channel_labeled, Mutex};
use crate::fault::FaultPoint;
use crate::server::Server;

use super::router::{route, AppState};
use super::wire::{read_request, write_response, Response, WireError, WireLimits};

/// Granularity of the acceptor's nonblocking poll and the connection
/// threads' idle ticks; bounds shutdown latency.
const POLL_TICK: Duration = Duration::from_millis(10);

/// Default for [`HttpConfig::idle_timeout`].
const IDLE_TIMEOUT: Duration = Duration::from_secs(30);

/// Default for [`HttpConfig::header_deadline`].
const HEADER_DEADLINE: Duration = Duration::from_secs(5);

/// Listener configuration.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Bind address, e.g. `127.0.0.1:8080`; port `0` picks an ephemeral
    /// port (see [`HttpServer::addr`]).
    pub addr: String,
    /// Connection threads — the ceiling on concurrently served
    /// sockets.
    pub conn_threads: usize,
    /// Pending-connection channel bound; overflow is shed with 503.
    pub conn_queue: usize,
    /// How long a keep-alive connection may sit idle before we close it
    /// (default 30 s).
    pub idle_timeout: Duration,
    /// Once a request's first byte has arrived, how long the client has
    /// to deliver the *rest* of it (headers + body). A slow-loris peer
    /// trickling header bytes is answered with 408 and closed instead
    /// of pinning a connection thread forever (default 5 s).
    pub header_deadline: Duration,
    pub limits: WireLimits,
}

impl HttpConfig {
    pub fn new(addr: impl Into<String>) -> Self {
        HttpConfig {
            addr: addr.into(),
            conn_threads: 8,
            conn_queue: 64,
            idle_timeout: IDLE_TIMEOUT,
            header_deadline: HEADER_DEADLINE,
            limits: WireLimits::default(),
        }
    }
}

/// A running HTTP front door over a [`Server`]. Owns the acceptor and
/// connection threads; dropping without [`HttpServer::shutdown`] leaks
/// them (the CLI and tests always shut down explicitly).
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    conn_threads: Vec<std::thread::JoinHandle<()>>,
    server: Option<Server>,
    state: AppState,
}

impl HttpServer {
    /// Bind and start serving `server` over HTTP.
    pub fn start(server: Server, cfg: HttpConfig) -> Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding http listener on {}", cfg.addr))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        listener
            .set_nonblocking(true)
            .context("setting listener nonblocking")?;
        let state = AppState {
            handle: server.handle(),
            stats: server.stats.clone(),
            batch: server.batch_size(),
            workers: server.workers(),
            model: server.model_name().to_string(),
            image_elems: server.handle().image_shape().numel(),
            queue_capacity: server.queue_capacity(),
            faults: server.faults(),
            obs: server.obs(),
            trace_seed: Arc::new(AtomicU64::new(0)),
            started: Instant::now(),
        };
        let stop = Arc::new(AtomicBool::new(false));
        let (conn_tx, conn_rx) = sync_channel_labeled::<TcpStream>(cfg.conn_queue.max(1), "conns");
        let conn_rx = Arc::new(Mutex::labeled(conn_rx, "conns-rx"));

        let mut conn_threads = Vec::with_capacity(cfg.conn_threads.max(1));
        for _ in 0..cfg.conn_threads.max(1) {
            let conn_rx = conn_rx.clone();
            let state = state.clone();
            let stop = stop.clone();
            let limits = cfg.limits;
            let idle_timeout = cfg.idle_timeout;
            let header_deadline = cfg.header_deadline;
            conn_threads.push(std::thread::spawn(move || loop {
                // Receiver disconnects when the acceptor (sole sender)
                // exits — that is the pool's shutdown signal. Crucially
                // the pool keeps draining handed-off sockets until that
                // disconnect: bailing out early on the stop flag would
                // strand accepted connections (see `drain_protocol`'s
                // `abandon_queue_on_stop` bug switch, BSL056).
                let stream = match conn_rx.lock().unwrap_or_else(|p| p.into_inner()).recv() {
                    Ok(s) => s,
                    Err(_) => return,
                };
                serve_connection(stream, &state, &limits, &stop, idle_timeout, header_deadline);
            }));
        }

        let acceptor = {
            let stop = stop.clone();
            std::thread::spawn(move || {
                // Ordering: Relaxed — polling a boolean signal; see the
                // contract comment in `shutdown`.
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => match conn_tx.try_send(stream) {
                            Ok(()) => {}
                            // Pool saturated: shed at the door rather
                            // than queueing sockets without bound.
                            Err(TrySendError::Full(stream)) => shed(stream),
                            Err(TrySendError::Disconnected(_)) => return,
                        },
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(POLL_TICK);
                        }
                        // Transient accept errors (e.g. aborted
                        // handshake): back off briefly and keep going.
                        Err(_) => std::thread::sleep(POLL_TICK),
                    }
                }
                // conn_tx drops here, disconnecting the pool.
            })
        };

        Ok(HttpServer {
            addr,
            stop,
            acceptor: Some(acceptor),
            conn_threads,
            server: Some(server),
            state,
        })
    }

    /// The bound address (resolves port `0` to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared request-serving state (stats, model metadata).
    pub fn state(&self) -> &AppState {
        &self.state
    }

    /// Flag observed by the accept loop and all connection threads;
    /// setting it (e.g. from a signal handler) begins shutdown, which
    /// [`HttpServer::shutdown`] completes.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Graceful shutdown: stop accepting, finish in-flight requests,
    /// drain the dispatch queue, join everything.
    pub fn shutdown(mut self) {
        // Ordering: Relaxed suffices for the stop flag everywhere. It
        // is a pure boolean signal — no data is published through it
        // (the sockets travel through the channel, whose send/recv is
        // the synchronizing edge), pollers only need eventual
        // visibility (guaranteed for atomic stores), and the `join`s
        // below are full happens-before edges for everything that
        // follows.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(acceptor) = self.acceptor.take() {
            let _ = acceptor.join();
        }
        for t in self.conn_threads.drain(..) {
            let _ = t.join();
        }
        // Only after every connection thread is done: they may still
        // need live workers to answer their last request.
        if let Some(server) = self.server.take() {
            server.stop();
        }
    }
}

/// Declarative concurrency topology of the HTTP front door for the
/// static lint, embedding the batching server it owns. Mirrors
/// [`HttpServer::start`] / [`HttpServer::shutdown`] exactly: the
/// acceptor polls the `stop` flag; connection threads exit when the
/// bounded `conns` channel disconnects, which happens precisely when
/// the acceptor (its only sender) is joined; the embedded server is
/// stopped last because draining connections may still need live
/// workers.
pub fn topology(conn_threads: usize, conn_queue: usize) -> crate::analysis::Topology {
    use crate::analysis::{ExitCondition, ShutdownStep, Topology};
    Topology::new("http-listener")
        .gate("stop")
        .thread("acceptor", 1, ExitCondition::FlagSet("stop".into()))
        .thread(
            "conn",
            conn_threads,
            ExitCondition::DisconnectOf("conns".into()),
        )
        .channel("conns", conn_queue, &["acceptor"], &["conn"], None)
        .on_shutdown(ShutdownStep::CloseGate("stop".into()))
        .on_shutdown(ShutdownStep::Join("acceptor".into()))
        .on_shutdown(ShutdownStep::Join("conn".into()))
        .extend(crate::server::topology(4, 64))
}

/// Bug switches for [`drain_protocol`]. `Default` (all `false`) is the
/// shipped listener protocol.
#[derive(Debug, Clone, Copy, Default)]
pub struct ListenerBugs {
    /// Break the drain contract: connection threads bail out when they
    /// see the stop flag instead of serving the sockets already handed
    /// off — an accepted connection is dropped unanswered (BSL056).
    pub abandon_queue_on_stop: bool,
}

/// Model-checked replica of the listener's coordination protocol —
/// the sync skeleton of [`HttpServer::start`] / [`HttpServer::shutdown`]:
/// one acceptor handing sockets to a bounded channel (shedding on
/// `Full`, like [`shed`]), a pool of connection threads draining it
/// until disconnect, shutdown via stop flag → join acceptor → join
/// pool. Each accepted connection is an obligation; serving (or
/// shedding with a 503) completes it. Explored by
/// `brainslug check --schedules` and the model-check test suite.
pub fn drain_protocol(conn_threads: usize, conn_queue: usize, conns: usize, bugs: ListenerBugs) {
    use crate::conc::sync::{model, AtomicBool as StopFlag};

    let stop = Arc::new(StopFlag::new(false));
    let (conn_tx, conn_rx) = sync_channel_labeled::<model::Obligation>(conn_queue.max(1), "conns");
    let conn_rx = Arc::new(Mutex::labeled(conn_rx, "conns-rx"));

    let mut pool = Vec::with_capacity(conn_threads);
    for k in 0..conn_threads {
        let conn_rx = conn_rx.clone();
        let stop = stop.clone();
        pool.push(model::spawn(&format!("conn-{k}"), move || loop {
            let conn = {
                match conn_rx.lock() {
                    Ok(q) => q.recv(),
                    Err(_) => return,
                }
            };
            match conn {
                Ok(ob) => {
                    if bugs.abandon_queue_on_stop && stop.load(Ordering::Relaxed) {
                        // Buggy: drop the socket unanswered.
                        drop(ob);
                    } else {
                        // serve_connection answers it (even mid-shutdown,
                        // with `Connection: close`).
                        ob.complete();
                    }
                }
                Err(_) => return, // acceptor gone and queue drained
            }
        }));
    }

    let acceptor = {
        let stop = stop.clone();
        model::spawn("acceptor", move || {
            for i in 0..conns {
                if stop.load(Ordering::Relaxed) {
                    return; // conn_tx drops here, disconnecting the pool
                }
                let ob = model::obligation(&format!("conn-{i}"));
                match conn_tx.try_send(ob) {
                    Ok(()) => {}
                    // Pool saturated: shed() answers 503 at the door.
                    Err(TrySendError::Full(ob)) => ob.complete(),
                    // Pool gone entirely (not reachable pre-shutdown,
                    // kept for parity with the real accept loop).
                    Err(TrySendError::Disconnected(ob)) => {
                        ob.complete();
                        return;
                    }
                }
            }
        })
    };

    // shutdown(): flag, then join in handoff order.
    stop.store(true, Ordering::Relaxed);
    acceptor.join();
    for h in pool {
        h.join();
    }
}

/// Canned 503 for connections shed at the accept stage; best-effort
/// (the client may already be gone).
fn shed(mut stream: TcpStream) {
    // Accepted sockets are blocking on Linux, but make it explicit —
    // some platforms inherit the listener's nonblocking flag.
    let _ = stream.set_nonblocking(false);
    let mut resp = Response::error(503, "connection pool saturated; retry later");
    resp.retry_after = Some(1);
    resp.close = true;
    let _ = write_response(&mut stream, &resp, true);
}

/// [`TcpStream`] wrapper enforcing a per-request read deadline.
///
/// Disarmed (`deadline: None`) it is a transparent passthrough — the
/// idle wait between keep-alive requests is governed by `idle_timeout`
/// in [`serve_connection`] instead. Armed, it absorbs the 250 ms
/// socket-timeout ticks and keeps retrying until bytes arrive, the
/// deadline passes (`expired` is set and the read fails), or shutdown
/// begins. This is what turns a slow-loris client — one that trickles
/// header bytes just fast enough to defeat the socket timeout — into a
/// bounded 408 instead of a pinned connection thread.
struct DeadlineStream<'a> {
    stream: TcpStream,
    /// Absolute deadline for the bytes of the in-progress request.
    deadline: Option<Instant>,
    /// Set when a read failed because `deadline` passed; lets the
    /// caller distinguish "peer too slow" (408) from "peer gone".
    expired: bool,
    stop: &'a AtomicBool,
}

impl Read for DeadlineStream<'_> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let Some(deadline) = self.deadline else {
            return self.stream.read(buf);
        };
        loop {
            if Instant::now() >= deadline {
                self.expired = true;
                return Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    "request read deadline expired",
                ));
            }
            // Ordering: Relaxed — boolean signal, same contract as the
            // other stop-flag polls in this module.
            if self.stop.load(Ordering::Relaxed) {
                return Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    "server shutting down",
                ));
            }
            match self.stream.read(buf) {
                // Socket-timeout tick with no data: re-check the
                // deadline and the stop flag, then wait again.
                Err(e)
                    if e.kind() == io::ErrorKind::WouldBlock
                        || e.kind() == io::ErrorKind::TimedOut
                        || e.kind() == io::ErrorKind::Interrupted => {}
                other => return other,
            }
        }
    }
}

impl Write for DeadlineStream<'_> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.stream.write(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.stream.flush()
    }
}

/// Write adapter for [`FaultPoint::PartialWrite`]: delivers a few bytes
/// per call and periodically fails with `Interrupted`, exercising the
/// retry loop in [`super::wire::write_full`] over a real socket.
/// Deterministic given its seed.
struct ChoppyWriter<'a, W: Write> {
    inner: &'a mut W,
    rng: u64,
}

impl<W: Write> Write for ChoppyWriter<'_, W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let r = crate::rng::splitmix64(&mut self.rng);
        if r % 5 == 0 {
            return Err(io::Error::new(
                io::ErrorKind::Interrupted,
                "injected fault: partial-write",
            ));
        }
        let n = buf.len().min(1 + (r % 7) as usize);
        self.inner.write(&buf[..n])
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// Serve one connection until it closes, errors, times out (idle or
/// mid-request), or the server begins shutdown.
fn serve_connection(
    stream: TcpStream,
    state: &AppState,
    limits: &WireLimits,
    stop: &AtomicBool,
    idle_timeout: Duration,
    header_deadline: Duration,
) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    // Short read timeout = the idle-wait tick: between requests we spin
    // on fill_buf so keep-alive waits stay interruptible by `stop`.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let mut reader = BufReader::new(DeadlineStream {
        stream,
        deadline: None,
        expired: false,
        stop,
    });
    loop {
        // Idle wait: block (bounded by the read timeout) until the next
        // request's first byte, EOF, or shutdown.
        let idle_start = Instant::now();
        loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            match reader.fill_buf() {
                Ok([]) => return, // clean close from the peer
                Ok(_) => break,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if idle_start.elapsed() > idle_timeout {
                        return;
                    }
                }
                Err(_) => return,
            }
        }
        // A request has begun: simulate the peer's NIC dying under us.
        if let Some(f) = &state.faults {
            if f.fire(FaultPoint::SocketReset) {
                return;
            }
        }
        // First byte seen → the client owns a bounded budget for the
        // rest of the request.
        {
            let ds = reader.get_mut();
            ds.deadline = Some(Instant::now() + header_deadline);
            ds.expired = false;
        }
        let parsed = read_request(&mut reader, limits);
        reader.get_mut().deadline = None;
        let resp = match parsed {
            Ok(req) => {
                let mut resp = route(state, &req);
                resp.close |= !req.keep_alive;
                resp
            }
            Err(WireError::Bad(msg)) => {
                // The stream may be desynchronised; answer and close.
                let mut resp = Response::error(400, &msg);
                resp.close = true;
                resp
            }
            Err(WireError::TooLarge { declared, limit }) => {
                // Body left unread — closing is mandatory.
                let mut resp = Response::error(
                    413,
                    &format!("body of {declared} bytes exceeds limit of {limit}"),
                );
                resp.close = true;
                resp
            }
            // The peer had a live request in flight but trickled or
            // stalled past the deadline: tell it so, then hang up.
            Err(WireError::Io(_)) if reader.get_ref().expired => {
                let mut resp = Response::error(408, "request not received within deadline");
                resp.close = true;
                resp
            }
            // Peer vanished mid-request: nothing sensible to say, and
            // nobody to say it to.
            Err(WireError::Io(_)) | Err(WireError::Eof) => return,
        };
        // During shutdown, answer the request we already read but tell
        // the client not to reuse the connection.
        let closing = resp.close || stop.load(Ordering::Relaxed);
        let wrote = match &state.faults {
            // Partial-write storm: chop the response into 1–7 byte
            // slices with injected `Interrupted`s; `write_full` must
            // still deliver every byte.
            Some(f) if f.fire(FaultPoint::PartialWrite) => {
                let mut choppy = ChoppyWriter {
                    inner: reader.get_mut(),
                    rng: f.seed().wrapping_add(f.draws(FaultPoint::PartialWrite)),
                };
                write_response(&mut choppy, &resp, closing)
            }
            _ => write_response(reader.get_mut(), &resp, closing),
        };
        if wrote.is_err() || closing {
            return;
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        // Best-effort: if `shutdown` was skipped (e.g. a panicking
        // test), still unblock the threads so the process can exit.
        self.stop.store(true, Ordering::Relaxed);
    }
}
