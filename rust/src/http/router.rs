//! Route table: maps parsed requests onto the serving engine.
//!
//! Three endpoints, mirrored in DESIGN.md §HTTP Serving:
//!
//! | method | path        | body in                              | 200 body out                     |
//! |--------|-------------|--------------------------------------|----------------------------------|
//! | POST   | `/v1/run`   | `{"model": "...", "input": [...]}`   | `{"model": ..., "output": [...]}`|
//! | GET    | `/v1/stats` | —                                    | [`ServerStats::to_json`] + serving metadata |
//! | GET    | `/healthz`  | —                                    | `{"ok": true}`                   |
//!
//! The hot path (`POST /v1/run`) never builds a JSON tree for the
//! request: the two fields are pulled straight off the byte stream with
//! the lazy scanners in [`crate::json`]. Backpressure from the bounded
//! dispatch queue maps onto the wire as 503 + `Retry-After`.

use std::sync::Arc;
use std::time::Instant;

use crate::json::{self, Json};
use crate::server::{InferError, ServerHandle, ServerStats};

use super::wire::{Request, Response};

/// Everything a connection thread needs to answer requests. Cheap to
/// clone (all `Arc`s and small copies).
#[derive(Clone)]
pub struct AppState {
    pub handle: ServerHandle,
    pub stats: Arc<ServerStats>,
    /// Compiled batch size of the served engine (for occupancy).
    pub batch: usize,
    /// Worker-pool size (reported in `/v1/stats`).
    pub workers: usize,
    /// Served model name; `POST /v1/run` rejects any other with 404.
    pub model: String,
    /// Expected `input` element count per request.
    pub image_elems: usize,
    pub started: Instant,
}

/// Dispatch one request. Infallible by design: every failure becomes a
/// response with the right status code.
pub fn route(state: &AppState, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/run") => run(state, &req.body),
        ("GET", "/v1/stats") => stats(state),
        ("GET", "/healthz") => Response::json(200, "{\"ok\":true}".to_string()),
        // Known paths with the wrong verb get 405 + Allow, per RFC.
        (_, "/v1/run") => {
            let mut resp = Response::error(405, "use POST");
            resp.allow = Some("POST");
            resp
        }
        (_, "/v1/stats") | (_, "/healthz") => {
            let mut resp = Response::error(405, "use GET");
            resp.allow = Some("GET");
            resp
        }
        (_, path) => Response::error(404, &format!("no route for {path}")),
    }
}

/// `POST /v1/run`: lazy-extract `model` and `input`, submit to the
/// dispatch queue, serialise the output tensor.
fn run(state: &AppState, body: &[u8]) -> Response {
    let Ok(text) = std::str::from_utf8(body) else {
        return Response::error(400, "body is not valid UTF-8");
    };
    match json::scan_str_field(text, "model") {
        Ok(Some(model)) if model == state.model => {}
        Ok(Some(model)) => {
            return Response::error(
                404,
                &format!("model {model:?} not served here (serving {:?})", state.model),
            )
        }
        Ok(None) => return Response::error(400, "missing \"model\" field"),
        Err(e) => return Response::error(400, &format!("{e:#}")),
    }
    let input = match json::scan_f32_array_field(text, "input") {
        Ok(Some(v)) => v,
        Ok(None) => return Response::error(400, "missing \"input\" field"),
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    if input.len() != state.image_elems {
        return Response::error(
            400,
            &format!(
                "input has {} elements, expected {}",
                input.len(),
                state.image_elems
            ),
        );
    }
    match state.handle.try_infer(input) {
        Ok(tensor) => {
            let mut o = Json::object();
            o.set("model", Json::Str(state.model.clone()));
            o.set(
                "output",
                Json::Arr(tensor.data.iter().map(|v| Json::Num(*v as f64)).collect()),
            );
            Response::json(200, o.to_string_compact())
        }
        // Backpressure → 503 with a back-off hint. This is the wire
        // face of QueuePolicy::Reject.
        Err(e @ InferError::QueueFull { .. }) => {
            let mut resp = Response::error(503, &e.to_string());
            resp.retry_after = Some(1);
            resp
        }
        // Shutdown → 503 and close, so keep-alive clients re-resolve.
        Err(e @ InferError::Stopped) => {
            let mut resp = Response::error(503, &e.to_string());
            resp.retry_after = Some(1);
            resp.close = true;
            resp
        }
        Err(e @ InferError::BadInput(_)) => Response::error(400, &e.to_string()),
        Err(e @ InferError::Exec(_)) => Response::error(500, &e.to_string()),
    }
}

/// `GET /v1/stats`: the shared [`ServerStats`] snapshot plus serving
/// metadata the load harness needs (model name, expected input size).
fn stats(state: &AppState) -> Response {
    let mut o = state.stats.to_json(state.batch);
    o.set("model", Json::Str(state.model.clone()));
    o.set("workers", Json::from_usize(state.workers));
    o.set("image_elems", Json::from_usize(state.image_elems));
    o.set(
        "uptime_s",
        Json::Num(state.started.elapsed().as_secs_f64()),
    );
    Response::json(200, o.to_string_compact())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench;
    use crate::device::DeviceSpec;
    use crate::engine::Engine;
    use crate::optimizer::CollapseOptions;
    use crate::server::{QueuePolicy, Server, ServerConfig};

    fn test_state() -> (Server, AppState) {
        let builder = Engine::builder()
            .graph_owned(bench::block_net(1, 2, 2, 8))
            .device(DeviceSpec::tpu_core())
            .brainslug(CollapseOptions::default())
            .sim()
            .seed(11);
        let server = ServerConfig::new(builder)
            .workers(1)
            .queue_depth(4)
            .queue_policy(QueuePolicy::Block)
            .start()
            .expect("server start");
        let state = AppState {
            handle: server.handle(),
            stats: server.stats.clone(),
            batch: server.batch_size(),
            workers: server.workers(),
            model: server.model_name().to_string(),
            image_elems: server.handle().image_shape().numel(),
            started: Instant::now(),
        };
        (server, state)
    }

    fn post_run(state: &AppState, body: &str) -> Response {
        let req = Request {
            method: "POST".into(),
            path: "/v1/run".into(),
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
            keep_alive: true,
        };
        route(state, &req)
    }

    fn get(state: &AppState, path: &str) -> Response {
        let req = Request {
            method: "GET".into(),
            path: path.into(),
            headers: Vec::new(),
            body: Vec::new(),
            keep_alive: true,
        };
        route(state, &req)
    }

    #[test]
    fn run_round_trips_through_json() {
        let (server, state) = test_state();
        let input = crate::rng::fill_f32(11, state.image_elems);
        let mut body = Json::object();
        body.set("model", Json::Str(state.model.clone()));
        body.set(
            "input",
            Json::Arr(input.iter().map(|v| Json::Num(*v as f64)).collect()),
        );
        let resp = post_run(&state, &body.to_string_compact());
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let parsed = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(parsed.str_field("model").unwrap(), state.model);
        let wire_out: Vec<f32> = parsed
            .arr_field("output")
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        // Parity with the in-process path on the same handle.
        let direct = state.handle.infer(input).unwrap();
        assert_eq!(wire_out, direct.data);
        server.stop();
    }

    #[test]
    fn run_input_errors_are_400() {
        let (server, state) = test_state();
        for body in [
            "not json at all",
            "{}",
            &format!("{{\"model\":\"{}\"}}", state.model),
            &format!("{{\"model\":\"{}\",\"input\":\"nope\"}}", state.model),
            &format!("{{\"model\":\"{}\",\"input\":[1,2,3]}}", state.model),
        ] {
            let resp = post_run(&state, body);
            assert_eq!(resp.status, 400, "body {body:?}");
        }
        server.stop();
    }

    #[test]
    fn unknown_model_is_404() {
        let (server, state) = test_state();
        let resp = post_run(&state, "{\"model\":\"nonesuch\",\"input\":[1]}");
        assert_eq!(resp.status, 404);
        server.stop();
    }

    #[test]
    fn unknown_routes_and_wrong_methods() {
        let (server, state) = test_state();
        assert_eq!(get(&state, "/nope").status, 404);
        assert_eq!(get(&state, "/v1/run").status, 405);
        assert_eq!(get(&state, "/v1/run").allow, Some("POST"));
        let resp = route(
            &state,
            &Request {
                method: "DELETE".into(),
                path: "/healthz".into(),
                headers: Vec::new(),
                body: Vec::new(),
                keep_alive: true,
            },
        );
        assert_eq!((resp.status, resp.allow), (405, Some("GET")));
        server.stop();
    }

    #[test]
    fn stats_and_healthz() {
        let (server, state) = test_state();
        let resp = get(&state, "/healthz");
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"{\"ok\":true}");
        let resp = get(&state, "/v1/stats");
        assert_eq!(resp.status, 200);
        let parsed = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(parsed.str_field("model").unwrap(), state.model);
        assert_eq!(parsed.usize_field("workers").unwrap(), 1);
        assert_eq!(parsed.usize_field("image_elems").unwrap(), state.image_elems);
        assert!(parsed.f64_field("uptime_s").unwrap() >= 0.0);
        server.stop();
    }

    #[test]
    fn stopped_server_maps_to_503() {
        let (server, state) = test_state();
        server.stop();
        let resp = post_run(
            &state,
            &format!(
                "{{\"model\":\"{}\",\"input\":{}}}",
                state.model,
                Json::Arr(vec![Json::Num(0.0); state.image_elems]).to_string_compact()
            ),
        );
        assert_eq!(resp.status, 503);
        assert!(resp.close);
    }
}
