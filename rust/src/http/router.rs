//! Route table: maps parsed requests onto the serving engine.
//!
//! Four endpoints, mirrored in DESIGN.md §HTTP Serving:
//!
//! | method | path          | body in                              | 200 body out                     |
//! |--------|---------------|--------------------------------------|----------------------------------|
//! | POST   | `/v1/run`     | `{"model": "...", "input": [...]}`   | `{"model": ..., "output": [...]}`|
//! | GET    | `/v1/stats`   | —                                    | [`ServerStats::to_json`] + serving metadata |
//! | GET    | `/v1/metrics` | —                                    | Prometheus text exposition (v0.0.4) |
//! | GET    | `/healthz`    | —                                    | `{"ok": true, "state": "ready"}` |
//!
//! The hot path (`POST /v1/run`) never builds a JSON tree for the
//! request: the two fields are pulled straight off the byte stream with
//! the lazy scanners in [`crate::json`]. Backpressure from the bounded
//! dispatch queue maps onto the wire as 503 + a queue-depth-aware
//! `Retry-After`.
//!
//! Two request headers participate in the fault story (DESIGN.md
//! §Fault Injection & Recovery):
//!
//! * `x-brainslug-deadline-ms: N` — relative deadline; the request is
//!   shed with 504 if it cannot execute within `N` ms of arrival.
//! * `x-brainslug-fault: <point>` — queue a one-shot fault trigger
//!   ([`crate::fault::FaultInjector::trigger`]); honored only when the
//!   server was started with fault injection armed, 400 otherwise.
//!
//! One header participates in the observability story (DESIGN.md
//! §Observability): `x-brainslug-trace: <hex64>` names the trace id
//! attributed to the request's spans. When absent, the router mints
//! one (SplitMix64 over a per-listener seed). Either way the resolved
//! id is echoed back as a response header on *every* routed response —
//! success and error paths alike — so clients can correlate any
//! response, including a 503 shed, with the recorded spans.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::fault::{FaultInjector, FaultPoint};
use crate::json::{self, Json};
use crate::server::{suggested_retry_after, HealthPhase, InferError, ServerHandle, ServerStats};

use super::wire::{Request, Response};

/// Everything a connection thread needs to answer requests. Cheap to
/// clone (all `Arc`s and small copies).
#[derive(Clone)]
pub struct AppState {
    pub handle: ServerHandle,
    pub stats: Arc<ServerStats>,
    /// Compiled batch size of the served engine (for occupancy).
    pub batch: usize,
    /// Worker-pool size (reported in `/v1/stats`).
    pub workers: usize,
    /// Served model name; `POST /v1/run` rejects any other with 404.
    pub model: String,
    /// Expected `input` element count per request.
    pub image_elems: usize,
    /// Dispatch-queue bound, the denominator of the queue-depth-aware
    /// `Retry-After` hint.
    pub queue_capacity: usize,
    /// Armed fault injector, if the server was started with one. Gates
    /// the `x-brainslug-fault` trigger header and the `fault_injection`
    /// stats block.
    pub faults: Option<Arc<FaultInjector>>,
    /// The server's observability state: always-on metrics registry
    /// (rendered by `GET /v1/metrics`) plus the span recorder when
    /// tracing was armed at startup.
    pub obs: Arc<crate::obs::Obs>,
    /// Seed for minting trace ids when the client didn't send
    /// `x-brainslug-trace` ([`crate::obs::next_trace_id`]).
    pub trace_seed: Arc<AtomicU64>,
    pub started: Instant,
}

impl AppState {
    /// Current back-off hint for 503 responses, scaled by how full the
    /// dispatch queue is right now.
    fn retry_after_now(&self) -> u32 {
        suggested_retry_after(self.stats.queue_depth_now(), self.queue_capacity)
    }
}

/// Dispatch one request. Infallible by design: every failure becomes a
/// response with the right status code, and every response — error
/// paths included — carries the resolved `x-brainslug-trace` echo.
pub fn route(state: &AppState, req: &Request) -> Response {
    let trace = req
        .header("x-brainslug-trace")
        .and_then(crate::obs::parse_trace_id)
        .unwrap_or_else(|| crate::obs::next_trace_id(&state.trace_seed));
    let mut resp = match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/run") => run(state, req, trace),
        ("GET", "/v1/stats") => stats(state),
        ("GET", "/v1/metrics") => metrics(state),
        ("GET", "/healthz") => healthz(state),
        // Known paths with the wrong verb get 405 + Allow, per RFC.
        (_, "/v1/run") => {
            let mut resp = Response::error(405, "use POST");
            resp.allow = Some("POST");
            resp
        }
        (_, "/v1/stats") | (_, "/v1/metrics") | (_, "/healthz") => {
            let mut resp = Response::error(405, "use GET");
            resp.allow = Some("GET");
            resp
        }
        (_, path) => Response::error(404, &format!("no route for {path}")),
    };
    resp.trace = Some(trace);
    resp
}

/// The documented [`InferError`] → wire mapping, in one exhaustive
/// match (no wildcard arm: adding an `InferError` variant without
/// deciding its status code is a compile error here, and the mapping
/// test in this module pins the decisions):
///
/// | variant            | status | headers                    | close |
/// |--------------------|--------|----------------------------|-------|
/// | `QueueFull`        | 503    | `Retry-After` (queue-aware)| no    |
/// | `Stopped`          | 503    | `Retry-After: 1`           | yes   |
/// | `BadInput`         | 400    | —                          | no    |
/// | `Exec`             | 500    | —                          | no    |
/// | `WorkerCrashed`    | 503    | `Retry-After` (queue-aware)| no    |
/// | `DeadlineExceeded` | 504    | —                          | no    |
pub fn infer_error_response(state: &AppState, err: &InferError) -> Response {
    match err {
        // Backpressure → 503 with a back-off hint scaled by queue
        // depth. This is the wire face of QueuePolicy::Reject.
        InferError::QueueFull { .. } => {
            let mut resp = Response::error(503, &err.to_string());
            resp.retry_after = Some(state.retry_after_now());
            resp
        }
        // Shutdown → 503 and close, so keep-alive clients re-resolve.
        InferError::Stopped => {
            let mut resp = Response::error(503, &err.to_string());
            resp.retry_after = Some(1);
            resp.close = true;
            resp
        }
        InferError::BadInput(_) => Response::error(400, &err.to_string()),
        InferError::Exec(_) => Response::error(500, &err.to_string()),
        // Transient: the replica is rebuilding; the connection itself
        // is fine, so keep it open and invite a retry.
        InferError::WorkerCrashed { .. } => {
            let mut resp = Response::error(503, &err.to_string());
            resp.retry_after = Some(state.retry_after_now());
            resp
        }
        // The client's own deadline passed; retrying is its call — no
        // Retry-After, the budget is spent.
        InferError::DeadlineExceeded { .. } => Response::error(504, &err.to_string()),
    }
}

/// `POST /v1/run`: lazy-extract `model` and `input`, submit to the
/// dispatch queue (tagging the request with its resolved trace id),
/// serialise the output tensor.
fn run(state: &AppState, req: &Request, trace: u64) -> Response {
    // Fault trigger header first: it must queue even if this very
    // request then crashes on it.
    if let Some(v) = req.header("x-brainslug-fault") {
        let Some(inj) = state.faults.as_ref() else {
            return Response::error(400, "fault injection is not armed on this server");
        };
        match FaultPoint::parse(v) {
            Some(p) => inj.trigger(p),
            None => return Response::error(400, &format!("unknown fault point {v:?}")),
        }
    }
    let deadline = match req.header("x-brainslug-deadline-ms") {
        None => None,
        Some(v) => match v.parse::<u64>() {
            Ok(ms) if ms > 0 => Some(Instant::now() + Duration::from_millis(ms)),
            _ => {
                return Response::error(
                    400,
                    &format!("invalid x-brainslug-deadline-ms {v:?} (want positive integer)"),
                )
            }
        },
    };
    let Ok(text) = std::str::from_utf8(&req.body) else {
        return Response::error(400, "body is not valid UTF-8");
    };
    match json::scan_str_field(text, "model") {
        Ok(Some(model)) if model == state.model => {}
        Ok(Some(model)) => {
            return Response::error(
                404,
                &format!("model {model:?} not served here (serving {:?})", state.model),
            )
        }
        Ok(None) => return Response::error(400, "missing \"model\" field"),
        Err(e) => return Response::error(400, &format!("{e:#}")),
    }
    let input = match json::scan_f32_array_field(text, "input") {
        Ok(Some(v)) => v,
        Ok(None) => return Response::error(400, "missing \"input\" field"),
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    if input.len() != state.image_elems {
        return Response::error(
            400,
            &format!(
                "input has {} elements, expected {}",
                input.len(),
                state.image_elems
            ),
        );
    }
    match state.handle.try_infer_deadline_traced(input, deadline, trace) {
        Ok(tensor) => {
            let mut o = Json::object();
            o.set("model", Json::Str(state.model.clone()));
            o.set(
                "output",
                Json::Arr(tensor.data.iter().map(|v| Json::Num(*v as f64)).collect()),
            );
            Response::json(200, o.to_string_compact())
        }
        Err(e) => infer_error_response(state, &e),
    }
}

/// `GET /v1/stats`: the shared [`ServerStats`] snapshot plus serving
/// metadata the load harness needs (model name, expected input size),
/// plus the `fault_injection` block when the injector is armed.
fn stats(state: &AppState) -> Response {
    let mut o = state.stats.to_json(state.batch);
    o.set("model", Json::Str(state.model.clone()));
    o.set("workers", Json::from_usize(state.workers));
    o.set("image_elems", Json::from_usize(state.image_elems));
    o.set(
        "uptime_s",
        Json::Num(state.started.elapsed().as_secs_f64()),
    );
    if let Some(inj) = state.faults.as_ref() {
        o.set("fault_injection", inj.to_json());
    }
    Response::json(200, o.to_string_compact())
}

/// `GET /v1/metrics`: the same counters as `/v1/stats` plus the
/// server's observability registry (per-segment execution-time
/// histograms, fault-injection draw/fire counters when armed), in the
/// Prometheus text exposition format (version 0.0.4). Scrape-friendly
/// twin of `/v1/stats`: plain text, monotonic counters, cumulative
/// histogram buckets.
fn metrics(state: &AppState) -> Response {
    let s = &state.stats;
    let mut exp = crate::obs::Exposition::new();
    exp.counter(
        "brainslug_requests_total",
        "Requests answered (any status).",
        &[],
        s.requests.load(Ordering::Relaxed),
    );
    exp.counter(
        "brainslug_batches_total",
        "Batches executed across the worker pool.",
        &[],
        s.batches.load(Ordering::Relaxed),
    );
    exp.counter(
        "brainslug_padded_slots_total",
        "Batch slots padded because the queue ran dry.",
        &[],
        s.padded_slots.load(Ordering::Relaxed),
    );
    exp.counter(
        "brainslug_rejected_total",
        "Requests refused by queue backpressure.",
        &[],
        s.rejected.load(Ordering::Relaxed),
    );
    exp.counter(
        "brainslug_deadline_dropped_total",
        "Requests shed past their deadline.",
        &[],
        s.deadline_dropped.load(Ordering::Relaxed),
    );
    exp.counter(
        "brainslug_restarts_total",
        "Worker crashes recovered by the supervisor.",
        &[],
        s.restarts.load(Ordering::Relaxed),
    );
    exp.gauge(
        "brainslug_queue_depth",
        "Requests currently in the dispatch queue.",
        &[],
        s.queue_depth_now() as f64,
    );
    exp.gauge(
        "brainslug_queue_peak",
        "High-water mark of the dispatch queue.",
        &[],
        s.queue_peak.load(Ordering::Relaxed) as f64,
    );
    for (i, batches) in s.worker_batches().into_iter().enumerate() {
        let w = i.to_string();
        exp.counter(
            "brainslug_worker_batches_total",
            "Batches executed, by worker.",
            &[("worker", w.as_str())],
            batches,
        );
    }
    for (i, restarts) in s.worker_restarts().into_iter().enumerate() {
        let w = i.to_string();
        exp.counter(
            "brainslug_worker_restarts_total",
            "Crash recoveries, by worker.",
            &[("worker", w.as_str())],
            restarts,
        );
    }
    exp.histogram_seconds(
        "brainslug_request_latency_seconds",
        "End-to-end (enqueue to reply) request latency.",
        &[],
        &s.latency,
    );
    if let Some(inj) = state.faults.as_ref() {
        for p in FaultPoint::ALL {
            exp.counter(
                "brainslug_fault_draws_total",
                "Fault-point probability draws, by point.",
                &[("point", p.name())],
                inj.draws(p),
            );
            exp.counter(
                "brainslug_fault_fired_total",
                "Faults actually fired, by point.",
                &[("point", p.name())],
                inj.fired(p),
            );
        }
    }
    // Registry families last: per-segment execution-time histograms
    // recorded by the worker pool (`brainslug_segment_seconds`).
    state.obs.metrics.render(&mut exp);
    Response::text(200, "text/plain; version=0.0.4", exp.finish())
}

/// `GET /healthz`: the health state machine on the wire. `Ready` and
/// `Degraded` answer 200 (the server accepts work — degraded only
/// means reduced capacity); `Starting` and `Draining` answer 503 with
/// the queue-aware `Retry-After`, and `Draining` closes so probes
/// re-resolve.
fn healthz(state: &AppState) -> Response {
    let phase = state.stats.health.phase();
    let mut o = Json::object();
    o.set("ok", Json::Bool(state.stats.health.is_serving()));
    o.set("state", Json::Str(phase.name().to_string()));
    match phase {
        HealthPhase::Ready | HealthPhase::Degraded => Response::json(200, o.to_string_compact()),
        HealthPhase::Starting | HealthPhase::Draining => {
            let mut resp = Response::json(503, o.to_string_compact());
            resp.retry_after = Some(state.retry_after_now());
            resp.close = phase == HealthPhase::Draining;
            resp
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench;
    use crate::device::DeviceSpec;
    use crate::engine::Engine;
    use crate::optimizer::CollapseOptions;
    use crate::server::{QueuePolicy, Server, ServerConfig};

    fn test_state_with(faults: Option<Arc<FaultInjector>>) -> (Server, AppState) {
        let builder = Engine::builder()
            .graph_owned(bench::block_net(1, 2, 2, 8))
            .device(DeviceSpec::tpu_core())
            .brainslug(CollapseOptions::default())
            .sim()
            .seed(11);
        let mut config = ServerConfig::new(builder)
            .workers(1)
            .queue_depth(4)
            .queue_policy(QueuePolicy::Block);
        if let Some(inj) = faults.clone() {
            config = config.faults(inj);
        }
        let server = config.start().expect("server start");
        let state = AppState {
            handle: server.handle(),
            stats: server.stats.clone(),
            batch: server.batch_size(),
            workers: server.workers(),
            model: server.model_name().to_string(),
            image_elems: server.handle().image_shape().numel(),
            queue_capacity: server.queue_capacity(),
            faults,
            obs: server.obs(),
            trace_seed: Arc::new(AtomicU64::new(0)),
            started: Instant::now(),
        };
        (server, state)
    }

    fn test_state() -> (Server, AppState) {
        test_state_with(None)
    }

    fn post_run_with(state: &AppState, headers: Vec<(String, String)>, body: &str) -> Response {
        let req = Request {
            method: "POST".into(),
            path: "/v1/run".into(),
            headers,
            body: body.as_bytes().to_vec(),
            keep_alive: true,
        };
        route(state, &req)
    }

    fn post_run(state: &AppState, body: &str) -> Response {
        post_run_with(state, Vec::new(), body)
    }

    fn get(state: &AppState, path: &str) -> Response {
        let req = Request {
            method: "GET".into(),
            path: path.into(),
            headers: Vec::new(),
            body: Vec::new(),
            keep_alive: true,
        };
        route(state, &req)
    }

    fn run_body(state: &AppState) -> String {
        format!(
            "{{\"model\":\"{}\",\"input\":{}}}",
            state.model,
            Json::Arr(vec![Json::Num(0.0); state.image_elems]).to_string_compact()
        )
    }

    #[test]
    fn run_round_trips_through_json() {
        let (server, state) = test_state();
        let input = crate::rng::fill_f32(11, state.image_elems);
        let mut body = Json::object();
        body.set("model", Json::Str(state.model.clone()));
        body.set(
            "input",
            Json::Arr(input.iter().map(|v| Json::Num(*v as f64)).collect()),
        );
        let resp = post_run(&state, &body.to_string_compact());
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let parsed = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(parsed.str_field("model").unwrap(), state.model);
        let wire_out: Vec<f32> = parsed
            .arr_field("output")
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        // Parity with the in-process path on the same handle.
        let direct = state.handle.infer(input).unwrap();
        assert_eq!(wire_out, direct.data);
        server.stop();
    }

    #[test]
    fn run_input_errors_are_400() {
        let (server, state) = test_state();
        for body in [
            "not json at all",
            "{}",
            &format!("{{\"model\":\"{}\"}}", state.model),
            &format!("{{\"model\":\"{}\",\"input\":\"nope\"}}", state.model),
            &format!("{{\"model\":\"{}\",\"input\":[1,2,3]}}", state.model),
        ] {
            let resp = post_run(&state, body);
            assert_eq!(resp.status, 400, "body {body:?}");
        }
        server.stop();
    }

    #[test]
    fn unknown_model_is_404() {
        let (server, state) = test_state();
        let resp = post_run(&state, "{\"model\":\"nonesuch\",\"input\":[1]}");
        assert_eq!(resp.status, 404);
        server.stop();
    }

    #[test]
    fn unknown_routes_and_wrong_methods() {
        let (server, state) = test_state();
        assert_eq!(get(&state, "/nope").status, 404);
        assert_eq!(get(&state, "/v1/run").status, 405);
        assert_eq!(get(&state, "/v1/run").allow, Some("POST"));
        let resp = route(
            &state,
            &Request {
                method: "DELETE".into(),
                path: "/healthz".into(),
                headers: Vec::new(),
                body: Vec::new(),
                keep_alive: true,
            },
        );
        assert_eq!((resp.status, resp.allow), (405, Some("GET")));
        server.stop();
    }

    #[test]
    fn stats_and_healthz() {
        let (server, state) = test_state();
        let resp = get(&state, "/healthz");
        assert_eq!(resp.status, 200);
        let parsed = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert!(parsed.bool_field("ok").unwrap());
        assert_eq!(parsed.str_field("state").unwrap(), "ready");
        let resp = get(&state, "/v1/stats");
        assert_eq!(resp.status, 200);
        let parsed = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(parsed.str_field("model").unwrap(), state.model);
        assert_eq!(parsed.usize_field("workers").unwrap(), 1);
        assert_eq!(parsed.usize_field("image_elems").unwrap(), state.image_elems);
        assert_eq!(parsed.usize_field("restarts").unwrap(), 0);
        assert_eq!(parsed.usize_field("deadline_dropped").unwrap(), 0);
        assert_eq!(parsed.str_field("health").unwrap(), "ready");
        assert!(parsed.f64_field("uptime_s").unwrap() >= 0.0);
        // Unarmed server: no fault_injection block.
        assert!(parsed.get("fault_injection").is_none());
        server.stop();
    }

    /// Satellite: every routed response echoes `x-brainslug-trace` —
    /// the client's id verbatim when one was sent, a freshly minted
    /// non-zero id otherwise, on error paths included.
    #[test]
    fn every_response_carries_a_trace_id() {
        let (server, state) = test_state();
        let resp = post_run_with(
            &state,
            vec![("x-brainslug-trace".into(), "deadbeef".into())],
            &run_body(&state),
        );
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        assert_eq!(resp.trace, Some(0xDEAD_BEEF), "client id echoed");
        // Garbage ids are ignored, not 400: a fresh id is minted.
        let resp = post_run_with(
            &state,
            vec![("x-brainslug-trace".into(), "not hex".into())],
            &run_body(&state),
        );
        assert!(resp.trace.is_some_and(|t| t != 0xDEAD_BEEF && t != 0));
        // Error paths echo too: 404, 405, and 400 all carry an id.
        assert!(get(&state, "/nope").trace.is_some_and(|t| t != 0));
        assert!(get(&state, "/v1/run").trace.is_some_and(|t| t != 0));
        assert!(post_run(&state, "{}").trace.is_some_and(|t| t != 0));
        server.stop();
    }

    /// Tentpole: `/v1/metrics` renders the Prometheus text exposition.
    /// Shape checks (TYPE/HELP lines, name{labels} value samples) live
    /// in `obs::metrics`; this pins the route, content type, and that
    /// the serving counters and per-segment families show up.
    #[test]
    fn metrics_exposition_covers_serving_counters_and_segments() {
        let (server, state) = test_state();
        let resp = post_run(&state, &run_body(&state));
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let resp = get(&state, "/v1/metrics");
        assert_eq!(resp.status, 200);
        assert_eq!(resp.content_type, "text/plain; version=0.0.4");
        let text = std::str::from_utf8(&resp.body).unwrap();
        assert!(text.contains("# TYPE brainslug_requests_total counter"), "{text}");
        assert!(text.contains("brainslug_requests_total 1"), "{text}");
        assert!(
            text.contains("brainslug_worker_batches_total{worker=\"0\"}"),
            "{text}"
        );
        assert!(
            text.contains("brainslug_request_latency_seconds_count 1"),
            "{text}"
        );
        // The always-on registry: one series per executed segment.
        assert!(
            text.contains("# TYPE brainslug_segment_seconds histogram"),
            "{text}"
        );
        assert!(text.contains("brainslug_segment_seconds_count{segment="), "{text}");
        // Unarmed server: no fault families.
        assert!(!text.contains("brainslug_fault_draws_total"), "{text}");
        // Wrong verb is 405 like the other GET routes.
        let resp = route(
            &state,
            &Request {
                method: "POST".into(),
                path: "/v1/metrics".into(),
                headers: Vec::new(),
                body: Vec::new(),
                keep_alive: true,
            },
        );
        assert_eq!((resp.status, resp.allow), (405, Some("GET")));
        server.stop();
    }

    #[test]
    fn stopped_server_maps_to_503_and_healthz_drains() {
        let (server, state) = test_state();
        server.stop();
        let resp = post_run(&state, &run_body(&state));
        assert_eq!(resp.status, 503);
        assert!(resp.close);
        let resp = get(&state, "/healthz");
        assert_eq!(resp.status, 503);
        assert!(resp.close, "draining probes should re-resolve");
        assert!(resp.retry_after.is_some());
        let parsed = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert!(!parsed.bool_field("ok").unwrap());
        assert_eq!(parsed.str_field("state").unwrap(), "draining");
    }

    /// Satellite: the exhaustive `InferError` → wire mapping. The match
    /// in [`infer_error_response`] has no wildcard arm, so a new
    /// variant fails to compile there; this test pins the documented
    /// status/header/close decisions for every variant.
    #[test]
    fn fault_infer_error_wire_mapping_is_exhaustive() {
        let (server, state) = test_state();
        let cases: Vec<(InferError, u16, bool, bool)> = vec![
            // (error, status, has Retry-After, closes)
            (InferError::QueueFull { capacity: 4 }, 503, true, false),
            (InferError::Stopped, 503, true, true),
            (InferError::BadInput("bad".into()), 400, false, false),
            (InferError::Exec("boom".into()), 500, false, false),
            (InferError::WorkerCrashed { worker: 0 }, 503, true, false),
            (InferError::DeadlineExceeded { waited_ms: 7 }, 504, false, false),
        ];
        for (err, status, retries, closes) in cases {
            let resp = infer_error_response(&state, &err);
            assert_eq!(resp.status, status, "{err:?}");
            assert_eq!(resp.retry_after.is_some(), retries, "{err:?}");
            assert_eq!(resp.close, closes, "{err:?}");
            // Every error body is the standard {"error": ...} shape.
            let parsed = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
            assert_eq!(parsed.str_field("error").unwrap(), err.to_string());
        }
        server.stop();
    }

    #[test]
    fn fault_deadline_header_is_parsed_and_validated() {
        let (server, state) = test_state();
        // Generous deadline: request succeeds.
        let resp = post_run_with(
            &state,
            vec![("x-brainslug-deadline-ms".into(), "10000".into())],
            &run_body(&state),
        );
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        // Invalid values are 400, not silently ignored.
        for bad in ["0", "-3", "soon", ""] {
            let resp = post_run_with(
                &state,
                vec![("x-brainslug-deadline-ms".into(), bad.into())],
                &run_body(&state),
            );
            assert_eq!(resp.status, 400, "deadline {bad:?}");
        }
        server.stop();
    }

    #[test]
    fn fault_trigger_header_crashes_then_recovers() {
        let inj = Arc::new(FaultInjector::new(1));
        let (server, state) = test_state_with(Some(inj.clone()));
        // The request carrying the trigger is the next batch: it takes
        // the crash and gets the transient 503.
        let resp = post_run_with(
            &state,
            vec![("x-brainslug-fault".into(), "worker-panic".into())],
            &run_body(&state),
        );
        assert_eq!(resp.status, 503, "{}", String::from_utf8_lossy(&resp.body));
        assert!(resp.retry_after.is_some());
        // The rebuilt replica answers the retry.
        let resp = post_run(&state, &run_body(&state));
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        // Stats surface the restart and the armed injector.
        let parsed =
            json::parse(std::str::from_utf8(&get(&state, "/v1/stats").body).unwrap()).unwrap();
        assert_eq!(parsed.usize_field("restarts").unwrap(), 1);
        let fi = parsed.get("fault_injection").expect("armed block");
        assert_eq!(
            fi.get("points").unwrap().get("worker-panic").unwrap().usize_field("fired").unwrap(),
            1
        );
        // Unknown fault names are rejected.
        let resp = post_run_with(
            &state,
            vec![("x-brainslug-fault".into(), "nonsense".into())],
            &run_body(&state),
        );
        assert_eq!(resp.status, 400);
        server.stop();
    }

    #[test]
    fn fault_trigger_header_requires_armed_injector() {
        let (server, state) = test_state();
        let resp = post_run_with(
            &state,
            vec![("x-brainslug-fault".into(), "worker-panic".into())],
            &run_body(&state),
        );
        assert_eq!(resp.status, 400);
        server.stop();
    }
}
