//! Diagnostic codes, severities, and renderers for the static verifier.
//!
//! Every check in [`crate::analysis`] reports through a [`Diagnostic`]
//! carrying a stable [`DiagCode`] (`BSL0xx`). Codes are part of the
//! public contract: tests, CI gates, and downstream tooling key on them,
//! so existing codes must never be renumbered — only appended.
//!
//! Code space:
//! - `BSL001`–`BSL019`: graph lint ([`crate::analysis::graph_lint`])
//! - `BSL020`–`BSL039`: plan verifier ([`crate::analysis::plan_verify`])
//! - `BSL040`–`BSL049`: concurrency topology lint ([`crate::analysis::topo`])
//! - `BSL050`–`BSL059`: schedule model checker ([`crate::conc`])

use crate::json::Json;

/// How bad a finding is. `Error` means the artifact is unsound and must
/// not execute; `Warning` means suspicious-but-runnable (promoted to
/// failure under `--deny warnings`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// Stable diagnostic codes. The numeric part never changes meaning.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DiagCode {
    // --- graph lint ---
    /// Graph empty or node 0 is not the Input node.
    EmptyGraph,
    /// Node id does not equal its index in the node vector.
    NodeIdMismatch,
    /// Edge references a node at or after its consumer (cycle / non-topological).
    NonTopologicalEdge,
    /// Edge references a node id outside the graph (dangling edge).
    DanglingEdge,
    /// Input layer appears at an interior node.
    InteriorInput,
    /// Layer got the wrong number of inputs.
    ArityMismatch,
    /// Shape mismatch at an Add/Concat join.
    JoinShapeMismatch,
    /// Stored node shape disagrees with static re-inference.
    StoredShapeMismatch,
    /// Degenerate op config (zero-size window, stride 0, window larger
    /// than padded input, zero channels, non-dividing adaptive pool).
    DegenerateOp,
    /// Graph output id out of range.
    BadOutput,
    /// Non-output node with no consumers (dangling node).
    DanglingNode,
    /// Mixed dtypes at a join where the dims otherwise agree.
    JoinDtypeMix,
    // --- plan verifier ---
    /// Plan does not cover the graph: node missing, duplicated, or out
    /// of range.
    PlanCoverage,
    /// Stack chain broken: consecutive stack nodes are not a unary
    /// producer/consumer chain.
    StackChainBroken,
    /// Branch join malformed: join is not Add/Concat, or arm count
    /// disagrees with join arity.
    BranchJoinMalformed,
    /// Branch arm inconsistent: arm does not start at the region entry
    /// or its output is not the matching join input.
    BranchArmMismatch,
    /// Multi-step sequence working set exceeds the collapse budget.
    BudgetOverrun,
    /// Halo back-propagation can underflow: a band of the planned
    /// geometry reaches zero rows at some step.
    HaloUnderflow,
    /// Branch-arm stack exceeds its skip-reserved budget (the
    /// `reserved_bytes` floor accounting is broken).
    SkipReservationBroken,
    /// Band buffer / shape chain broken: step or sequence shapes do not
    /// chain, or fused ops disagree with the stack's node list.
    BandShapeChain,
    /// Fused op has no breadth-first fallback (non-optimizable layer
    /// inside a stack).
    NoFallback,
    /// tile_rows exceeds the sequence output height (wasteful but
    /// clamped at run time).
    TileRowsExceedHeight,
    // --- concurrency topology lint ---
    /// Capacity-zero channel cycle (rendezvous deadlock).
    ZeroCapacityCycle,
    /// Shutdown tokens sent on a gated channel before the gate closes
    /// (requests accepted after tokens → lost-wakeup / dropped work).
    SendBeforeGateClose,
    /// Thread is never joined and does not end with its scope.
    UnjoinedThread,
    /// Channel endpoint or gate references an undeclared thread/gate,
    /// or a channel has no senders/receivers.
    BadEndpoint,
    /// Thread joined before its exit condition is established
    /// (insufficient shutdown tokens, senders still live, gate open).
    JoinWithoutTermination,
    /// Gate declared but never closed during shutdown.
    GateNeverClosed,
    // --- schedule model checker ---
    /// An explored schedule deadlocked: every live thread blocked (or
    /// the execution exceeded its step budget). The violating schedule
    /// is attached as a replayable note.
    ModelDeadlock,
    /// Cycle in the lock-acquisition-order graph accumulated from real
    /// acquisition traces across explored schedules.
    LockOrderCycle,
    /// `Condvar::wait` used without a predicate loop (`wait_while`);
    /// vulnerable to spurious wakeups and missed re-checks.
    BareCondvarWait,
    /// Deadlock in which threads block on a condvar that previously
    /// fired notifies into an empty wait-set (a lost notification).
    LostNotify,
    /// Send attempted on a channel whose receiver was already gone.
    SendAfterClose,
    /// Shutdown token observed on a gated channel while the gate was
    /// still open — requests can slip in FIFO-behind the tokens.
    GateAfterTokens,
    /// Protocol reached join/quiescence with open obligations: queued
    /// work never received, or accepted work never completed.
    NonQuiescentJoin,
}

impl DiagCode {
    /// The stable wire code, e.g. `"BSL024"`.
    pub fn as_str(&self) -> &'static str {
        match self {
            DiagCode::EmptyGraph => "BSL001",
            DiagCode::NodeIdMismatch => "BSL002",
            DiagCode::NonTopologicalEdge => "BSL003",
            DiagCode::DanglingEdge => "BSL004",
            DiagCode::InteriorInput => "BSL005",
            DiagCode::ArityMismatch => "BSL006",
            DiagCode::JoinShapeMismatch => "BSL007",
            DiagCode::StoredShapeMismatch => "BSL008",
            DiagCode::DegenerateOp => "BSL009",
            DiagCode::BadOutput => "BSL010",
            DiagCode::DanglingNode => "BSL011",
            DiagCode::JoinDtypeMix => "BSL012",
            DiagCode::PlanCoverage => "BSL020",
            DiagCode::StackChainBroken => "BSL021",
            DiagCode::BranchJoinMalformed => "BSL022",
            DiagCode::BranchArmMismatch => "BSL023",
            DiagCode::BudgetOverrun => "BSL024",
            DiagCode::HaloUnderflow => "BSL025",
            DiagCode::SkipReservationBroken => "BSL026",
            DiagCode::BandShapeChain => "BSL027",
            DiagCode::NoFallback => "BSL028",
            DiagCode::TileRowsExceedHeight => "BSL029",
            DiagCode::ZeroCapacityCycle => "BSL040",
            DiagCode::SendBeforeGateClose => "BSL041",
            DiagCode::UnjoinedThread => "BSL042",
            DiagCode::BadEndpoint => "BSL043",
            DiagCode::JoinWithoutTermination => "BSL044",
            DiagCode::GateNeverClosed => "BSL045",
            DiagCode::ModelDeadlock => "BSL050",
            DiagCode::LockOrderCycle => "BSL051",
            DiagCode::BareCondvarWait => "BSL052",
            DiagCode::LostNotify => "BSL053",
            DiagCode::SendAfterClose => "BSL054",
            DiagCode::GateAfterTokens => "BSL055",
            DiagCode::NonQuiescentJoin => "BSL056",
        }
    }

    /// Default severity. Warnings are suspicious-but-runnable patterns
    /// (bare condvar waits, sends the caller already handles the `Err`
    /// of); everything else makes the artifact unsound.
    pub fn severity(&self) -> Severity {
        match self {
            DiagCode::JoinDtypeMix
            | DiagCode::TileRowsExceedHeight
            | DiagCode::GateNeverClosed
            | DiagCode::BareCondvarWait
            | DiagCode::SendAfterClose => Severity::Warning,
            _ => Severity::Error,
        }
    }

    /// One-line explanation for the code table (`DESIGN.md` mirrors
    /// these).
    pub fn explain(&self) -> &'static str {
        match self {
            DiagCode::EmptyGraph => "graph is empty or node 0 is not the Input node",
            DiagCode::NodeIdMismatch => "node id does not match its position in the node vector",
            DiagCode::NonTopologicalEdge => "edge points at or after its consumer (cycle)",
            DiagCode::DanglingEdge => "edge references a node id outside the graph",
            DiagCode::InteriorInput => "Input layer at an interior node",
            DiagCode::ArityMismatch => "layer has the wrong number of inputs",
            DiagCode::JoinShapeMismatch => "shapes disagree at an add/concat join",
            DiagCode::StoredShapeMismatch => "stored shape disagrees with static re-inference",
            DiagCode::DegenerateOp => "degenerate op config (zero window, stride 0, window > input, zero channels)",
            DiagCode::BadOutput => "graph output id out of range",
            DiagCode::DanglingNode => "non-output node has no consumers",
            DiagCode::JoinDtypeMix => "join inputs mix dtypes",
            DiagCode::PlanCoverage => "plan misses or duplicates a graph node",
            DiagCode::StackChainBroken => "stack nodes are not a unary producer/consumer chain",
            DiagCode::BranchJoinMalformed => "branch join is not add/concat or arm count mismatches join arity",
            DiagCode::BranchArmMismatch => "branch arm entry/exit disagrees with the region",
            DiagCode::BudgetOverrun => "multi-step sequence working set exceeds the collapse budget",
            DiagCode::HaloUnderflow => "halo back-propagation reaches zero rows for some band",
            DiagCode::SkipReservationBroken => "branch-arm stack exceeds its skip-reserved budget",
            DiagCode::BandShapeChain => "step/sequence shapes do not chain through the stack",
            DiagCode::NoFallback => "fused op has no breadth-first fallback kernel",
            DiagCode::TileRowsExceedHeight => "tile_rows exceeds the sequence output height",
            DiagCode::ZeroCapacityCycle => "capacity-zero channel cycle (rendezvous deadlock)",
            DiagCode::SendBeforeGateClose => "shutdown tokens sent before the intake gate closes",
            DiagCode::UnjoinedThread => "thread is never joined and does not end with its scope",
            DiagCode::BadEndpoint => "channel/gate references an undeclared endpoint",
            DiagCode::JoinWithoutTermination => "thread joined before its exit condition is established",
            DiagCode::GateNeverClosed => "gate declared but never closed during shutdown",
            DiagCode::ModelDeadlock => "explored schedule deadlocks: every live thread is blocked",
            DiagCode::LockOrderCycle => "cycle in the observed lock-acquisition-order graph",
            DiagCode::BareCondvarWait => "condvar wait without a predicate loop (use wait_while)",
            DiagCode::LostNotify => "deadlock behind a notify that fired into an empty wait-set",
            DiagCode::SendAfterClose => "send attempted on a channel whose receiver is gone",
            DiagCode::GateAfterTokens => "shutdown token sent on a gated channel before the gate closed",
            DiagCode::NonQuiescentJoin => "join/quiescence reached with queued or unanswered work",
        }
    }
}

/// One finding: a code, where it is, what is wrong, and optional notes.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub code: DiagCode,
    pub severity: Severity,
    /// Where: `"vgg16: node 3 ('features.1')"`, `"plan for resnet18:
    /// segment 4"`, `"topology 'server'"`.
    pub subject: String,
    /// Graph node id when the finding is about one node.
    pub node: Option<usize>,
    pub message: String,
    pub notes: Vec<String>,
}

impl Diagnostic {
    pub fn new(code: DiagCode, subject: impl Into<String>, message: impl Into<String>) -> Self {
        Diagnostic {
            code,
            severity: code.severity(),
            subject: subject.into(),
            node: None,
            message: message.into(),
            notes: Vec::new(),
        }
    }

    pub fn at_node(mut self, id: usize) -> Self {
        self.node = Some(id);
        self
    }

    pub fn note(mut self, note: impl Into<String>) -> Self {
        self.notes.push(note.into());
        self
    }

    /// rustc-style multi-line rendering:
    ///
    /// ```text
    /// error[BSL024]: working set 40960 B exceeds budget 16384 B
    ///   --> plan for resnet18: segment 4, sequence 0
    ///    = note: multi-step sequences must fit the collapse budget
    /// ```
    pub fn render(&self) -> String {
        let mut s = format!(
            "{}[{}]: {}\n  --> {}",
            self.severity.name(),
            self.code.as_str(),
            self.message,
            self.subject
        );
        for n in &self.notes {
            s.push_str("\n   = note: ");
            s.push_str(n);
        }
        s
    }

    /// One-line rendering for embedding in `Result<_, String>` paths.
    pub fn render_oneline(&self) -> String {
        format!("[{}] {}: {}", self.code.as_str(), self.subject, self.message)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::object();
        j.set("code", Json::Str(self.code.as_str().into()))
            .set("severity", Json::Str(self.severity.name().into()))
            .set("subject", Json::Str(self.subject.clone()))
            .set("message", Json::Str(self.message.clone()));
        if let Some(id) = self.node {
            j.set("node", Json::from_usize(id));
        }
        if !self.notes.is_empty() {
            j.set(
                "notes",
                Json::Arr(self.notes.iter().map(|n| Json::Str(n.clone())).collect()),
            );
        }
        j
    }
}

/// A collection of findings from one or more passes.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub diags: Vec<Diagnostic>,
}

impl Report {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, d: Diagnostic) {
        self.diags.push(d);
    }

    pub fn extend(&mut self, ds: Vec<Diagnostic>) {
        self.diags.extend(ds);
    }

    pub fn error_count(&self) -> usize {
        self.diags
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .count()
    }

    pub fn warning_count(&self) -> usize {
        self.diags
            .iter()
            .filter(|d| d.severity == Severity::Warning)
            .count()
    }

    /// True when nothing at or above the failing severity was found.
    pub fn is_clean(&self, deny_warnings: bool) -> bool {
        self.error_count() == 0 && (!deny_warnings || self.warning_count() == 0)
    }

    /// Full text rendering: errors first, then warnings, then a summary
    /// line.
    pub fn render_text(&self) -> String {
        let mut sorted: Vec<&Diagnostic> = self.diags.iter().collect();
        sorted.sort_by_key(|d| std::cmp::Reverse(d.severity));
        let mut out = String::new();
        for d in sorted {
            out.push_str(&d.render());
            out.push_str("\n\n");
        }
        out.push_str(&format!(
            "check result: {} error(s), {} warning(s)",
            self.error_count(),
            self.warning_count()
        ));
        out
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::object();
        j.set(
            "diagnostics",
            Json::Arr(self.diags.iter().map(Diagnostic::to_json).collect()),
        )
        .set("errors", Json::from_usize(self.error_count()))
        .set("warnings", Json::from_usize(self.warning_count()));
        j
    }

    /// True if any diagnostic carries `code`.
    pub fn has_code(&self, code: DiagCode) -> bool {
        self.diags.iter().any(|d| d.code == code)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_and_unique() {
        let all = [
            DiagCode::EmptyGraph,
            DiagCode::NodeIdMismatch,
            DiagCode::NonTopologicalEdge,
            DiagCode::DanglingEdge,
            DiagCode::InteriorInput,
            DiagCode::ArityMismatch,
            DiagCode::JoinShapeMismatch,
            DiagCode::StoredShapeMismatch,
            DiagCode::DegenerateOp,
            DiagCode::BadOutput,
            DiagCode::DanglingNode,
            DiagCode::JoinDtypeMix,
            DiagCode::PlanCoverage,
            DiagCode::StackChainBroken,
            DiagCode::BranchJoinMalformed,
            DiagCode::BranchArmMismatch,
            DiagCode::BudgetOverrun,
            DiagCode::HaloUnderflow,
            DiagCode::SkipReservationBroken,
            DiagCode::BandShapeChain,
            DiagCode::NoFallback,
            DiagCode::TileRowsExceedHeight,
            DiagCode::ZeroCapacityCycle,
            DiagCode::SendBeforeGateClose,
            DiagCode::UnjoinedThread,
            DiagCode::BadEndpoint,
            DiagCode::JoinWithoutTermination,
            DiagCode::GateNeverClosed,
            DiagCode::ModelDeadlock,
            DiagCode::LockOrderCycle,
            DiagCode::BareCondvarWait,
            DiagCode::LostNotify,
            DiagCode::SendAfterClose,
            DiagCode::GateAfterTokens,
            DiagCode::NonQuiescentJoin,
        ];
        let mut seen = std::collections::BTreeSet::new();
        for c in all {
            assert!(c.as_str().starts_with("BSL"), "{}", c.as_str());
            assert!(seen.insert(c.as_str()), "duplicate code {}", c.as_str());
            assert!(!c.explain().is_empty());
        }
        // Pinned: renumbering any of these is a breaking change.
        assert_eq!(DiagCode::BudgetOverrun.as_str(), "BSL024");
        assert_eq!(DiagCode::HaloUnderflow.as_str(), "BSL025");
        assert_eq!(DiagCode::SendBeforeGateClose.as_str(), "BSL041");
        assert_eq!(DiagCode::ModelDeadlock.as_str(), "BSL050");
        assert_eq!(DiagCode::GateAfterTokens.as_str(), "BSL055");
        assert_eq!(DiagCode::NonQuiescentJoin.as_str(), "BSL056");
    }

    #[test]
    fn render_is_rustc_style() {
        let d = Diagnostic::new(DiagCode::BudgetOverrun, "plan for x: segment 1", "too big")
            .note("fit the budget");
        let r = d.render();
        assert!(r.starts_with("error[BSL024]: too big"));
        assert!(r.contains("--> plan for x: segment 1"));
        assert!(r.contains("= note: fit the budget"));
    }

    #[test]
    fn report_counts_and_deny() {
        let mut r = Report::new();
        r.push(Diagnostic::new(DiagCode::TileRowsExceedHeight, "s", "w"));
        assert_eq!(r.error_count(), 0);
        assert_eq!(r.warning_count(), 1);
        assert!(r.is_clean(false));
        assert!(!r.is_clean(true));
        r.push(Diagnostic::new(DiagCode::PlanCoverage, "s", "e"));
        assert!(!r.is_clean(false));
        let j = r.to_json();
        assert_eq!(j.usize_field("errors").unwrap(), 1);
        assert_eq!(j.usize_field("warnings").unwrap(), 1);
        assert_eq!(j.arr_field("diagnostics").unwrap().len(), 2);
    }
}
