//! Pass 1: graph lint — full static shape/dtype re-inference over the
//! [`Graph`] IR.
//!
//! Subsumes the original `Graph::validate` (which now delegates here)
//! and extends it: every finding carries the node id, the node's
//! user-facing name, and a stable `BSL0xx` code instead of a bare
//! `String`. The pass is total — it never panics, even on graphs whose
//! shapes would make `Layer::infer_shape`'s window helpers assert —
//! because window sanity ([`Layer::check_config`]) is checked *before*
//! inference runs.
//!
//! Check order per node: identity (BSL002), edges (BSL003/BSL004),
//! interior inputs (BSL005), arity (BSL006), degenerate configs
//! (BSL009), inference + join classification (BSL007/BSL009/BSL012),
//! stored-shape agreement (BSL008); then whole-graph checks: output
//! range (BSL010) and dangling nodes (BSL011).

use super::diag::{DiagCode, Diagnostic};
use crate::graph::{Graph, Layer, Shape};

/// Human-oriented location string: network, node id, node name, kind.
fn subject(g: &Graph, id: usize) -> String {
    match g.nodes.get(id) {
        Some(n) => format!(
            "{}: node {} ('{}', {})",
            g.name,
            id,
            n.name,
            n.layer.kind_name()
        ),
        None => format!("{}: node {}", g.name, id),
    }
}

/// Run the full graph lint. Returns every finding (errors and
/// warnings); an empty vector means the graph is well-formed.
pub fn lint_graph(g: &Graph) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    if g.nodes.is_empty() {
        diags.push(Diagnostic::new(
            DiagCode::EmptyGraph,
            g.name.clone(),
            "graph has no nodes",
        ));
        return diags;
    }
    if !matches!(g.nodes[0].layer, Layer::Input { .. }) {
        diags.push(
            Diagnostic::new(
                DiagCode::EmptyGraph,
                subject(g, 0),
                "node 0 must be the Input node",
            )
            .at_node(0),
        );
    }

    for (idx, node) in g.nodes.iter().enumerate() {
        if node.id != idx {
            diags.push(
                Diagnostic::new(
                    DiagCode::NodeIdMismatch,
                    subject(g, idx),
                    format!("node id {} does not match its index {idx}", node.id),
                )
                .at_node(idx),
            );
        }

        let mut edges_ok = true;
        for &i in &node.inputs {
            if i >= g.nodes.len() {
                diags.push(
                    Diagnostic::new(
                        DiagCode::DanglingEdge,
                        subject(g, idx),
                        format!("input edge references node {i}, but the graph has only {} nodes", g.nodes.len()),
                    )
                    .at_node(idx),
                );
                edges_ok = false;
            } else if i >= idx {
                diags.push(
                    Diagnostic::new(
                        DiagCode::NonTopologicalEdge,
                        subject(g, idx),
                        format!("input edge from node {i} is not topologically earlier"),
                    )
                    .at_node(idx)
                    .note("the node vector is append-only; a forward or self edge implies a cycle"),
                );
                edges_ok = false;
            }
        }

        if idx > 0 && matches!(node.layer, Layer::Input { .. }) {
            diags.push(
                Diagnostic::new(
                    DiagCode::InteriorInput,
                    subject(g, idx),
                    "Input layer at an interior node",
                )
                .at_node(idx),
            );
            continue;
        }

        let (min_in, max_in) = node.layer.arity();
        if node.inputs.len() < min_in || node.inputs.len() > max_in {
            let expected = if max_in == usize::MAX {
                format!(">= {min_in}")
            } else if min_in == max_in {
                format!("{min_in}")
            } else {
                format!("{min_in}..={max_in}")
            };
            diags.push(
                Diagnostic::new(
                    DiagCode::ArityMismatch,
                    subject(g, idx),
                    format!(
                        "{} expects {expected} input(s), got {}",
                        node.layer.kind_name(),
                        node.inputs.len()
                    ),
                )
                .at_node(idx),
            );
            continue;
        }
        if !edges_ok {
            continue; // can't infer shapes through bad edges
        }

        let in_shapes: Vec<&Shape> = node.inputs.iter().map(|&i| &g.nodes[i].shape).collect();
        if let Err(reason) = node.layer.check_config(&in_shapes) {
            diags.push(
                Diagnostic::new(DiagCode::DegenerateOp, subject(g, idx), reason).at_node(idx),
            );
            continue;
        }
        match node.layer.infer_shape(&in_shapes) {
            Err(reason) => {
                let code = match node.layer {
                    Layer::Add | Layer::Concat => {
                        // A join whose input dims agree but dtypes differ
                        // is runnable-but-suspicious, not structurally
                        // broken.
                        let dims_agree = match node.layer {
                            Layer::Add => in_shapes
                                .windows(2)
                                .all(|w| w[0].dims == w[1].dims),
                            _ => true,
                        };
                        let dtype_mix =
                            in_shapes.iter().any(|s| s.dtype != in_shapes[0].dtype);
                        if dims_agree && dtype_mix {
                            DiagCode::JoinDtypeMix
                        } else {
                            DiagCode::JoinShapeMismatch
                        }
                    }
                    _ => DiagCode::DegenerateOp,
                };
                diags.push(Diagnostic::new(code, subject(g, idx), reason).at_node(idx));
            }
            Ok(inferred) => {
                if inferred != node.shape {
                    diags.push(
                        Diagnostic::new(
                            DiagCode::StoredShapeMismatch,
                            subject(g, idx),
                            format!(
                                "stored shape {} disagrees with inferred {}",
                                node.shape, inferred
                            ),
                        )
                        .at_node(idx),
                    );
                }
                // Concat takes the first input's dtype, so inference
                // succeeds even when the arms disagree — flag it.
                if matches!(node.layer, Layer::Concat)
                    && in_shapes.iter().any(|s| s.dtype != in_shapes[0].dtype)
                {
                    diags.push(
                        Diagnostic::new(
                            DiagCode::JoinDtypeMix,
                            subject(g, idx),
                            "concat inputs mix dtypes; output takes the first input's dtype",
                        )
                        .at_node(idx),
                    );
                }
            }
        }
    }

    if g.output >= g.nodes.len() {
        diags.push(Diagnostic::new(
            DiagCode::BadOutput,
            g.name.clone(),
            format!(
                "output id {} out of range (graph has {} nodes)",
                g.output,
                g.nodes.len()
            ),
        ));
    } else {
        // Dangling-node check, edge-tolerant (out-of-range inputs were
        // already reported above, so just skip them here).
        let mut has_consumer = vec![false; g.nodes.len()];
        for node in &g.nodes {
            for &i in &node.inputs {
                if let Some(slot) = has_consumer.get_mut(i) {
                    *slot = true;
                }
            }
        }
        for (idx, consumed) in has_consumer.iter().enumerate() {
            if idx != g.output && !consumed {
                diags.push(
                    Diagnostic::new(
                        DiagCode::DanglingNode,
                        subject(g, idx),
                        "node is neither the output nor consumed by any other node",
                    )
                    .at_node(idx),
                );
            }
        }
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, Layer, PoolKind, Shape, Window2d};

    fn base() -> Graph {
        let mut g = Graph::new("lint-test", Shape::nchw(1, 4, 8, 8));
        let c = g.push(
            "conv",
            Layer::Conv2d {
                out_channels: 4,
                window: Window2d::square(3, 1, 1),
                bias: false,
            },
        );
        g.add("relu", Layer::Relu, &[c]);
        g
    }

    fn codes(g: &Graph) -> Vec<&'static str> {
        lint_graph(g).iter().map(|d| d.code.as_str()).collect()
    }

    #[test]
    fn clean_graph_has_no_findings() {
        assert!(codes(&base()).is_empty());
    }

    #[test]
    fn forward_edge_is_a_cycle() {
        let mut g = base();
        g.nodes[1].inputs = vec![2];
        assert!(codes(&g).contains(&"BSL003"));
    }

    #[test]
    fn out_of_range_edge_dangles() {
        let mut g = base();
        g.nodes[2].inputs = vec![99];
        let c = codes(&g);
        assert!(c.contains(&"BSL004"), "{c:?}");
    }

    #[test]
    fn stored_shape_mismatch() {
        let mut g = base();
        g.nodes[2].shape = Shape::nchw(1, 4, 7, 7);
        assert!(codes(&g).contains(&"BSL008"));
    }

    #[test]
    fn degenerate_window_is_flagged_not_panicking() {
        let mut g = base();
        // Stride 0 would assert inside conv_out_dim if inference ran.
        g.nodes[1].layer = Layer::Pool2d {
            kind: PoolKind::Max,
            window: Window2d {
                kernel: (3, 3),
                stride: (0, 1),
                pad: (1, 1),
            },
            ceil_mode: false,
            count_include_pad: true,
        };
        assert!(codes(&g).contains(&"BSL009"));
    }

    #[test]
    fn window_larger_than_padded_input() {
        let mut g = base();
        g.nodes[1].layer = Layer::Pool2d {
            kind: PoolKind::Max,
            window: Window2d::square(64, 1, 0),
            ceil_mode: false,
            count_include_pad: true,
        };
        assert!(codes(&g).contains(&"BSL009"));
    }

    #[test]
    fn bad_output_and_dangling() {
        let mut g = base();
        g.output = 42;
        assert!(codes(&g).contains(&"BSL010"));
        let mut g = base();
        g.output = 1; // relu at node 2 now dangles
        assert!(codes(&g).contains(&"BSL011"));
    }

    #[test]
    fn add_arity_and_join_mismatch() {
        let mut g = base();
        g.add("add", Layer::Add, &[1, 2]);
        assert!(codes(&g).is_empty()); // same shapes: fine
        g.nodes[3].inputs = vec![1];
        assert!(codes(&g).contains(&"BSL006"));
    }

    #[test]
    fn dtype_mix_is_a_warning() {
        let mut g = base();
        // Second arm in bf16, same dims: add join flags BSL012, not BSL007.
        let mut s = Shape::nchw(1, 4, 8, 8);
        s.dtype = DType::BF16;
        g.nodes[2].shape = s;
        // (Stored-shape check fires too — relu inferred f32 — but the
        // join itself must classify as a dtype mix.)
        g.add("add", Layer::Add, &[1, 1]);
        g.nodes[3].inputs = vec![1, 2];
        let ds = lint_graph(&g);
        assert!(ds
            .iter()
            .any(|d| d.code == DiagCode::JoinDtypeMix && d.node == Some(3)));
    }
}
