//! Static verification subsystem (`brainslug check`).
//!
//! Four passes, every finding a [`Diagnostic`] with a stable `BSL0xx`
//! code (the full table lives in [`diag::DiagCode`] and DESIGN.md
//! §Static Analysis):
//!
//! 1. [`graph_lint`] (BSL001–BSL012) — full static shape/dtype
//!    inference over a [`crate::graph::Graph`]: dangling and
//!    non-topological edges, arity, join shape/dtype agreement,
//!    degenerate op configs, stored-vs-inferred shape drift.
//!    `Graph::validate` delegates here.
//! 2. [`plan_verify`] (BSL020–BSL029) — proof-oriented verification of
//!    a [`crate::optimizer::Plan`]: coverage/chain/branch structure,
//!    working sets re-derived against the collapse budget, halo
//!    back-propagation proven to never underflow for any band offset,
//!    skip-reservation accounting, breadth-first fallbacks.
//!    `Plan::validate` delegates to the structural half; the engine
//!    runs the resource half in debug builds.
//! 3. [`topo`] (BSL040–BSL045) — the runtime's thread/channel/gate
//!    topology declared as data and checked for rendezvous cycles,
//!    drain-ordering races, unjoined threads and blocking joins.
//! 4. [`crate::conc`] (BSL050–BSL056, opt-in via `--schedules N`
//!    because it executes code) — schedule model checking: replicas of
//!    the real drain/queue/pool protocols run under a controlled
//!    scheduler that explores bounded-preemption interleavings plus
//!    seeded random walks, turning observed deadlocks, lock-order
//!    cycles, lost notifies, gate/token ordering violations and
//!    stranded work into diagnostics with replayable counterexample
//!    schedules. Pass 3 checks the *declared* shape; pass 4 checks the
//!    *behavior* of the code that claims to implement it.
//!
//! Severity policy: everything that proves a real defect is
//! [`Severity::Error`]; stylistic or clamped-at-runtime findings
//! (BSL012, BSL029, BSL045) are warnings so `--deny warnings` stays
//! meaningful. `brainslug check --all-zoo --deny warnings` must exit 0
//! on the shipped zoo — CI enforces this.

pub mod diag;
pub mod graph_lint;
pub mod plan_verify;
pub mod topo;

pub use diag::{DiagCode, Diagnostic, Report, Severity};
pub use graph_lint::lint_graph;
pub use plan_verify::{verify_plan, verify_resources, verify_structure};
pub use topo::{check_topology, ChannelSpec, ExitCondition, ShutdownStep, ThreadSpec, Topology};

/// The concurrency topologies the runtime actually instantiates, with
/// their default sizings. `brainslug check` and the test suite lint all
/// of them; a change to the server/listener/pool threading model must
/// update the matching `topology()` constructor, which keeps the model
/// honest.
pub fn standard_topologies() -> Vec<Topology> {
    vec![
        crate::server::topology(4, 64),
        crate::http::listener::topology(8, 64),
        crate::cpu::par::topology(4),
        crate::obs::topology(4),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_topologies_are_clean() {
        for t in standard_topologies() {
            let diags = check_topology(&t);
            assert!(diags.is_empty(), "{}: {diags:?}", t.name);
        }
    }
}
