//! Pass 2: plan verifier — a proof-oriented static pass over
//! [`Plan`]/[`Segment`]/[`Stack`].
//!
//! Two halves:
//!
//! * [`verify_structure`] (BSL020–BSL023, BSL027, BSL028) — the plan
//!   partitions the graph exactly, stack chains are unary
//!   producer/consumer runs, branch regions are well-formed, fused ops
//!   chain shape-to-shape, and every fused node has a breadth-first
//!   fallback kernel. This replaces `Plan::validate`'s original ad-hoc
//!   string checks (that method now delegates here).
//! * [`verify_resources`] (BSL024–BSL026, BSL029) — symbolically
//!   re-derives each sequence's working set against the *same*
//!   [`effective_budget`] the packer used, proves the halo
//!   back-propagation cannot underflow rows for any band offset (full
//!   bands and the final partial band — the invariant the PR 2 clamp
//!   enforces dynamically), and re-derives branch-arm skip reservations
//!   (`reserved_bytes` + entry plane) to catch broken accounting.
//!
//! Proven invariants (see DESIGN.md §Static Analysis):
//! 1. coverage: every graph node in exactly one segment;
//! 2. chain: stack nodes form a unary single-producer chain;
//! 3. shape chain: step/sequence shapes compose, and fused ops agree
//!    with the stack's node list (band buffers are sized from these
//!    shapes, so a break here means an undersized buffer at run time);
//! 4. fallback: every fused node `is_optimizable` (has a standalone
//!    breadth-first kernel to fall back to);
//! 5. budget: every *multi-step* sequence's working set at its chosen
//!    `tile_rows` fits the effective budget. Single-step sequences are
//!    exempt by design: a sequence that cannot be split further may
//!    legitimately exceed the budget (e.g. a classifier-head row on a
//!    16 KiB paper budget) — the packer isolates it instead of failing;
//! 6. halo: for every band offset, back-propagated band heights stay
//!    ≥ 1 through every step;
//! 7. reservation: branch-arm stacks fit the skip-reserved budget
//!    (entry plane bytes subtracted, 1/8 floor).

use super::diag::{DiagCode, Diagnostic};
use crate::device::DeviceSpec;
use crate::graph::{Graph, Layer, NodeId, Shape};
use crate::optimizer::plan::live_plane_bytes;
use crate::optimizer::{effective_budget, CollapseOptions, Plan, Segment, Stack};

/// Band geometry of a tensor, or `None` for ranks the collapse tiling
/// model does not cover (the total, non-panicking twin of the private
/// `row_geometry` in `collapse.rs`).
fn geometry(shape: &Shape) -> Option<(usize, usize)> {
    match shape.rank() {
        4 => Some((shape.height(), shape.width())),
        2 => Some((shape.batch(), shape.channels())),
        _ => None,
    }
}

fn subj(plan: &Plan) -> String {
    format!("plan for {}", plan.network)
}

fn stack_span(st: &Stack) -> String {
    match (st.nodes.first(), st.nodes.last()) {
        (Some(a), Some(b)) if a != b => format!("stack n{a}..n{b}"),
        (Some(a), _) => format!("stack n{a}"),
        _ => "empty stack".to_string(),
    }
}

fn first_node_of(seg: &Segment) -> Option<NodeId> {
    match seg {
        Segment::Single(id) => Some(*id),
        Segment::Stack(st) => st.nodes.first().copied(),
        Segment::Branch { .. } => None,
    }
}

fn mark(plan: &Plan, seen: &mut [bool], id: NodeId, diags: &mut Vec<Diagnostic>) {
    match seen.get_mut(id) {
        None => diags.push(
            Diagnostic::new(
                DiagCode::PlanCoverage,
                subj(plan),
                format!("plan references node {id}, which is outside the graph"),
            )
            .at_node(id),
        ),
        Some(s) if *s => diags.push(
            Diagnostic::new(
                DiagCode::PlanCoverage,
                subj(plan),
                format!("node {id} appears twice in plan"),
            )
            .at_node(id),
        ),
        Some(s) => *s = true,
    }
}

/// Structural verification: coverage, chains, branches, shape chains,
/// fallbacks. Returns every finding.
pub fn verify_structure(graph: &Graph, plan: &Plan) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let mut seen = vec![false; graph.nodes.len()];
    if let Some(s) = seen.first_mut() {
        *s = true; // input placeholder is implicit
    }
    for seg in &plan.segments {
        check_segment(graph, plan, seg, &mut seen, true, &mut diags);
    }
    for (id, covered) in seen.iter().enumerate() {
        if !covered {
            diags.push(
                Diagnostic::new(
                    DiagCode::PlanCoverage,
                    subj(plan),
                    format!(
                        "node {id} ('{}') missing from plan",
                        graph.node(id).name
                    ),
                )
                .at_node(id),
            );
        }
    }
    diags
}

fn check_segment(
    graph: &Graph,
    plan: &Plan,
    seg: &Segment,
    seen: &mut [bool],
    allow_branch: bool,
    diags: &mut Vec<Diagnostic>,
) {
    match seg {
        Segment::Single(id) => mark(plan, seen, *id, diags),
        Segment::Stack(st) => check_stack(graph, plan, st, seen, diags),
        Segment::Branch { arms, join } => {
            if !allow_branch {
                diags.push(
                    Diagnostic::new(
                        DiagCode::BranchJoinMalformed,
                        subj(plan),
                        format!("nested branch segment at join {join}"),
                    )
                    .at_node(*join),
                );
            }
            check_branch(graph, plan, arms, *join, seen, diags);
        }
    }
}

fn check_stack(
    graph: &Graph,
    plan: &Plan,
    st: &Stack,
    seen: &mut [bool],
    diags: &mut Vec<Diagnostic>,
) {
    let where_ = format!("{}: {}", subj(plan), stack_span(st));
    for &id in &st.nodes {
        mark(plan, seen, id, diags);
    }
    // Stack nodes must form a consecutive unary chain.
    for w in st.nodes.windows(2) {
        if let Some(n) = graph.nodes.get(w[1]) {
            if n.inputs != [w[0]] {
                diags.push(
                    Diagnostic::new(
                        DiagCode::StackChainBroken,
                        where_.clone(),
                        format!("stack chain broken between {} and {}", w[0], w[1]),
                    )
                    .at_node(w[1]),
                );
            }
        }
    }
    // Every fused node needs a breadth-first fallback kernel.
    for &id in &st.nodes {
        if let Some(n) = graph.nodes.get(id) {
            if !n.layer.is_optimizable() {
                diags.push(
                    Diagnostic::new(
                        DiagCode::NoFallback,
                        where_.clone(),
                        format!(
                            "node {id} ('{}', {}) is not optimizable: it has no fused \
                             depth-first kernel and no breadth-first fallback inside a stack",
                            n.name,
                            n.layer.kind_name()
                        ),
                    )
                    .at_node(id),
                );
            }
        }
    }
    if st.sequences.is_empty()
        || st
            .sequences
            .iter()
            .any(|s| s.steps.is_empty() || s.steps.iter().any(|stp| stp.ops.is_empty()))
    {
        diags.push(Diagnostic::new(
            DiagCode::BandShapeChain,
            where_,
            "stack contains an empty sequence or step",
        ));
        return;
    }
    // The flattened fused ops must be exactly the stack's nodes, in order.
    let op_nodes: Vec<NodeId> = st
        .sequences
        .iter()
        .flat_map(|s| &s.steps)
        .flat_map(|s| &s.ops)
        .map(|o| o.node)
        .collect();
    if op_nodes != st.nodes {
        diags.push(Diagnostic::new(
            DiagCode::BandShapeChain,
            where_.clone(),
            format!(
                "fused ops cover nodes {op_nodes:?} but the stack lists {:?}",
                st.nodes
            ),
        ));
    }
    // Shape chain: steps within a sequence, then sequence boundaries.
    // Band buffers are sized from these shapes; a break here means an
    // under- (or mis-)sized buffer at run time.
    for seq in &st.sequences {
        for w in seq.steps.windows(2) {
            if w[0].out_shape() != w[1].in_shape() {
                diags.push(Diagnostic::new(
                    DiagCode::BandShapeChain,
                    where_.clone(),
                    format!(
                        "step shapes do not chain: {} -> {}",
                        w[0].out_shape(),
                        w[1].in_shape()
                    ),
                ));
            }
        }
    }
    for w in st.sequences.windows(2) {
        if w[0].out_shape() != w[1].in_shape() {
            diags.push(Diagnostic::new(
                DiagCode::BandShapeChain,
                where_.clone(),
                format!(
                    "sequence shapes do not chain: {} -> {}",
                    w[0].out_shape(),
                    w[1].in_shape()
                ),
            ));
        }
    }
    // Endpoints must agree with the graph.
    if let (Some(&first), Some(&last)) = (st.nodes.first(), st.nodes.last()) {
        if let (Some(fnode), Some(lnode)) = (graph.nodes.get(first), graph.nodes.get(last)) {
            if let Some(producer) = fnode.inputs.first().and_then(|&e| graph.nodes.get(e)) {
                if let Some(seq0) = st.sequences.first() {
                    if &producer.shape != seq0.in_shape() {
                        diags.push(Diagnostic::new(
                            DiagCode::BandShapeChain,
                            where_.clone(),
                            format!(
                                "stack input shape {} != producer shape {}",
                                seq0.in_shape(),
                                producer.shape
                            ),
                        ));
                    }
                }
            }
            if let Some(seq_last) = st.sequences.last() {
                if &lnode.shape != seq_last.out_shape() {
                    diags.push(Diagnostic::new(
                        DiagCode::BandShapeChain,
                        where_,
                        format!(
                            "stack output shape {} != node {last} shape {}",
                            seq_last.out_shape(),
                            lnode.shape
                        ),
                    ));
                }
            }
        }
    }
}

/// Structural checks for one branch region: the join is an `Add`/
/// `Concat` with one arm per input, every arm is a unary chain hanging
/// off one shared entry, and each arm's output is the matching join
/// input (the entry itself for an identity skip arm).
fn check_branch(
    graph: &Graph,
    plan: &Plan,
    arms: &[Vec<Segment>],
    join: NodeId,
    seen: &mut [bool],
    diags: &mut Vec<Diagnostic>,
) {
    let Some(jn) = graph.nodes.get(join) else {
        mark(plan, seen, join, diags);
        return;
    };
    if !matches!(jn.layer, Layer::Add | Layer::Concat) {
        diags.push(
            Diagnostic::new(
                DiagCode::BranchJoinMalformed,
                subj(plan),
                format!(
                    "branch join {join} ('{}') is {}, not add/concat",
                    jn.name,
                    jn.layer.kind_name()
                ),
            )
            .at_node(join),
        );
    }
    if arms.len() != jn.inputs.len() {
        diags.push(
            Diagnostic::new(
                DiagCode::BranchJoinMalformed,
                subj(plan),
                format!(
                    "branch at {join}: {} arms for {} join inputs",
                    arms.len(),
                    jn.inputs.len()
                ),
            )
            .at_node(join),
        );
    }
    // Derive the region entry from the first non-empty arm's head.
    let entry = match arms.iter().find_map(|arm| arm.first()) {
        Some(seg) => match first_node_of(seg)
            .and_then(|f| graph.nodes.get(f).map(|n| (f, n.inputs.clone())))
        {
            Some((_, inputs)) if inputs.len() == 1 => inputs[0],
            Some((f, _)) => {
                diags.push(
                    Diagnostic::new(
                        DiagCode::BranchArmMismatch,
                        subj(plan),
                        format!("branch arm head {f} is not unary"),
                    )
                    .at_node(f),
                );
                for arm in arms {
                    for seg in arm {
                        check_segment(graph, plan, seg, seen, false, diags);
                    }
                }
                mark(plan, seen, join, diags);
                return;
            }
            None => {
                diags.push(
                    Diagnostic::new(
                        DiagCode::BranchArmMismatch,
                        subj(plan),
                        format!("branch at {join}: arm starts with an empty or nested segment"),
                    )
                    .at_node(join),
                );
                for arm in arms {
                    for seg in arm {
                        check_segment(graph, plan, seg, seen, false, diags);
                    }
                }
                mark(plan, seen, join, diags);
                return;
            }
        },
        None => jn.inputs.first().copied().unwrap_or(0), // all identity skips
    };
    for (arm, &join_input) in arms.iter().zip(&jn.inputs) {
        let mut prev = entry;
        for seg in arm {
            check_segment(graph, plan, seg, seen, false, diags);
            let Some(first) = first_node_of(seg) else {
                diags.push(
                    Diagnostic::new(
                        DiagCode::BranchArmMismatch,
                        subj(plan),
                        format!("branch at {join}: nested or empty segment in arm"),
                    )
                    .at_node(join),
                );
                break;
            };
            if let Some(n) = graph.nodes.get(first) {
                if n.inputs != [prev] {
                    diags.push(
                        Diagnostic::new(
                            DiagCode::BranchArmMismatch,
                            subj(plan),
                            format!("branch arm broken at node {first} (expected input {prev})"),
                        )
                        .at_node(first),
                    );
                }
            }
            match seg.output_node() {
                Some(p) => prev = p,
                None => {
                    diags.push(
                        Diagnostic::new(
                            DiagCode::BranchArmMismatch,
                            subj(plan),
                            format!("branch at {join}: empty segment in arm"),
                        )
                        .at_node(join),
                    );
                    break;
                }
            }
        }
        if join_input != prev {
            diags.push(
                Diagnostic::new(
                    DiagCode::BranchArmMismatch,
                    subj(plan),
                    format!("branch arm output {prev} != join input {join_input}"),
                )
                .at_node(join),
            );
        }
    }
    mark(plan, seen, join, diags);
}

/// The entry tensor a branch's skip reservation pins, if derivable.
fn branch_entry_shape<'a>(
    graph: &'a Graph,
    arms: &[Vec<Segment>],
    join: NodeId,
) -> Option<&'a Shape> {
    let entry = match arms.iter().find_map(|arm| arm.first()) {
        Some(seg) => {
            let first = first_node_of(seg)?;
            *graph.nodes.get(first)?.inputs.first()?
        }
        None => *graph.nodes.get(join)?.inputs.first()?,
    };
    graph.nodes.get(entry).map(|n| &n.shape)
}

/// Resource verification: budget, halo, reservations, band geometry.
/// Must receive the same `device` and `opts` the plan was built with —
/// the point is to re-derive the packer's own arithmetic.
pub fn verify_resources(
    graph: &Graph,
    plan: &Plan,
    device: &DeviceSpec,
    opts: &CollapseOptions,
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    for seg in &plan.segments {
        match seg {
            Segment::Single(_) => {}
            Segment::Stack(st) => {
                check_stack_resources(plan, st, device, opts, false, &mut diags)
            }
            Segment::Branch { arms, join } => {
                // Re-derive the skip reservation exactly as the planner
                // does: entry plane bytes on top of the caller's
                // reservation, floored at 1/8 inside effective_budget.
                let arm_opts = branch_entry_shape(graph, arms, *join).map(|shape| {
                    CollapseOptions {
                        reserved_bytes: opts
                            .reserved_bytes
                            .saturating_add(live_plane_bytes(shape)),
                        ..*opts
                    }
                });
                let arm_opts = arm_opts.as_ref().unwrap_or(opts);
                for arm in arms {
                    for seg in arm {
                        if let Segment::Stack(st) = seg {
                            check_stack_resources(plan, st, device, arm_opts, true, &mut diags);
                        }
                    }
                }
            }
        }
    }
    diags
}

fn check_stack_resources(
    plan: &Plan,
    st: &Stack,
    device: &DeviceSpec,
    opts: &CollapseOptions,
    in_arm: bool,
    diags: &mut Vec<Diagnostic>,
) {
    let budget = effective_budget(device, opts);
    for (qi, seq) in st.sequences.iter().enumerate() {
        if seq.steps.is_empty() || seq.steps.iter().any(|s| s.ops.is_empty()) {
            continue; // structure pass reports BSL027 for these
        }
        let where_ = format!("{}: {}, sequence {qi}", subj(plan), stack_span(st));
        let Some((out_h, _)) = geometry(seq.out_shape()) else {
            continue;
        };
        if seq.steps.iter().any(|s| geometry(s.in_shape()).is_none()) {
            continue;
        }
        // --- BSL025: halo underflow ---
        if seq.tile_rows == 0 {
            diags.push(
                Diagnostic::new(
                    DiagCode::HaloUnderflow,
                    where_,
                    "tile_rows is 0: every band back-propagates to zero rows",
                )
                .note("collapse/seal clamp min_tile_rows to >= 1; a zero here means the plan was corrupted after sealing"),
            );
            continue;
        }
        if let Some(step) = seq.steps.iter().find(|s| {
            let (k, stride) = s.row_window();
            k == 0 || stride == 0
        }) {
            diags.push(Diagnostic::new(
                DiagCode::HaloUnderflow,
                where_,
                format!(
                    "step '{}' has a zero kernel/stride row window: band back-propagation is undefined",
                    step.sig()
                ),
            ));
            continue;
        }
        if out_h == 0 {
            diags.push(Diagnostic::new(
                DiagCode::HaloUnderflow,
                where_,
                "sequence output has zero rows",
            ));
            continue;
        }
        // Prove: for every band offset, the back-propagated band height
        // stays >= 1 at every step. All bands have height `rows` except
        // the final partial band — checking both heights covers every
        // offset.
        let rows = seq.tile_rows.min(out_h);
        let n_bands = out_h.div_ceil(rows);
        let last_rows = out_h - (n_bands - 1) * rows;
        let mut underflow = false;
        for h in [rows, last_rows] {
            let mut r = h;
            for step in seq.steps.iter().rev() {
                let in_h = geometry(step.in_shape()).map_or(1, |(ih, _)| ih);
                r = step.in_rows(r).min(in_h);
                if r == 0 {
                    diags.push(
                        Diagnostic::new(
                            DiagCode::HaloUnderflow,
                            where_.clone(),
                            format!(
                                "a band of {h} output rows back-propagates to zero rows at step '{}'",
                                step.sig()
                            ),
                        )
                        .note("the clamped band heights must stay >= 1 for every band offset"),
                    );
                    underflow = true;
                    break;
                }
            }
            if underflow {
                break;
            }
        }
        if underflow {
            continue;
        }
        // --- BSL029: wasteful band height (clamped at run time) ---
        if seq.tile_rows > out_h {
            diags.push(Diagnostic::new(
                DiagCode::TileRowsExceedHeight,
                where_.clone(),
                format!(
                    "tile_rows {} exceeds the sequence output height {out_h}",
                    seq.tile_rows
                ),
            ));
        }
        // --- BSL024 / BSL026: working set vs budget ---
        // Multi-step sequences only: the packer guarantees a multi-step
        // sequence fits (it splits otherwise), so an overrun proves the
        // plan or its accounting was corrupted. A single-step sequence
        // cannot be split further and may legitimately exceed the
        // budget (documented allowance; see module docs).
        if seq.steps.len() > 1 {
            let ws = seq.working_set_bytes(seq.tile_rows);
            if ws > budget {
                let (code, ctx) = if in_arm {
                    (
                        DiagCode::SkipReservationBroken,
                        format!(
                            " (skip-reserved budget: {} B reserved of {} B limit)",
                            opts.reserved_bytes,
                            opts.budget_bytes.unwrap_or(device.resource_limit())
                        ),
                    )
                } else {
                    (DiagCode::BudgetOverrun, String::new())
                };
                diags.push(
                    Diagnostic::new(
                        code,
                        where_.clone(),
                        format!(
                            "working set {ws} B at tile_rows {} exceeds the effective budget {budget} B{ctx}",
                            seq.tile_rows
                        ),
                    )
                    .note("multi-step sequences must fit the collapse budget; the packer splits any that do not"),
                );
            }
        }
    }
}

/// Both halves of the plan verifier in one call.
pub fn verify_plan(
    graph: &Graph,
    plan: &Plan,
    device: &DeviceSpec,
    opts: &CollapseOptions,
) -> Vec<Diagnostic> {
    let mut diags = verify_structure(graph, plan);
    diags.extend(verify_resources(graph, plan, device, opts));
    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Layer, PoolKind, Window2d};
    use crate::optimizer::optimize;

    fn pool3() -> Layer {
        Layer::Pool2d {
            kind: PoolKind::Max,
            window: Window2d::square(3, 1, 1),
            ceil_mode: false,
            count_include_pad: true,
        }
    }

    fn chain_graph() -> Graph {
        let mut g = Graph::new("chain", Shape::nchw(1, 8, 32, 32));
        g.push("bn", Layer::BatchNorm2d { eps: 1e-5 });
        g.push("relu", Layer::Relu);
        g.push("pool", pool3());
        g
    }

    #[test]
    fn valid_plan_is_clean() {
        let g = chain_graph();
        let dev = DeviceSpec::paper_cpu();
        let opts = CollapseOptions::default();
        let plan = optimize(&g, &dev, &opts);
        let diags = verify_plan(&g, &plan, &dev, &opts);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn zoo_paper_plans_are_clean() {
        for name in ["vgg16_bn", "resnet18", "densenet121", "squeezenet1_0"] {
            let g = crate::zoo::build(name, crate::zoo::paper_config(name, 1));
            let dev = DeviceSpec::paper_cpu();
            let opts = CollapseOptions::default();
            let plan = optimize(&g, &dev, &opts);
            let diags = verify_plan(&g, &plan, &dev, &opts);
            assert!(diags.is_empty(), "{name}: {diags:?}");
        }
    }

    #[test]
    fn tile_rows_zero_is_halo_underflow() {
        let g = chain_graph();
        let dev = DeviceSpec::paper_cpu();
        let opts = CollapseOptions::default();
        let mut plan = optimize(&g, &dev, &opts);
        for seg in &mut plan.segments {
            if let Segment::Stack(st) = seg {
                st.sequences[0].tile_rows = 0;
            }
        }
        let diags = verify_resources(&g, &plan, &dev, &opts);
        assert!(
            diags.iter().any(|d| d.code == DiagCode::HaloUnderflow),
            "{diags:?}"
        );
    }

    #[test]
    fn oversized_tile_rows_is_a_warning() {
        let g = chain_graph();
        let dev = DeviceSpec::paper_cpu();
        let opts = CollapseOptions::default();
        let mut plan = optimize(&g, &dev, &opts);
        for seg in &mut plan.segments {
            if let Segment::Stack(st) = seg {
                let out_h = st.sequences[0].out_shape().height();
                st.sequences[0].tile_rows = out_h + 5;
            }
        }
        let diags = verify_resources(&g, &plan, &dev, &opts);
        assert!(
            diags
                .iter()
                .any(|d| d.code == DiagCode::TileRowsExceedHeight
                    && d.severity == crate::analysis::Severity::Warning),
            "{diags:?}"
        );
    }
}
