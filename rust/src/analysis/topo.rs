//! Pass 3: concurrency topology lint.
//!
//! The runtime's threads, channels, locks and shutdown protocol are
//! declared here *as data* — a [`Topology`] — and checked statically
//! (BSL040–BSL045) instead of being re-audited by hand after every
//! change. Each concurrent subsystem registers its own topology
//! ([`crate::server::Server`], the HTTP listener, the CPU band pool);
//! `brainslug check` and the test suite verify all of them.
//!
//! The model is deliberately small: named thread groups with an exit
//! condition, named channels with capacities and endpoints, named gate
//! flags, and an ordered shutdown script. That is enough to catch the
//! deadlock classes this codebase has actually hit:
//!
//! * a rendezvous (capacity-0) channel cycle — both sides block in
//!   send, nobody reaches recv (BSL040);
//! * shutdown tokens sent before the admission gate closes — a racing
//!   producer re-fills the queue and a worker consumes the token meant
//!   for another, leaving a thread parked forever (BSL041, the PR 6
//!   drain-ordering bug class);
//! * a thread that is neither scope-joined nor joined by the shutdown
//!   script — a silent leak (BSL042);
//! * a join whose termination condition is never established by the
//!   preceding shutdown steps — join blocks forever (BSL044).

use super::diag::{DiagCode, Diagnostic};

/// Why a thread group eventually exits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExitCondition {
    /// Exits after receiving a dedicated token on this channel
    /// (one token per thread in the group).
    TokenOn(String),
    /// Exits when this channel disconnects (every sender dropped).
    DisconnectOf(String),
    /// Exits when this gate flag is observed closed (polling loop).
    FlagSet(String),
    /// Joined implicitly by a `thread::scope` at the spawn site.
    ScopeEnd,
}

/// A group of identical threads.
#[derive(Debug, Clone)]
pub struct ThreadSpec {
    pub name: String,
    pub count: usize,
    pub exit: ExitCondition,
}

/// A channel with its capacity and endpoints. Endpoints name declared
/// thread groups, or `"main"` for the owning/calling thread.
#[derive(Debug, Clone)]
pub struct ChannelSpec {
    pub name: String,
    /// `sync_channel` bound; 0 means rendezvous.
    pub capacity: usize,
    pub senders: Vec<String>,
    pub receivers: Vec<String>,
    /// Gate flag that must be closed before shutdown tokens are sent on
    /// this channel (admission control).
    pub gate: Option<String>,
}

/// One step of the ordered shutdown script.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShutdownStep {
    /// Close an admission gate (no new work enters after this).
    CloseGate(String),
    /// Send `count` shutdown tokens on a channel.
    SendTokens { channel: String, count: usize },
    /// Drop every sender handle of a channel (disconnects receivers).
    DropSenders(String),
    /// Join every thread in a group.
    Join(String),
}

/// Declarative model of one concurrent subsystem.
#[derive(Debug, Clone, Default)]
pub struct Topology {
    pub name: String,
    pub threads: Vec<ThreadSpec>,
    pub channels: Vec<ChannelSpec>,
    pub gates: Vec<String>,
    pub shutdown: Vec<ShutdownStep>,
}

impl Topology {
    pub fn new(name: impl Into<String>) -> Self {
        Topology {
            name: name.into(),
            ..Topology::default()
        }
    }

    pub fn thread(mut self, name: impl Into<String>, count: usize, exit: ExitCondition) -> Self {
        self.threads.push(ThreadSpec {
            name: name.into(),
            count,
            exit,
        });
        self
    }

    pub fn channel(
        mut self,
        name: impl Into<String>,
        capacity: usize,
        senders: &[&str],
        receivers: &[&str],
        gate: Option<&str>,
    ) -> Self {
        self.channels.push(ChannelSpec {
            name: name.into(),
            capacity,
            senders: senders.iter().map(|s| s.to_string()).collect(),
            receivers: receivers.iter().map(|s| s.to_string()).collect(),
            gate: gate.map(|g| g.to_string()),
        });
        self
    }

    pub fn gate(mut self, name: impl Into<String>) -> Self {
        self.gates.push(name.into());
        self
    }

    pub fn on_shutdown(mut self, step: ShutdownStep) -> Self {
        self.shutdown.push(step);
        self
    }

    /// Compose another subsystem's topology into this one (e.g. the
    /// HTTP front door embeds the batching server it shuts down last).
    pub fn extend(mut self, other: Topology) -> Self {
        self.threads.extend(other.threads);
        self.channels.extend(other.channels);
        self.gates.extend(other.gates);
        self.shutdown.extend(other.shutdown);
        self
    }

    fn thread_spec(&self, name: &str) -> Option<&ThreadSpec> {
        self.threads.iter().find(|t| t.name == name)
    }

    fn has_endpoint(&self, name: &str) -> bool {
        name == "main" || self.thread_spec(name).is_some()
    }
}

/// Check one topology. Returns every finding.
pub fn check_topology(t: &Topology) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let subj = |detail: &str| format!("topology '{}': {detail}", t.name);

    // --- BSL043: declarations must be closed ---
    for ch in &t.channels {
        for ep in ch.senders.iter().chain(&ch.receivers) {
            if !t.has_endpoint(ep) {
                diags.push(Diagnostic::new(
                    DiagCode::BadEndpoint,
                    subj(&format!("channel '{}'", ch.name)),
                    format!("endpoint '{ep}' is not a declared thread group or 'main'"),
                ));
            }
        }
        if ch.senders.is_empty() || ch.receivers.is_empty() {
            diags.push(Diagnostic::new(
                DiagCode::BadEndpoint,
                subj(&format!("channel '{}'", ch.name)),
                "channel must have at least one sender and one receiver",
            ));
        }
        if let Some(g) = &ch.gate {
            if !t.gates.contains(g) {
                diags.push(Diagnostic::new(
                    DiagCode::BadEndpoint,
                    subj(&format!("channel '{}'", ch.name)),
                    format!("gate '{g}' is not declared"),
                ));
            }
        }
    }
    for step in &t.shutdown {
        match step {
            ShutdownStep::CloseGate(g) => {
                if !t.gates.contains(g) {
                    diags.push(Diagnostic::new(
                        DiagCode::BadEndpoint,
                        subj("shutdown"),
                        format!("CloseGate('{g}'): gate is not declared"),
                    ));
                }
            }
            ShutdownStep::SendTokens { channel, .. } | ShutdownStep::DropSenders(channel) => {
                if !t.channels.iter().any(|c| &c.name == channel) {
                    diags.push(Diagnostic::new(
                        DiagCode::BadEndpoint,
                        subj("shutdown"),
                        format!("shutdown step names undeclared channel '{channel}'"),
                    ));
                }
            }
            ShutdownStep::Join(name) => {
                if t.thread_spec(name).is_none() {
                    diags.push(Diagnostic::new(
                        DiagCode::BadEndpoint,
                        subj("shutdown"),
                        format!("Join('{name}'): thread group is not declared"),
                    ));
                }
            }
        }
    }

    // --- BSL040: rendezvous cycle ---
    // Edge s -> r for every capacity-0 channel: s blocks in send until r
    // reaches recv. A cycle among these edges can deadlock with every
    // participant parked in send.
    let zero: Vec<&ChannelSpec> = t.channels.iter().filter(|c| c.capacity == 0).collect();
    if !zero.is_empty() {
        let parties: Vec<&str> = {
            let mut v: Vec<&str> = t.threads.iter().map(|t| t.name.as_str()).collect();
            v.push("main");
            v
        };
        let index = |name: &str| parties.iter().position(|p| *p == name);
        let n = parties.len();
        let mut adj = vec![vec![]; n];
        for ch in &zero {
            for s in &ch.senders {
                for r in &ch.receivers {
                    if let (Some(si), Some(ri)) = (index(s), index(r)) {
                        adj[si].push((ri, ch.name.clone()));
                    }
                }
            }
        }
        // DFS cycle detection (colors: 0 white, 1 on stack, 2 done).
        let mut color = vec![0u8; n];
        fn dfs(
            v: usize,
            adj: &[Vec<(usize, String)>],
            color: &mut [u8],
            trail: &mut Vec<String>,
        ) -> Option<Vec<String>> {
            color[v] = 1;
            for (w, ch) in &adj[v] {
                trail.push(ch.clone());
                if color[*w] == 1 {
                    return Some(trail.clone());
                }
                if color[*w] == 0 {
                    if let Some(c) = dfs(*w, adj, color, trail) {
                        return Some(c);
                    }
                }
                trail.pop();
            }
            color[v] = 2;
            None
        }
        for v in 0..n {
            if color[v] == 0 {
                if let Some(cycle) = dfs(v, &adj, &mut color, &mut Vec::new()) {
                    diags.push(
                        Diagnostic::new(
                            DiagCode::ZeroCapacityCycle,
                            subj("channels"),
                            format!(
                                "rendezvous (capacity-0) channel cycle through [{}]: all parties can block in send",
                                cycle.join(", ")
                            ),
                        )
                        .note("give at least one channel in the cycle a non-zero capacity, or break the cycle"),
                    );
                    break;
                }
            }
        }
    }

    // --- BSL041 / BSL044 / BSL045: shutdown script ordering ---
    let mut closed_gates: Vec<&str> = Vec::new();
    let mut tokens_sent: Vec<(&str, usize)> = Vec::new(); // (channel, total)
    let mut dropped: Vec<&str> = Vec::new();
    let mut joined: Vec<&str> = Vec::new();
    for step in &t.shutdown {
        match step {
            ShutdownStep::CloseGate(g) => closed_gates.push(g),
            ShutdownStep::SendTokens { channel, count } => {
                if let Some(ch) = t.channels.iter().find(|c| &c.name == channel) {
                    if let Some(gate) = &ch.gate {
                        if !closed_gates.contains(&gate.as_str()) {
                            diags.push(
                                Diagnostic::new(
                                    DiagCode::SendBeforeGateClose,
                                    subj("shutdown"),
                                    format!(
                                        "shutdown tokens sent on '{channel}' before gate '{gate}' closes: \
                                         a racing producer can enqueue past the tokens and strand a worker"
                                    ),
                                )
                                .note("close the admission gate first, then send one token per worker"),
                            );
                        }
                    }
                }
                tokens_sent.push((channel, *count));
            }
            ShutdownStep::DropSenders(channel) => dropped.push(channel),
            ShutdownStep::Join(name) => {
                joined.push(name);
                let Some(spec) = t.thread_spec(name) else {
                    continue; // BSL043 already reported
                };
                let established = match &spec.exit {
                    ExitCondition::ScopeEnd => true,
                    ExitCondition::FlagSet(g) => closed_gates.contains(&g.as_str()),
                    ExitCondition::TokenOn(ch) => {
                        let total: usize = tokens_sent
                            .iter()
                            .filter(|(c, _)| *c == ch)
                            .map(|(_, n)| *n)
                            .sum();
                        total >= spec.count
                    }
                    ExitCondition::DisconnectOf(ch) => {
                        dropped.contains(&ch.as_str())
                            || t.channels
                                .iter()
                                .find(|c| &c.name == ch)
                                .is_some_and(|c| {
                                    // Disconnect also happens once every
                                    // sending thread group has been joined
                                    // (their sender handles drop on exit).
                                    !c.senders.is_empty()
                                        && c.senders.iter().all(|s| {
                                            s != "main" && joined.contains(&s.as_str())
                                        })
                                })
                    }
                };
                if !established {
                    diags.push(
                        Diagnostic::new(
                            DiagCode::JoinWithoutTermination,
                            subj("shutdown"),
                            format!(
                                "Join('{name}') before its exit condition {:?} is established: join can block forever",
                                spec.exit
                            ),
                        )
                        .note("order the shutdown script so the condition (tokens sent, senders dropped, gate closed) precedes the join"),
                    );
                }
            }
        }
    }

    // --- BSL042: unjoined thread leak ---
    for th in &t.threads {
        if th.exit != ExitCondition::ScopeEnd && !joined.contains(&th.name.as_str()) {
            diags.push(
                Diagnostic::new(
                    DiagCode::UnjoinedThread,
                    subj(&format!("thread group '{}'", th.name)),
                    "thread is neither scope-joined nor joined by the shutdown script (leak)",
                )
                .note("add a Join step, or spawn inside a thread::scope"),
            );
        }
    }

    // --- BSL045: gate declared but never closed (warning) ---
    for g in &t.gates {
        if !t
            .shutdown
            .iter()
            .any(|s| matches!(s, ShutdownStep::CloseGate(x) if x == g))
        {
            diags.push(Diagnostic::new(
                DiagCode::GateNeverClosed,
                subj(&format!("gate '{g}'")),
                "gate is declared but no shutdown step ever closes it",
            ));
        }
    }

    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::DiagCode;

    fn server_like(workers: usize, queue: usize) -> Topology {
        Topology::new("test-server")
            .gate("closed")
            .thread("worker", workers, ExitCondition::TokenOn("dispatch".into()))
            .channel("dispatch", queue, &["main"], &["worker"], Some("closed"))
            .on_shutdown(ShutdownStep::CloseGate("closed".into()))
            .on_shutdown(ShutdownStep::SendTokens {
                channel: "dispatch".into(),
                count: workers,
            })
            .on_shutdown(ShutdownStep::Join("worker".into()))
    }

    #[test]
    fn well_formed_server_topology_is_clean() {
        assert!(check_topology(&server_like(4, 64)).is_empty());
    }

    #[test]
    fn tokens_before_gate_close_is_drain_ordering_bug() {
        let mut t = server_like(4, 64);
        t.shutdown.swap(0, 1); // send tokens, then close the gate
        let diags = check_topology(&t);
        assert!(diags.iter().any(|d| d.code == DiagCode::SendBeforeGateClose));
    }

    #[test]
    fn missing_join_is_a_leak() {
        let mut t = server_like(2, 8);
        t.shutdown.pop();
        let diags = check_topology(&t);
        assert!(diags.iter().any(|d| d.code == DiagCode::UnjoinedThread));
    }

    #[test]
    fn too_few_tokens_blocks_join() {
        let mut t = server_like(4, 64);
        if let ShutdownStep::SendTokens { count, .. } = &mut t.shutdown[1] {
            *count = 2; // 4 workers, 2 tokens
        }
        let diags = check_topology(&t);
        assert!(diags.iter().any(|d| d.code == DiagCode::JoinWithoutTermination));
    }

    #[test]
    fn zero_capacity_cycle_detected() {
        let t = Topology::new("cycle")
            .thread("a", 1, ExitCondition::ScopeEnd)
            .thread("b", 1, ExitCondition::ScopeEnd)
            .channel("ab", 0, &["a"], &["b"], None)
            .channel("ba", 0, &["b"], &["a"], None);
        let diags = check_topology(&t);
        assert!(diags.iter().any(|d| d.code == DiagCode::ZeroCapacityCycle));
    }

    #[test]
    fn undeclared_endpoint_is_flagged() {
        let t = Topology::new("bad")
            .thread("w", 1, ExitCondition::ScopeEnd)
            .channel("c", 1, &["ghost"], &["w"], None);
        let diags = check_topology(&t);
        assert!(diags.iter().any(|d| d.code == DiagCode::BadEndpoint));
    }

    #[test]
    fn disconnect_join_satisfied_by_joining_senders() {
        // conn threads exit when the conns channel disconnects, which the
        // script establishes by joining the acceptor (sole sender) first.
        let t = Topology::new("listener-like")
            .gate("stop")
            .thread("acceptor", 1, ExitCondition::FlagSet("stop".into()))
            .thread("conn", 4, ExitCondition::DisconnectOf("conns".into()))
            .channel("conns", 64, &["acceptor"], &["conn"], None)
            .on_shutdown(ShutdownStep::CloseGate("stop".into()))
            .on_shutdown(ShutdownStep::Join("acceptor".into()))
            .on_shutdown(ShutdownStep::Join("conn".into()));
        assert!(check_topology(&t).is_empty());
    }

    #[test]
    fn unclosed_gate_is_a_warning() {
        let t = Topology::new("warn")
            .gate("closed")
            .thread("w", 1, ExitCondition::ScopeEnd);
        let diags = check_topology(&t);
        assert!(diags
            .iter()
            .any(|d| d.code == DiagCode::GateNeverClosed
                && d.severity == crate::analysis::Severity::Warning));
    }
}
