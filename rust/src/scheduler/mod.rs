//! Execution phase (§4.2): run a network through the PJRT runtime,
//! either breadth-first (the PyTorch-style baseline — one executable per
//! layer, every intermediate through main memory) or as a BrainSlug
//! [`Plan`] (collapsed stacks through their fused depth-first kernels,
//! everything else unchanged).
//!
//! The scheduler owns buffer lifetime (activations are dropped as soon as
//! their last consumer ran) and per-segment timing, which the measured
//! benchmarks aggregate into the paper's table rows.

pub mod executor;
pub mod metrics;

pub use executor::Executor;
pub use metrics::{ExecStats, SegmentStat};
