//! Per-segment execution metrics.

/// Timing record of one executed unit (layer or stack).
#[derive(Debug, Clone)]
pub struct SegmentStat {
    /// Executable name (or `native:<kind>` for scheduler-native ops).
    pub name: String,
    /// Layer kind, or "stack".
    pub kind: String,
    pub seconds: f64,
    /// True if this unit is (or consists of) optimizable layers.
    pub optimizable: bool,
}

/// Aggregated stats of one network execution.
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub segments: Vec<SegmentStat>,
    pub total_s: f64,
}

impl ExecStats {
    pub fn push(&mut self, name: String, kind: String, seconds: f64, optimizable: bool) {
        self.total_s += seconds;
        self.segments.push(SegmentStat {
            name,
            kind,
            seconds,
            optimizable,
        });
    }

    /// Time spent in optimizable layers / stacks.
    pub fn optimizable_s(&self) -> f64 {
        self.segments
            .iter()
            .filter(|s| s.optimizable)
            .map(|s| s.seconds)
            .sum()
    }

    /// Time per layer kind (descending).
    pub fn by_kind(&self) -> Vec<(String, f64)> {
        let mut map = std::collections::BTreeMap::new();
        for s in &self.segments {
            *map.entry(s.kind.clone()).or_insert(0.0) += s.seconds;
        }
        let mut v: Vec<(String, f64)> = map.into_iter().collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregation() {
        let mut st = ExecStats::default();
        st.push("conv_x".into(), "conv2d".into(), 0.5, false);
        st.push("relu_x".into(), "relu".into(), 0.2, true);
        st.push("conv_y".into(), "conv2d".into(), 0.3, false);
        assert!((st.total_s - 1.0).abs() < 1e-12);
        assert!((st.optimizable_s() - 0.2).abs() < 1e-12);
        let by = st.by_kind();
        assert_eq!(by[0].0, "conv2d");
        assert!((by[0].1 - 0.8).abs() < 1e-12);
    }
}
