//! The plan executor.

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::graph::{Graph, Layer, NodeId, Shape};
use crate::optimizer::{OpKind, Plan, Segment, Stack};
use crate::runtime::{layer_exec_name, stack_exec_name, HostTensor, ParamStore, Runtime};

use super::metrics::ExecStats;

/// Consume one input of a node from the value map: decrement the
/// remaining-consumer count and drop the map entry once the last
/// consumer has taken it. Values are `Arc`-shared, so fan-out nodes
/// (residual / concat skip planes) hand every consumer the same buffer
/// instead of deep-copying the activation per edge — the scheme is
/// shared between [`Executor`] and [`crate::cpu::CpuBackend`].
pub(crate) fn take_value(
    values: &mut HashMap<NodeId, Arc<HostTensor>>,
    remaining: &mut [usize],
    id: NodeId,
) -> Result<Arc<HostTensor>> {
    let v = values
        .get(&id)
        .ok_or_else(|| anyhow!("value for node {id} not computed yet"))?
        .clone();
    remaining[id] -= 1;
    if remaining[id] == 0 {
        values.remove(&id);
    }
    Ok(v)
}

/// Executes a fixed graph instance against a [`Runtime`], with
/// deterministic parameters from seed.
///
/// Owns shared handles (`Rc<Runtime>`, `Arc<Graph>`) rather than
/// borrows so backends ([`crate::engine::PjrtBackend`]) can hold an
/// executor alongside the runtime it executes on.
pub struct Executor {
    runtime: Rc<Runtime>,
    graph: Arc<Graph>,
    params: ParamStore,
    /// Remaining-consumer counts template (computed once).
    consumers: Vec<usize>,
}

impl Executor {
    pub fn new(runtime: Rc<Runtime>, graph: Arc<Graph>, seed: u64) -> Self {
        let cons = graph.consumer_map();
        let consumers = (0..graph.nodes.len()).map(|i| cons.count(i)).collect();
        let params = ParamStore::new(graph.clone(), seed);
        Executor {
            runtime,
            graph,
            params,
            consumers,
        }
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Deterministic synthetic input for this graph (the "image batch").
    pub fn synthetic_input(&self) -> HostTensor {
        let seed = crate::rng::tensor_seed(self.params.seed(), "input");
        HostTensor::from_seed(
            self.graph.input_shape().clone(),
            seed,
            crate::rng::ParamKind::Activation,
        )
    }

    /// Execute one non-stacked layer.
    fn run_single(
        &mut self,
        values: &mut HashMap<NodeId, Arc<HostTensor>>,
        remaining: &mut [usize],
        id: NodeId,
        stats: &mut ExecStats,
    ) -> Result<()> {
        let node = self.graph.node(id);
        let t0 = std::time::Instant::now();
        let out = match &node.layer {
            Layer::Input { .. } => unreachable!("input node is pre-seeded"),
            // Scheduler-native ops: no kernel needed.
            Layer::Dropout { .. } => {
                // Identity at inference: share the Arc, no copy.
                let x = take_value(values, remaining, node.inputs[0])?;
                stats.push(
                    format!("native:{}", node.name),
                    "dropout".into(),
                    t0.elapsed().as_secs_f64(),
                    true,
                );
                values.insert(id, x);
                return Ok(());
            }
            Layer::Flatten => {
                let x = take_value(values, remaining, node.inputs[0])?;
                let out = Arc::unwrap_or_clone(x).reshape(node.shape.clone());
                stats.push(
                    format!("native:{}", node.name),
                    "flatten".into(),
                    t0.elapsed().as_secs_f64(),
                    false,
                );
                values.insert(id, Arc::new(out));
                return Ok(());
            }
            _ => {
                let name = layer_exec_name(&self.graph, node)
                    .expect("non-native layer must have an executable");
                let acts: Vec<Arc<HostTensor>> = node
                    .inputs
                    .iter()
                    .map(|&i| take_value(values, remaining, i))
                    .collect::<Result<_>>()?;
                let params = self.params.exec_params(id);
                let mut args: Vec<&HostTensor> = acts.iter().map(|a| a.as_ref()).collect();
                args.extend(params.iter());
                let out = self.runtime.execute(&name, &args)?;
                stats.push(
                    name,
                    node.layer.kind_name().into(),
                    t0.elapsed().as_secs_f64(),
                    node.layer.is_optimizable(),
                );
                out
            }
        };
        values.insert(id, Arc::new(out));
        Ok(())
    }

    /// Execute a collapsed stack through its fused executable.
    fn run_stack(
        &mut self,
        values: &mut HashMap<NodeId, Arc<HostTensor>>,
        remaining: &mut [usize],
        stack: &Stack,
        stats: &mut ExecStats,
    ) -> Result<()> {
        let t0 = std::time::Instant::now();
        let first = self.graph.node(stack.nodes[0]);
        let x = take_value(values, remaining, first.inputs[0])?;
        // Gather folded BN params for every bn op, in op order (§4.2:
        // "the front-end gathers all necessary data and parameter
        // tensors").
        let mut bn_params: Vec<HostTensor> = Vec::new();
        for seq in &stack.sequences {
            for step in &seq.steps {
                for op in &step.ops {
                    if matches!(op.kind, OpKind::BnAffine { .. }) {
                        let (s, b) = self.params.bn_folded(op.node);
                        bn_params.push(s);
                        bn_params.push(b);
                    }
                }
            }
        }
        let name = stack_exec_name(stack);
        let mut args: Vec<&HostTensor> = vec![x.as_ref()];
        args.extend(bn_params.iter());
        let out = self.runtime.execute(&name, &args)?;
        // Interior nodes were never materialized; mark their consumers
        // as satisfied (they are all internal to the stack except the
        // last node's).
        let last = *stack
            .nodes
            .last()
            .expect("plan verifier rejects empty stacks");
        for &id in &stack.nodes {
            if id != last {
                remaining[id] = 0;
            }
        }
        stats.push(name, "stack".into(), t0.elapsed().as_secs_f64(), true);
        values.insert(last, Arc::new(out));
        Ok(())
    }

    /// Run breadth-first (baseline): every layer its own executable.
    pub fn run_baseline(&mut self, input: HostTensor) -> Result<(HostTensor, ExecStats)> {
        self.check_input(&input)?;
        let mut stats = ExecStats::default();
        let mut values = HashMap::new();
        let mut remaining = self.consumers.clone();
        values.insert(0usize, Arc::new(input));
        for id in 1..self.graph.nodes.len() {
            self.run_single(&mut values, &mut remaining, id, &mut stats)?;
        }
        let out = values
            .remove(&self.graph.output)
            .ok_or_else(|| anyhow!("output not computed"))?;
        Ok((Arc::unwrap_or_clone(out), stats))
    }

    /// Execute one plan segment. Branch segments run depth-first
    /// arm-by-arm: every arm consumes the (already materialized) entry
    /// value, then the join reduces the arm outputs. The
    /// remaining-consumer bookkeeping is execution-order independent, so
    /// the single/stack machinery applies inside arms unchanged.
    fn run_segment(
        &mut self,
        values: &mut HashMap<NodeId, Arc<HostTensor>>,
        remaining: &mut [usize],
        seg: &Segment,
        stats: &mut ExecStats,
    ) -> Result<()> {
        match seg {
            Segment::Single(id) => self.run_single(values, remaining, *id, stats),
            Segment::Stack(st) => self.run_stack(values, remaining, st, stats),
            Segment::Branch { arms, join } => {
                for arm in arms {
                    for seg in arm {
                        self.run_segment(values, remaining, seg, stats)?;
                    }
                }
                self.run_single(values, remaining, *join, stats)
            }
        }
    }

    /// Run a BrainSlug plan: stacks fused, branch regions depth-first
    /// arm-by-arm, the rest as in the baseline.
    pub fn run_plan(&mut self, plan: &Plan, input: HostTensor) -> Result<(HostTensor, ExecStats)> {
        self.check_input(&input)?;
        let mut stats = ExecStats::default();
        let mut values = HashMap::new();
        let mut remaining = self.consumers.clone();
        values.insert(0usize, Arc::new(input));
        for seg in &plan.segments {
            self.run_segment(&mut values, &mut remaining, seg, &mut stats)?;
        }
        let out = values
            .remove(&self.graph.output)
            .ok_or_else(|| anyhow!("output not computed"))?;
        Ok((Arc::unwrap_or_clone(out), stats))
    }

    fn check_input(&self, input: &HostTensor) -> Result<()> {
        let want: &Shape = self.graph.input_shape();
        if &input.shape != want {
            anyhow::bail!("input shape {} != network input {}", input.shape, want);
        }
        Ok(())
    }
}
