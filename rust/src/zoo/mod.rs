//! Model zoo: the 21 TorchVision architectures the paper evaluates (§5),
//! built as [`Graph`]s.
//!
//! Families: AlexNet (A), DenseNet-121/161/169/201 (D), Inception-V3 (I),
//! ResNet-18/34/50/101/152 (R), SqueezeNet-1.0/1.1 (S) and
//! VGG-11/13/16/19 with and without Batch Normalization (V).
//!
//! Every builder takes a [`ZooConfig`] so the same topology can be
//! instantiated at the paper's ImageNet scale (224²/299², width 1.0) for
//! the memory-traffic simulator, or at a reduced scale for measured
//! wall-clock runs on the CPU PJRT backend. Channel widths scale with
//! `width_mult`; final pooling is adaptive so any admissible resolution
//! works.

pub mod alexnet;
pub mod densenet;
pub mod inception;
pub mod resnet;
pub mod squeezenet;
pub mod vgg;

use crate::graph::Graph;

/// Instantiation parameters for a zoo network.
#[derive(Debug, Clone, Copy)]
pub struct ZooConfig {
    /// Batch size (N of NCHW).
    pub batch: usize,
    /// Input spatial resolution (square). Paper scale: 224 (299 for
    /// Inception-V3, which substitutes its own default when `None`-like
    /// behaviour is desired — see [`paper_config`]).
    pub input: usize,
    /// Channel width multiplier (1.0 = paper scale).
    pub width_mult: f64,
    /// Classifier output dimension.
    pub num_classes: usize,
}

impl ZooConfig {
    /// Scale a channel count by `width_mult` (min 1, rounded).
    pub fn ch(&self, c: usize) -> usize {
        ((c as f64 * self.width_mult).round() as usize).max(1)
    }
}

impl Default for ZooConfig {
    fn default() -> Self {
        ZooConfig {
            batch: 1,
            input: 224,
            width_mult: 1.0,
            num_classes: 1000,
        }
    }
}

/// Resolve family aliases to canonical zoo names ("vgg" → "vgg16",
/// "resnet" → "resnet18", …). Canonical names pass through unchanged, so
/// every name-taking entry point can call this unconditionally.
pub fn resolve(name: &str) -> &str {
    match name {
        "vgg" => "vgg16",
        "vgg_bn" | "vgg-bn" => "vgg16_bn",
        "resnet" => "resnet18",
        "densenet" => "densenet121",
        "squeezenet" => "squeezenet1_1",
        "inception" => "inception_v3",
        other => other,
    }
}

/// Paper-scale config for a given network (224², or 299² for Inception).
pub fn paper_config(name: &str, batch: usize) -> ZooConfig {
    ZooConfig {
        batch,
        input: if resolve(name) == "inception_v3" { 299 } else { 224 },
        width_mult: 1.0,
        num_classes: 1000,
    }
}

/// Reduced-scale config used for measured (wall-clock) experiments on the
/// CPU PJRT backend: 64² inputs (96² for Inception, whose stem needs the
/// extra extent), quarter width, 10 classes.
pub fn small_config(name: &str, batch: usize) -> ZooConfig {
    ZooConfig {
        batch,
        input: if resolve(name) == "inception_v3" { 96 } else { 64 },
        width_mult: 0.25,
        num_classes: 10,
    }
}

/// All 21 evaluated architecture names, in the paper's Table 1/2 order.
pub const ALL_NETWORKS: &[&str] = &[
    "alexnet",
    "inception_v3",
    "densenet121",
    "densenet161",
    "densenet169",
    "densenet201",
    "resnet18",
    "resnet34",
    "resnet50",
    "resnet101",
    "resnet152",
    "squeezenet1_0",
    "squeezenet1_1",
    "vgg11",
    "vgg11_bn",
    "vgg13",
    "vgg13_bn",
    "vgg16",
    "vgg16_bn",
    "vgg19",
    "vgg19_bn",
];

/// Build a network by name. Panics on unknown names (use
/// [`try_build`] for fallible lookup).
pub fn build(name: &str, cfg: ZooConfig) -> Graph {
    try_build(name, cfg).unwrap_or_else(|| panic!("unknown network: {name}"))
}

/// Build a network by name (family aliases accepted), returning `None`
/// for unknown names.
pub fn try_build(name: &str, cfg: ZooConfig) -> Option<Graph> {
    let g = match resolve(name) {
        "alexnet" => alexnet::alexnet(cfg),
        "inception_v3" => inception::inception_v3(cfg),
        "densenet121" => densenet::densenet(cfg, "densenet121", 64, 32, &[6, 12, 24, 16]),
        "densenet161" => densenet::densenet(cfg, "densenet161", 96, 48, &[6, 12, 36, 24]),
        "densenet169" => densenet::densenet(cfg, "densenet169", 64, 32, &[6, 12, 32, 32]),
        "densenet201" => densenet::densenet(cfg, "densenet201", 64, 32, &[6, 12, 48, 32]),
        "resnet18" => resnet::resnet_basic(cfg, "resnet18", &[2, 2, 2, 2]),
        "resnet34" => resnet::resnet_basic(cfg, "resnet34", &[3, 4, 6, 3]),
        "resnet50" => resnet::resnet_bottleneck(cfg, "resnet50", &[3, 4, 6, 3]),
        "resnet101" => resnet::resnet_bottleneck(cfg, "resnet101", &[3, 4, 23, 3]),
        "resnet152" => resnet::resnet_bottleneck(cfg, "resnet152", &[3, 8, 36, 3]),
        "squeezenet1_0" => squeezenet::squeezenet(cfg, "1_0"),
        "squeezenet1_1" => squeezenet::squeezenet(cfg, "1_1"),
        "vgg11" => vgg::vgg(cfg, "vgg11", vgg::CFG_A, false),
        "vgg11_bn" => vgg::vgg(cfg, "vgg11_bn", vgg::CFG_A, true),
        "vgg13" => vgg::vgg(cfg, "vgg13", vgg::CFG_B, false),
        "vgg13_bn" => vgg::vgg(cfg, "vgg13_bn", vgg::CFG_B, true),
        "vgg16" => vgg::vgg(cfg, "vgg16", vgg::CFG_D, false),
        "vgg16_bn" => vgg::vgg(cfg, "vgg16_bn", vgg::CFG_D, true),
        "vgg19" => vgg::vgg(cfg, "vgg19", vgg::CFG_E, false),
        "vgg19_bn" => vgg::vgg(cfg, "vgg19_bn", vgg::CFG_E, true),
        _ => return None,
    };
    Some(g)
}

/// Shared builder helpers for the zoo modules.
pub(crate) mod util {
    use crate::graph::{Graph, Layer, NodeId, PoolKind, Window2d};

    pub fn conv(
        g: &mut Graph,
        name: &str,
        out_channels: usize,
        window: Window2d,
        bias: bool,
    ) -> NodeId {
        g.push(
            name,
            Layer::Conv2d {
                out_channels,
                window,
                bias,
            },
        )
    }

    pub fn bn(g: &mut Graph, name: &str) -> NodeId {
        g.push(name, Layer::BatchNorm2d { eps: 1e-5 })
    }

    pub fn relu(g: &mut Graph, name: &str) -> NodeId {
        g.push(name, Layer::Relu)
    }

    pub fn maxpool(g: &mut Graph, name: &str, k: usize, s: usize, p: usize) -> NodeId {
        g.push(
            name,
            Layer::Pool2d {
                kind: PoolKind::Max,
                window: Window2d::square(k, s, p),
                ceil_mode: false,
                count_include_pad: true,
            },
        )
    }

    pub fn maxpool_ceil(g: &mut Graph, name: &str, k: usize, s: usize) -> NodeId {
        g.push(
            name,
            Layer::Pool2d {
                kind: PoolKind::Max,
                window: Window2d::square(k, s, 0),
                ceil_mode: true,
                count_include_pad: true,
            },
        )
    }

    pub fn avgpool(g: &mut Graph, name: &str, k: usize, s: usize, p: usize) -> NodeId {
        g.push(
            name,
            Layer::Pool2d {
                kind: PoolKind::Avg,
                window: Window2d::square(k, s, p),
                ceil_mode: false,
                count_include_pad: true,
            },
        )
    }

    pub fn global_avgpool(g: &mut Graph, name: &str) -> NodeId {
        g.push(name, Layer::AdaptiveAvgPool { out_hw: (1, 1) })
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_networks_build_and_validate_at_paper_scale() {
        for name in ALL_NETWORKS {
            let g = build(name, paper_config(name, 2));
            g.validate()
                .unwrap_or_else(|e| panic!("{name} invalid: {e}"));
            assert_eq!(g.output_shape().dims, vec![2, 1000], "{name} output");
        }
    }

    #[test]
    fn all_networks_build_at_small_scale() {
        for name in ALL_NETWORKS {
            let g = build(name, small_config(name, 4));
            g.validate()
                .unwrap_or_else(|e| panic!("{name} invalid: {e}"));
            assert_eq!(g.output_shape().dims, vec![4, 10], "{name} output");
        }
    }

    #[test]
    fn layer_counts_are_paperlike() {
        // Exact counts depend on how the paper tallied modules; ours must
        // at least land in the right regime and preserve the ordering
        // reported in Table 2 (AlexNet smallest, DenseNet-201 largest).
        let count = |n: &str| build(n, paper_config(n, 1)).num_layers();
        let alex = count("alexnet");
        let d201 = count("densenet201");
        let r152 = count("resnet152");
        assert!(alex < 40, "alexnet has {alex} layers");
        assert!(d201 > 500, "densenet201 has {d201} layers");
        assert!(alex < count("resnet18"));
        assert!(count("resnet18") < r152);
        assert!(r152 < d201);
    }

    #[test]
    fn unknown_network_is_none() {
        assert!(try_build("nope", ZooConfig::default()).is_none());
    }

    #[test]
    fn family_aliases_resolve() {
        assert_eq!(resolve("vgg"), "vgg16");
        assert_eq!(resolve("resnet"), "resnet18");
        assert_eq!(resolve("resnet50"), "resnet50"); // canonical passthrough
        let g = try_build("vgg", small_config("vgg", 1)).unwrap();
        assert_eq!(g.name, "vgg16");
        // Alias-aware configs: "inception" gets the larger stem input.
        assert_eq!(small_config("inception", 1).input, 96);
        assert_eq!(paper_config("inception", 1).input, 299);
    }

    #[test]
    fn width_mult_scales_params() {
        let full = build("vgg11", paper_config("vgg11", 1)).num_params();
        let quarter = build(
            "vgg11",
            ZooConfig {
                width_mult: 0.25,
                ..paper_config("vgg11", 1)
            },
        )
        .num_params();
        assert!(quarter < full / 8, "quarter width should cut params >8x");
    }
}
