//! AlexNet (Krizhevsky et al., 2012), TorchVision layout.
//!
//! The classifier's hidden width (4096) scales with the config's width
//! multiplier; the first linear layer's input size is derived from the
//! actual flattened feature extent so any admissible input resolution
//! works (the stem needs input ≥ 63 so the final pool is non-degenerate).

use crate::graph::{Graph, Layer, Shape, Window2d};

use super::util::{conv, maxpool, relu};
use super::ZooConfig;

pub fn alexnet(cfg: ZooConfig) -> Graph {
    let mut g = Graph::new("alexnet", Shape::nchw(cfg.batch, 3, cfg.input, cfg.input));

    conv(
        &mut g,
        "features.0.conv",
        cfg.ch(64),
        Window2d {
            kernel: (11, 11),
            stride: (4, 4),
            pad: (2, 2),
        },
        true,
    );
    relu(&mut g, "features.1.relu");
    maxpool(&mut g, "features.2.maxpool", 3, 2, 0);

    conv(
        &mut g,
        "features.3.conv",
        cfg.ch(192),
        Window2d::square(5, 1, 2),
        true,
    );
    relu(&mut g, "features.4.relu");
    maxpool(&mut g, "features.5.maxpool", 3, 2, 0);

    conv(
        &mut g,
        "features.6.conv",
        cfg.ch(384),
        Window2d::square(3, 1, 1),
        true,
    );
    relu(&mut g, "features.7.relu");
    conv(
        &mut g,
        "features.8.conv",
        cfg.ch(256),
        Window2d::square(3, 1, 1),
        true,
    );
    relu(&mut g, "features.9.relu");
    conv(
        &mut g,
        "features.10.conv",
        cfg.ch(256),
        Window2d::square(3, 1, 1),
        true,
    );
    relu(&mut g, "features.11.relu");
    maxpool(&mut g, "features.12.maxpool", 3, 2, 0);

    g.push("flatten", Layer::Flatten);
    let hidden = cfg.ch(4096);
    g.push("classifier.0.dropout", Layer::Dropout { p: 0.5 });
    g.push(
        "classifier.1.fc",
        Layer::Linear {
            out_features: hidden,
            bias: true,
        },
    );
    g.push("classifier.2.relu", Layer::Relu);
    g.push("classifier.3.dropout", Layer::Dropout { p: 0.5 });
    g.push(
        "classifier.4.fc",
        Layer::Linear {
            out_features: hidden,
            bias: true,
        },
    );
    g.push("classifier.5.relu", Layer::Relu);
    g.push(
        "classifier.6.fc",
        Layer::Linear {
            out_features: cfg.num_classes,
            bias: true,
        },
    );
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo::paper_config;

    #[test]
    fn paper_scale_shapes() {
        let g = alexnet(paper_config("alexnet", 128));
        // 224 -> conv11s4p2 -> 55 -> pool -> 27 -> conv5p2 -> 27 -> pool
        // -> 13 -> 3x conv3p1 -> 13 -> pool -> 6.
        let feat = g
            .nodes
            .iter()
            .find(|n| n.name == "features.12.maxpool")
            .unwrap();
        assert_eq!(feat.shape.dims, vec![128, 256, 6, 6]);
        assert_eq!(g.output_shape().dims, vec![128, 1000]);
    }

    #[test]
    fn dropout_counts() {
        let g = alexnet(paper_config("alexnet", 1));
        assert_eq!(g.kind_histogram()["dropout"], 2);
        assert_eq!(g.kind_histogram()["conv2d"], 5);
        assert_eq!(g.kind_histogram()["maxpool"], 3);
    }
}
