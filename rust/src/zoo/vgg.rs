//! VGG-11/13/16/19 with and without batch normalization (Simonyan &
//! Zisserman, 2014), TorchVision layout.

use crate::graph::{Graph, Layer, Shape, Window2d};

use super::util::{bn, conv, maxpool, relu};
use super::ZooConfig;

/// Stage spec: `C(n)` = 3×3 conv with `n` output channels, `M` = 2×2/2
/// max-pool. These are TorchVision's cfgs A/B/D/E.
#[derive(Debug, Clone, Copy)]
pub enum Item {
    C(usize),
    M,
}

use Item::{C, M};

pub const CFG_A: &[Item] = &[
    C(64), M, C(128), M, C(256), C(256), M, C(512), C(512), M, C(512), C(512), M,
];
pub const CFG_B: &[Item] = &[
    C(64), C(64), M, C(128), C(128), M, C(256), C(256), M, C(512), C(512), M, C(512), C(512), M,
];
pub const CFG_D: &[Item] = &[
    C(64), C(64), M, C(128), C(128), M, C(256), C(256), C(256), M, C(512), C(512), C(512), M,
    C(512), C(512), C(512), M,
];
pub const CFG_E: &[Item] = &[
    C(64), C(64), M, C(128), C(128), M, C(256), C(256), C(256), C(256), M, C(512), C(512),
    C(512), C(512), M, C(512), C(512), C(512), C(512), M,
];

pub fn vgg(cfg: ZooConfig, name: &str, items: &[Item], batch_norm: bool) -> Graph {
    let mut g = Graph::new(name, Shape::nchw(cfg.batch, 3, cfg.input, cfg.input));
    let mut idx = 0;
    for item in items {
        match item {
            C(ch) => {
                conv(
                    &mut g,
                    &format!("features.{idx}.conv"),
                    cfg.ch(*ch),
                    Window2d::square(3, 1, 1),
                    // TorchVision VGG convs keep bias even with BN.
                    true,
                );
                idx += 1;
                if batch_norm {
                    bn(&mut g, &format!("features.{idx}.bn"));
                    idx += 1;
                }
                relu(&mut g, &format!("features.{idx}.relu"));
                idx += 1;
            }
            M => {
                maxpool(&mut g, &format!("features.{idx}.maxpool"), 2, 2, 0);
                idx += 1;
            }
        }
    }
    g.push("flatten", Layer::Flatten);
    let hidden = cfg.ch(4096);
    g.push(
        "classifier.0.fc",
        Layer::Linear {
            out_features: hidden,
            bias: true,
        },
    );
    g.push("classifier.1.relu", Layer::Relu);
    g.push("classifier.2.dropout", Layer::Dropout { p: 0.5 });
    g.push(
        "classifier.3.fc",
        Layer::Linear {
            out_features: hidden,
            bias: true,
        },
    );
    g.push("classifier.4.relu", Layer::Relu);
    g.push("classifier.5.dropout", Layer::Dropout { p: 0.5 });
    g.push(
        "classifier.6.fc",
        Layer::Linear {
            out_features: cfg.num_classes,
            bias: true,
        },
    );
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo::paper_config;

    #[test]
    fn conv_counts_match_names() {
        let cases: &[(&str, &[Item], usize)] = &[
            ("vgg11", CFG_A, 8),
            ("vgg13", CFG_B, 10),
            ("vgg16", CFG_D, 13),
            ("vgg19", CFG_E, 16),
        ];
        for (name, items, n_convs) in cases {
            let g = vgg(paper_config(name, 1), name, items, false);
            assert_eq!(g.kind_histogram()["conv2d"], *n_convs, "{name}");
        }
    }

    #[test]
    fn bn_variant_adds_bn_per_conv() {
        let g = vgg(paper_config("vgg16_bn", 1), "vgg16_bn", CFG_D, true);
        assert_eq!(g.kind_histogram()["batchnorm"], 13);
        // 224 / 2^5 = 7 final extent.
        let flat = g.nodes.iter().find(|n| n.name == "flatten").unwrap();
        assert_eq!(flat.shape.dims, vec![1, 512 * 7 * 7]);
    }
}
