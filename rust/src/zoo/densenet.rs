//! DenseNet-121/161/169/201 (Huang et al., 2017), TorchVision layout.
//!
//! Dense layer: BN → ReLU → 1×1 conv (4·growth) → BN → ReLU → 3×3 conv
//! (growth), concatenated with its input. Transition: BN → ReLU → 1×1
//! conv (half) → 2×2/2 avg-pool. These BN→ReLU prefixes are exactly the
//! consecutive optimizable runs that give DenseNets the largest gains in
//! the paper (Figures 13/14).

use crate::graph::{Graph, Layer, NodeId, Shape, Window2d};

use super::util::{avgpool, bn, conv, global_avgpool, maxpool, relu};
use super::ZooConfig;

fn dense_layer(g: &mut Graph, prefix: &str, input: NodeId, growth: usize) -> NodeId {
    g.add(
        format!("{prefix}.norm1"),
        Layer::BatchNorm2d { eps: 1e-5 },
        &[input],
    );
    relu(g, &format!("{prefix}.relu1"));
    conv(
        g,
        &format!("{prefix}.conv1"),
        4 * growth,
        Window2d::square(1, 1, 0),
        false,
    );
    bn(g, &format!("{prefix}.norm2"));
    relu(g, &format!("{prefix}.relu2"));
    let new = conv(
        g,
        &format!("{prefix}.conv2"),
        growth,
        Window2d::square(3, 1, 1),
        false,
    );
    g.add(format!("{prefix}.concat"), Layer::Concat, &[input, new])
}

fn transition(g: &mut Graph, prefix: &str, out_channels: usize) {
    bn(g, &format!("{prefix}.norm"));
    relu(g, &format!("{prefix}.relu"));
    conv(
        g,
        &format!("{prefix}.conv"),
        out_channels,
        Window2d::square(1, 1, 0),
        false,
    );
    avgpool(g, &format!("{prefix}.pool"), 2, 2, 0);
}

pub fn densenet(
    cfg: ZooConfig,
    name: &str,
    init_features: usize,
    growth: usize,
    block_config: &[usize],
) -> Graph {
    let mut g = Graph::new(name, Shape::nchw(cfg.batch, 3, cfg.input, cfg.input));
    let init = cfg.ch(init_features);
    let growth = cfg.ch(growth);

    // Stem.
    conv(
        &mut g,
        "features.conv0",
        init,
        Window2d {
            kernel: (7, 7),
            stride: (2, 2),
            pad: (3, 3),
        },
        false,
    );
    bn(&mut g, "features.norm0");
    relu(&mut g, "features.relu0");
    maxpool(&mut g, "features.pool0", 3, 2, 1);

    let mut channels = init;
    for (bi, &n_layers) in block_config.iter().enumerate() {
        for li in 0..n_layers {
            let input = g.output;
            dense_layer(
                &mut g,
                &format!("features.denseblock{}.denselayer{}", bi + 1, li + 1),
                input,
                growth,
            );
            channels += growth;
        }
        if bi + 1 != block_config.len() {
            channels /= 2;
            transition(
                &mut g,
                &format!("features.transition{}", bi + 1),
                channels,
            );
        }
    }

    // Final norm + relu then classifier.
    bn(&mut g, "features.norm5");
    relu(&mut g, "features.relu5");
    global_avgpool(&mut g, "avgpool");
    g.push("flatten", Layer::Flatten);
    g.push(
        "classifier",
        Layer::Linear {
            out_features: cfg.num_classes,
            bias: true,
        },
    );
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo::paper_config;

    #[test]
    fn densenet121_channel_bookkeeping() {
        let g = densenet(
            paper_config("densenet121", 1),
            "densenet121",
            64,
            32,
            &[6, 12, 24, 16],
        );
        // Final feature channels: ((64+6*32)/2 + 12*32)/2 ... = 1024.
        let norm5 = g.nodes.iter().find(|n| n.name == "features.norm5").unwrap();
        assert_eq!(norm5.shape.channels(), 1024);
        assert_eq!(norm5.shape.height(), 7);
    }

    #[test]
    fn densenet161_uses_growth_48() {
        let g = densenet(
            paper_config("densenet161", 1),
            "densenet161",
            96,
            48,
            &[6, 12, 36, 24],
        );
        let norm5 = g.nodes.iter().find(|n| n.name == "features.norm5").unwrap();
        assert_eq!(norm5.shape.channels(), 2208);
    }

    #[test]
    fn dense_layers_have_concat_fanout() {
        let g = densenet(
            paper_config("densenet121", 1),
            "densenet121",
            64,
            32,
            &[6, 12, 24, 16],
        );
        let h = g.kind_histogram();
        assert_eq!(h["concat"], 6 + 12 + 24 + 16);
        // two convs per dense layer + stem + 3 transitions.
        assert_eq!(h["conv2d"], 2 * 58 + 1 + 3);
    }
}
