//! SqueezeNet 1.0 / 1.1 (Iandola et al., 2016), TorchVision layout.
//!
//! Fire module: 1×1 squeeze conv + ReLU, then parallel 1×1 and 3×3
//! expand convs (each + ReLU) concatenated on the channel axis. The
//! classifier is conv-based: dropout → 1×1 conv(num_classes) → ReLU →
//! global avg-pool.

use crate::graph::{Graph, Layer, Shape, Window2d};

use super::util::{conv, global_avgpool, maxpool_ceil, relu};
use super::ZooConfig;

fn fire(g: &mut Graph, prefix: &str, squeeze: usize, e1x1: usize, e3x3: usize) {
    conv(
        g,
        &format!("{prefix}.squeeze"),
        squeeze,
        Window2d::square(1, 1, 0),
        true,
    );
    let s = relu(g, &format!("{prefix}.squeeze_relu"));
    let a = g.add(
        format!("{prefix}.expand1x1"),
        Layer::Conv2d {
            out_channels: e1x1,
            window: Window2d::square(1, 1, 0),
            bias: true,
        },
        &[s],
    );
    let a = g.add(format!("{prefix}.expand1x1_relu"), Layer::Relu, &[a]);
    let b = g.add(
        format!("{prefix}.expand3x3"),
        Layer::Conv2d {
            out_channels: e3x3,
            window: Window2d::square(3, 1, 1),
            bias: true,
        },
        &[s],
    );
    let b = g.add(format!("{prefix}.expand3x3_relu"), Layer::Relu, &[b]);
    g.add(format!("{prefix}.concat"), Layer::Concat, &[a, b]);
}

pub fn squeezenet(cfg: ZooConfig, version: &str) -> Graph {
    let name = format!("squeezenet{version}");
    let mut g = Graph::new(name, Shape::nchw(cfg.batch, 3, cfg.input, cfg.input));
    let c = |x: usize| cfg.ch(x);

    match version {
        "1_0" => {
            conv(
                &mut g,
                "features.0.conv",
                c(96),
                Window2d::square(7, 2, 0),
                true,
            );
            relu(&mut g, "features.1.relu");
            maxpool_ceil(&mut g, "features.2.maxpool", 3, 2);
            fire(&mut g, "features.3", c(16), c(64), c(64));
            fire(&mut g, "features.4", c(16), c(64), c(64));
            fire(&mut g, "features.5", c(32), c(128), c(128));
            maxpool_ceil(&mut g, "features.6.maxpool", 3, 2);
            fire(&mut g, "features.7", c(32), c(128), c(128));
            fire(&mut g, "features.8", c(48), c(192), c(192));
            fire(&mut g, "features.9", c(48), c(192), c(192));
            fire(&mut g, "features.10", c(64), c(256), c(256));
            maxpool_ceil(&mut g, "features.11.maxpool", 3, 2);
            fire(&mut g, "features.12", c(64), c(256), c(256));
        }
        "1_1" => {
            conv(
                &mut g,
                "features.0.conv",
                c(64),
                Window2d::square(3, 2, 0),
                true,
            );
            relu(&mut g, "features.1.relu");
            maxpool_ceil(&mut g, "features.2.maxpool", 3, 2);
            fire(&mut g, "features.3", c(16), c(64), c(64));
            fire(&mut g, "features.4", c(16), c(64), c(64));
            maxpool_ceil(&mut g, "features.5.maxpool", 3, 2);
            fire(&mut g, "features.6", c(32), c(128), c(128));
            fire(&mut g, "features.7", c(32), c(128), c(128));
            maxpool_ceil(&mut g, "features.8.maxpool", 3, 2);
            fire(&mut g, "features.9", c(48), c(192), c(192));
            fire(&mut g, "features.10", c(48), c(192), c(192));
            fire(&mut g, "features.11", c(64), c(256), c(256));
            fire(&mut g, "features.12", c(64), c(256), c(256));
        }
        _ => panic!("unknown squeezenet version {version}"),
    }

    // Conv classifier.
    g.push("classifier.0.dropout", Layer::Dropout { p: 0.5 });
    conv(
        &mut g,
        "classifier.1.conv",
        cfg.num_classes,
        Window2d::square(1, 1, 0),
        true,
    );
    relu(&mut g, "classifier.2.relu");
    global_avgpool(&mut g, "classifier.3.avgpool");
    g.push("flatten", Layer::Flatten);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo::paper_config;

    #[test]
    fn v10_structure() {
        let g = squeezenet(paper_config("squeezenet1_0", 1), "1_0");
        let h = g.kind_histogram();
        // 8 fires * 3 convs + stem + classifier = 26 convs.
        assert_eq!(h["conv2d"], 26);
        assert_eq!(h["concat"], 8);
        assert_eq!(h["maxpool"], 3);
        assert_eq!(g.output_shape().dims, vec![1, 1000]);
    }

    #[test]
    fn v11_final_channels() {
        let g = squeezenet(paper_config("squeezenet1_1", 1), "1_1");
        let last_fire = g
            .nodes
            .iter()
            .find(|n| n.name == "features.12.concat")
            .unwrap();
        assert_eq!(last_fire.shape.channels(), 512);
    }

    #[test]
    fn ceil_mode_pools_present() {
        let g = squeezenet(paper_config("squeezenet1_0", 1), "1_0");
        let pools = g
            .nodes
            .iter()
            .filter(|n| matches!(n.layer, Layer::Pool2d { ceil_mode: true, .. }))
            .count();
        assert_eq!(pools, 3);
    }
}
