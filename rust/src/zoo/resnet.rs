//! ResNet-18/34 (BasicBlock) and ResNet-50/101/152 (Bottleneck), He et
//! al. 2016, TorchVision layout.

use crate::graph::{Graph, Layer, NodeId, Shape, Window2d};

use super::util::{bn, conv, global_avgpool, maxpool, relu};
use super::ZooConfig;

/// Stem shared by every ResNet: 7×7/2 conv → BN → ReLU → 3×3/2 max-pool.
fn stem(g: &mut Graph, cfg: &ZooConfig) {
    conv(
        g,
        "conv1",
        cfg.ch(64),
        Window2d {
            kernel: (7, 7),
            stride: (2, 2),
            pad: (3, 3),
        },
        false,
    );
    bn(g, "bn1");
    relu(g, "relu1");
    maxpool(g, "maxpool", 3, 2, 1);
}

/// BasicBlock: 3×3 conv-BN-ReLU, 3×3 conv-BN, residual add, ReLU.
fn basic_block(g: &mut Graph, prefix: &str, planes: usize, stride: usize, downsample: bool) {
    let identity = g.output;
    conv(
        g,
        &format!("{prefix}.conv1"),
        planes,
        Window2d::square(3, stride, 1),
        false,
    );
    bn(g, &format!("{prefix}.bn1"));
    relu(g, &format!("{prefix}.relu1"));
    conv(
        g,
        &format!("{prefix}.conv2"),
        planes,
        Window2d::square(3, 1, 1),
        false,
    );
    let main = bn(g, &format!("{prefix}.bn2"));
    let skip = shortcut(g, prefix, identity, planes, stride, downsample);
    g.add(format!("{prefix}.add"), Layer::Add, &[main, skip]);
    relu(g, &format!("{prefix}.relu2"));
}

/// Bottleneck: 1×1 reduce, 3×3, 1×1 expand (×4), residual add, ReLU.
fn bottleneck_block(g: &mut Graph, prefix: &str, planes: usize, stride: usize, downsample: bool) {
    let identity = g.output;
    conv(
        g,
        &format!("{prefix}.conv1"),
        planes,
        Window2d::square(1, 1, 0),
        false,
    );
    bn(g, &format!("{prefix}.bn1"));
    relu(g, &format!("{prefix}.relu1"));
    conv(
        g,
        &format!("{prefix}.conv2"),
        planes,
        Window2d::square(3, stride, 1),
        false,
    );
    bn(g, &format!("{prefix}.bn2"));
    relu(g, &format!("{prefix}.relu2"));
    conv(
        g,
        &format!("{prefix}.conv3"),
        planes * 4,
        Window2d::square(1, 1, 0),
        false,
    );
    let main = bn(g, &format!("{prefix}.bn3"));
    let skip = shortcut(g, prefix, identity, planes * 4, stride, downsample);
    g.add(format!("{prefix}.add"), Layer::Add, &[main, skip]);
    relu(g, &format!("{prefix}.relu3"));
}

/// Identity or 1×1-conv+BN projection shortcut.
fn shortcut(
    g: &mut Graph,
    prefix: &str,
    identity: NodeId,
    out_planes: usize,
    stride: usize,
    downsample: bool,
) -> NodeId {
    if !downsample {
        return identity;
    }
    let c = g.add(
        format!("{prefix}.downsample.conv"),
        Layer::Conv2d {
            out_channels: out_planes,
            window: Window2d::square(1, stride, 0),
            bias: false,
        },
        &[identity],
    );
    g.add(
        format!("{prefix}.downsample.bn"),
        Layer::BatchNorm2d { eps: 1e-5 },
        &[c],
    )
}

fn head(g: &mut Graph, cfg: &ZooConfig) {
    global_avgpool(g, "avgpool");
    g.push("flatten", Layer::Flatten);
    g.push(
        "fc",
        Layer::Linear {
            out_features: cfg.num_classes,
            bias: true,
        },
    );
}

pub fn resnet_basic(cfg: ZooConfig, name: &str, blocks: &[usize; 4]) -> Graph {
    let mut g = Graph::new(name, Shape::nchw(cfg.batch, 3, cfg.input, cfg.input));
    stem(&mut g, &cfg);
    let mut in_planes = cfg.ch(64);
    for (stage, &n) in blocks.iter().enumerate() {
        let planes = cfg.ch(64 << stage);
        let stride = if stage == 0 { 1 } else { 2 };
        for b in 0..n {
            let prefix = format!("layer{}.{}", stage + 1, b);
            let (s, down) = if b == 0 {
                (stride, stride != 1 || in_planes != planes)
            } else {
                (1, false)
            };
            basic_block(&mut g, &prefix, planes, s, down);
        }
        in_planes = planes;
    }
    head(&mut g, &cfg);
    g
}

pub fn resnet_bottleneck(cfg: ZooConfig, name: &str, blocks: &[usize; 4]) -> Graph {
    let mut g = Graph::new(name, Shape::nchw(cfg.batch, 3, cfg.input, cfg.input));
    stem(&mut g, &cfg);
    let mut in_planes = cfg.ch(64);
    for (stage, &n) in blocks.iter().enumerate() {
        let planes = cfg.ch(64 << stage);
        let stride = if stage == 0 { 1 } else { 2 };
        for b in 0..n {
            let prefix = format!("layer{}.{}", stage + 1, b);
            let (s, down) = if b == 0 {
                (stride, true) // expansion always forces a projection at b=0
            } else {
                (1, false)
            };
            bottleneck_block(&mut g, &prefix, planes, s, down);
        }
        in_planes = planes * 4;
    }
    let _ = in_planes;
    head(&mut g, &cfg);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo::paper_config;

    #[test]
    fn resnet18_structure() {
        let g = resnet_basic(paper_config("resnet18", 1), "resnet18", &[2, 2, 2, 2]);
        let h = g.kind_histogram();
        // 1 stem + 8 blocks * 2 + 3 downsample projections = 20 convs.
        assert_eq!(h["conv2d"], 20);
        assert_eq!(h["add"], 8);
        assert_eq!(g.output_shape().dims, vec![1, 1000]);
    }

    #[test]
    fn resnet50_structure() {
        let g = resnet_bottleneck(paper_config("resnet50", 1), "resnet50", &[3, 4, 6, 3]);
        let h = g.kind_histogram();
        // stem 1 + 16 blocks * 3 + 4 projections = 53 convs.
        assert_eq!(h["conv2d"], 53);
        assert_eq!(h["add"], 16);
        // stage extents: 224 -> 112 -> 56 (pool) -> 56,28,14,7.
        let last_relu = g.nodes.iter().rev().find(|n| n.name.contains("relu3")).unwrap();
        assert_eq!(last_relu.shape.dims, vec![1, 2048, 7, 7]);
    }

    #[test]
    fn downsample_on_first_block_of_each_later_stage() {
        let g = resnet_basic(paper_config("resnet18", 1), "resnet18", &[2, 2, 2, 2]);
        let n_down = g
            .nodes
            .iter()
            .filter(|n| n.name.contains("downsample.conv"))
            .count();
        assert_eq!(n_down, 3); // stages 2..4
    }
}
