//! Inception-V3 (Szegedy et al., 2015), TorchVision layout (eval mode:
//! no auxiliary classifier).
//!
//! Every conv is a `BasicConv2d` = conv(bias=false) → BN → ReLU, so the
//! network is dense in optimizable BN→ReLU pairs — the paper optimizes
//! 203 of its 316 layers (Table 2).

use crate::graph::{Graph, Layer, NodeId, PoolKind, Shape, Window2d};

use super::util::{global_avgpool, maxpool};
use super::ZooConfig;

/// conv → BN → ReLU starting from an explicit input node; returns output.
fn basic(g: &mut Graph, prefix: &str, input: NodeId, out: usize, window: Window2d) -> NodeId {
    let c = g.add(
        format!("{prefix}.conv"),
        Layer::Conv2d {
            out_channels: out,
            window,
            bias: false,
        },
        &[input],
    );
    let b = g.add(format!("{prefix}.bn"), Layer::BatchNorm2d { eps: 1e-3 }, &[c]);
    g.add(format!("{prefix}.relu"), Layer::Relu, &[b])
}

fn sq(k: usize, s: usize, p: usize) -> Window2d {
    Window2d::square(k, s, p)
}

fn rect(kh: usize, kw: usize, ph: usize, pw: usize) -> Window2d {
    Window2d {
        kernel: (kh, kw),
        stride: (1, 1),
        pad: (ph, pw),
    }
}

/// 3×3/1/1 average pool used by the pooled branches.
fn branch_avgpool(g: &mut Graph, prefix: &str, input: NodeId) -> NodeId {
    g.add(
        format!("{prefix}.pool"),
        Layer::Pool2d {
            kind: PoolKind::Avg,
            window: Window2d::square(3, 1, 1),
            ceil_mode: false,
            count_include_pad: true,
        },
        &[input],
    )
}

fn inception_a(g: &mut Graph, prefix: &str, cfg: &ZooConfig, pool_features: usize) {
    let input = g.output;
    let b1 = basic(g, &format!("{prefix}.branch1x1"), input, cfg.ch(64), sq(1, 1, 0));
    let b5 = basic(g, &format!("{prefix}.branch5x5_1"), input, cfg.ch(48), sq(1, 1, 0));
    let b5 = basic(g, &format!("{prefix}.branch5x5_2"), b5, cfg.ch(64), sq(5, 1, 2));
    let b3 = basic(g, &format!("{prefix}.branch3x3dbl_1"), input, cfg.ch(64), sq(1, 1, 0));
    let b3 = basic(g, &format!("{prefix}.branch3x3dbl_2"), b3, cfg.ch(96), sq(3, 1, 1));
    let b3 = basic(g, &format!("{prefix}.branch3x3dbl_3"), b3, cfg.ch(96), sq(3, 1, 1));
    let bp = branch_avgpool(g, &format!("{prefix}.branch_pool"), input);
    let bp = basic(
        g,
        &format!("{prefix}.branch_pool_conv"),
        bp,
        cfg.ch(pool_features),
        sq(1, 1, 0),
    );
    g.add(format!("{prefix}.concat"), Layer::Concat, &[b1, b5, b3, bp]);
}

fn inception_b(g: &mut Graph, prefix: &str, cfg: &ZooConfig) {
    let input = g.output;
    let b3 = basic(g, &format!("{prefix}.branch3x3"), input, cfg.ch(384), sq(3, 2, 0));
    let bd = basic(g, &format!("{prefix}.branch3x3dbl_1"), input, cfg.ch(64), sq(1, 1, 0));
    let bd = basic(g, &format!("{prefix}.branch3x3dbl_2"), bd, cfg.ch(96), sq(3, 1, 1));
    let bd = basic(g, &format!("{prefix}.branch3x3dbl_3"), bd, cfg.ch(96), sq(3, 2, 0));
    let bp = g.add(
        format!("{prefix}.branch_pool"),
        Layer::Pool2d {
            kind: PoolKind::Max,
            window: Window2d::square(3, 2, 0),
            ceil_mode: false,
            count_include_pad: true,
        },
        &[input],
    );
    g.add(format!("{prefix}.concat"), Layer::Concat, &[b3, bd, bp]);
}

fn inception_c(g: &mut Graph, prefix: &str, cfg: &ZooConfig, c7: usize) {
    let input = g.output;
    let c7 = cfg.ch(c7);
    let out = cfg.ch(192);
    let b1 = basic(g, &format!("{prefix}.branch1x1"), input, out, sq(1, 1, 0));
    let b7 = basic(g, &format!("{prefix}.branch7x7_1"), input, c7, sq(1, 1, 0));
    let b7 = basic(g, &format!("{prefix}.branch7x7_2"), b7, c7, rect(1, 7, 0, 3));
    let b7 = basic(g, &format!("{prefix}.branch7x7_3"), b7, out, rect(7, 1, 3, 0));
    let bd = basic(g, &format!("{prefix}.branch7x7dbl_1"), input, c7, sq(1, 1, 0));
    let bd = basic(g, &format!("{prefix}.branch7x7dbl_2"), bd, c7, rect(7, 1, 3, 0));
    let bd = basic(g, &format!("{prefix}.branch7x7dbl_3"), bd, c7, rect(1, 7, 0, 3));
    let bd = basic(g, &format!("{prefix}.branch7x7dbl_4"), bd, c7, rect(7, 1, 3, 0));
    let bd = basic(g, &format!("{prefix}.branch7x7dbl_5"), bd, out, rect(1, 7, 0, 3));
    let bp = branch_avgpool(g, &format!("{prefix}.branch_pool"), input);
    let bp = basic(g, &format!("{prefix}.branch_pool_conv"), bp, out, sq(1, 1, 0));
    g.add(format!("{prefix}.concat"), Layer::Concat, &[b1, b7, bd, bp]);
}

fn inception_d(g: &mut Graph, prefix: &str, cfg: &ZooConfig) {
    let input = g.output;
    let b3 = basic(g, &format!("{prefix}.branch3x3_1"), input, cfg.ch(192), sq(1, 1, 0));
    let b3 = basic(g, &format!("{prefix}.branch3x3_2"), b3, cfg.ch(320), sq(3, 2, 0));
    let b7 = basic(g, &format!("{prefix}.branch7x7x3_1"), input, cfg.ch(192), sq(1, 1, 0));
    let b7 = basic(g, &format!("{prefix}.branch7x7x3_2"), b7, cfg.ch(192), rect(1, 7, 0, 3));
    let b7 = basic(g, &format!("{prefix}.branch7x7x3_3"), b7, cfg.ch(192), rect(7, 1, 3, 0));
    let b7 = basic(g, &format!("{prefix}.branch7x7x3_4"), b7, cfg.ch(192), sq(3, 2, 0));
    let bp = g.add(
        format!("{prefix}.branch_pool"),
        Layer::Pool2d {
            kind: PoolKind::Max,
            window: Window2d::square(3, 2, 0),
            ceil_mode: false,
            count_include_pad: true,
        },
        &[input],
    );
    g.add(format!("{prefix}.concat"), Layer::Concat, &[b3, b7, bp]);
}

fn inception_e(g: &mut Graph, prefix: &str, cfg: &ZooConfig) {
    let input = g.output;
    let b1 = basic(g, &format!("{prefix}.branch1x1"), input, cfg.ch(320), sq(1, 1, 0));
    let b3 = basic(g, &format!("{prefix}.branch3x3_1"), input, cfg.ch(384), sq(1, 1, 0));
    let b3a = basic(g, &format!("{prefix}.branch3x3_2a"), b3, cfg.ch(384), rect(1, 3, 0, 1));
    let b3b = basic(g, &format!("{prefix}.branch3x3_2b"), b3, cfg.ch(384), rect(3, 1, 1, 0));
    let b3 = g.add(format!("{prefix}.branch3x3_concat"), Layer::Concat, &[b3a, b3b]);
    let bd = basic(g, &format!("{prefix}.branch3x3dbl_1"), input, cfg.ch(448), sq(1, 1, 0));
    let bd = basic(g, &format!("{prefix}.branch3x3dbl_2"), bd, cfg.ch(384), sq(3, 1, 1));
    let bda = basic(g, &format!("{prefix}.branch3x3dbl_3a"), bd, cfg.ch(384), rect(1, 3, 0, 1));
    let bdb = basic(g, &format!("{prefix}.branch3x3dbl_3b"), bd, cfg.ch(384), rect(3, 1, 1, 0));
    let bd = g.add(
        format!("{prefix}.branch3x3dbl_concat"),
        Layer::Concat,
        &[bda, bdb],
    );
    let bp = branch_avgpool(g, &format!("{prefix}.branch_pool"), input);
    let bp = basic(g, &format!("{prefix}.branch_pool_conv"), bp, cfg.ch(192), sq(1, 1, 0));
    g.add(format!("{prefix}.concat"), Layer::Concat, &[b1, b3, bd, bp]);
}

pub fn inception_v3(cfg: ZooConfig) -> Graph {
    let mut g = Graph::new(
        "inception_v3",
        Shape::nchw(cfg.batch, 3, cfg.input, cfg.input),
    );

    // Stem.
    let x = g.output;
    let x = basic(&mut g, "Conv2d_1a_3x3", x, cfg.ch(32), sq(3, 2, 0));
    let x = basic(&mut g, "Conv2d_2a_3x3", x, cfg.ch(32), sq(3, 1, 0));
    let _ = basic(&mut g, "Conv2d_2b_3x3", x, cfg.ch(64), sq(3, 1, 1));
    maxpool(&mut g, "maxpool1", 3, 2, 0);
    let x = g.output;
    let x = basic(&mut g, "Conv2d_3b_1x1", x, cfg.ch(80), sq(1, 1, 0));
    let _ = basic(&mut g, "Conv2d_4a_3x3", x, cfg.ch(192), sq(3, 1, 0));
    maxpool(&mut g, "maxpool2", 3, 2, 0);

    inception_a(&mut g, "Mixed_5b", &cfg, 32);
    inception_a(&mut g, "Mixed_5c", &cfg, 64);
    inception_a(&mut g, "Mixed_5d", &cfg, 64);
    inception_b(&mut g, "Mixed_6a", &cfg);
    inception_c(&mut g, "Mixed_6b", &cfg, 128);
    inception_c(&mut g, "Mixed_6c", &cfg, 160);
    inception_c(&mut g, "Mixed_6d", &cfg, 160);
    inception_c(&mut g, "Mixed_6e", &cfg, 192);
    inception_d(&mut g, "Mixed_7a", &cfg);
    inception_e(&mut g, "Mixed_7b", &cfg);
    inception_e(&mut g, "Mixed_7c", &cfg);

    global_avgpool(&mut g, "avgpool");
    g.push("dropout", Layer::Dropout { p: 0.5 });
    g.push("flatten", Layer::Flatten);
    g.push(
        "fc",
        Layer::Linear {
            out_features: cfg.num_classes,
            bias: true,
        },
    );
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo::paper_config;

    #[test]
    fn paper_scale_extents() {
        let g = inception_v3(paper_config("inception_v3", 1));
        // 299 -> 149 -> 147 -> 147 -> 73 -> 73 -> 71 -> 35.
        let m5b_in = g.nodes.iter().find(|n| n.name == "maxpool2").unwrap();
        assert_eq!(m5b_in.shape.dims, vec![1, 192, 35, 35]);
        // Mixed_5b output: 64+64+96+32 = 256 channels.
        let m5b = g.nodes.iter().find(|n| n.name == "Mixed_5b.concat").unwrap();
        assert_eq!(m5b.shape.channels(), 256);
        // Mixed_6e output: 768 @ 17x17.
        let m6e = g.nodes.iter().find(|n| n.name == "Mixed_6e.concat").unwrap();
        assert_eq!(m6e.shape.dims, vec![1, 768, 17, 17]);
        // Mixed_7c output: 2048 @ 8x8.
        let m7c = g.nodes.iter().find(|n| n.name == "Mixed_7c.concat").unwrap();
        assert_eq!(m7c.shape.dims, vec![1, 2048, 8, 8]);
        assert_eq!(g.output_shape().dims, vec![1, 1000]);
    }

    #[test]
    fn layer_count_in_table2_regime() {
        let g = inception_v3(paper_config("inception_v3", 1));
        // Paper reports 316 layers; our module tally differs slightly but
        // must land in the same regime.
        let n = g.num_layers();
        assert!(
            (250..400).contains(&n),
            "inception layer count {n} out of regime"
        );
    }
}
