//! Minimal CLI argument parser (offline environment has no clap).
//!
//! Supports `command [--flag] [--key value] [positional...]` with typed
//! accessors and an error on unknown flags, which is all the `brainslug`
//! binary needs.

use std::collections::BTreeMap;

/// Parsed arguments: one subcommand, flags, key-values, positionals.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    /// Flags consumed via accessors (for unknown-flag detection).
    known: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> anyhow::Result<Args> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--key=value` or `--key value` or boolean `--key`.
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    let v = it.next().unwrap_or_default();
                    flags.insert(name.to_string(), v);
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Ok(Args {
            command,
            flags,
            positional,
            known: Default::default(),
        })
    }

    pub fn from_env() -> anyhow::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.known.borrow_mut().push(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key}: bad number '{v}': {e}")),
        }
    }

    /// Optional strictly-positive integer flag: `None` when absent; a
    /// one-line error naming the flag for zero, negative, or garbage
    /// values — count-like flags (`--threads`, `--workers`,
    /// `--collapse-budget`) must fail loudly, not clamp silently.
    pub fn get_positive_usize(&self, key: &str) -> anyhow::Result<Option<usize>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => match v.parse::<usize>() {
                Ok(0) | Err(_) => anyhow::bail!(
                    "--{key}: must be a positive integer (got '{v}')"
                ),
                Ok(n) => Ok(Some(n)),
            },
        }
    }

    /// Optional float flag (`None` when absent, error on a bad number).
    pub fn get_f64(&self, key: &str) -> anyhow::Result<Option<f64>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key}: bad number '{v}': {e}")),
        }
    }

    /// Comma-separated list of positive integers (e.g. `--workers
    /// 1,2,4`); `default` when absent, error on garbage or zeros.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|part| match part.trim().parse::<usize>() {
                    Ok(n) if n > 0 => Ok(n),
                    _ => Err(anyhow::anyhow!(
                        "--{key}: expected comma-separated positive integers (got '{v}')"
                    )),
                })
                .collect(),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error if any flag was provided that no accessor asked about.
    /// Call after all `get*` calls.
    pub fn reject_unknown(&self) -> anyhow::Result<()> {
        let known = self.known.borrow();
        for k in self.flags.keys() {
            if !known.iter().any(|x| x == k) {
                anyhow::bail!("unknown flag --{k} for command '{}'", self.command);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn basic_forms() {
        // NB: a bare boolean flag greedily consumes a following
        // non-flag token, so positionals go before boolean flags (or use
        // `--flag=true`). None of the binary's commands mix them.
        let a = parse(&["run", "pos1", "--net", "resnet18", "--batch=8", "--verbose"]);
        assert_eq!(a.command, "run");
        assert_eq!(a.get("net"), Some("resnet18"));
        assert_eq!(a.get_usize("batch", 1).unwrap(), 8);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
        a.reject_unknown().unwrap();
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert_eq!(a.get_or("missing", "d"), "d");
        assert!(a.get_usize("n", 0).is_err());
        assert!(a.get_f64("n").is_err());
        assert_eq!(a.get_f64("missing").unwrap(), None);
        let b = parse(&["x", "--pace", "0.5"]);
        assert_eq!(b.get_f64("pace").unwrap(), Some(0.5));
    }

    #[test]
    fn positive_usize_rejects_zero_negative_and_garbage() {
        for bad in ["0", "-3", "abc", "1.5"] {
            let a = parse(&["x", "--threads", bad]);
            let err = a.get_positive_usize("threads").unwrap_err().to_string();
            assert!(
                err.contains("--threads") && err.contains("positive integer"),
                "{bad}: {err}"
            );
        }
        let a = parse(&["x", "--threads", "4"]);
        assert_eq!(a.get_positive_usize("threads").unwrap(), Some(4));
        assert_eq!(a.get_positive_usize("missing").unwrap(), None);
    }

    #[test]
    fn usize_list_parses_and_rejects() {
        let a = parse(&["x", "--workers", "1,2,4"]);
        assert_eq!(a.get_usize_list("workers", &[8]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.get_usize_list("missing", &[8, 16]).unwrap(), vec![8, 16]);
        for bad in ["1,0,2", "a,b", "1,,2", ""] {
            let a = parse(&["x", "--workers", bad]);
            assert!(a.get_usize_list("workers", &[1]).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse(&["x", "--oops", "1"]);
        let _ = a.get("fine");
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn boolean_flag_before_another_flag() {
        let a = parse(&["x", "--flag", "--other", "v"]);
        assert!(a.get_bool("flag"));
        assert_eq!(a.get("other"), Some("v"));
    }
}
