//! Shared benchmark harness: timing helpers, table rendering, and the
//! canonical experiment sets used by `emit-requests`, the criterion-style
//! benches, and the examples.
//!
//! The build environment has no criterion crate, so `measure` implements
//! the paper's own methodology directly: N timed repetitions, report the
//! *minimum* (§5: "we take the minimum execution time for both PyTorch
//! and BrainSlug results").

pub mod experiments;

pub use experiments::{
    artifacts_present, block_engine, block_net, build_measured, fig10_measured_blocks,
    fig10_strategies, fig16_worker_counts, measured_batches, measured_device, measured_engine,
    measured_networks, measured_opts, measured_runtime, oracle_seed, paper_engine, serving_engine,
    ARTIFACT_DIR,
};

use std::path::PathBuf;
use std::time::Instant;

use crate::json::Json;

/// Emit one bench's machine-readable result rows: print each as a
/// `BENCH {json}` line (the format trend-tracking tools grep for) and
/// write the whole array to `BENCH_<name>.json` at the repo root, so
/// every bench run leaves its rows on disk instead of only on stdout.
///
/// The repo root is resolved from the crate manifest directory
/// (`rust/`'s parent), independent of the invocation cwd. Returns the
/// path written (best-effort: an unwritable disk degrades to
/// stdout-only with a warning, never a panic mid-bench).
pub fn emit_bench_json(name: &str, rows: Vec<Json>) -> PathBuf {
    for row in &rows {
        println!("BENCH {}", row.to_string_compact());
    }
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(PathBuf::from)
        .unwrap_or_default();
    let path = root.join(format!("BENCH_{name}.json"));
    let doc = Json::Arr(rows);
    if let Err(e) = std::fs::write(&path, doc.to_string_pretty()) {
        eprintln!("warning: could not write {}: {e}", path.display());
    }
    path
}

/// Run `f` `warmup + iters` times; return the minimum of the timed iters
/// in seconds.
pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut best = f64::INFINITY;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Simple fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                // Right-align numeric-looking cells, left-align text.
                let numeric = cell
                    .chars()
                    .all(|ch| ch.is_ascii_digit() || "+-.%x".contains(ch));
                if numeric && !cell.is_empty() {
                    line.push_str(&format!("{:>w$}", cell, w = widths[c]));
                } else {
                    line.push_str(&format!("{:<w$}", cell, w = widths[c]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds adaptively (µs/ms/s).
pub fn fmt_time(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Format a speed-up percentage in the paper's convention.
pub fn fmt_pct(p: f64) -> String {
    format!("{p:+.1}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_min() {
        let mut calls = 0;
        let t = measure(1, 3, || {
            calls += 1;
        });
        assert_eq!(calls, 4);
        assert!(t >= 0.0 && t < 1.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["net", "speedup"]);
        t.row(vec!["alexnet".into(), "+5.3%".into()]);
        t.row(vec!["densenet121".into(), "+15.2%".into()]);
        let r = t.render();
        assert!(r.contains("alexnet"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[1].chars().all(|c| c == '-'), true);
    }

    #[test]
    fn emit_bench_json_writes_rows_to_disk() {
        let mut row = Json::object();
        row.set("bench", Json::Str("selftest".into()));
        row.set("value", Json::Num(1.5));
        let path = emit_bench_json("selftest_tmp", vec![row]);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("selftest"));
        assert!(text.trim_start().starts_with('['));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(5e-6), "5.0us");
        assert_eq!(fmt_time(2.5e-3), "2.50ms");
        assert_eq!(fmt_time(1.5), "1.500s");
        assert_eq!(fmt_pct(5.25), "+5.2%");
        assert_eq!(fmt_pct(-3.0), "-3.0%");
    }
}
