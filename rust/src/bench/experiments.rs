//! Canonical experiment definitions shared by `emit-requests`, the
//! benches, the examples, and the integration tests.
//!
//! Whatever appears here determines which artifacts `make artifacts`
//! compiles — the request emitter, the scheduler, and the benches all go
//! through these functions, so names always line up.

use std::rc::Rc;

use crate::device::DeviceSpec;
use crate::engine::{Backend, Engine, EngineBuilder, PjrtBackend};
use crate::graph::{Graph, Layer, PoolKind, Shape, Window2d};
use crate::optimizer::CollapseOptions;
use crate::runtime::Runtime;

/// Artifact directory (relative to the repo root / cwd).
pub const ARTIFACT_DIR: &str = crate::engine::DEFAULT_ARTIFACT_DIR;

/// Seed for all deterministic parameters/inputs in measured experiments.
pub fn oracle_seed() -> u64 {
    crate::engine::DEFAULT_SEED
}

/// True when the AOT artifact manifest exists — the gate for every
/// measured (wall-clock PJRT) bench section.
pub fn artifacts_present() -> bool {
    std::path::Path::new(ARTIFACT_DIR)
        .join("manifest.json")
        .exists()
}

/// One shared PJRT runtime over [`ARTIFACT_DIR`] for a measured bench
/// section, or `None` (skip the section) when artifacts are absent.
/// Sharing keeps the compiled-executable cache warm across the many
/// engines a bench builds.
pub fn measured_runtime() -> Option<Rc<Runtime>> {
    Runtime::new(std::path::Path::new(ARTIFACT_DIR))
        .ok()
        .map(Rc::new)
}

/// Build `builder` against a shared measured runtime (see
/// [`measured_runtime`]); the engine's backend reuses `runtime`'s
/// executable cache instead of opening its own PJRT client.
pub fn build_measured(builder: EngineBuilder, runtime: &Rc<Runtime>) -> anyhow::Result<Engine> {
    let rt = runtime.clone();
    builder.build_with(move |graph, _device, seed| {
        Ok(Box::new(PjrtBackend::with_runtime(rt, graph.clone(), seed)) as Box<dyn Backend>)
    })
}

/// [`EngineBuilder`] preconfigured for the measured experiment set: the
/// named zoo network at reduced scale, measured device/options/seed, and
/// the PJRT backend over [`ARTIFACT_DIR`].
pub fn measured_engine(name: &str, batch: usize) -> EngineBuilder {
    Engine::builder()
        .zoo_small(name, batch)
        .device(measured_device())
        .brainslug(measured_opts())
        .artifacts(ARTIFACT_DIR)
        .seed(oracle_seed())
}

/// [`EngineBuilder`] for a paper-scale simulated experiment on `device`
/// (default collapse options, sim backend — no artifacts needed).
pub fn paper_engine(name: &str, batch: usize, device: &DeviceSpec) -> EngineBuilder {
    Engine::builder()
        .zoo_paper(name, batch)
        .device(device.clone())
        .brainslug(CollapseOptions::default())
        .sim()
        .seed(oracle_seed())
}

/// [`EngineBuilder`] over a measured-scale Figure-10 block network with
/// explicit collapse options (PJRT backend).
pub fn block_engine(blocks: usize, batch: usize, c: usize, h: usize, opts: CollapseOptions) -> EngineBuilder {
    Engine::builder()
        .graph_owned(block_net(blocks, batch, c, h))
        .device(measured_device())
        .brainslug(opts)
        .artifacts(ARTIFACT_DIR)
        .seed(oracle_seed())
}

/// Networks in the *measured* (wall-clock, PJRT CPU) experiment set —
/// one per family, at reduced scale. The remaining 17 networks are
/// covered at paper scale by the memsim benches.
pub fn measured_networks() -> &'static [&'static str] {
    &["alexnet", "resnet18", "vgg11_bn", "squeezenet1_1"]
}

/// Batch sizes for measured experiments.
pub fn measured_batches() -> &'static [usize] {
    &[1, 8]
}

/// Device model whose budget drives collapse decisions in measured mode.
/// The TPU-core profile exercises the Pallas/VMEM tiling path described
/// in DESIGN.md §Hardware-Adaptation.
pub fn measured_device() -> DeviceSpec {
    DeviceSpec::tpu_core()
}

/// Collapse options for measured experiments.
pub fn measured_opts() -> CollapseOptions {
    CollapseOptions::default()
}

/// The Figure-10 synthetic block network: `blocks` repetitions of
/// <MaxPool 3×3/1/1, BatchNorm, ReLU> over a `c`-channel `h×h` input.
pub fn block_net(blocks: usize, batch: usize, c: usize, h: usize) -> Graph {
    let mut g = Graph::new(
        format!("blocks{blocks}"),
        Shape::nchw(batch, c, h, h),
    );
    for i in 0..blocks {
        g.push(
            format!("b{i}.pool"),
            Layer::Pool2d {
                kind: PoolKind::Max,
                window: Window2d::square(3, 1, 1),
                ceil_mode: false,
                count_include_pad: true,
            },
        );
        g.push(format!("b{i}.bn"), Layer::BatchNorm2d { eps: 1e-5 });
        g.push(format!("b{i}.relu"), Layer::Relu);
    }
    g
}

/// [`EngineBuilder`] for the serving-scaling experiment (`fig16`): a
/// measured-scale block network on the *paced* sim backend, so one
/// batch occupies real wall-clock time and worker-pool queueing is
/// genuine. `pace_scale = 0.0` degenerates to the unpaced sim backend
/// (used to probe the model time when calibrating a scale).
pub fn serving_engine(batch: usize, pace_scale: f64) -> EngineBuilder {
    Engine::builder()
        .graph_owned(block_net(2, batch, 4, 16))
        .device(measured_device())
        .brainslug(measured_opts())
        .sim_paced(pace_scale)
        .seed(oracle_seed())
}

/// Worker-pool sizes swept by the serving-scaling experiment.
pub fn fig16_worker_counts() -> &'static [usize] {
    &[1, 2, 4, 8]
}

/// The three collapse strategies evaluated in Figure 10.
pub fn fig10_strategies() -> Vec<(&'static str, CollapseOptions)> {
    vec![
        (
            "1step",
            CollapseOptions {
                max_steps_per_sequence: Some(1),
                ..Default::default()
            },
        ),
        (
            "5step",
            CollapseOptions {
                max_steps_per_sequence: Some(5),
                ..Default::default()
            },
        ),
        ("unrestricted", CollapseOptions::default()),
    ]
}

/// Measured Figure-10 block counts (paper sweeps 1..40 at full scale; the
/// memsim bench covers that range, the measured bench a subset).
pub fn fig10_measured_blocks() -> &'static [usize] {
    &[1, 2, 4, 8]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::optimize;

    #[test]
    fn block_net_is_fully_optimizable() {
        let g = block_net(3, 2, 8, 32);
        g.validate().unwrap();
        assert_eq!(g.num_layers(), 9);
        let plan = optimize(&g, &measured_device(), &measured_opts());
        plan.validate(&g).unwrap();
        assert_eq!(plan.num_stacks(), 1); // one maximal chain
        assert_eq!(plan.num_optimized_layers(), 9);
    }

    #[test]
    fn strategies_differ_in_sequence_count() {
        let g = block_net(6, 1, 8, 32);
        let dev = measured_device();
        let counts: Vec<usize> = fig10_strategies()
            .iter()
            .map(|(_, opts)| {
                let plan = optimize(&g, &dev, opts);
                plan.stacks().map(|s| s.sequences.len()).sum()
            })
            .collect();
        // 1-step: 6 sequences; 5-step: 2; unrestricted: <= 2.
        assert_eq!(counts[0], 6);
        assert!(counts[1] <= 2);
        assert!(counts[2] <= counts[1]);
    }
}
