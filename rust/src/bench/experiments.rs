//! Canonical experiment definitions shared by `emit-requests`, the
//! benches, the examples, and the integration tests.
//!
//! Whatever appears here determines which artifacts `make artifacts`
//! compiles — the request emitter, the scheduler, and the benches all go
//! through these functions, so names always line up.

use crate::device::DeviceSpec;
use crate::graph::{Graph, Layer, PoolKind, Shape, Window2d};
use crate::optimizer::CollapseOptions;

/// Artifact directory (relative to the repo root / cwd).
pub const ARTIFACT_DIR: &str = "artifacts";

/// Seed for all deterministic parameters/inputs in measured experiments.
pub fn oracle_seed() -> u64 {
    0x5EED_2026
}

/// Networks in the *measured* (wall-clock, PJRT CPU) experiment set —
/// one per family, at reduced scale. The remaining 17 networks are
/// covered at paper scale by the memsim benches.
pub fn measured_networks() -> &'static [&'static str] {
    &["alexnet", "resnet18", "vgg11_bn", "squeezenet1_1"]
}

/// Batch sizes for measured experiments.
pub fn measured_batches() -> &'static [usize] {
    &[1, 8]
}

/// Device model whose budget drives collapse decisions in measured mode.
/// The TPU-core profile exercises the Pallas/VMEM tiling path described
/// in DESIGN.md §Hardware-Adaptation.
pub fn measured_device() -> DeviceSpec {
    DeviceSpec::tpu_core()
}

/// Collapse options for measured experiments.
pub fn measured_opts() -> CollapseOptions {
    CollapseOptions::default()
}

/// The Figure-10 synthetic block network: `blocks` repetitions of
/// <MaxPool 3×3/1/1, BatchNorm, ReLU> over a `c`-channel `h×h` input.
pub fn block_net(blocks: usize, batch: usize, c: usize, h: usize) -> Graph {
    let mut g = Graph::new(
        format!("blocks{blocks}"),
        Shape::nchw(batch, c, h, h),
    );
    for i in 0..blocks {
        g.push(
            format!("b{i}.pool"),
            Layer::Pool2d {
                kind: PoolKind::Max,
                window: Window2d::square(3, 1, 1),
                ceil_mode: false,
                count_include_pad: true,
            },
        );
        g.push(format!("b{i}.bn"), Layer::BatchNorm2d { eps: 1e-5 });
        g.push(format!("b{i}.relu"), Layer::Relu);
    }
    g
}

/// The three collapse strategies evaluated in Figure 10.
pub fn fig10_strategies() -> Vec<(&'static str, CollapseOptions)> {
    vec![
        (
            "1step",
            CollapseOptions {
                max_steps_per_sequence: Some(1),
                ..Default::default()
            },
        ),
        (
            "5step",
            CollapseOptions {
                max_steps_per_sequence: Some(5),
                ..Default::default()
            },
        ),
        ("unrestricted", CollapseOptions::default()),
    ]
}

/// Measured Figure-10 block counts (paper sweeps 1..40 at full scale; the
/// memsim bench covers that range, the measured bench a subset).
pub fn fig10_measured_blocks() -> &'static [usize] {
    &[1, 2, 4, 8]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::optimize;

    #[test]
    fn block_net_is_fully_optimizable() {
        let g = block_net(3, 2, 8, 32);
        g.validate().unwrap();
        assert_eq!(g.num_layers(), 9);
        let plan = optimize(&g, &measured_device(), &measured_opts());
        plan.validate(&g).unwrap();
        assert_eq!(plan.num_stacks(), 1); // one maximal chain
        assert_eq!(plan.num_optimized_layers(), 9);
    }

    #[test]
    fn strategies_differ_in_sequence_count() {
        let g = block_net(6, 1, 8, 32);
        let dev = measured_device();
        let counts: Vec<usize> = fig10_strategies()
            .iter()
            .map(|(_, opts)| {
                let plan = optimize(&g, &dev, opts);
                plan.stacks().map(|s| s.sequences.len()).sum()
            })
            .collect();
        // 1-step: 6 sequences; 5-step: 2; unrestricted: <= 2.
        assert_eq!(counts[0], 6);
        assert!(counts[1] <= 2);
        assert!(counts[2] <= counts[1]);
    }
}
