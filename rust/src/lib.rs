//! # BrainSlug-RS
//!
//! Reproduction of *BrainSlug: Transparent Acceleration of Deep Learning
//! Through Depth-First Parallelism* (Weber, Schmidt, Niepert, Huici —
//! NEC Laboratories Europe, 2018) as a three-layer Rust + JAX + Pallas
//! stack.
//!
//! The paper's contribution — detecting runs of element-wise + pooling
//! layers in a network DAG and *collapsing* them into fused, cache-tiled
//! depth-first kernels — lives in [`optimizer`]. The networks it operates
//! on are built by [`zoo`] over the [`graph`] IR; [`device`] models the
//! hardware the collapser packs against; [`memsim`] is the memory-traffic
//! substrate that regenerates the paper's tables and figures at paper
//! scale; [`runtime`] + [`scheduler`] execute optimized plans on the PJRT
//! CPU backend using artifacts AOT-compiled from JAX/Pallas; [`cpu`] is
//! the native in-process backend — real f32 kernels plus a depth-first
//! band walker — that measures baseline-vs-depth-first wall-clock with
//! no artifacts at all; [`autotune`] searches the plan space on that
//! backend with real timed runs and persists per-network winners to a
//! profile cache the engine reloads transparently; [`server`] is the
//! batching inference front-end used by the end-to-end example; [`http`]
//! puts that front-end behind a zero-dependency HTTP/1.1 + JSON wire
//! protocol with a closed/open-loop load harness (`bench-serve`);
//! [`fault`] is the seeded fault-injection layer that lets tests and
//! benches storm that stack (worker panics, stalls, socket resets)
//! and prove it degrades instead of dying.
//!
//! [`engine`] is the public facade over all of the above: an
//! [`engine::EngineBuilder`] resolves the network, runs the optimizer,
//! validates the plan, and binds an [`engine::Backend`] (real PJRT
//! execution or artifact-free `memsim` simulation), so callers write
//! `Engine::builder().zoo_small("vgg11_bn", 8).build()?.run(input)`
//! instead of wiring the pipeline by hand. [`analysis`] is the static
//! verification subsystem behind `brainslug check`: graph lint, plan
//! verifier and concurrency-topology lint, every finding carrying a
//! stable `BSL0xx` diagnostic code. [`conc`] extends that from declared
//! shape to observed behavior: a loom-style controlled scheduler
//! model-checks replicas of the real drain/queue/pool protocols and
//! reports violations (BSL050–BSL056) with replayable counterexample
//! schedules. [`obs`] closes the loop on all of it: zero-overhead-
//! when-disabled spans over the depth-first hot path (Chrome-trace
//! export via `brainslug trace`), a Prometheus-style `GET /v1/metrics`
//! registry, and a predicted-vs-measured drift report against the
//! `memsim` cost model.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

// No unsafe anywhere in the crate: the depth-first walkers index with
// checked slices, and concurrency goes through std channels/locks.
#![deny(unsafe_code)]
// Library code must not unwrap lock/channel/option results — poison and
// disconnect are handled or propagated as typed errors. Tests and
// benches are exempt via clippy.toml (`allow-unwrap-in-tests`); the few
// deliberate remaining sites use `expect` with an invariant message.
#![warn(clippy::unwrap_used)]
// Pedantic/restriction selections we actually want (the rest of
// `pedantic` is too noisy for numeric kernel code full of index
// arithmetic and `as` casts; see DESIGN.md §Static Analysis for the
// allow-list rationale):
#![warn(clippy::map_unwrap_or)]
#![warn(clippy::dbg_macro)]
#![warn(clippy::todo)]
#![warn(clippy::unimplemented)]

pub mod analysis;
pub mod autotune;
pub mod bench;
pub mod cli;
pub mod conc;
pub mod cpu;
pub mod device;
pub mod engine;
pub mod fault;
pub mod graph;
pub mod http;
pub mod json;
pub mod memsim;
pub mod obs;
pub mod optimizer;
pub mod rng;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod zoo;
