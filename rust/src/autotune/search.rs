//! Candidate enumeration and the memsim cost-model pre-pass.
//!
//! The tuning space is the cross product of the collapse knobs the
//! measured walker actually responds to:
//!
//! * **budget scale** — [`CollapseOptions::budget_bytes`] at fractions /
//!   multiples of the device preset's `resource_limit()`. Presets derive
//!   budgets from static cache parameters (§4.4); the empirically best
//!   working-set size varies per network topology and machine.
//! * **band-height caps** — [`CollapseOptions::max_tile_rows`] and
//!   `min_tile_rows`: shorter bands cut halo redundancy, taller bands
//!   cut per-band overhead; the sweet spot is plane-size dependent.
//!
//! Measuring the full product on hardware is wasteful, so a *cost-model
//! pre-pass* plans every candidate and ranks it with the `memsim`
//! analytic model ([`crate::memsim::simulate_plan`]) — the same model
//! that regenerates the paper's tables, and sensitive to exactly what
//! the knobs change (sequence splits, band heights, halo factors). Only
//! the top-K predictions (plus the device-preset default, which always
//! survives as the comparison anchor) graduate to timed runs.

use crate::device::DeviceSpec;
use crate::graph::Graph;
use crate::memsim::simulate_plan;
use crate::optimizer::{optimize, CollapseOptions};

use super::profile::describe_opts;
use super::TuneLevel;

/// One point in the collapse-configuration search space.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Human-readable knob description ("default", "budget=… tile<=…").
    pub label: String,
    pub opts: CollapseOptions,
}

impl Candidate {
    /// The device-preset configuration every tuning run is anchored to.
    pub fn default_preset() -> Candidate {
        Candidate {
            label: "default".to_string(),
            opts: CollapseOptions::default(),
        }
    }

    pub fn is_default(&self) -> bool {
        self.opts == CollapseOptions::default()
    }
}

/// Enumerate the candidate collapse configurations for `level` on
/// `device`. Always contains the device-preset default exactly once.
pub fn candidate_space(level: TuneLevel, device: &DeviceSpec) -> Vec<Candidate> {
    let limit = device.resource_limit();
    let budget_scales: &[f64] = match level {
        TuneLevel::Fast => &[0.5, 1.0, 2.0, 4.0],
        TuneLevel::Full => &[0.25, 0.5, 1.0, 2.0, 4.0, 8.0],
    };
    let tile_caps: &[Option<usize>] = match level {
        TuneLevel::Fast => &[None, Some(1), Some(4)],
        TuneLevel::Full => &[None, Some(1), Some(2), Some(4), Some(8), Some(16)],
    };
    let min_rows: &[usize] = match level {
        TuneLevel::Fast => &[1],
        TuneLevel::Full => &[1, 2, 4],
    };
    let mut out = Vec::new();
    for &scale in budget_scales {
        // Scale 1.0 is the preset budget itself: keep `budget_bytes`
        // unset so the candidate is recognizably the default config.
        let budget_bytes = if (scale - 1.0).abs() < 1e-9 {
            None
        } else {
            Some((((limit as f64) * scale).round() as usize).max(1024))
        };
        for &cap in tile_caps {
            for &mn in min_rows {
                if cap.is_some_and(|c| mn > c) {
                    continue; // cap wins anyway; skip the duplicate
                }
                let opts = CollapseOptions {
                    budget_bytes,
                    max_tile_rows: cap,
                    min_tile_rows: mn,
                    ..Default::default()
                };
                out.push(Candidate {
                    label: describe_opts(&opts),
                    opts,
                });
            }
        }
    }
    out
}

/// Plan every candidate and rank by memsim-predicted plan time
/// (ascending). Returns `(candidate, predicted_seconds)` pairs.
pub fn rank_by_cost_model(
    graph: &Graph,
    device: &DeviceSpec,
    candidates: Vec<Candidate>,
) -> Vec<(Candidate, f64)> {
    let mut scored: Vec<(Candidate, f64)> = candidates
        .into_iter()
        .map(|c| {
            let plan = optimize(graph, device, &c.opts);
            let predicted = simulate_plan(graph, &plan, device).total_s;
            (c, predicted)
        })
        .collect();
    scored.sort_by(|a, b| a.1.total_cmp(&b.1));
    scored
}

/// Keep the `top_k` best-predicted candidates, plus the default preset
/// whether or not the model liked it (it anchors the measured
/// comparison and is the fallback when every challenger loses).
pub fn survivors(scored: Vec<(Candidate, f64)>, top_k: usize) -> Vec<(Candidate, f64)> {
    let mut keep: Vec<(Candidate, f64)> = Vec::with_capacity(top_k + 1);
    for (c, s) in &scored {
        if keep.len() >= top_k.max(1) {
            break;
        }
        keep.push((c.clone(), *s));
    }
    if !keep.iter().any(|(c, _)| c.is_default()) {
        if let Some(d) = scored.iter().find(|(c, _)| c.is_default()) {
            keep.push(d.clone());
        }
    }
    keep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench;

    #[test]
    fn space_contains_exactly_one_default() {
        let device = DeviceSpec::host_cpu();
        for level in [TuneLevel::Fast, TuneLevel::Full] {
            let space = candidate_space(level, &device);
            assert!(space.len() >= 8, "{level:?}: space too small");
            let defaults = space.iter().filter(|c| c.is_default()).count();
            assert_eq!(defaults, 1, "{level:?}");
        }
    }

    #[test]
    fn full_space_is_a_superset_scale_of_fast() {
        let device = DeviceSpec::host_cpu();
        assert!(
            candidate_space(TuneLevel::Full, &device).len()
                > candidate_space(TuneLevel::Fast, &device).len()
        );
    }

    #[test]
    fn ranking_is_ascending_and_survivors_keep_default() {
        let g = bench::block_net(3, 1, 4, 24);
        let device = DeviceSpec::host_cpu();
        let scored = rank_by_cost_model(&g, &device, candidate_space(TuneLevel::Fast, &device));
        for w in scored.windows(2) {
            assert!(w[0].1 <= w[1].1, "ranking not ascending");
        }
        for k in [1, 2, 3] {
            let kept = survivors(scored.clone(), k);
            assert!(kept.len() >= k.min(scored.len()));
            assert!(
                kept.iter().any(|(c, _)| c.is_default()),
                "default must always survive (k={k})"
            );
            assert!(kept.len() <= k + 1);
        }
    }

    #[test]
    fn candidates_produce_distinct_plans() {
        // The knobs must actually reach the planner: a tiny budget and
        // the preset budget should disagree on sequence counts for a
        // deep stack.
        let g = bench::block_net(6, 1, 8, 32);
        let device = DeviceSpec::host_cpu();
        let seq_count = |opts: &CollapseOptions| -> usize {
            optimize(&g, &device, opts)
                .stacks()
                .map(|s| s.sequences.len())
                .sum()
        };
        let preset = seq_count(&CollapseOptions::default());
        let starved = seq_count(&CollapseOptions {
            budget_bytes: Some(1024),
            ..Default::default()
        });
        assert!(starved > preset, "budget injection did not reach collapse");
    }
}
