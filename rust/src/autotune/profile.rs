//! The persistent profile cache: tuning pays once, every later run is
//! faster with zero flags.
//!
//! A [`Profile`] records the winning [`CollapseOptions`] (plus the
//! measured evidence) for one *tuning key* — network signature ×
//! device × thread count. The [`ProfileStore`] serializes profiles to a
//! small JSON file (default `~/.brainslug/profiles.json`, see
//! [`ProfileStore::default_path`]); `EngineBuilder` transparently loads
//! it on later `run`/`serve` invocations, so the zero-user-effort
//! transparency promise of the source paper extends to hardware
//! adaptation: nothing about the caller's code changes, the plan just
//! gets the empirically fastest configuration for this machine.
//!
//! Robustness rules (covered by the tests below):
//! * a missing file is an empty store — first `tune` creates it;
//! * a corrupt or wrong-version file degrades to an empty store with a
//!   one-line warning, never a crash (the next `save` repairs it);
//! * a malformed entry is skipped with a warning, healthy entries load;
//! * lookups miss (fall back to the device preset) whenever the
//!   network structure, device, or thread count differs from what was
//!   tuned — the key encodes all three.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::graph::Graph;
use crate::json::{self, Json};
use crate::optimizer::{fnv64_hex, CollapseOptions};

/// Schema version of `profiles.json`. Bump on incompatible change; old
/// files then degrade to "no profiles" rather than misapplying configs.
const VERSION: usize = 1;

/// Structural signature of a network: FNV-1a over the canonical JSON
/// serialization (layer kinds, windows, shapes — batch included — and
/// wiring). Two graphs tune interchangeably iff their signatures match.
pub fn graph_signature(g: &Graph) -> String {
    fnv64_hex(&crate::graph::graph_to_json(g).to_string_compact())
}

/// Cache key: network signature × device name × thread count.
pub fn profile_key(signature: &str, device: &str, threads: usize) -> String {
    format!("{signature}|{device}|t{threads}")
}

/// One tuned configuration with its measured evidence.
#[derive(Debug, Clone, PartialEq)]
pub struct Profile {
    /// Human-readable network name (debugging only; the signature is
    /// what lookups key on).
    pub network: String,
    pub signature: String,
    /// Device preset name the tuning ran against.
    pub device: String,
    pub threads: usize,
    /// The winning collapse configuration.
    pub opts: CollapseOptions,
    /// Measured time of the winner (head-to-head, min-of-N seconds).
    pub tuned_s: f64,
    /// Measured time of the default preset under the same methodology.
    pub default_s: f64,
}

/// Short human-readable description of a collapse configuration
/// relative to the device preset defaults.
pub fn describe_opts(opts: &CollapseOptions) -> String {
    let mut parts = Vec::new();
    if let Some(b) = opts.budget_bytes {
        parts.push(format!("budget={b}B"));
    }
    if let Some(c) = opts.max_tile_rows {
        parts.push(format!("tile<={c}"));
    }
    if opts.min_tile_rows > 1 {
        parts.push(format!("min_rows={}", opts.min_tile_rows));
    }
    if let Some(m) = opts.max_steps_per_sequence {
        parts.push(format!("steps<={m}"));
    }
    if parts.is_empty() {
        "default".to_string()
    } else {
        parts.join(" ")
    }
}

impl Profile {
    pub fn key(&self) -> String {
        profile_key(&self.signature, &self.device, self.threads)
    }

    /// One-line description of the tuned configuration.
    pub fn describe(&self) -> String {
        describe_opts(&self.opts)
    }

    fn to_json(&self) -> Json {
        let opt_usize = |v: Option<usize>| match v {
            Some(n) => Json::from_usize(n),
            None => Json::Null,
        };
        let mut o = Json::object();
        o.set("network", Json::Str(self.network.clone()));
        o.set("signature", Json::Str(self.signature.clone()));
        o.set("device", Json::Str(self.device.clone()));
        o.set("threads", Json::from_usize(self.threads));
        o.set("budget_bytes", opt_usize(self.opts.budget_bytes));
        o.set("max_tile_rows", opt_usize(self.opts.max_tile_rows));
        o.set(
            "max_steps_per_sequence",
            opt_usize(self.opts.max_steps_per_sequence),
        );
        o.set("min_tile_rows", Json::from_usize(self.opts.min_tile_rows));
        o.set("reserved_bytes", Json::from_usize(self.opts.reserved_bytes));
        o.set("tuned_s", Json::Num(self.tuned_s));
        o.set("default_s", Json::Num(self.default_s));
        o
    }

    fn from_json(j: &Json) -> Result<Profile> {
        let opt_usize = |key: &str| -> Result<Option<usize>> {
            match j.req(key)? {
                Json::Null => Ok(None),
                v => Ok(Some(v.as_usize().with_context(|| {
                    format!("field '{key}' not a non-negative integer")
                })?)),
            }
        };
        Ok(Profile {
            network: j.str_field("network")?,
            signature: j.str_field("signature")?,
            device: j.str_field("device")?,
            threads: j.usize_field("threads")?,
            opts: CollapseOptions {
                budget_bytes: opt_usize("budget_bytes")?,
                max_tile_rows: opt_usize("max_tile_rows")?,
                max_steps_per_sequence: opt_usize("max_steps_per_sequence")?,
                min_tile_rows: j.usize_field("min_tile_rows")?,
                reserved_bytes: j.usize_field("reserved_bytes")?,
            },
            tuned_s: j.f64_field("tuned_s")?,
            default_s: j.f64_field("default_s")?,
        })
    }
}

/// In-memory view of `profiles.json`. `Send + Sync` plain data, so a
/// server loads it once and shares it across worker replicas
/// ([`crate::engine::EngineBuilder::preload_profiles`]).
#[derive(Debug, Clone, Default)]
pub struct ProfileStore {
    profiles: BTreeMap<String, Profile>,
}

impl ProfileStore {
    /// Default on-disk location: `$BRAINSLUG_PROFILE_PATH` if set, else
    /// `$HOME/.brainslug/profiles.json` (cwd-relative `.brainslug/`
    /// when no home directory exists).
    pub fn default_path() -> PathBuf {
        if let Some(p) = std::env::var_os("BRAINSLUG_PROFILE_PATH") {
            return PathBuf::from(p);
        }
        let home = std::env::var_os("HOME")
            .map_or_else(|| PathBuf::from("."), PathBuf::from);
        home.join(".brainslug").join("profiles.json")
    }

    /// Load a store from disk. Missing file → empty store (silently:
    /// the first `tune` creates it). Corrupt JSON or wrong schema
    /// version → empty store with a one-line warning, never a crash;
    /// individually malformed entries are skipped the same way.
    pub fn load(path: &Path) -> ProfileStore {
        let mut store = ProfileStore::default();
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(_) => return store,
        };
        let doc = match json::parse(&text) {
            Ok(d) => d,
            Err(e) => {
                eprintln!(
                    "warning: ignoring corrupt profile cache {} ({e}); using device defaults",
                    path.display()
                );
                return store;
            }
        };
        if doc.get("version").and_then(Json::as_usize) != Some(VERSION) {
            eprintln!(
                "warning: profile cache {} has an unknown schema version; using device defaults",
                path.display()
            );
            return store;
        }
        let Some(entries) = doc.get("profiles").and_then(Json::as_obj) else {
            eprintln!(
                "warning: profile cache {} has no 'profiles' object; using device defaults",
                path.display()
            );
            return store;
        };
        for (key, entry) in entries {
            match Profile::from_json(entry) {
                Ok(p) => {
                    store.profiles.insert(p.key(), p);
                }
                Err(e) => {
                    eprintln!(
                        "warning: skipping malformed profile '{key}' in {}: {e}",
                        path.display()
                    );
                }
            }
        }
        store
    }

    /// Persist to disk (creates parent directories). The write goes to
    /// a sibling temp file and is renamed into place, so a concurrent
    /// `load` never observes a truncated/corrupt cache; concurrent
    /// *writers* are last-writer-wins on the whole file (fine for a
    /// per-user tuning cache — re-tuning regenerates lost entries).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut entries = Json::object();
        for p in self.profiles.values() {
            entries.set(&p.key(), p.to_json());
        }
        let mut doc = Json::object();
        doc.set("version", Json::from_usize(VERSION));
        doc.set("profiles", entries);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        std::fs::write(&tmp, doc.to_string_pretty())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} into place", tmp.display()))
    }

    pub fn get(&self, signature: &str, device: &str, threads: usize) -> Option<&Profile> {
        self.profiles.get(&profile_key(signature, device, threads))
    }

    /// Insert (or replace) the profile under its own key.
    pub fn insert(&mut self, profile: Profile) {
        self.profiles.insert(profile.key(), profile);
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(name: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("brainslug_test_{}_{name}", std::process::id()))
            .join("profiles.json")
    }

    fn sample_profile() -> Profile {
        Profile {
            network: "vgg16".into(),
            signature: "abc123".into(),
            device: "host-cpu".into(),
            threads: 2,
            opts: CollapseOptions {
                budget_bytes: Some(65536),
                max_tile_rows: Some(4),
                ..Default::default()
            },
            tuned_s: 1.0e-3,
            default_s: 2.0e-3,
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let path = tmp_path("roundtrip");
        let p = sample_profile();
        let mut store = ProfileStore::default();
        store.insert(p.clone());
        store.save(&path).unwrap();
        let loaded = ProfileStore::load(&path);
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded.get("abc123", "host-cpu", 2), Some(&p));
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn key_mismatch_on_device_or_threads_misses() {
        let path = tmp_path("mismatch");
        let mut store = ProfileStore::default();
        store.insert(sample_profile());
        store.save(&path).unwrap();
        let loaded = ProfileStore::load(&path);
        // Same signature, different thread count: miss.
        assert!(loaded.get("abc123", "host-cpu", 1).is_none());
        // Same signature, different device: miss.
        assert!(loaded.get("abc123", "tpu-core", 2).is_none());
        // Different network structure: miss.
        assert!(loaded.get("zzz", "host-cpu", 2).is_none());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn corrupt_json_falls_back_to_empty_and_save_repairs() {
        let path = tmp_path("corrupt");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, "{ this is not json").unwrap();
        let store = ProfileStore::load(&path);
        assert!(store.is_empty(), "corrupt cache must degrade to defaults");
        // Saving over the corrupt file repairs it.
        let mut fresh = ProfileStore::default();
        fresh.insert(sample_profile());
        fresh.save(&path).unwrap();
        assert_eq!(ProfileStore::load(&path).len(), 1);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn wrong_version_and_malformed_entries_are_skipped() {
        let path = tmp_path("version");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, r#"{"version": 99, "profiles": {}}"#).unwrap();
        assert!(ProfileStore::load(&path).is_empty());
        // One healthy entry + one malformed entry: the healthy one loads.
        let mut store = ProfileStore::default();
        store.insert(sample_profile());
        store.save(&path).unwrap();
        let mut doc = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        if let Json::Obj(m) = &mut doc {
            if let Some(Json::Obj(entries)) = m.get_mut("profiles") {
                entries.insert("bad".into(), Json::Str("nope".into()));
            }
        }
        std::fs::write(&path, doc.to_string_pretty()).unwrap();
        let loaded = ProfileStore::load(&path);
        assert_eq!(loaded.len(), 1);
        assert!(loaded.get("abc123", "host-cpu", 2).is_some());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn missing_file_is_an_empty_store() {
        let store = ProfileStore::load(Path::new("/nonexistent/brainslug/profiles.json"));
        assert!(store.is_empty());
    }

    #[test]
    fn graph_signature_tracks_structure_and_batch() {
        let a = crate::bench::block_net(2, 1, 4, 16);
        let same = crate::bench::block_net(2, 1, 4, 16);
        let deeper = crate::bench::block_net(3, 1, 4, 16);
        let bigger_batch = crate::bench::block_net(2, 2, 4, 16);
        assert_eq!(graph_signature(&a), graph_signature(&same));
        assert_ne!(graph_signature(&a), graph_signature(&deeper));
        assert_ne!(graph_signature(&a), graph_signature(&bigger_batch));
    }

    #[test]
    fn describe_opts_is_compact() {
        assert_eq!(describe_opts(&CollapseOptions::default()), "default");
        let tuned = CollapseOptions {
            budget_bytes: Some(32768),
            max_tile_rows: Some(8),
            ..Default::default()
        };
        assert_eq!(describe_opts(&tuned), "budget=32768B tile<=8");
    }
}
