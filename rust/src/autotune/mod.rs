//! The autotuning subsystem: search the plan space on real hardware,
//! persist per-network profiles.
//!
//! The paper's hardware-adaptation story (§4.4) derives collapse
//! budgets from *static* device parameters. With a native backend that
//! really executes ([`crate::cpu`]) we can do what the paper could not:
//! **measure** each candidate plan and pick the empirically fastest
//! one — the framework-level tuning dimension highlighted by Wang et
//! al. (arXiv:1908.04705) — while keeping the zero-user-effort
//! transparency promise: tuning pays once, the winner persists to a
//! profile cache that [`crate::engine::EngineBuilder`] loads
//! automatically on every later `run`/`serve`.
//!
//! ## Pipeline
//!
//! ```text
//!  candidate_space(level)          12–90 collapse configs (budget ×
//!        │                         band-height caps), TuneLevel-sized
//!        ▼
//!  rank_by_cost_model()            memsim pre-pass: plan every config,
//!        │  keep top-K + default   predict its time, prune the rest
//!        ▼
//!  timed runs on CpuBackend        warmup + median-of-N per candidate
//!        │  early-exit pruning     × thread count; a first run slower
//!        ▼                         than 1.5× the incumbent is dropped
//!  head-to-head + parity           winner vs default re-measured
//!        │                         interleaved (min-of-N); baseline
//!        ▼                         parity asserted on the winner
//!  Profile → ProfileStore          keyed signature × device × threads
//! ```
//!
//! The default preset is always fully measured and wins ties, so
//! `tuned_s <= default_s` holds for every [`ThreadResult`] by
//! construction — tuning can only help, never silently regress.

pub mod profile;
pub mod search;

pub use profile::{describe_opts, graph_signature, profile_key, Profile, ProfileStore};
pub use search::{candidate_space, rank_by_cost_model, survivors, Candidate};

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Result};

use crate::device::DeviceSpec;
use crate::engine::Engine;
use crate::graph::Graph;
use crate::optimizer::CollapseOptions;
use crate::runtime::HostTensor;

/// How hard to search: `Fast` for CI smokes and transparent first-run
/// tuning, `Full` for overnight profiling of a serving fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TuneLevel {
    Fast,
    Full,
}

impl TuneLevel {
    /// Parse a CLI level name (`brainslug tune --budget fast|full`).
    pub fn parse(name: &str) -> Result<TuneLevel> {
        match name {
            "fast" => Ok(TuneLevel::Fast),
            "full" => Ok(TuneLevel::Full),
            other => bail!("unknown tune budget '{other}' (fast|full)"),
        }
    }

    /// Candidates that graduate from the cost-model pre-pass.
    pub fn top_k(self) -> usize {
        match self {
            TuneLevel::Fast => 4,
            TuneLevel::Full => 8,
        }
    }

    /// Timed repetitions per measured candidate (median taken).
    pub fn iters(self) -> usize {
        match self {
            TuneLevel::Fast => 3,
            TuneLevel::Full => 5,
        }
    }
}

/// A candidate's first timed run must stay within this factor of the
/// incumbent best or the remaining repetitions are skipped.
const EARLY_EXIT_FACTOR: f64 = 1.5;

/// One measured point of the tuning run (for reports and benches).
#[derive(Debug, Clone)]
pub struct MeasuredCandidate {
    pub label: String,
    pub opts: CollapseOptions,
    pub threads: usize,
    /// memsim cost-model prediction (the pre-pass ranking key).
    pub predicted_s: f64,
    /// Median of the timed runs — or the single probe run when pruned.
    pub measured_s: f64,
    /// True when early-exit pruning skipped the remaining repetitions.
    pub pruned: bool,
}

/// The tuning verdict for one thread count.
#[derive(Debug, Clone)]
pub struct ThreadResult {
    pub threads: usize,
    /// Winning configuration (the default preset when nothing beat it).
    pub winner: Candidate,
    /// Head-to-head measured time of the default preset (seconds).
    pub default_s: f64,
    /// Head-to-head measured time of the winner; `<= default_s` by
    /// construction (the default wins ties and lost re-matches).
    pub tuned_s: f64,
    /// The persistable record of this verdict.
    pub profile: Profile,
}

impl ThreadResult {
    /// Measured improvement over the default preset, in the paper's
    /// speed-up convention (`>= 0`).
    pub fn gain_pct(&self) -> f64 {
        crate::memsim::speedup_pct(self.default_s, self.tuned_s)
    }
}

/// Everything a tuning run learned.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    pub network: String,
    pub signature: String,
    pub device: String,
    /// Size of the full candidate space before the cost-model pre-pass.
    pub candidates_total: usize,
    /// Candidates that survived the pre-pass (measured per thread).
    pub candidates_measured: usize,
    pub measured: Vec<MeasuredCandidate>,
    pub per_thread: Vec<ThreadResult>,
}

impl TuneOutcome {
    /// The thread result with the largest measured gain.
    pub fn best(&self) -> &ThreadResult {
        self.per_thread
            .iter()
            .max_by(|a, b| a.gain_pct().total_cmp(&b.gain_pct()))
            .expect("tune() always yields at least one thread result")
    }
}

/// Thread counts a no-flag `brainslug tune` sweeps: powers of two up to
/// the host's parallelism (capped at 8), plus the exact core count.
pub fn default_thread_sweep() -> Vec<usize> {
    let cores = std::thread::available_parallelism()
        .map_or(1, |n| n.get())
        .min(8);
    let mut v: Vec<usize> = [1usize, 2, 4, 8]
        .into_iter()
        .filter(|&t| t <= cores)
        .collect();
    if !v.contains(&cores) {
        v.push(cores);
    }
    v
}

fn cpu_engine(
    graph: &Arc<Graph>,
    device: &DeviceSpec,
    seed: u64,
    opts: CollapseOptions,
    threads: usize,
) -> Result<Engine> {
    // `no_profile` matters: the default-preset candidate must measure
    // the *actual* preset, not a previously tuned profile.
    Engine::builder()
        .graph(graph.clone())
        .device(device.clone())
        .brainslug(opts)
        .cpu(threads)
        .no_profile()
        .seed(seed)
        .build()
}

/// Warmup-free timed loop (callers warm up first): `iters` runs, median
/// returned. When `early_exit_above` is set and the first run exceeds
/// it, the remaining runs are skipped and `(first_run, true)` returns.
fn timed_median(
    engine: &mut Engine,
    input: &HostTensor,
    iters: usize,
    early_exit_above: Option<f64>,
) -> Result<(f64, bool)> {
    let iters = iters.max(1);
    let mut ts = Vec::with_capacity(iters);
    for i in 0..iters {
        let t0 = Instant::now();
        engine.run(input.clone())?;
        ts.push(t0.elapsed().as_secs_f64());
        if i == 0 {
            if let Some(limit) = early_exit_above {
                if ts[0] > limit {
                    return Ok((ts[0], true));
                }
            }
        }
    }
    ts.sort_by(f64::total_cmp);
    Ok((ts[ts.len() / 2], false))
}

/// Final verdict for one thread count: re-measure the challenger against
/// the default preset *interleaved* (min-of-N per side, robust to
/// machine drift during the candidate sweep). A challenger that loses
/// the re-match is discarded — the default preset is the winner and
/// `tuned_s == default_s`, so tuning never regresses.
fn head_to_head(
    graph: &Arc<Graph>,
    device: &DeviceSpec,
    seed: u64,
    threads: usize,
    challenger: &Candidate,
    level: TuneLevel,
) -> Result<(f64, f64, Candidate)> {
    let mut de = cpu_engine(graph, device, seed, CollapseOptions::default(), threads)?;
    let mut ce = cpu_engine(graph, device, seed, challenger.opts, threads)?;
    let input = de.synthetic_input();
    de.run(input.clone())?;
    ce.run(input.clone())?;
    let rounds = level.iters().max(3);
    let (mut d_best, mut c_best) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..rounds {
        let t0 = Instant::now();
        de.run(input.clone())?;
        d_best = d_best.min(t0.elapsed().as_secs_f64());
        let t0 = Instant::now();
        ce.run(input.clone())?;
        c_best = c_best.min(t0.elapsed().as_secs_f64());
    }
    if c_best < d_best {
        Ok((d_best, c_best, challenger.clone()))
    } else {
        Ok((d_best, d_best, Candidate::default_preset()))
    }
}

/// The winning schedule must stay numerically transparent: baseline
/// breadth-first vs the tuned depth-first plan, `allclose` at the same
/// tolerance `brainslug run` enforces.
fn check_parity(
    graph: &Arc<Graph>,
    device: &DeviceSpec,
    seed: u64,
    threads: usize,
    opts: CollapseOptions,
) -> Result<()> {
    let mut engine = cpu_engine(graph, device, seed, opts, threads)?;
    let input = engine.synthetic_input();
    let (base, _) = engine.run_baseline(input.clone())?;
    let (df, _) = engine.run(input)?;
    ensure!(
        base.allclose(&df, 1e-4, 1e-4),
        "autotune: winning config breaks parity with the baseline schedule \
         (max |diff| = {:.3e})",
        base.max_abs_diff(&df)
    );
    Ok(())
}

/// Tune `graph` on `device` for each thread count in `threads`:
/// cost-model pre-pass, timed runs on the native CPU backend, and a
/// parity-checked head-to-head verdict per thread count. See the
/// module docs for the full pipeline.
pub fn tune(
    graph: &Arc<Graph>,
    device: &DeviceSpec,
    seed: u64,
    level: TuneLevel,
    threads: &[usize],
) -> Result<TuneOutcome> {
    ensure!(!threads.is_empty(), "autotune: empty thread-count list");
    for &t in threads {
        ensure!(t >= 1, "autotune: thread counts must be >= 1 (got {t})");
    }
    graph
        .validate()
        .map_err(|e| anyhow!("autotune: invalid graph '{}': {e}", graph.name))?;

    let space = candidate_space(level, device);
    let candidates_total = space.len();
    let ranked = rank_by_cost_model(graph, device, space);
    let short_list = survivors(ranked, level.top_k());
    let candidates_measured = short_list.len();

    let nt = threads.len();
    let mut measured: Vec<MeasuredCandidate> = Vec::new();
    // Per-thread incumbents: (median_seconds, short_list index).
    let mut best: Vec<Option<(f64, usize)>> = vec![None; nt];
    let mut default_median: Vec<Option<f64>> = vec![None; nt];

    for (si, (cand, predicted_s)) in short_list.iter().enumerate() {
        // One engine per collapse config; `set_threads` sweeps the
        // thread dimension without rebuilding the parameter caches.
        let mut engine = cpu_engine(graph, device, seed, cand.opts, threads[0])?;
        let input = engine.synthetic_input();
        for (ti, &t) in threads.iter().enumerate() {
            ensure!(
                engine.set_threads(t),
                "autotune: backend '{}' has no thread knob",
                engine.backend_name()
            );
            engine.run(input.clone())?; // warmup at this thread count
            let limit = if cand.is_default() {
                None // the anchor is always fully measured
            } else {
                best[ti].map(|(b, _)| b * EARLY_EXIT_FACTOR)
            };
            let (t_med, pruned) = timed_median(&mut engine, &input, level.iters(), limit)?;
            if !pruned && best[ti].is_none_or(|(b, _)| t_med < b) {
                best[ti] = Some((t_med, si));
            }
            if cand.is_default() {
                default_median[ti] = Some(t_med);
            }
            measured.push(MeasuredCandidate {
                label: cand.label.clone(),
                opts: cand.opts,
                threads: t,
                predicted_s: *predicted_s,
                measured_s: t_med,
                pruned,
            });
        }
    }

    let signature = graph_signature(graph);
    let mut per_thread = Vec::with_capacity(nt);
    // Parity is determined by the collapse options, not the thread
    // count (band geometry is thread-invariant), so verify each
    // distinct winning config once instead of once per thread result.
    let mut parity_checked: Vec<CollapseOptions> = Vec::new();
    for (ti, &t) in threads.iter().enumerate() {
        let (sweep_best_s, bi) = best[ti].expect("first candidate is never pruned");
        let d_med = default_median[ti].expect("the default preset is always measured");
        let sweep_winner = short_list[bi].0.clone();
        let (default_s, tuned_s, winner) = if sweep_winner.is_default() {
            (d_med, sweep_best_s, sweep_winner)
        } else {
            head_to_head(graph, device, seed, t, &sweep_winner, level)?
        };
        if !parity_checked.contains(&winner.opts) {
            check_parity(graph, device, seed, t, winner.opts)?;
            parity_checked.push(winner.opts);
        }
        let profile = Profile {
            network: graph.name.clone(),
            signature: signature.clone(),
            device: device.name.clone(),
            threads: t,
            opts: winner.opts,
            tuned_s,
            default_s,
        };
        per_thread.push(ThreadResult {
            threads: t,
            winner,
            default_s,
            tuned_s,
            profile,
        });
    }

    Ok(TuneOutcome {
        network: graph.name.clone(),
        signature,
        device: device.name.clone(),
        candidates_total,
        candidates_measured,
        measured,
        per_thread,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench;

    #[test]
    fn tune_level_parses() {
        assert_eq!(TuneLevel::parse("fast").unwrap(), TuneLevel::Fast);
        assert_eq!(TuneLevel::parse("full").unwrap(), TuneLevel::Full);
        assert!(TuneLevel::parse("overnight").is_err());
    }

    #[test]
    fn default_thread_sweep_is_sane() {
        let sweep = default_thread_sweep();
        assert!(!sweep.is_empty());
        assert_eq!(sweep[0], 1);
        for t in &sweep {
            assert!(*t >= 1 && *t <= 8);
        }
    }

    #[test]
    fn tune_rejects_bad_thread_lists() {
        let g = Arc::new(bench::block_net(1, 1, 2, 8));
        let device = DeviceSpec::host_cpu();
        assert!(tune(&g, &device, 1, TuneLevel::Fast, &[]).is_err());
        assert!(tune(&g, &device, 1, TuneLevel::Fast, &[0]).is_err());
    }

    #[test]
    fn tune_block_net_end_to_end() {
        // A tiny fully-optimizable net through the whole pipeline:
        // pre-pass, timed sweep, head-to-head, parity.
        let g = Arc::new(bench::block_net(2, 1, 2, 12));
        let device = DeviceSpec::host_cpu();
        let outcome = tune(&g, &device, 7, TuneLevel::Fast, &[1]).unwrap();
        assert_eq!(outcome.per_thread.len(), 1);
        assert!(outcome.candidates_measured <= outcome.candidates_total);
        let tr = &outcome.per_thread[0];
        assert!(tr.tuned_s > 0.0 && tr.default_s > 0.0);
        assert!(
            tr.tuned_s <= tr.default_s,
            "tuning regressed: {} > {}",
            tr.tuned_s,
            tr.default_s
        );
        assert!(tr.gain_pct() >= 0.0);
        // The default anchor is always fully measured (never pruned).
        assert!(outcome
            .measured
            .iter()
            .any(|m| m.opts == CollapseOptions::default() && !m.pruned));
        // The persistable profile matches the verdict.
        assert_eq!(tr.profile.threads, 1);
        assert_eq!(tr.profile.opts, tr.winner.opts);
        assert_eq!(tr.profile.signature, outcome.signature);
    }

    #[test]
    fn tune_sweeps_multiple_thread_counts() {
        let g = Arc::new(bench::block_net(1, 1, 2, 10));
        let device = DeviceSpec::host_cpu();
        let outcome = tune(&g, &device, 3, TuneLevel::Fast, &[1, 2]).unwrap();
        assert_eq!(outcome.per_thread.len(), 2);
        assert_eq!(outcome.per_thread[0].threads, 1);
        assert_eq!(outcome.per_thread[1].threads, 2);
        // Every measured point carries a positive time.
        for m in &outcome.measured {
            assert!(m.measured_s > 0.0 && m.predicted_s > 0.0);
        }
        // best() picks one of the thread results.
        let best = outcome.best();
        assert!(outcome.per_thread.iter().any(|t| t.threads == best.threads));
    }
}
