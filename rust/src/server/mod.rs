//! Batching inference server — the L3 coordination front-end used by the
//! end-to-end example.
//!
//! Executables are AOT-compiled for a fixed batch size `B`, so the
//! batcher gathers up to `B` single-image requests (or closes a batch
//! after `max_wait`), pads the batch with zeros, runs the scheduler once,
//! and scatters the per-image outputs back to the callers. This is the
//! standard fixed-shape dynamic-batching pattern (vLLM-style routers do
//! the same against compiled engines).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::graph::{Graph, Shape};
use crate::optimizer::Plan;
use crate::runtime::{HostTensor, Runtime};
use crate::scheduler::Executor;

/// One inference request: a single image (batch dim 1) and a reply
/// channel.
struct Request {
    image: Vec<f32>,
    reply: Sender<HostTensor>,
    enqueued: Instant,
}

/// Channel message: a request, or an explicit shutdown signal (cloned
/// handles may outlive the server, so channel-closure alone cannot end
/// the loop).
enum Msg {
    Infer(Request),
    Shutdown,
}

/// Server statistics.
#[derive(Debug, Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Sum of per-request latency in microseconds.
    pub latency_us_sum: AtomicU64,
}

impl ServerStats {
    pub fn mean_latency_ms(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_us_sum.load(Ordering::Relaxed) as f64 / n as f64 / 1000.0
    }

    pub fn occupancy(&self, batch: usize) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        let total_slots = b * batch as u64;
        1.0 - self.padded_slots.load(Ordering::Relaxed) as f64 / total_slots as f64
    }
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Msg>,
    image_shape: Shape,
}

impl ServerHandle {
    /// Submit one image; blocks until the result is available.
    pub fn infer(&self, image: Vec<f32>) -> Result<HostTensor> {
        anyhow::ensure!(
            image.len() == self.image_shape.numel(),
            "image has {} elements, expected {}",
            image.len(),
            self.image_shape.numel()
        );
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Infer(Request {
                image,
                reply: tx,
                enqueued: Instant::now(),
            }))
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx.recv()?)
    }

    pub fn image_shape(&self) -> &Shape {
        &self.image_shape
    }
}

/// The batching server. Owns the scheduler thread.
pub struct Server {
    handle: ServerHandle,
    pub stats: Arc<ServerStats>,
    join: Option<std::thread::JoinHandle<()>>,
    shutdown: Sender<Msg>,
}

impl Server {
    /// Start a server over `graph` (whose batch dim is the compiled batch
    /// size). `plan = None` serves breadth-first; `Some` serves the
    /// BrainSlug plan.
    ///
    /// The PJRT runtime is `!Send` (Rc-based internals), so it is created
    /// *inside* the scheduler thread from `artifact_dir`; startup errors
    /// are reported through the returned `Result`.
    pub fn start(
        artifact_dir: PathBuf,
        graph: Arc<Graph>,
        plan: Option<Arc<Plan>>,
        seed: u64,
        max_wait: Duration,
    ) -> Result<Server> {
        let (tx, rx) = channel::<Msg>();
        let stats = Arc::new(ServerStats::default());
        let image_shape = {
            let mut dims = graph.input_shape().dims.clone();
            dims[0] = 1;
            Shape::new(dims, graph.input_shape().dtype)
        };
        let handle = ServerHandle {
            tx: tx.clone(),
            image_shape: image_shape.clone(),
        };
        let stats2 = stats.clone();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::spawn(move || {
            let runtime = match Runtime::new(&artifact_dir) {
                Ok(r) => {
                    let _ = ready_tx.send(Ok(()));
                    r
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            batch_loop(runtime, graph, plan, seed, rx, stats2, max_wait);
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server thread died during startup"))??;
        Ok(Server {
            handle,
            stats,
            join: Some(join),
            shutdown: tx,
        })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Stop the server and join the scheduler thread. Cloned handles
    /// become inert (their sends fail) once the loop exits.
    pub fn stop(mut self) {
        let _ = self.shutdown.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn batch_loop(
    runtime: Runtime,
    graph: Arc<Graph>,
    plan: Option<Arc<Plan>>,
    seed: u64,
    rx: Receiver<Msg>,
    stats: Arc<ServerStats>,
    max_wait: Duration,
) {
    let batch = graph.input_shape().batch();
    let image_elems = graph.input_shape().numel() / batch;
    let mut executor = Executor::new(&runtime, &graph, seed);
    // Collect-until-full-or-timeout loop.
    loop {
        let first = match rx.recv() {
            Ok(Msg::Infer(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => return,
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + max_wait;
        let mut shutdown_after = false;
        while pending.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Infer(r)) => pending.push(r),
                Ok(Msg::Shutdown) => {
                    shutdown_after = true;
                    break;
                }
                Err(_) => break,
            }
        }
        // Assemble the padded batch tensor.
        let mut data = vec![0.0f32; graph.input_shape().numel()];
        for (i, r) in pending.iter().enumerate() {
            data[i * image_elems..(i + 1) * image_elems].copy_from_slice(&r.image);
        }
        let input = HostTensor::new(graph.input_shape().clone(), data);
        let result = match &plan {
            Some(p) => executor.run_plan(p, input),
            None => executor.run_baseline(input),
        };
        let (out, _stats) = match result {
            Ok(v) => v,
            Err(e) => {
                log::error!("batch execution failed: {e:#}");
                if shutdown_after {
                    return;
                }
                continue; // reply channels drop → callers see an error
            }
        };
        let out_elems = out.shape.numel() / batch;
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats
            .padded_slots
            .fetch_add((batch - pending.len()) as u64, Ordering::Relaxed);
        let mut out_dims = out.shape.dims.clone();
        out_dims[0] = 1;
        for (i, r) in pending.iter().enumerate() {
            let slice = out.data[i * out_elems..(i + 1) * out_elems].to_vec();
            let t = HostTensor::new(Shape::new(out_dims.clone(), out.shape.dtype), slice);
            stats.requests.fetch_add(1, Ordering::Relaxed);
            stats.latency_us_sum.fetch_add(
                r.enqueued.elapsed().as_micros() as u64,
                Ordering::Relaxed,
            );
            let _ = r.reply.send(t);
        }
        if shutdown_after {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = ServerStats::default();
        s.requests.store(4, Ordering::Relaxed);
        s.latency_us_sum.store(8000, Ordering::Relaxed);
        s.batches.store(2, Ordering::Relaxed);
        s.padded_slots.store(4, Ordering::Relaxed);
        assert!((s.mean_latency_ms() - 2.0).abs() < 1e-9);
        assert!((s.occupancy(4) - 0.5).abs() < 1e-9);
    }
}
