//! Batching inference server — the L3 coordination front-end used by the
//! end-to-end example.
//!
//! Executables are AOT-compiled for a fixed batch size `B`, so the
//! batcher gathers up to `B` single-image requests (or closes a batch
//! after `max_wait`), pads the batch with zeros, runs the engine once,
//! and scatters the per-image outputs back to the callers. This is the
//! standard fixed-shape dynamic-batching pattern (vLLM-style routers do
//! the same against compiled engines).
//!
//! The server is configured with a [`ServerConfig`] wrapping an
//! [`EngineBuilder`]: the engine (and its non-`Send` PJRT runtime) is
//! built *inside* the scheduler thread, so the same config drives real
//! PJRT serving and artifact-free [`SimBackend`](crate::engine::SimBackend)
//! serving — which is how the batching logic gets integration-tested
//! below without any artifacts directory.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::{Engine, EngineBuilder};
use crate::graph::Shape;
use crate::runtime::HostTensor;

/// One inference request: a single image (batch dim 1) and a reply
/// channel.
struct Request {
    image: Vec<f32>,
    reply: Sender<HostTensor>,
    enqueued: Instant,
}

/// Channel message: a request, or an explicit shutdown signal (cloned
/// handles may outlive the server, so channel-closure alone cannot end
/// the loop).
enum Msg {
    Infer(Request),
    Shutdown,
}

/// Server statistics.
#[derive(Debug, Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Sum of per-request latency in microseconds.
    pub latency_us_sum: AtomicU64,
}

impl ServerStats {
    /// Mean per-request latency; `0.0` (never NaN) before any request
    /// completes.
    pub fn mean_latency_ms(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_us_sum.load(Ordering::Relaxed) as f64 / n as f64 / 1000.0
    }

    /// Fraction of batch slots that carried real requests; `0.0` (never
    /// NaN) before any batch ran or for a degenerate `batch` of zero.
    pub fn occupancy(&self, batch: usize) -> f64 {
        let total_slots = self.batches.load(Ordering::Relaxed) * batch as u64;
        if total_slots == 0 {
            return 0.0;
        }
        1.0 - self.padded_slots.load(Ordering::Relaxed) as f64 / total_slots as f64
    }
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Msg>,
    image_shape: Shape,
}

impl ServerHandle {
    /// Submit one image; blocks until the result is available.
    pub fn infer(&self, image: Vec<f32>) -> Result<HostTensor> {
        anyhow::ensure!(
            image.len() == self.image_shape.numel(),
            "image has {} elements, expected {}",
            image.len(),
            self.image_shape.numel()
        );
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Infer(Request {
                image,
                reply: tx,
                enqueued: Instant::now(),
            }))
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx.recv()?)
    }

    pub fn image_shape(&self) -> &Shape {
        &self.image_shape
    }
}

/// Configuration for [`Server::start`]: which engine to serve and how
/// the batcher behaves.
pub struct ServerConfig {
    engine: EngineBuilder,
    max_wait: Duration,
}

impl ServerConfig {
    /// Serve the network described by `engine`. The builder's graph
    /// batch dimension is the compiled batch size `B`; its mode decides
    /// baseline vs BrainSlug serving; its backend decides PJRT vs sim.
    pub fn new(engine: EngineBuilder) -> Self {
        ServerConfig {
            engine,
            max_wait: Duration::from_millis(5),
        }
    }

    /// Maximum time the batcher waits to fill a batch before closing it
    /// partially (default 5 ms).
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.max_wait = max_wait;
        self
    }

    /// Start the server (see [`Server::start`]).
    pub fn start(self) -> Result<Server> {
        Server::start(self)
    }
}

/// The batching server. Owns the scheduler thread.
pub struct Server {
    handle: ServerHandle,
    pub stats: Arc<ServerStats>,
    /// Compiled batch size `B` of the served network.
    batch: usize,
    join: Option<std::thread::JoinHandle<()>>,
    shutdown: Sender<Msg>,
}

impl Server {
    /// Start a server from `config`.
    ///
    /// PJRT engines are `!Send` (Rc-based internals), so the engine is
    /// built *inside* the scheduler thread from the (Send) builder;
    /// build errors are reported through the returned `Result`.
    pub fn start(config: ServerConfig) -> Result<Server> {
        let ServerConfig { engine, max_wait } = config;
        let (tx, rx) = channel::<Msg>();
        let stats = Arc::new(ServerStats::default());
        let stats2 = stats.clone();
        let (ready_tx, ready_rx) = channel::<Result<Shape>>();
        let join = std::thread::spawn(move || {
            let mut engine = match engine.build() {
                Ok(e) => e,
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let input_shape = engine.graph().input_shape().clone();
            let _ = ready_tx.send(Ok(input_shape));
            batch_loop(&mut engine, rx, stats2, max_wait);
        });
        let input_shape = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server thread died during startup"))??;
        let batch = input_shape.batch();
        let mut dims = input_shape.dims.clone();
        dims[0] = 1;
        let handle = ServerHandle {
            tx: tx.clone(),
            image_shape: Shape::new(dims, input_shape.dtype),
        };
        Ok(Server {
            handle,
            stats,
            batch,
            join: Some(join),
            shutdown: tx,
        })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Compiled batch size `B` of the served network.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Batch occupancy over the server's own batch size.
    pub fn occupancy(&self) -> f64 {
        self.stats.occupancy(self.batch)
    }

    /// Stop the server and join the scheduler thread. Cloned handles
    /// become inert (their sends fail) once the loop exits.
    pub fn stop(mut self) {
        let _ = self.shutdown.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn batch_loop(
    engine: &mut Engine,
    rx: Receiver<Msg>,
    stats: Arc<ServerStats>,
    max_wait: Duration,
) {
    let in_shape = engine.graph().input_shape().clone();
    let batch = in_shape.batch();
    let image_elems = in_shape.numel() / batch;
    // Collect-until-full-or-timeout loop.
    loop {
        let first = match rx.recv() {
            Ok(Msg::Infer(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => return,
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + max_wait;
        let mut shutdown_after = false;
        while pending.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Infer(r)) => pending.push(r),
                Ok(Msg::Shutdown) => {
                    shutdown_after = true;
                    break;
                }
                Err(_) => break,
            }
        }
        // Assemble the padded batch tensor.
        let mut data = vec![0.0f32; in_shape.numel()];
        for (i, r) in pending.iter().enumerate() {
            data[i * image_elems..(i + 1) * image_elems].copy_from_slice(&r.image);
        }
        let input = HostTensor::new(in_shape.clone(), data);
        let (out, _stats) = match engine.run(input) {
            Ok(v) => v,
            Err(e) => {
                log::error!("batch execution failed: {e:#}");
                if shutdown_after {
                    return;
                }
                continue; // reply channels drop → callers see an error
            }
        };
        let out_elems = out.shape.numel() / batch;
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats
            .padded_slots
            .fetch_add((batch - pending.len()) as u64, Ordering::Relaxed);
        let mut out_dims = out.shape.dims.clone();
        out_dims[0] = 1;
        for (i, r) in pending.iter().enumerate() {
            let slice = out.data[i * out_elems..(i + 1) * out_elems].to_vec();
            let t = HostTensor::new(Shape::new(out_dims.clone(), out.shape.dtype), slice);
            stats.requests.fetch_add(1, Ordering::Relaxed);
            stats.latency_us_sum.fetch_add(
                r.enqueued.elapsed().as_micros() as u64,
                Ordering::Relaxed,
            );
            let _ = r.reply.send(t);
        }
        if shutdown_after {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench;
    use crate::device::DeviceSpec;
    use crate::engine::Engine;
    use crate::optimizer::CollapseOptions;

    #[test]
    fn stats_math() {
        let s = ServerStats::default();
        s.requests.store(4, Ordering::Relaxed);
        s.latency_us_sum.store(8000, Ordering::Relaxed);
        s.batches.store(2, Ordering::Relaxed);
        s.padded_slots.store(4, Ordering::Relaxed);
        assert!((s.mean_latency_ms() - 2.0).abs() < 1e-9);
        assert!((s.occupancy(4) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn stats_empty_server_is_nan_free() {
        let s = ServerStats::default();
        assert_eq!(s.mean_latency_ms(), 0.0);
        assert_eq!(s.occupancy(4), 0.0);
        // Degenerate batch size must not divide by zero either.
        assert_eq!(s.occupancy(0), 0.0);
        assert!(s.mean_latency_ms().is_finite());
        assert!(s.occupancy(0).is_finite());
    }

    /// A sim-backed server over a tiny block network with batch `b`.
    fn sim_server(b: usize, max_wait: Duration) -> Server {
        let engine = Engine::builder()
            .graph_owned(bench::block_net(1, b, 2, 8))
            .device(DeviceSpec::tpu_core())
            .brainslug(CollapseOptions::default())
            .sim()
            .seed(11);
        ServerConfig::new(engine).max_wait(max_wait).start().unwrap()
    }

    fn spawn_requests(server: &Server, n: usize) -> Vec<std::thread::JoinHandle<Result<HostTensor>>> {
        let elems = server.handle().image_shape().numel();
        (0..n)
            .map(|i| {
                let h = server.handle();
                std::thread::spawn(move || h.infer(vec![i as f32; elems]))
            })
            .collect()
    }

    #[test]
    fn sim_batching_fills_to_capacity() {
        let server = sim_server(4, Duration::from_secs(10));
        let workers = spawn_requests(&server, 4);
        for w in workers {
            let out = w.join().unwrap().unwrap();
            assert_eq!(out.shape.batch(), 1);
        }
        assert_eq!(server.stats.requests.load(Ordering::Relaxed), 4);
        assert_eq!(server.stats.batches.load(Ordering::Relaxed), 1);
        assert_eq!(server.stats.padded_slots.load(Ordering::Relaxed), 0);
        assert!((server.occupancy() - 1.0).abs() < 1e-9);
        assert!(server.stats.mean_latency_ms().is_finite());
        server.stop();
    }

    #[test]
    fn sim_timeout_closes_partial_batch() {
        let server = sim_server(4, Duration::from_millis(30));
        let out = server.handle().infer(vec![1.0; server.handle().image_shape().numel()]);
        assert!(out.is_ok());
        assert_eq!(server.stats.requests.load(Ordering::Relaxed), 1);
        assert_eq!(server.stats.batches.load(Ordering::Relaxed), 1);
        // Three of four slots were zero-padding.
        assert_eq!(server.stats.padded_slots.load(Ordering::Relaxed), 3);
        assert!((server.occupancy() - 0.25).abs() < 1e-9);
        server.stop();
    }

    #[test]
    fn sim_padded_slot_accounting_across_batches() {
        let b = 4;
        let n = 5;
        let server = sim_server(b, Duration::from_millis(100));
        let workers = spawn_requests(&server, n);
        for w in workers {
            assert!(w.join().unwrap().is_ok());
        }
        let requests = server.stats.requests.load(Ordering::Relaxed);
        let batches = server.stats.batches.load(Ordering::Relaxed);
        let padded = server.stats.padded_slots.load(Ordering::Relaxed);
        assert_eq!(requests, n as u64);
        assert!(batches >= 2, "5 requests cannot fit one batch of 4");
        // Conservation: every slot is either a request or padding.
        assert_eq!(batches * b as u64, requests + padded);
        server.stop();
    }

    #[test]
    fn sim_clean_shutdown_with_cloned_handles() {
        let server = sim_server(2, Duration::from_millis(10));
        let h1 = server.handle();
        let h2 = h1.clone();
        assert!(h1.infer(vec![0.0; h1.image_shape().numel()]).is_ok());
        server.stop();
        // Cloned handles outlive the server but become inert.
        let err = h2.infer(vec![0.0; h2.image_shape().numel()]).unwrap_err();
        assert!(err.to_string().contains("server stopped"), "{err}");
    }

    #[test]
    fn wrong_image_size_rejected_without_touching_server() {
        let server = sim_server(2, Duration::from_millis(10));
        let err = server.handle().infer(vec![0.0; 3]).unwrap_err();
        assert!(err.to_string().contains("elements"), "{err}");
        assert_eq!(server.stats.requests.load(Ordering::Relaxed), 0);
        server.stop();
    }

    #[test]
    fn pjrt_build_error_reported_through_start() {
        let engine = Engine::builder()
            .graph_owned(bench::block_net(1, 2, 2, 8))
            .artifacts("/nonexistent/artifact/dir");
        let err = ServerConfig::new(engine).start().unwrap_err();
        assert!(err.to_string().contains("manifest"), "{err}");
    }
}
