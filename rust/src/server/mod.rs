//! Batching inference server — the L3 coordination front-end used by the
//! end-to-end example.
//!
//! Executables are AOT-compiled for a fixed batch size `B`, so the
//! batcher gathers up to `B` single-image requests (or closes a batch
//! after `max_wait`), pads the batch with zeros, runs an engine once,
//! and scatters the per-image outputs back to the callers. This is the
//! standard fixed-shape dynamic-batching pattern (vLLM-style routers do
//! the same against compiled engines).
//!
//! ## Worker pool
//!
//! Throughput scales past one batch in flight via a *sharded worker
//! pool* ([`ServerConfig::workers`]): N threads each build their own
//! [`Engine`] from the shared (`Send`) [`EngineBuilder`] — PJRT engines
//! are `!Send`, so replication happens at the builder level — and pull
//! from one shared, **bounded** dispatch queue:
//!
//! ```text
//!   infer() ──┐
//!   infer() ──┼──► bounded queue (depth D) ──► worker 0: Engine #0
//!   infer() ──┘        │  QueuePolicy:        ► worker 1: Engine #1
//!                      │    Block | Reject     ► ...      Engine #N-1
//!                      └── backpressure        (gather → pad → run →
//!                                               scatter, per worker)
//! ```
//!
//! The queue bound is the backpressure seam: when it is full, `infer`
//! either blocks ([`QueuePolicy::Block`], the default) or fails fast
//! ([`QueuePolicy::Reject`]) instead of growing an unbounded backlog.
//! Workers lock the queue only while *gathering* a batch; execution
//! runs outside the lock, so up to N batches are in flight at once.
//!
//! The server is configured with a [`ServerConfig`] wrapping an
//! [`EngineBuilder`]: the same config drives real PJRT serving and
//! artifact-free [`SimBackend`](crate::engine::SimBackend) serving —
//! which is how the batching logic gets integration-tested below
//! without any artifacts directory. Pool-scaling behaviour is measured
//! by `benches/fig16_serving_scaling.rs` on the *paced* sim backend
//! ([`EngineBuilder::sim_paced`]), where a batch occupies real
//! wall-clock time and queueing is genuine.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::engine::{Engine, EngineBuilder};
use crate::graph::Shape;
use crate::runtime::HostTensor;

/// One inference request: a single image (batch dim 1) and a reply
/// channel. The reply carries an explicit error when batch execution
/// fails, so callers never see a bare disconnected-channel error.
struct Request {
    image: Vec<f32>,
    reply: Sender<Result<HostTensor>>,
    enqueued: Instant,
}

/// Channel message: a request, or an explicit shutdown signal (cloned
/// handles may outlive the server, so channel-closure alone cannot end
/// a worker loop). Each worker consumes exactly one `Shutdown`.
enum Msg {
    Infer(Request),
    Shutdown,
}

/// What `infer` does when the bounded dispatch queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueuePolicy {
    /// Block the caller until a slot frees up (default).
    Block,
    /// Fail fast with a "queue full" error (counted in
    /// [`ServerStats::rejected`]).
    Reject,
}

/// Server statistics, aggregated across all workers. Per-worker batch
/// counts are kept separately ([`ServerStats::worker_batches`]) so load
/// imbalance is observable.
#[derive(Debug, Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Sum of per-request latency in microseconds.
    pub latency_us_sum: AtomicU64,
    /// Requests refused by [`QueuePolicy::Reject`] on a full queue.
    pub rejected: AtomicU64,
    /// Requests currently sitting in the dispatch queue — an
    /// approximate gauge, never exceeding the configured bound by more
    /// than the races below: the sender increments *after* a successful
    /// send, so a worker's decrement can transiently drive it negative
    /// (readers clamp at zero).
    pub queue_depth: AtomicI64,
    /// High-water mark of [`Self::queue_depth`].
    pub queue_peak: AtomicU64,
    /// Batches executed by each worker.
    worker_batches: Vec<AtomicU64>,
}

impl ServerStats {
    /// Stats block for a pool of `n` workers.
    pub fn with_workers(n: usize) -> Self {
        ServerStats {
            worker_batches: (0..n).map(|_| AtomicU64::new(0)).collect(),
            ..Default::default()
        }
    }

    /// Mean per-request latency; `0.0` (never NaN) before any request
    /// completes.
    pub fn mean_latency_ms(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_us_sum.load(Ordering::Relaxed) as f64 / n as f64 / 1000.0
    }

    /// Fraction of batch slots that carried real requests; `0.0` (never
    /// NaN) before any batch ran or for a degenerate `batch` of zero.
    pub fn occupancy(&self, batch: usize) -> f64 {
        let total_slots = self.batches.load(Ordering::Relaxed) * batch as u64;
        if total_slots == 0 {
            return 0.0;
        }
        1.0 - self.padded_slots.load(Ordering::Relaxed) as f64 / total_slots as f64
    }

    /// Current dispatch-queue occupancy, clamped at zero (see
    /// [`Self::queue_depth`] for the gauge's race tolerance).
    pub fn queue_depth_now(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed).max(0) as u64
    }

    /// Batches executed per worker (index = worker id).
    pub fn worker_batches(&self) -> Vec<u64> {
        self.worker_batches
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<Msg>,
    image_shape: Shape,
    policy: QueuePolicy,
    capacity: usize,
    stats: Arc<ServerStats>,
}

impl ServerHandle {
    /// Submit one image; blocks until the result is available. When the
    /// dispatch queue is full the call blocks or fails fast per the
    /// server's [`QueuePolicy`].
    pub fn infer(&self, image: Vec<f32>) -> Result<HostTensor> {
        anyhow::ensure!(
            image.len() == self.image_shape.numel(),
            "image has {} elements, expected {}",
            image.len(),
            self.image_shape.numel()
        );
        let (tx, rx) = channel();
        let msg = Msg::Infer(Request {
            image,
            reply: tx,
            enqueued: Instant::now(),
        });
        match self.policy {
            QueuePolicy::Block => self
                .tx
                .send(msg)
                .map_err(|_| anyhow!("server stopped"))?,
            QueuePolicy::Reject => match self.tx.try_send(msg) {
                Ok(()) => {}
                Err(TrySendError::Full(_)) => {
                    self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                    anyhow::bail!(
                        "server queue full (capacity {}); retry later",
                        self.capacity
                    );
                }
                Err(TrySendError::Disconnected(_)) => anyhow::bail!("server stopped"),
            },
        }
        // Gauge the queue occupancy only after the send succeeded: a
        // caller blocked in `send` is not *in* the queue, so the peak
        // stays bounded by the configured depth (modulo the benign
        // decrement-first race documented on `queue_depth`).
        let depth = self.stats.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        if depth > 0 {
            self.stats
                .queue_peak
                .fetch_max(depth as u64, Ordering::Relaxed);
        }
        rx.recv()
            .map_err(|_| anyhow!("server stopped before the request completed"))?
    }

    pub fn image_shape(&self) -> &Shape {
        &self.image_shape
    }
}

/// Configuration for [`Server::start`]: which engine to serve and how
/// the batcher and its worker pool behave.
pub struct ServerConfig {
    engine: EngineBuilder,
    max_wait: Duration,
    workers: usize,
    queue_depth: usize,
    queue_policy: QueuePolicy,
}

impl ServerConfig {
    /// Serve the network described by `engine`. The builder's graph
    /// batch dimension is the compiled batch size `B`; its mode decides
    /// baseline vs BrainSlug serving; its backend decides PJRT vs sim.
    /// Defaults: one worker, queue depth 64, [`QueuePolicy::Block`],
    /// 5 ms `max_wait`.
    pub fn new(engine: EngineBuilder) -> Self {
        ServerConfig {
            engine,
            max_wait: Duration::from_millis(5),
            workers: 1,
            queue_depth: 64,
            queue_policy: QueuePolicy::Block,
        }
    }

    /// Maximum time a worker waits to fill a batch before closing it
    /// partially (default 5 ms).
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.max_wait = max_wait;
        self
    }

    /// Number of pool workers; each builds its own engine replica from
    /// the shared builder (clamped to at least 1, default 1).
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Bound of the shared dispatch queue, in requests (clamped to at
    /// least 1, default 64). A full queue exerts backpressure per the
    /// [`QueuePolicy`].
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth.max(1);
        self
    }

    /// What `infer` does when the queue is full (default
    /// [`QueuePolicy::Block`]).
    pub fn queue_policy(mut self, policy: QueuePolicy) -> Self {
        self.queue_policy = policy;
        self
    }

    /// Start the server (see [`Server::start`]).
    pub fn start(self) -> Result<Server> {
        Server::start(self)
    }
}

/// The batching server. Owns the worker threads.
pub struct Server {
    handle: ServerHandle,
    pub stats: Arc<ServerStats>,
    /// Compiled batch size `B` of the served network.
    batch: usize,
    joins: Vec<std::thread::JoinHandle<()>>,
    shutdown: SyncSender<Msg>,
}

impl Server {
    /// Start a server from `config`.
    ///
    /// PJRT engines are `!Send` (Rc-based internals), so each worker
    /// builds its own engine *inside* its thread from the (Send)
    /// builder; if any replica fails to build, startup fails with that
    /// error and the healthy workers are torn down.
    pub fn start(config: ServerConfig) -> Result<Server> {
        let ServerConfig {
            engine,
            max_wait,
            workers,
            queue_depth,
            queue_policy,
        } = config;
        // Tune once, up front: a builder carrying `.autotune(level)`
        // must not re-run the whole timed search in every worker thread
        // (concurrent searches contend on the cores, replicas could
        // adopt different winners, and nothing would persist once the
        // policy below is baked). After this, the builder carries the
        // winning options and no pending tune.
        let engine = engine.apply_autotune()?;
        // Per-worker profile reuse: read the tuned-profile cache once
        // and bake it into the builder, so the N worker replicas below
        // share one in-memory store instead of re-reading the file N
        // times (see `EngineBuilder::preload_profiles`).
        let engine = engine.preload_profiles();
        let stats = Arc::new(ServerStats::with_workers(workers));
        let (tx, rx) = sync_channel::<Msg>(queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let (ready_tx, ready_rx) = channel::<Result<Shape>>();
        let mut joins = Vec::with_capacity(workers);
        for worker in 0..workers {
            let builder = engine.clone();
            let rx = rx.clone();
            let stats = stats.clone();
            let ready_tx = ready_tx.clone();
            joins.push(std::thread::spawn(move || {
                let mut engine = match builder.build() {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let _ = ready_tx.send(Ok(engine.graph().input_shape().clone()));
                drop(ready_tx);
                batch_loop(worker, &mut engine, &rx, &stats, max_wait);
            }));
        }
        drop(ready_tx);
        let mut input_shape: Option<Shape> = None;
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..workers {
            match ready_rx.recv() {
                Ok(Ok(shape)) => {
                    if input_shape.is_none() {
                        input_shape = Some(shape);
                    }
                }
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!("server worker died during startup"));
                    }
                    break;
                }
            }
        }
        let input_shape = match (input_shape, first_err) {
            (Some(shape), None) => shape,
            (_, err) => {
                // Tear down: dropping the only external sender
                // disconnects the queue, so idle workers exit.
                drop(tx);
                for j in joins {
                    let _ = j.join();
                }
                return Err(
                    err.unwrap_or_else(|| anyhow!("server worker died during startup"))
                );
            }
        };
        let batch = input_shape.batch();
        let mut dims = input_shape.dims.clone();
        dims[0] = 1;
        let handle = ServerHandle {
            tx: tx.clone(),
            image_shape: Shape::new(dims, input_shape.dtype),
            policy: queue_policy,
            capacity: queue_depth,
            stats: stats.clone(),
        };
        Ok(Server {
            handle,
            stats,
            batch,
            joins,
            shutdown: tx,
        })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Compiled batch size `B` of the served network.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Number of pool workers.
    pub fn workers(&self) -> usize {
        self.joins.len()
    }

    /// Batch occupancy over the server's own batch size.
    pub fn occupancy(&self) -> f64 {
        self.stats.occupancy(self.batch)
    }

    /// Stop the server and join all workers. Requests already queued are
    /// drained first (FIFO: the shutdown signals queue behind them).
    /// Cloned handles become inert (their sends fail) once the last
    /// worker exits.
    pub fn stop(mut self) {
        for _ in 0..self.joins.len() {
            if self.shutdown.send(Msg::Shutdown).is_err() {
                break;
            }
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// One worker's serve loop: lock the shared queue, gather up to `batch`
/// requests (or until `max_wait`), release the lock, execute, scatter.
/// Execution happens outside the lock so the pool overlaps batches.
fn batch_loop(
    worker: usize,
    engine: &mut Engine,
    rx: &Arc<Mutex<Receiver<Msg>>>,
    stats: &Arc<ServerStats>,
    max_wait: Duration,
) {
    let in_shape = engine.graph().input_shape().clone();
    let batch = in_shape.batch();
    let image_elems = in_shape.numel() / batch;
    loop {
        let (pending, shutdown_after) = {
            let q = match rx.lock() {
                Ok(q) => q,
                Err(_) => return, // another worker panicked mid-gather
            };
            let first = match q.recv() {
                Ok(Msg::Infer(r)) => {
                    stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    r
                }
                Ok(Msg::Shutdown) | Err(_) => return,
            };
            let mut pending = vec![first];
            let deadline = Instant::now() + max_wait;
            let mut shutdown_after = false;
            while pending.len() < batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match q.recv_timeout(deadline - now) {
                    Ok(Msg::Infer(r)) => {
                        stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
                        pending.push(r);
                    }
                    Ok(Msg::Shutdown) => {
                        shutdown_after = true;
                        break;
                    }
                    Err(_) => break,
                }
            }
            (pending, shutdown_after)
        };
        // Assemble the padded batch tensor.
        let mut data = vec![0.0f32; in_shape.numel()];
        for (i, r) in pending.iter().enumerate() {
            data[i * image_elems..(i + 1) * image_elems].copy_from_slice(&r.image);
        }
        let input = HostTensor::new(in_shape.clone(), data);
        match engine.run(input) {
            Ok((out, _stats)) => {
                let out_elems = out.shape.numel() / batch;
                stats.batches.fetch_add(1, Ordering::Relaxed);
                stats.worker_batches[worker].fetch_add(1, Ordering::Relaxed);
                stats
                    .padded_slots
                    .fetch_add((batch - pending.len()) as u64, Ordering::Relaxed);
                let mut out_dims = out.shape.dims.clone();
                out_dims[0] = 1;
                for (i, r) in pending.iter().enumerate() {
                    let slice = out.data[i * out_elems..(i + 1) * out_elems].to_vec();
                    let t =
                        HostTensor::new(Shape::new(out_dims.clone(), out.shape.dtype), slice);
                    stats.requests.fetch_add(1, Ordering::Relaxed);
                    stats.latency_us_sum.fetch_add(
                        r.enqueued.elapsed().as_micros() as u64,
                        Ordering::Relaxed,
                    );
                    let _ = r.reply.send(Ok(t));
                }
            }
            Err(e) => {
                // Reply with an explicit error instead of dropping the
                // channels (which surfaced as a cryptic "receiving on an
                // empty and disconnected channel" at the caller).
                log::error!("batch execution failed: {e:#}");
                let msg = format!("{e:#}");
                for r in &pending {
                    let _ = r
                        .reply
                        .send(Err(anyhow!("batch execution failed: {msg}")));
                }
            }
        }
        if shutdown_after {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench;
    use crate::device::DeviceSpec;
    use crate::engine::Engine;
    use crate::optimizer::CollapseOptions;

    #[test]
    fn stats_math() {
        let s = ServerStats::default();
        s.requests.store(4, Ordering::Relaxed);
        s.latency_us_sum.store(8000, Ordering::Relaxed);
        s.batches.store(2, Ordering::Relaxed);
        s.padded_slots.store(4, Ordering::Relaxed);
        assert!((s.mean_latency_ms() - 2.0).abs() < 1e-9);
        assert!((s.occupancy(4) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn stats_empty_server_is_nan_free() {
        let s = ServerStats::with_workers(3);
        assert_eq!(s.mean_latency_ms(), 0.0);
        assert_eq!(s.occupancy(4), 0.0);
        // Degenerate batch size must not divide by zero either.
        assert_eq!(s.occupancy(0), 0.0);
        assert!(s.mean_latency_ms().is_finite());
        assert!(s.occupancy(0).is_finite());
        assert_eq!(s.worker_batches(), vec![0, 0, 0]);
    }

    /// Builder for a sim-backed engine over a tiny block network with
    /// batch `b` (unpaced).
    fn sim_engine(b: usize) -> crate::engine::EngineBuilder {
        Engine::builder()
            .graph_owned(bench::block_net(1, b, 2, 8))
            .device(DeviceSpec::tpu_core())
            .brainslug(CollapseOptions::default())
            .sim()
            .seed(11)
    }

    /// A single-worker sim server (the pre-pool configuration).
    fn sim_server(b: usize, max_wait: Duration) -> Server {
        ServerConfig::new(sim_engine(b))
            .max_wait(max_wait)
            .start()
            .unwrap()
    }

    /// Pacing scale that makes one batch of the `sim_engine` network
    /// cost roughly `target` seconds of wall-clock.
    fn pace_scale_for(b: usize, target: f64) -> f64 {
        let mut probe = sim_engine(b).build().unwrap();
        let input = probe.synthetic_input();
        let (_, st) = probe.run(input).unwrap();
        target / st.total_s.max(1e-12)
    }

    fn spawn_requests(
        server: &Server,
        n: usize,
    ) -> Vec<std::thread::JoinHandle<Result<HostTensor>>> {
        let elems = server.handle().image_shape().numel();
        (0..n)
            .map(|i| {
                let h = server.handle();
                std::thread::spawn(move || h.infer(vec![i as f32; elems]))
            })
            .collect()
    }

    #[test]
    fn sim_batching_fills_to_capacity() {
        let server = sim_server(4, Duration::from_secs(10));
        let workers = spawn_requests(&server, 4);
        for w in workers {
            let out = w.join().unwrap().unwrap();
            assert_eq!(out.shape.batch(), 1);
        }
        assert_eq!(server.stats.requests.load(Ordering::Relaxed), 4);
        assert_eq!(server.stats.batches.load(Ordering::Relaxed), 1);
        assert_eq!(server.stats.padded_slots.load(Ordering::Relaxed), 0);
        assert!((server.occupancy() - 1.0).abs() < 1e-9);
        assert!(server.stats.mean_latency_ms().is_finite());
        server.stop();
    }

    #[test]
    fn sim_timeout_closes_partial_batch() {
        let server = sim_server(4, Duration::from_millis(30));
        let out = server
            .handle()
            .infer(vec![1.0; server.handle().image_shape().numel()]);
        assert!(out.is_ok());
        assert_eq!(server.stats.requests.load(Ordering::Relaxed), 1);
        assert_eq!(server.stats.batches.load(Ordering::Relaxed), 1);
        // Three of four slots were zero-padding.
        assert_eq!(server.stats.padded_slots.load(Ordering::Relaxed), 3);
        assert!((server.occupancy() - 0.25).abs() < 1e-9);
        server.stop();
    }

    #[test]
    fn sim_padded_slot_accounting_across_batches() {
        let b = 4;
        let n = 5;
        let server = sim_server(b, Duration::from_millis(100));
        let workers = spawn_requests(&server, n);
        for w in workers {
            assert!(w.join().unwrap().is_ok());
        }
        let requests = server.stats.requests.load(Ordering::Relaxed);
        let batches = server.stats.batches.load(Ordering::Relaxed);
        let padded = server.stats.padded_slots.load(Ordering::Relaxed);
        assert_eq!(requests, n as u64);
        assert!(batches >= 2, "5 requests cannot fit one batch of 4");
        // Conservation: every slot is either a request or padding.
        assert_eq!(batches * b as u64, requests + padded);
        server.stop();
    }

    #[test]
    fn sim_clean_shutdown_with_cloned_handles() {
        let server = sim_server(2, Duration::from_millis(10));
        let h1 = server.handle();
        let h2 = h1.clone();
        assert!(h1.infer(vec![0.0; h1.image_shape().numel()]).is_ok());
        server.stop();
        // Cloned handles outlive the server but become inert.
        let err = h2.infer(vec![0.0; h2.image_shape().numel()]).unwrap_err();
        assert!(err.to_string().contains("server stopped"), "{err}");
    }

    #[test]
    fn wrong_image_size_rejected_without_touching_server() {
        let server = sim_server(2, Duration::from_millis(10));
        let err = server.handle().infer(vec![0.0; 3]).unwrap_err();
        assert!(err.to_string().contains("elements"), "{err}");
        assert_eq!(server.stats.requests.load(Ordering::Relaxed), 0);
        server.stop();
    }

    #[test]
    fn pjrt_build_error_reported_through_start() {
        let engine = Engine::builder()
            .graph_owned(bench::block_net(1, 2, 2, 8))
            .artifacts("/nonexistent/artifact/dir");
        let err = ServerConfig::new(engine).workers(3).start().unwrap_err();
        assert!(err.to_string().contains("manifest"), "{err}");
    }

    #[test]
    fn worker_pool_stress_slot_conservation() {
        let b = 4;
        let pool = 4;
        let n = 64;
        let server = ServerConfig::new(sim_engine(b))
            .workers(pool)
            .queue_depth(2 * b)
            .max_wait(Duration::from_millis(5))
            .start()
            .unwrap();
        assert_eq!(server.workers(), pool);
        let clients = spawn_requests(&server, n);
        for c in clients {
            assert!(c.join().unwrap().is_ok());
        }
        let requests = server.stats.requests.load(Ordering::Relaxed);
        let batches = server.stats.batches.load(Ordering::Relaxed);
        let padded = server.stats.padded_slots.load(Ordering::Relaxed);
        assert_eq!(requests, n as u64);
        // Slot conservation holds across all workers.
        assert_eq!(batches * b as u64, requests + padded);
        let occ = server.occupancy();
        assert!((0.0..=1.0).contains(&occ), "occupancy {occ} out of range");
        // Per-worker counters sum to the aggregate batch count.
        let per: u64 = server.stats.worker_batches().iter().sum();
        assert_eq!(per, batches);
        assert!(server.stats.mean_latency_ms().is_finite());
        server.stop();
    }

    #[test]
    fn shutdown_with_queued_requests_is_clean() {
        // Paced batches occupy real time, so requests pile up in the
        // bounded queue; stopping mid-flood must neither hang nor leave
        // any caller without a reply (success or a clean error).
        let b = 2;
        let scale = pace_scale_for(b, 0.01);
        let server = ServerConfig::new(sim_engine(b).sim_paced(scale))
            .workers(2)
            .queue_depth(2)
            .max_wait(Duration::from_millis(1))
            .start()
            .unwrap();
        let stats = server.stats.clone();
        let clients = spawn_requests(&server, 12);
        std::thread::sleep(Duration::from_millis(5));
        server.stop();
        let mut served = 0u64;
        for c in clients {
            match c.join().unwrap() {
                Ok(_) => served += 1,
                Err(e) => {
                    assert!(e.to_string().contains("server stopped"), "{e}");
                }
            }
        }
        let requests = stats.requests.load(Ordering::Relaxed);
        let batches = stats.batches.load(Ordering::Relaxed);
        let padded = stats.padded_slots.load(Ordering::Relaxed);
        assert_eq!(served, requests);
        assert_eq!(batches * b as u64, requests + padded);
    }

    #[test]
    fn reject_policy_fails_fast_when_queue_full() {
        // One slow worker (paced, ~50 ms/batch), queue depth 1: the
        // first request occupies the worker, the second the queue, the
        // third must be rejected immediately.
        let scale = pace_scale_for(1, 0.05);
        let server = ServerConfig::new(sim_engine(1).sim_paced(scale))
            .workers(1)
            .queue_depth(1)
            .queue_policy(QueuePolicy::Reject)
            .max_wait(Duration::from_millis(1))
            .start()
            .unwrap();
        let elems = server.handle().image_shape().numel();
        let running = spawn_requests(&server, 1);
        std::thread::sleep(Duration::from_millis(10)); // worker picked it up
        let queued = spawn_requests(&server, 1);
        std::thread::sleep(Duration::from_millis(10)); // queue slot taken
        let t0 = Instant::now();
        let err = server.handle().infer(vec![0.0; elems]).unwrap_err();
        assert!(err.to_string().contains("queue full"), "{err}");
        assert!(
            t0.elapsed() < Duration::from_millis(40),
            "reject must not wait for the running batch"
        );
        assert_eq!(server.stats.rejected.load(Ordering::Relaxed), 1);
        for c in running.into_iter().chain(queued) {
            assert!(c.join().unwrap().is_ok());
        }
        assert!(server.stats.queue_peak.load(Ordering::Relaxed) >= 1);
        server.stop();
    }

    #[test]
    fn batch_failure_reports_explicit_error() {
        // Force a failing `engine.run` with an injected backend and
        // drive `batch_loop` directly: the blocked caller must receive
        // an explicit batch-execution-failed error, not a cryptic
        // disconnected-channel error.
        struct FailingBackend;
        impl crate::engine::Backend for FailingBackend {
            fn name(&self) -> &'static str {
                "fail"
            }
            fn run(
                &mut self,
                _work: &crate::engine::Workload,
                _input: HostTensor,
            ) -> Result<(HostTensor, crate::scheduler::ExecStats)> {
                anyhow::bail!("injected backend failure")
            }
        }
        let mut failing = sim_engine(2)
            .build_with(|_, _, _| Ok(Box::new(FailingBackend) as Box<dyn crate::engine::Backend>))
            .unwrap();
        let (tx, rx) = sync_channel(4);
        let (reply_tx, reply_rx) = channel();
        let stats = Arc::new(ServerStats::with_workers(1));
        let elems = failing.graph().input_shape().numel() / 2;
        stats.queue_depth.fetch_add(1, Ordering::Relaxed);
        tx.send(Msg::Infer(Request {
            image: vec![0.0; elems],
            reply: reply_tx,
            enqueued: Instant::now(),
        }))
        .unwrap();
        drop(tx);
        let rx = Arc::new(Mutex::new(rx));
        batch_loop(0, &mut failing, &rx, &stats, Duration::from_millis(1));
        let reply = reply_rx.recv().unwrap();
        let err = reply.unwrap_err();
        assert!(
            err.to_string().contains("batch execution failed"),
            "caller must see an explicit batch failure, got: {err}"
        );
        assert!(err.to_string().contains("injected backend failure"), "{err}");
        // Failed batches are not counted as served.
        assert_eq!(stats.requests.load(Ordering::Relaxed), 0);
        assert_eq!(stats.batches.load(Ordering::Relaxed), 0);
        assert_eq!(stats.queue_depth.load(Ordering::Relaxed), 0);
    }
}
