//! Batching inference server — the L3 coordination front-end used by the
//! end-to-end example.
//!
//! Executables are AOT-compiled for a fixed batch size `B`, so the
//! batcher gathers up to `B` single-image requests (or closes a batch
//! after `max_wait`), pads the batch with zeros, runs an engine once,
//! and scatters the per-image outputs back to the callers. This is the
//! standard fixed-shape dynamic-batching pattern (vLLM-style routers do
//! the same against compiled engines).
//!
//! ## Worker pool
//!
//! Throughput scales past one batch in flight via a *sharded worker
//! pool* ([`ServerConfig::workers`]): N threads each build their own
//! [`Engine`] from the shared (`Send`) [`EngineBuilder`] — PJRT engines
//! are `!Send`, so replication happens at the builder level — and pull
//! from one shared, **bounded** dispatch queue:
//!
//! ```text
//!   infer() ──┐
//!   infer() ──┼──► bounded queue (depth D) ──► worker 0: Engine #0
//!   infer() ──┘        │  QueuePolicy:        ► worker 1: Engine #1
//!                      │    Block | Reject     ► ...      Engine #N-1
//!                      └── backpressure        (gather → pad → run →
//!                                               scatter, per worker)
//! ```
//!
//! The queue bound is the backpressure seam: when it is full, `infer`
//! either blocks ([`QueuePolicy::Block`], the default) or fails fast
//! ([`QueuePolicy::Reject`]) instead of growing an unbounded backlog.
//! Workers lock the queue only while *gathering* a batch; execution
//! runs outside the lock, so up to N batches are in flight at once.
//!
//! The server is configured with a [`ServerConfig`] wrapping an
//! [`EngineBuilder`]: the same config drives real PJRT serving and
//! artifact-free [`SimBackend`](crate::engine::SimBackend) serving —
//! which is how the batching logic gets integration-tested below
//! without any artifacts directory. Pool-scaling behaviour is measured
//! by `benches/fig16_serving_scaling.rs` on the *paced* sim backend
//! ([`EngineBuilder::sim_paced`]), where a batch occupies real
//! wall-clock time and queueing is genuine.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{channel, Sender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

// The coordination spine — dispatch queue, queue mutex, shutdown gate —
// goes through the `conc::sync` facade: plain `std::sync` in production
// (one thread-local read at construction), modeled and schedule-explored
// under `brainslug check --schedules` / the model-check test suite. See
// [`drain_protocol`] for the explored replica of the drain dance.
use crate::conc::sync::{Gate, Mutex, Receiver, SyncSender};

use crate::engine::{Engine, EngineBuilder};
use crate::fault::{FaultInjector, FaultPoint};
use crate::graph::Shape;
use crate::json::Json;
use crate::runtime::HostTensor;

/// The end-to-end latency histogram is the shared fixed-bucket
/// implementation in [`crate::obs`] (two atomic increments on the hot
/// path, bucket-midpoint percentile reads accurate to
/// [`crate::obs::MIDPOINT_REL_ERROR`]). The historical name is kept as
/// an alias so existing callers and docs keep reading naturally.
pub use crate::obs::Histogram as LatencyHistogram;

/// Why a submitted request failed — the typed seam the HTTP front door
/// maps onto wire status codes (queue-full → 503 + `Retry-After`,
/// shutdown → 503, bad input → 400, execution failure → 500, worker
/// crash → 503 + `Retry-After`, missed deadline → 504; the exhaustive
/// mapping lives in [`crate::http::router::infer_error_response`]). The
/// `Display` strings are the stable messages the pre-HTTP `infer` API
/// always returned.
#[derive(Debug)]
pub enum InferError {
    /// The bounded dispatch queue was full under [`QueuePolicy::Reject`].
    QueueFull { capacity: usize },
    /// The server has stopped (or is draining for shutdown).
    Stopped,
    /// The image does not match the served input shape.
    BadInput(String),
    /// Batch execution failed on a worker. The message already carries
    /// the worker's "batch execution failed: …" context verbatim.
    Exec(String),
    /// The worker executing this request's batch panicked; the replica
    /// is being rebuilt. Transient — the same request retried a moment
    /// later lands on a healthy replica.
    WorkerCrashed { worker: usize },
    /// The request's deadline expired before (or while) a worker could
    /// execute it; it was shed without wasting batch slots.
    DeadlineExceeded { waited_ms: u64 },
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::QueueFull { capacity } => {
                write!(f, "server queue full (capacity {capacity}); retry later")
            }
            InferError::Stopped => write!(f, "server stopped"),
            InferError::BadInput(msg) | InferError::Exec(msg) => write!(f, "{msg}"),
            InferError::WorkerCrashed { worker } => {
                write!(f, "worker {worker} crashed mid-batch; replica restarting, retry")
            }
            InferError::DeadlineExceeded { waited_ms } => {
                write!(f, "deadline exceeded after {waited_ms} ms in queue")
            }
        }
    }
}

impl std::error::Error for InferError {}

/// One inference request: a single image (batch dim 1), a typed reply
/// channel, and an optional deadline. The reply carries an explicit
/// [`InferError`] on every failure path, so callers never see a bare
/// disconnected-channel error.
struct Request {
    image: Vec<f32>,
    reply: Sender<std::result::Result<HostTensor, InferError>>,
    enqueued: Instant,
    /// Absolute drop-dead time: a worker that gathers this request
    /// after the deadline sheds it with
    /// [`InferError::DeadlineExceeded`] instead of spending a batch
    /// slot on an answer nobody is waiting for.
    deadline: Option<Instant>,
    /// Trace id attributed to this request's spans (`0` = untraced).
    /// Flows from the HTTP front door's `x-brainslug-trace` header
    /// through [`ServerHandle::try_infer_deadline_traced`].
    trace: u64,
}

/// Channel message: a request, or an explicit shutdown signal (cloned
/// handles may outlive the server, so channel-closure alone cannot end
/// a worker loop). Each worker consumes exactly one `Shutdown`.
enum Msg {
    Infer(Request),
    Shutdown,
}

/// What `infer` does when the bounded dispatch queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueuePolicy {
    /// Block the caller until a slot frees up (default).
    Block,
    /// Fail fast with a "queue full" error (counted in
    /// [`ServerStats::rejected`]).
    Reject,
}

/// Lifecycle phase reported by the health state machine — see
/// [`HealthState`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthPhase {
    /// Workers are still building their engine replicas.
    Starting,
    /// Serving normally.
    Ready,
    /// Serving, but at least one replica is being rebuilt after a
    /// crash — capacity is reduced and clients should back off.
    Degraded,
    /// `stop()` has begun: accepted requests drain, new ones are
    /// refused.
    Draining,
}

impl HealthPhase {
    /// Stable lowercase name — the `state` field of `GET /healthz` and
    /// `GET /v1/stats`.
    pub fn name(self) -> &'static str {
        match self {
            HealthPhase::Starting => "starting",
            HealthPhase::Ready => "ready",
            HealthPhase::Degraded => "degraded",
            HealthPhase::Draining => "draining",
        }
    }
}

/// The server's health state machine:
/// `Starting → Ready ⇄ Degraded → Draining`. `Starting`, `Ready` and
/// `Draining` are explicit one-way transitions; `Degraded` is *derived*
/// — `Ready` with at least one replica mid-rebuild — so it clears
/// itself the moment the last rebuild finishes, with no extra
/// transition to forget.
///
/// Ordering: Relaxed throughout, per the [`ServerStats`] contract — the
/// phase is an advisory gauge for `/healthz` (a probe tolerates reading
/// the previous phase for an instant), and the `rebuilding` gauge is an
/// independent counter whose increments/decrements are RMW-atomic.
#[derive(Debug, Default)]
pub struct HealthState {
    /// 0 = Starting, 1 = Ready, 2 = Draining.
    phase: AtomicU8,
    /// Number of replicas currently rebuilding after a crash.
    rebuilding: AtomicI64,
}

impl HealthState {
    pub fn phase(&self) -> HealthPhase {
        match self.phase.load(Ordering::Relaxed) {
            0 => HealthPhase::Starting,
            2 => HealthPhase::Draining,
            _ => {
                if self.rebuilding.load(Ordering::Relaxed) > 0 {
                    HealthPhase::Degraded
                } else {
                    HealthPhase::Ready
                }
            }
        }
    }

    /// Whether `/healthz` should answer 200 (the server accepts work).
    pub fn is_serving(&self) -> bool {
        matches!(self.phase(), HealthPhase::Ready | HealthPhase::Degraded)
    }

    pub(crate) fn set_ready(&self) {
        self.phase.store(1, Ordering::Relaxed);
    }

    pub(crate) fn set_draining(&self) {
        self.phase.store(2, Ordering::Relaxed);
    }

    pub(crate) fn rebuild_started(&self) {
        self.rebuilding.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn rebuild_finished(&self) {
        self.rebuilding.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Queue-depth-aware `Retry-After` hint (seconds) for 503 responses:
/// an empty queue suggests an immediate-ish retry (1 s, the HTTP
/// header's floor resolution), a full queue up to `1 + 4 = 5`, clamped
/// at 8 for depth readings above capacity (possible transiently, see
/// [`ServerStats::queue_depth`]).
pub fn suggested_retry_after(queue_depth: u64, capacity: usize) -> u32 {
    let cap = capacity.max(1) as u64;
    (1 + (4 * queue_depth) / cap).min(8) as u32
}

/// Server statistics, aggregated across all workers. Per-worker batch
/// counts are kept separately ([`ServerStats::worker_batches`]) so load
/// imbalance is observable.
///
/// ## Memory-ordering contract (audited)
///
/// Every access in this struct is `Ordering::Relaxed`, deliberately:
///
/// - Each field is an *independent monotone counter or gauge*. No
///   reader derives a cross-field invariant that needs the counters to
///   be mutually ordered (conservation assertions like
///   `batches*B == requests+padded` are only checked after `stop()`
///   joins the workers, and a `join` is a full happens-before edge that
///   makes every Relaxed write visible).
/// - Nothing is *published through* these atomics: no reader loads a
///   counter and then dereferences data the writer prepared before the
///   store, so there is no release/acquire pairing to preserve.
///   (Contrast with a seqlock or a ready-flag, which would need
///   `Release` on the store and `Acquire` on the load.)
/// - Snapshot readers (`to_json`, the `serve` summary) only promise a
///   *tearing-tolerant* view: each field is individually atomic, the
///   set is not. `SeqCst` would not fix tearing — only a lock would —
///   so paying for it buys nothing.
/// - Relaxed atomics still forbid torn reads and lost increments
///   (`fetch_add` is atomic read-modify-write at every ordering), which
///   is the whole requirement here.
#[derive(Debug, Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Sum of per-request latency in microseconds.
    pub latency_us_sum: AtomicU64,
    /// Requests refused by [`QueuePolicy::Reject`] on a full queue.
    pub rejected: AtomicU64,
    /// Requests currently sitting in the dispatch queue — an
    /// approximate gauge, never exceeding the configured bound by more
    /// than the races below: the sender increments *after* a successful
    /// send, so a worker's decrement can transiently drive it negative
    /// (readers clamp at zero).
    pub queue_depth: AtomicI64,
    /// High-water mark of [`Self::queue_depth`].
    pub queue_peak: AtomicU64,
    /// End-to-end (enqueue → reply) latency distribution; p50/p95/p99
    /// feed `GET /v1/stats` and the `serve` summary. The shared
    /// fixed-bucket [`crate::obs::Histogram`]: two atomic increments
    /// per request on the hot path.
    pub latency: LatencyHistogram,
    /// Worker crashes recovered by the supervisor (counted per crash,
    /// *before* the crashed batch's callers are answered, so a client
    /// that saw [`InferError::WorkerCrashed`] is guaranteed to see the
    /// matching increment here).
    pub restarts: AtomicU64,
    /// Requests shed with [`InferError::DeadlineExceeded`] (at
    /// admission or by a worker's pre-execution sweep).
    pub deadline_dropped: AtomicU64,
    /// Health state machine driving `/healthz` (see [`HealthState`]).
    pub health: HealthState,
    /// Batches executed by each worker.
    worker_batches: Vec<AtomicU64>,
    /// Crash recoveries per worker (index = worker id).
    worker_restarts: Vec<AtomicU64>,
}

impl ServerStats {
    /// Stats block for a pool of `n` workers.
    pub fn with_workers(n: usize) -> Self {
        ServerStats {
            worker_batches: (0..n).map(|_| AtomicU64::new(0)).collect(),
            worker_restarts: (0..n).map(|_| AtomicU64::new(0)).collect(),
            ..Default::default()
        }
    }

    /// Mean per-request latency; `0.0` (never NaN) before any request
    /// completes.
    pub fn mean_latency_ms(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_us_sum.load(Ordering::Relaxed) as f64 / n as f64 / 1000.0
    }

    /// Fraction of batch slots that carried real requests; `0.0` (never
    /// NaN) before any batch ran or for a degenerate `batch` of zero.
    pub fn occupancy(&self, batch: usize) -> f64 {
        let total_slots = self.batches.load(Ordering::Relaxed) * batch as u64;
        if total_slots == 0 {
            return 0.0;
        }
        1.0 - self.padded_slots.load(Ordering::Relaxed) as f64 / total_slots as f64
    }

    /// Current dispatch-queue occupancy, clamped at zero (see
    /// [`Self::queue_depth`] for the gauge's race tolerance).
    pub fn queue_depth_now(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed).max(0) as u64
    }

    /// Batches executed per worker (index = worker id).
    pub fn worker_batches(&self) -> Vec<u64> {
        self.worker_batches
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Crash recoveries per worker (index = worker id).
    pub fn worker_restarts(&self) -> Vec<u64> {
        self.worker_restarts
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// End-to-end latency percentiles in milliseconds: (p50, p95, p99).
    /// All zero before the first completed request.
    pub fn latency_percentiles_ms(&self) -> (f64, f64, f64) {
        (
            self.latency.percentile_ms(0.50),
            self.latency.percentile_ms(0.95),
            self.latency.percentile_ms(0.99),
        )
    }

    /// Snapshot as a JSON object — the `GET /v1/stats` body. `batch` is
    /// the served engine's compiled batch size (needed for occupancy).
    pub fn to_json(&self, batch: usize) -> Json {
        let (p50, p95, p99) = self.latency_percentiles_ms();
        let mut o = Json::object();
        o.set(
            "requests",
            Json::Num(self.requests.load(Ordering::Relaxed) as f64),
        );
        o.set(
            "batches",
            Json::Num(self.batches.load(Ordering::Relaxed) as f64),
        );
        o.set(
            "rejected",
            Json::Num(self.rejected.load(Ordering::Relaxed) as f64),
        );
        o.set("batch", Json::from_usize(batch));
        o.set("occupancy", Json::Num(self.occupancy(batch)));
        o.set("queue_depth", Json::Num(self.queue_depth_now() as f64));
        o.set(
            "queue_peak",
            Json::Num(self.queue_peak.load(Ordering::Relaxed) as f64),
        );
        o.set("mean_latency_ms", Json::Num(self.mean_latency_ms()));
        // The percentiles are bucket-midpoint reads of the shared
        // fixed-bucket histogram, so they can differ from a load
        // generator's raw-sample percentiles (bench-serve, fig18) by up
        // to [`crate::obs::MIDPOINT_REL_ERROR`] (12.5 %) relative —
        // advertised here so clients comparing the two views know the
        // agreement contract.
        o.set("p50_ms", Json::Num(p50));
        o.set("p95_ms", Json::Num(p95));
        o.set("p99_ms", Json::Num(p99));
        o.set("percentile_source", Json::Str("histogram-midpoint".into()));
        o.set(
            "percentile_rel_error",
            Json::Num(crate::obs::MIDPOINT_REL_ERROR),
        );
        o.set(
            "restarts",
            Json::Num(self.restarts.load(Ordering::Relaxed) as f64),
        );
        o.set(
            "deadline_dropped",
            Json::Num(self.deadline_dropped.load(Ordering::Relaxed) as f64),
        );
        o.set("health", Json::Str(self.health.phase().name().into()));
        o.set(
            "worker_batches",
            Json::Arr(
                self.worker_batches()
                    .into_iter()
                    .map(|b| Json::Num(b as f64))
                    .collect(),
            ),
        );
        o.set(
            "worker_restarts",
            Json::Arr(
                self.worker_restarts()
                    .into_iter()
                    .map(|b| Json::Num(b as f64))
                    .collect(),
            ),
        );
        o
    }
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<Msg>,
    image_shape: Shape,
    policy: QueuePolicy,
    capacity: usize,
    stats: Arc<ServerStats>,
    /// Shutdown gate (see [`Server::stop`]): `infer` enqueues under the
    /// read side, `stop` flips the flag under the write side *before*
    /// sending the shutdown tokens, so every accepted request is
    /// FIFO-ordered ahead of every token and drains to a real reply.
    closed: Arc<Gate>,
}

impl ServerHandle {
    /// Submit one image; blocks until the result is available. When the
    /// dispatch queue is full the call blocks or fails fast per the
    /// server's [`QueuePolicy`]. Failures are typed ([`InferError`]) so
    /// front ends can map backpressure and shutdown onto wire status
    /// codes without string matching.
    pub fn try_infer(&self, image: Vec<f32>) -> std::result::Result<HostTensor, InferError> {
        self.try_infer_deadline(image, None)
    }

    /// [`Self::try_infer`] with an absolute deadline. An
    /// already-expired deadline is refused at admission without
    /// touching the queue; one that expires *in* the queue is shed by
    /// the gathering worker before execution. Both paths return
    /// [`InferError::DeadlineExceeded`] and count in
    /// [`ServerStats::deadline_dropped`].
    pub fn try_infer_deadline(
        &self,
        image: Vec<f32>,
        deadline: Option<Instant>,
    ) -> std::result::Result<HostTensor, InferError> {
        self.try_infer_deadline_traced(image, deadline, 0)
    }

    /// [`Self::try_infer_deadline`] attributed to trace id `trace`
    /// (`0` = untraced). When the server was started with an armed
    /// observability context ([`ServerConfig::obs`]), the request's
    /// queue wait + execution and the batch that carried it are
    /// recorded as Request/Batch spans under this id; the HTTP front
    /// door feeds the `x-brainslug-trace` header value through here.
    pub fn try_infer_deadline_traced(
        &self,
        image: Vec<f32>,
        deadline: Option<Instant>,
        trace: u64,
    ) -> std::result::Result<HostTensor, InferError> {
        if image.len() != self.image_shape.numel() {
            return Err(InferError::BadInput(format!(
                "image has {} elements, expected {}",
                image.len(),
                self.image_shape.numel()
            )));
        }
        if let Some(d) = deadline {
            if Instant::now() >= d {
                self.stats.deadline_dropped.fetch_add(1, Ordering::Relaxed);
                return Err(InferError::DeadlineExceeded { waited_ms: 0 });
            }
        }
        let (tx, rx) = channel();
        let msg = Msg::Infer(Request {
            image,
            reply: tx,
            enqueued: Instant::now(),
            deadline,
            trace,
        });
        {
            // Hold the gate's read side across the send: once `stop`
            // has taken the write side no new request can slip in
            // behind the shutdown tokens. Blocking sends under the read
            // side are fine — workers keep draining the queue until the
            // tokens (which `stop` can only send after this guard
            // drops) arrive, so blocked senders always make progress.
            let _admitted = match self.closed.enter() {
                Some(guard) => guard,
                None => return Err(InferError::Stopped),
            };
            match self.policy {
                QueuePolicy::Block => {
                    if self.tx.send(msg).is_err() {
                        return Err(InferError::Stopped);
                    }
                }
                QueuePolicy::Reject => match self.tx.try_send(msg) {
                    Ok(()) => {}
                    Err(TrySendError::Full(_)) => {
                        self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                        return Err(InferError::QueueFull {
                            capacity: self.capacity,
                        });
                    }
                    Err(TrySendError::Disconnected(_)) => return Err(InferError::Stopped),
                },
            }
        }
        // Gauge the queue occupancy only after the send succeeded: a
        // caller blocked in `send` is not *in* the queue, so the peak
        // stays bounded by the configured depth (modulo the benign
        // decrement-first race documented on `queue_depth`).
        // Ordering: Relaxed suffices — the gauge is advisory (readers
        // clamp at zero) and the send itself is the synchronizing edge
        // that hands the request to the worker; nothing is published
        // through this counter. Likewise `fetch_max` below: the peak is
        // monotone, and RMW atomicity alone guarantees no lost update.
        let depth = self.stats.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        if depth > 0 {
            self.stats
                .queue_peak
                .fetch_max(depth as u64, Ordering::Relaxed);
        }
        match rx.recv() {
            Ok(Ok(t)) => Ok(t),
            // The reply is typed end to end: execution failures, worker
            // crashes and in-queue deadline drops arrive as the exact
            // `InferError` the worker chose.
            Ok(Err(e)) => Err(e),
            // Unreachable post the drain fix (accepted requests always
            // get a reply); kept as a defensive mapping.
            Err(_) => Err(InferError::Stopped),
        }
    }

    /// [`Self::try_infer`] with the failure flattened into `anyhow` —
    /// the original API every in-process caller and test uses.
    pub fn infer(&self, image: Vec<f32>) -> Result<HostTensor> {
        self.try_infer(image).map_err(|e| anyhow!("{e}"))
    }

    pub fn image_shape(&self) -> &Shape {
        &self.image_shape
    }
}

/// Configuration for [`Server::start`]: which engine to serve and how
/// the batcher and its worker pool behave.
pub struct ServerConfig {
    engine: EngineBuilder,
    max_wait: Duration,
    workers: usize,
    queue_depth: usize,
    queue_policy: QueuePolicy,
    faults: Option<Arc<FaultInjector>>,
    obs: Option<Arc<crate::obs::Obs>>,
}

impl ServerConfig {
    /// Serve the network described by `engine`. The builder's graph
    /// batch dimension is the compiled batch size `B`; its mode decides
    /// baseline vs BrainSlug serving; its backend decides PJRT vs sim.
    /// Defaults: one worker, queue depth 64, [`QueuePolicy::Block`],
    /// 5 ms `max_wait`.
    pub fn new(engine: EngineBuilder) -> Self {
        ServerConfig {
            engine,
            max_wait: Duration::from_millis(5),
            workers: 1,
            queue_depth: 64,
            queue_policy: QueuePolicy::Block,
            faults: None,
            obs: None,
        }
    }

    /// Maximum time a worker waits to fill a batch before closing it
    /// partially (default 5 ms).
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.max_wait = max_wait;
        self
    }

    /// Number of pool workers; each builds its own engine replica from
    /// the shared builder (clamped to at least 1, default 1).
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Bound of the shared dispatch queue, in requests (clamped to at
    /// least 1, default 64). A full queue exerts backpressure per the
    /// [`QueuePolicy`].
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth.max(1);
        self
    }

    /// What `infer` does when the queue is full (default
    /// [`QueuePolicy::Block`]).
    pub fn queue_policy(mut self, policy: QueuePolicy) -> Self {
        self.queue_policy = policy;
        self
    }

    /// Arm fault injection: workers consult `faults` at the
    /// worker-panic, slow-exec and queue-stall points (default
    /// unarmed, a `None` branch with zero cost).
    pub fn faults(mut self, faults: Arc<FaultInjector>) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Arm span tracing: worker engines record Plan/Segment/Band/Kernel
    /// spans into `obs` and the batch loop adds Request/Batch spans,
    /// all attributed to the per-request trace id
    /// ([`ServerHandle::try_infer_deadline_traced`]). Without this the
    /// server still keeps an internal metrics registry (the always-on
    /// per-segment histograms behind `GET /v1/metrics`, reachable via
    /// [`Server::obs`]) but records no spans — the zero-overhead
    /// default, same `Option` arming pattern as [`Self::faults`].
    pub fn obs(mut self, obs: Arc<crate::obs::Obs>) -> Self {
        self.obs = Some(obs);
        self
    }

    /// Start the server (see [`Server::start`]).
    pub fn start(self) -> Result<Server> {
        Server::start(self)
    }
}

/// The batching server. Owns the worker threads.
pub struct Server {
    handle: ServerHandle,
    pub stats: Arc<ServerStats>,
    /// Compiled batch size `B` of the served network.
    batch: usize,
    /// Name of the served network (for `/v1/stats` and model routing).
    model: String,
    joins: Vec<std::thread::JoinHandle<()>>,
    shutdown: SyncSender<Msg>,
    closed: Arc<Gate>,
    queue_depth: usize,
    faults: Option<Arc<FaultInjector>>,
    obs: Arc<crate::obs::Obs>,
}

/// Worker-side observability hooks, shared across the pool: the
/// always-on metrics registry plus whether span tracing was armed
/// ([`ServerConfig::obs`]).
#[derive(Clone)]
struct ObsHook {
    obs: Arc<crate::obs::Obs>,
    tracing: bool,
}

impl Server {
    /// Start a server from `config`.
    ///
    /// PJRT engines are `!Send` (Rc-based internals), so each worker
    /// builds its own engine *inside* its thread from the (Send)
    /// builder; if any replica fails to build, startup fails with that
    /// error and the healthy workers are torn down.
    pub fn start(config: ServerConfig) -> Result<Server> {
        let ServerConfig {
            engine,
            max_wait,
            workers,
            queue_depth,
            queue_policy,
            faults,
            obs,
        } = config;
        // Tune once, up front: a builder carrying `.autotune(level)`
        // must not re-run the whole timed search in every worker thread
        // (concurrent searches contend on the cores, replicas could
        // adopt different winners, and nothing would persist once the
        // policy below is baked). After this, the builder carries the
        // winning options and no pending tune.
        let engine = engine.apply_autotune()?;
        // Per-worker profile reuse: read the tuned-profile cache once
        // and bake it into the builder, so the N worker replicas below
        // share one in-memory store instead of re-reading the file N
        // times (see `EngineBuilder::preload_profiles`).
        let engine = engine.preload_profiles();
        // Metrics are always on (two atomic increments per segment per
        // batch feed the `GET /v1/metrics` histograms); span tracing in
        // the worker engines is armed only when the caller supplied a
        // context, so the untraced hot path never reads a clock.
        let tracing = obs.is_some();
        let obs = obs.unwrap_or_default();
        let engine = if tracing {
            engine.obs(obs.clone())
        } else {
            engine
        };
        let hook = ObsHook {
            obs: obs.clone(),
            tracing,
        };
        let stats = Arc::new(ServerStats::with_workers(workers));
        let closed = Arc::new(Gate::labeled("closed"));
        let (tx, rx) = crate::conc::sync::sync_channel_labeled::<Msg>(queue_depth, "dispatch");
        // Declare the drain contract to the model checker: shutdown
        // tokens on `dispatch` are only legal once `closed` is shut.
        tx.bind_gate(&closed);
        let rx = Arc::new(Mutex::labeled(rx, "dispatch-rx"));
        let (ready_tx, ready_rx) = channel::<Result<(Shape, String)>>();
        let mut joins = Vec::with_capacity(workers);
        for worker in 0..workers {
            let builder = engine.clone();
            let rx = rx.clone();
            let stats = stats.clone();
            let ready_tx = ready_tx.clone();
            let faults = faults.clone();
            let hook = hook.clone();
            joins.push(std::thread::spawn(move || {
                let mut engine = match builder.build() {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let _ = ready_tx.send(Ok((
                    engine.graph().input_shape().clone(),
                    engine.graph().name.clone(),
                )));
                drop(ready_tx);
                // Supervised serve loop: `batch_loop` runs one replica
                // "life"; a crash (panic caught around execution) is
                // answered by rebuilding the replica from the builder
                // and going again. A shutdown token absorbed by the
                // crashed batch is honored — forgetting it is the
                // lost-restart race `fault::supervisor_protocol` pins
                // as BSL050.
                loop {
                    match batch_loop(
                        worker,
                        &mut engine,
                        &rx,
                        &stats,
                        max_wait,
                        faults.as_deref(),
                        &hook,
                    ) {
                        LoopExit::Shutdown => return,
                        LoopExit::Crashed { shutdown_pending } => {
                            if shutdown_pending {
                                return;
                            }
                            stats.health.rebuild_started();
                            let rebuilt = builder.build();
                            stats.health.rebuild_finished();
                            match rebuilt {
                                Ok(e) => engine = e,
                                Err(err) => {
                                    // Replica unrecoverable: stay live
                                    // answering typed errors so no
                                    // caller hangs, until shutdown.
                                    eprintln!(
                                        "server: worker {worker} replica rebuild failed: {err:#}; \
                                         draining with errors"
                                    );
                                    drain_with_errors(worker, &rx, &stats);
                                    return;
                                }
                            }
                        }
                    }
                }
            }));
        }
        drop(ready_tx);
        let mut input_shape: Option<(Shape, String)> = None;
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..workers {
            match ready_rx.recv() {
                Ok(Ok(shape)) => {
                    if input_shape.is_none() {
                        input_shape = Some(shape);
                    }
                }
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!("server worker died during startup"));
                    }
                    break;
                }
            }
        }
        let (input_shape, model) = match (input_shape, first_err) {
            (Some(pair), None) => pair,
            (_, err) => {
                // Tear down: dropping the only external sender
                // disconnects the queue, so idle workers exit.
                drop(tx);
                for j in joins {
                    let _ = j.join();
                }
                return Err(
                    err.unwrap_or_else(|| anyhow!("server worker died during startup"))
                );
            }
        };
        let batch = input_shape.batch();
        let mut dims = input_shape.dims.clone();
        dims[0] = 1;
        let handle = ServerHandle {
            tx: tx.clone(),
            image_shape: Shape::new(dims, input_shape.dtype),
            policy: queue_policy,
            capacity: queue_depth,
            stats: stats.clone(),
            closed: closed.clone(),
        };
        // Every replica built: the health machine leaves `Starting`.
        stats.health.set_ready();
        Ok(Server {
            handle,
            stats,
            batch,
            model,
            joins,
            shutdown: tx,
            closed,
            queue_depth,
            faults,
            obs,
        })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Compiled batch size `B` of the served network.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Name of the served network (the graph's `name`), used by the
    /// HTTP front door for model routing and `/v1/stats`.
    pub fn model_name(&self) -> &str {
        &self.model
    }

    /// Number of pool workers.
    pub fn workers(&self) -> usize {
        self.joins.len()
    }

    /// Batch occupancy over the server's own batch size.
    pub fn occupancy(&self) -> f64 {
        self.stats.occupancy(self.batch)
    }

    /// Bound of the dispatch queue — the capacity that
    /// [`suggested_retry_after`] scales against.
    pub fn queue_capacity(&self) -> usize {
        self.queue_depth
    }

    /// The armed fault injector, if any (`serve --fault-seed`).
    pub fn faults(&self) -> Option<Arc<FaultInjector>> {
        self.faults.clone()
    }

    /// The server's observability context: the always-on metrics
    /// registry (per-segment execution histograms for
    /// `GET /v1/metrics`) and — when tracing was armed via
    /// [`ServerConfig::obs`] — the recorded spans.
    pub fn obs(&self) -> Arc<crate::obs::Obs> {
        self.obs.clone()
    }

    /// Stop the server and join all workers. Graceful by construction:
    /// the shutdown gate is flipped under the write side of the
    /// `closed` lock *before* the per-worker shutdown tokens are sent,
    /// so every request whose enqueue succeeded (all of which happened
    /// under the read side, and therefore strictly before the tokens in
    /// the FIFO queue) is gathered and answered by a worker before that
    /// worker consumes a token and exits — no reply channel is ever
    /// dropped for an accepted request. Later `infer` calls fail fast
    /// with a clean "server stopped" error instead of racing the
    /// tokens.
    pub fn stop(mut self) {
        // Announce the drain before refusing work: a probe that races
        // `stop` may briefly see `draining` while its request still
        // lands, which is the benign direction (clients back off
        // early, no accepted request is lost).
        self.stats.health.set_draining();
        // Close the gate first: blocks until in-flight `try_infer`
        // enqueues (which hold the read side) land, then rejects
        // everything after — the tokens below are provably behind every
        // accepted request in the FIFO queue. `send_token` is a plain
        // send in production; under the model checker it tags the slot
        // so flipping these two steps is a BSL055 violation.
        self.closed.close();
        for _ in 0..self.joins.len() {
            if self.shutdown.send_token(Msg::Shutdown).is_err() {
                break;
            }
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// Declarative concurrency topology of one [`Server`] for the static
/// lint (`brainslug check` / [`crate::analysis::check_topology`]).
/// Mirrors exactly what [`Server::start`] spawns and what
/// [`Server::stop`] does, in order: flip the `closed` gate under the
/// write lock, send one shutdown token per worker on the bounded
/// dispatch queue, join the workers. Changing the threading model here
/// requires changing this model too — the lint keeps the two honest.
pub fn topology(workers: usize, queue_depth: usize) -> crate::analysis::Topology {
    use crate::analysis::{ExitCondition, ShutdownStep, Topology};
    Topology::new("server")
        .gate("closed")
        .thread("worker", workers, ExitCondition::TokenOn("dispatch".into()))
        .channel(
            "dispatch",
            queue_depth,
            &["main"],
            &["worker"],
            Some("closed"),
        )
        .on_shutdown(ShutdownStep::CloseGate("closed".into()))
        .on_shutdown(ShutdownStep::SendTokens {
            channel: "dispatch".into(),
            count: workers,
        })
        .on_shutdown(ShutdownStep::Join("worker".into()))
}

/// Bug switches for [`drain_protocol`]. `Default` (all `false`) is the
/// shipped protocol; each switch re-introduces one historical bug so the
/// model-check suite can prove the checker still finds them.
#[derive(Debug, Clone, Copy, Default)]
pub struct DrainBugs {
    /// Revert the PR 6 drain-ordering fix: send the per-worker shutdown
    /// tokens *before* closing the intake gate. A request admitted in
    /// the window lands behind a token in the FIFO queue and its reply
    /// channel is dropped — BSL055 (token on a still-open gate).
    pub tokens_before_gate: bool,
    /// Revert the PR 2 shutdown-while-queued fix: submit without the
    /// gate at all (and leave the channel unbound), so a request can
    /// enqueue after the tokens and strand in the queue when the last
    /// worker exits — BSL056 (non-quiescent join).
    pub ungated: bool,
}

/// Model-checked replica of the [`Server`] coordination protocol —
/// exactly the sync skeleton of [`Server::start`] / [`ServerHandle::try_infer`]
/// / [`Server::stop`] / [`batch_loop`], with engine execution replaced
/// by completing a [`crate::conc::sync::model::Obligation`] per
/// accepted request. Explored by `brainslug check --schedules` (clean
/// configuration) and the model-check test suite (bug configurations).
///
/// Also runs as a plain multi-threaded smoke test outside the model
/// (the facade falls back to `std::sync`).
pub fn drain_protocol(workers: usize, queue_depth: usize, requests: usize, bugs: DrainBugs) {
    use crate::conc::sync::{model, sync_channel_labeled};

    enum Job {
        Work(model::Obligation),
        Shutdown,
    }

    let gate = Arc::new(Gate::labeled("closed"));
    let (tx, rx) = sync_channel_labeled::<Job>(queue_depth, "dispatch");
    if !bugs.ungated {
        tx.bind_gate(&gate);
    }
    let rx = Arc::new(Mutex::labeled(rx, "dispatch-rx"));

    // Worker pool: the gather half of `batch_loop` (recv under the
    // shared queue mutex, one `Shutdown` consumed per worker).
    let mut pool = Vec::with_capacity(workers);
    for w in 0..workers {
        let rx = rx.clone();
        pool.push(model::spawn(&format!("worker-{w}"), move || loop {
            let msg = {
                let q = match rx.lock() {
                    Ok(q) => q,
                    Err(_) => return,
                };
                q.recv()
            };
            match msg {
                Ok(Job::Work(ob)) => ob.complete(),
                Ok(Job::Shutdown) | Err(_) => return,
            }
        }));
    }

    // Client: `try_infer`'s admission dance. Every *accepted* request
    // opens an obligation that only the serving worker completes; a
    // rejected request owes nothing.
    let client = {
        let gate = gate.clone();
        let tx = tx.clone();
        model::spawn("client", move || {
            for i in 0..requests {
                if bugs.ungated {
                    let _ = tx.send(Job::Work(model::obligation(&format!("request-{i}"))));
                } else {
                    match gate.enter() {
                        Some(_admitted) => {
                            // Hold the read side across the send, like
                            // `try_infer` — this is the FIFO fence.
                            let _ =
                                tx.send(Job::Work(model::obligation(&format!("request-{i}"))));
                        }
                        None => return, // stopped: reject fast, owe nothing
                    }
                }
            }
        })
    };

    // Shutdown (`Server::stop`), racing the client's submissions.
    if bugs.tokens_before_gate {
        for _ in 0..workers {
            let _ = tx.send_token(Job::Shutdown);
        }
        gate.close();
    } else {
        gate.close();
        for _ in 0..workers {
            let _ = tx.send_token(Job::Shutdown);
        }
    }
    client.join();
    for h in pool {
        h.join();
    }
}

/// Why one replica "life" of [`batch_loop`] ended — consumed by the
/// supervised outer loop in [`Server::start`].
enum LoopExit {
    /// A shutdown token was consumed (or the queue disconnected): the
    /// worker is done for good.
    Shutdown,
    /// Execution panicked; the in-flight batch has already been
    /// answered with [`InferError::WorkerCrashed`]. `shutdown_pending`
    /// is `true` when the crashed batch's gather had also absorbed a
    /// shutdown token — the supervisor must exit instead of restarting
    /// (otherwise the token is burned and `stop()` deadlocks: the
    /// lost-restart race pinned by `fault::supervisor_protocol`).
    Crashed { shutdown_pending: bool },
}

/// One worker's serve loop: lock the shared queue, gather up to `batch`
/// requests (or until `max_wait`), release the lock, shed expired
/// requests, execute, scatter. Execution happens outside the lock so
/// the pool overlaps batches, and is wrapped in `catch_unwind` so a
/// panicking replica answers its batch and reports to the supervisor
/// instead of stranding callers.
fn batch_loop(
    worker: usize,
    engine: &mut Engine,
    rx: &Arc<Mutex<Receiver<Msg>>>,
    stats: &Arc<ServerStats>,
    max_wait: Duration,
    faults: Option<&FaultInjector>,
    hook: &ObsHook,
) -> LoopExit {
    let in_shape = engine.graph().input_shape().clone();
    let batch = in_shape.batch();
    let image_elems = in_shape.numel() / batch;
    // Span shard for this worker thread, only when tracing is armed —
    // the untraced loop takes no clock reads and no recorder calls.
    let ts = hook
        .tracing
        .then(|| hook.obs.spans.thread(&format!("server-worker-{worker}")));
    // Per-segment metric series, cached per replica life so the
    // steady-state record path never touches the registry lock.
    let mut seg_hists: std::collections::HashMap<String, Arc<LatencyHistogram>> =
        std::collections::HashMap::new();
    loop {
        // Injection point `queue-stall`: a wedged dequeue. The queue
        // keeps admitting (and timing out) requests while this worker
        // sits out a beat, so backpressure and deadline shedding are
        // exercised for real.
        if let Some(f) = faults {
            if f.fire(FaultPoint::QueueStall) {
                std::thread::sleep(FaultInjector::stall());
            }
        }
        let (pending, shutdown_after) = {
            let q = match rx.lock() {
                Ok(q) => q,
                Err(_) => return LoopExit::Shutdown, // poisoned: peer panicked mid-gather
            };
            let first = match q.recv() {
                Ok(Msg::Infer(r)) => {
                    stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    r
                }
                Ok(Msg::Shutdown) | Err(_) => return LoopExit::Shutdown,
            };
            let mut pending = vec![first];
            let deadline = Instant::now() + max_wait;
            let mut shutdown_after = false;
            while pending.len() < batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match q.recv_timeout(deadline - now) {
                    Ok(Msg::Infer(r)) => {
                        stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
                        pending.push(r);
                    }
                    Ok(Msg::Shutdown) => {
                        shutdown_after = true;
                        break;
                    }
                    Err(_) => break,
                }
            }
            (pending, shutdown_after)
        };
        // Deadline sweep: answer expired requests with the typed 504
        // error *before* spending batch slots on them. Checked here —
        // after queue wait, before execution — because queue wait is
        // where deadlines actually die under load.
        let now = Instant::now();
        let mut live = Vec::with_capacity(pending.len());
        for r in pending {
            match r.deadline {
                Some(d) if now >= d => {
                    stats.deadline_dropped.fetch_add(1, Ordering::Relaxed);
                    let waited_ms = r.enqueued.elapsed().as_millis() as u64;
                    let _ = r.reply.send(Err(InferError::DeadlineExceeded { waited_ms }));
                }
                _ => live.push(r),
            }
        }
        if live.is_empty() {
            // Whole batch expired: nothing to run, but a consumed
            // shutdown token must still be honored.
            if shutdown_after {
                return LoopExit::Shutdown;
            }
            continue;
        }
        // Assemble the padded batch tensor.
        let mut data = vec![0.0f32; in_shape.numel()];
        for (i, r) in live.iter().enumerate() {
            data[i * image_elems..(i + 1) * image_elems].copy_from_slice(&r.image);
        }
        let input = HostTensor::new(in_shape.clone(), data);
        // Injection points `worker-panic` / `slow-exec` live inside the
        // unwind boundary with the engine: an injected panic takes the
        // exact recovery path a real mid-execution panic would.
        // `AssertUnwindSafe` is the supervision contract made explicit:
        // on unwind the engine is assumed poisoned and is *never run
        // again* — the supervisor rebuilds it from the builder.
        // The batch span (and the traced engine run) is attributed to
        // the first live request's trace id — one batch, one trace.
        let btrace = if ts.is_some() {
            live.first().map_or(0, |r| r.trace)
        } else {
            0
        };
        let t0 = ts.is_some().then(Instant::now);
        let exec = catch_unwind(AssertUnwindSafe(|| {
            if let Some(f) = faults {
                if f.fire(FaultPoint::WorkerPanic) {
                    panic!("injected fault: worker-panic");
                }
                if f.fire(FaultPoint::SlowExec) {
                    std::thread::sleep(FaultInjector::stall());
                }
            }
            engine.run_traced(input, btrace)
        }));
        if let (Some(ts), Some(t0)) = (ts.as_ref(), t0) {
            ts.record(crate::obs::SpanKind::Batch, "batch", btrace, t0);
        }
        match exec {
            Ok(Ok((out, exec_stats))) => {
                let out_elems = out.shape.numel() / batch;
                // Ordering: all Relaxed — independent statistical
                // counters (see the `ServerStats` contract). The reply
                // `send` two lines down is what publishes the result to
                // the caller; these counters piggyback no data.
                stats.batches.fetch_add(1, Ordering::Relaxed);
                stats.worker_batches[worker].fetch_add(1, Ordering::Relaxed);
                stats
                    .padded_slots
                    .fetch_add((batch - live.len()) as u64, Ordering::Relaxed);
                // Always-on per-segment metrics: one histogram series
                // per executed segment name, fed from the engine's own
                // `ExecStats` (measured on the CPU backend, modeled on
                // sim — either way `/v1/metrics` shows where batch time
                // goes).
                for seg in &exec_stats.segments {
                    let h = seg_hists.entry(seg.name.clone()).or_insert_with(|| {
                        hook.obs.metrics.histogram(
                            "brainslug_segment_seconds",
                            "Per-segment execution time of served batches.",
                            "segment",
                            &seg.name,
                        )
                    });
                    h.record((seg.seconds * 1e6) as u64);
                }
                let mut out_dims = out.shape.dims.clone();
                out_dims[0] = 1;
                for (i, r) in live.iter().enumerate() {
                    let slice = out.data[i * out_elems..(i + 1) * out_elems].to_vec();
                    let t =
                        HostTensor::new(Shape::new(out_dims.clone(), out.shape.dtype), slice);
                    stats.requests.fetch_add(1, Ordering::Relaxed);
                    let us = r.enqueued.elapsed().as_micros() as u64;
                    stats.latency_us_sum.fetch_add(us, Ordering::Relaxed);
                    stats.latency.record(us);
                    if let Some(ts) = ts.as_ref() {
                        // Request span: enqueue → reply, so queue wait
                        // is visible as the gap down to the Batch span.
                        ts.record(crate::obs::SpanKind::Request, "request", r.trace, r.enqueued);
                    }
                    let _ = r.reply.send(Ok(t));
                }
            }
            Ok(Err(e)) => {
                // Reply with an explicit error instead of dropping the
                // channels (which surfaced as a cryptic "receiving on an
                // empty and disconnected channel" at the caller).
                eprintln!("server: batch execution failed: {e:#}");
                let msg = format!("{e:#}");
                for r in &live {
                    let _ = r
                        .reply
                        .send(Err(InferError::Exec(format!("batch execution failed: {msg}"))));
                }
            }
            Err(_panic) => {
                // Count the crash *before* answering the batch, so any
                // client that observed `WorkerCrashed` is guaranteed to
                // find the matching restart in `/v1/stats` (the reply
                // send is the publishing edge; Relaxed RMWs done before
                // it are visible to the receiver-side reader).
                stats.restarts.fetch_add(1, Ordering::Relaxed);
                stats.worker_restarts[worker].fetch_add(1, Ordering::Relaxed);
                eprintln!("server: worker {worker} panicked mid-batch; answering batch and rebuilding");
                for r in &live {
                    let _ = r.reply.send(Err(InferError::WorkerCrashed { worker }));
                }
                return LoopExit::Crashed {
                    shutdown_pending: shutdown_after,
                };
            }
        }
        if shutdown_after {
            return LoopExit::Shutdown;
        }
    }
}

/// Last-resort serve loop for a worker whose replica could not be
/// rebuilt: keep draining the shared queue, answering every request
/// with the typed crash error (so no caller ever hangs on a dead
/// replica), until a shutdown token arrives.
fn drain_with_errors(worker: usize, rx: &Arc<Mutex<Receiver<Msg>>>, stats: &Arc<ServerStats>) {
    loop {
        let msg = {
            match rx.lock() {
                Ok(q) => q.recv(),
                Err(_) => return,
            }
        };
        match msg {
            Ok(Msg::Infer(r)) => {
                stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
                let _ = r.reply.send(Err(InferError::WorkerCrashed { worker }));
            }
            Ok(Msg::Shutdown) | Err(_) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench;
    use crate::device::DeviceSpec;
    use crate::engine::Engine;
    use crate::optimizer::CollapseOptions;

    // The histogram's own unit tests (bucket monotonicity, midpoint
    // tightness, percentile math) live with the shared implementation
    // in `obs::metrics`; here we only exercise the serving-side wiring.

    #[test]
    fn stats_json_shape() {
        let s = ServerStats::with_workers(2);
        s.requests.store(4, Ordering::Relaxed);
        s.batches.store(2, Ordering::Relaxed);
        s.rejected.store(1, Ordering::Relaxed);
        s.latency.record(2_000);
        let j = s.to_json(4);
        assert_eq!(j.usize_field("requests").unwrap(), 4);
        assert_eq!(j.usize_field("rejected").unwrap(), 1);
        assert_eq!(j.usize_field("batch").unwrap(), 4);
        assert_eq!(j.arr_field("worker_batches").unwrap().len(), 2);
        assert!(j.f64_field("p50_ms").unwrap() > 0.0);
        assert!(j.f64_field("p99_ms").unwrap() >= j.f64_field("p50_ms").unwrap());
        // The document round-trips through our own parser.
        let parsed = crate::json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.usize_field("requests").unwrap(), 4);
    }

    #[test]
    fn stats_math() {
        let s = ServerStats::default();
        s.requests.store(4, Ordering::Relaxed);
        s.latency_us_sum.store(8000, Ordering::Relaxed);
        s.batches.store(2, Ordering::Relaxed);
        s.padded_slots.store(4, Ordering::Relaxed);
        assert!((s.mean_latency_ms() - 2.0).abs() < 1e-9);
        assert!((s.occupancy(4) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn stats_empty_server_is_nan_free() {
        let s = ServerStats::with_workers(3);
        assert_eq!(s.mean_latency_ms(), 0.0);
        assert_eq!(s.occupancy(4), 0.0);
        // Degenerate batch size must not divide by zero either.
        assert_eq!(s.occupancy(0), 0.0);
        assert!(s.mean_latency_ms().is_finite());
        assert!(s.occupancy(0).is_finite());
        assert_eq!(s.worker_batches(), vec![0, 0, 0]);
    }

    /// Builder for a sim-backed engine over a tiny block network with
    /// batch `b` (unpaced).
    fn sim_engine(b: usize) -> crate::engine::EngineBuilder {
        Engine::builder()
            .graph_owned(bench::block_net(1, b, 2, 8))
            .device(DeviceSpec::tpu_core())
            .brainslug(CollapseOptions::default())
            .sim()
            .seed(11)
    }

    /// A single-worker sim server (the pre-pool configuration).
    fn sim_server(b: usize, max_wait: Duration) -> Server {
        ServerConfig::new(sim_engine(b))
            .max_wait(max_wait)
            .start()
            .unwrap()
    }

    /// Pacing scale that makes one batch of the `sim_engine` network
    /// cost roughly `target` seconds of wall-clock.
    fn pace_scale_for(b: usize, target: f64) -> f64 {
        let mut probe = sim_engine(b).build().unwrap();
        let input = probe.synthetic_input();
        let (_, st) = probe.run(input).unwrap();
        target / st.total_s.max(1e-12)
    }

    fn spawn_requests(
        server: &Server,
        n: usize,
    ) -> Vec<std::thread::JoinHandle<Result<HostTensor>>> {
        let elems = server.handle().image_shape().numel();
        (0..n)
            .map(|i| {
                let h = server.handle();
                std::thread::spawn(move || h.infer(vec![i as f32; elems]))
            })
            .collect()
    }

    #[test]
    fn sim_batching_fills_to_capacity() {
        let server = sim_server(4, Duration::from_secs(10));
        let workers = spawn_requests(&server, 4);
        for w in workers {
            let out = w.join().unwrap().unwrap();
            assert_eq!(out.shape.batch(), 1);
        }
        assert_eq!(server.stats.requests.load(Ordering::Relaxed), 4);
        assert_eq!(server.stats.batches.load(Ordering::Relaxed), 1);
        assert_eq!(server.stats.padded_slots.load(Ordering::Relaxed), 0);
        assert!((server.occupancy() - 1.0).abs() < 1e-9);
        assert!(server.stats.mean_latency_ms().is_finite());
        server.stop();
    }

    #[test]
    fn sim_timeout_closes_partial_batch() {
        let server = sim_server(4, Duration::from_millis(30));
        let out = server
            .handle()
            .infer(vec![1.0; server.handle().image_shape().numel()]);
        assert!(out.is_ok());
        assert_eq!(server.stats.requests.load(Ordering::Relaxed), 1);
        assert_eq!(server.stats.batches.load(Ordering::Relaxed), 1);
        // Three of four slots were zero-padding.
        assert_eq!(server.stats.padded_slots.load(Ordering::Relaxed), 3);
        assert!((server.occupancy() - 0.25).abs() < 1e-9);
        server.stop();
    }

    #[test]
    fn sim_padded_slot_accounting_across_batches() {
        let b = 4;
        let n = 5;
        let server = sim_server(b, Duration::from_millis(100));
        let workers = spawn_requests(&server, n);
        for w in workers {
            assert!(w.join().unwrap().is_ok());
        }
        let requests = server.stats.requests.load(Ordering::Relaxed);
        let batches = server.stats.batches.load(Ordering::Relaxed);
        let padded = server.stats.padded_slots.load(Ordering::Relaxed);
        assert_eq!(requests, n as u64);
        assert!(batches >= 2, "5 requests cannot fit one batch of 4");
        // Conservation: every slot is either a request or padding.
        assert_eq!(batches * b as u64, requests + padded);
        server.stop();
    }

    #[test]
    fn sim_clean_shutdown_with_cloned_handles() {
        let server = sim_server(2, Duration::from_millis(10));
        let h1 = server.handle();
        let h2 = h1.clone();
        assert!(h1.infer(vec![0.0; h1.image_shape().numel()]).is_ok());
        server.stop();
        // Cloned handles outlive the server but become inert.
        let err = h2.infer(vec![0.0; h2.image_shape().numel()]).unwrap_err();
        assert!(err.to_string().contains("server stopped"), "{err}");
    }

    #[test]
    fn wrong_image_size_rejected_without_touching_server() {
        let server = sim_server(2, Duration::from_millis(10));
        let err = server.handle().infer(vec![0.0; 3]).unwrap_err();
        assert!(err.to_string().contains("elements"), "{err}");
        assert_eq!(server.stats.requests.load(Ordering::Relaxed), 0);
        server.stop();
    }

    #[test]
    fn pjrt_build_error_reported_through_start() {
        let engine = Engine::builder()
            .graph_owned(bench::block_net(1, 2, 2, 8))
            .artifacts("/nonexistent/artifact/dir");
        let err = ServerConfig::new(engine).workers(3).start().unwrap_err();
        assert!(err.to_string().contains("manifest"), "{err}");
    }

    #[test]
    fn worker_pool_stress_slot_conservation() {
        let b = 4;
        let pool = 4;
        let n = 64;
        let server = ServerConfig::new(sim_engine(b))
            .workers(pool)
            .queue_depth(2 * b)
            .max_wait(Duration::from_millis(5))
            .start()
            .unwrap();
        assert_eq!(server.workers(), pool);
        let clients = spawn_requests(&server, n);
        for c in clients {
            assert!(c.join().unwrap().is_ok());
        }
        let requests = server.stats.requests.load(Ordering::Relaxed);
        let batches = server.stats.batches.load(Ordering::Relaxed);
        let padded = server.stats.padded_slots.load(Ordering::Relaxed);
        assert_eq!(requests, n as u64);
        // Slot conservation holds across all workers.
        assert_eq!(batches * b as u64, requests + padded);
        let occ = server.occupancy();
        assert!((0.0..=1.0).contains(&occ), "occupancy {occ} out of range");
        // Per-worker counters sum to the aggregate batch count.
        let per: u64 = server.stats.worker_batches().iter().sum();
        assert_eq!(per, batches);
        assert!(server.stats.mean_latency_ms().is_finite());
        server.stop();
    }

    #[test]
    fn shutdown_with_queued_requests_is_clean() {
        // Paced batches occupy real time, so requests pile up in the
        // bounded queue; stopping mid-flood must neither hang nor leave
        // any caller without a reply (success or a clean error).
        let b = 2;
        let scale = pace_scale_for(b, 0.01);
        let server = ServerConfig::new(sim_engine(b).sim_paced(scale))
            .workers(2)
            .queue_depth(2)
            .max_wait(Duration::from_millis(1))
            .start()
            .unwrap();
        let stats = server.stats.clone();
        let clients = spawn_requests(&server, 12);
        std::thread::sleep(Duration::from_millis(5));
        server.stop();
        let mut served = 0u64;
        for c in clients {
            match c.join().unwrap() {
                Ok(_) => served += 1,
                Err(e) => {
                    assert!(e.to_string().contains("server stopped"), "{e}");
                }
            }
        }
        let requests = stats.requests.load(Ordering::Relaxed);
        let batches = stats.batches.load(Ordering::Relaxed);
        let padded = stats.padded_slots.load(Ordering::Relaxed);
        assert_eq!(served, requests);
        assert_eq!(batches * b as u64, requests + padded);
    }

    #[test]
    fn reject_policy_fails_fast_when_queue_full() {
        // One slow worker (paced, ~50 ms/batch), queue depth 1: the
        // first request occupies the worker, the second the queue, the
        // third must be rejected immediately.
        let scale = pace_scale_for(1, 0.05);
        let server = ServerConfig::new(sim_engine(1).sim_paced(scale))
            .workers(1)
            .queue_depth(1)
            .queue_policy(QueuePolicy::Reject)
            .max_wait(Duration::from_millis(1))
            .start()
            .unwrap();
        let elems = server.handle().image_shape().numel();
        let running = spawn_requests(&server, 1);
        std::thread::sleep(Duration::from_millis(10)); // worker picked it up
        let queued = spawn_requests(&server, 1);
        std::thread::sleep(Duration::from_millis(10)); // queue slot taken
        let t0 = Instant::now();
        let err = server.handle().infer(vec![0.0; elems]).unwrap_err();
        assert!(err.to_string().contains("queue full"), "{err}");
        assert!(
            t0.elapsed() < Duration::from_millis(40),
            "reject must not wait for the running batch"
        );
        assert_eq!(server.stats.rejected.load(Ordering::Relaxed), 1);
        for c in running.into_iter().chain(queued) {
            assert!(c.join().unwrap().is_ok());
        }
        assert!(server.stats.queue_peak.load(Ordering::Relaxed) >= 1);
        server.stop();
    }

    #[test]
    fn shutdown_while_queued_drains_every_accepted_request() {
        // Regression for the graceful-drain fix: requests whose enqueue
        // succeeded before `stop()` must all complete with a real
        // result — none may observe a dropped reply channel ("server
        // stopped before the request completed"). One slow worker
        // (paced ~30 ms/batch, batch 1) and a roomy queue, so all three
        // requests enqueue immediately and two are still queued when
        // stop() lands.
        let scale = pace_scale_for(1, 0.03);
        let server = ServerConfig::new(sim_engine(1).sim_paced(scale))
            .workers(1)
            .queue_depth(4)
            .max_wait(Duration::from_millis(1))
            .start()
            .unwrap();
        let stats = server.stats.clone();
        let clients = spawn_requests(&server, 3);
        // Wait until every request is accepted (in the queue or on the
        // worker) before stopping.
        let t0 = Instant::now();
        while stats.queue_depth_now() + stats.requests.load(Ordering::Relaxed) < 2 {
            assert!(t0.elapsed() < Duration::from_secs(5), "requests never enqueued");
            std::thread::sleep(Duration::from_millis(1));
        }
        // Settle: the queue has room for all three, so the last send (a
        // few µs behind its siblings) lands well inside this window.
        std::thread::sleep(Duration::from_millis(15));
        let handle = server.handle();
        server.stop();
        for c in clients {
            let out = c.join().unwrap();
            assert!(out.is_ok(), "accepted request dropped: {:?}", out.err());
        }
        assert_eq!(stats.requests.load(Ordering::Relaxed), 3);
        // Post-stop submissions fail fast with the clean typed error.
        let err = handle
            .try_infer(vec![0.0; handle.image_shape().numel()])
            .unwrap_err();
        assert!(matches!(err, InferError::Stopped), "{err}");
        // Latency percentiles were recorded for the drained requests.
        let (p50, _, p99) = stats.latency_percentiles_ms();
        assert!(p50 > 0.0 && p99 >= p50);
    }

    #[test]
    fn batch_failure_reports_explicit_error() {
        // Force a failing `engine.run` with an injected backend and
        // drive `batch_loop` directly: the blocked caller must receive
        // an explicit batch-execution-failed error, not a cryptic
        // disconnected-channel error.
        struct FailingBackend;
        impl crate::engine::Backend for FailingBackend {
            fn name(&self) -> &'static str {
                "fail"
            }
            fn run(
                &mut self,
                _work: &crate::engine::Workload,
                _input: HostTensor,
            ) -> Result<(HostTensor, crate::scheduler::ExecStats)> {
                anyhow::bail!("injected backend failure")
            }
        }
        let mut failing = sim_engine(2)
            .build_with(|_, _, _| Ok(Box::new(FailingBackend) as Box<dyn crate::engine::Backend>))
            .unwrap();
        let (tx, rx) = crate::conc::sync::sync_channel(4);
        let (reply_tx, reply_rx) = channel();
        let stats = Arc::new(ServerStats::with_workers(1));
        let elems = failing.graph().input_shape().numel() / 2;
        stats.queue_depth.fetch_add(1, Ordering::Relaxed);
        tx.send(Msg::Infer(Request {
            image: vec![0.0; elems],
            reply: reply_tx,
            enqueued: Instant::now(),
            deadline: None,
            trace: 0,
        }))
        .unwrap();
        drop(tx);
        let rx = Arc::new(Mutex::new(rx));
        let hook = ObsHook {
            obs: Arc::default(),
            tracing: false,
        };
        let exit = batch_loop(
            0,
            &mut failing,
            &rx,
            &stats,
            Duration::from_millis(1),
            None,
            &hook,
        );
        assert!(matches!(exit, LoopExit::Shutdown), "bail!-errors do not crash the replica");
        let reply = reply_rx.recv().unwrap();
        let err = reply.unwrap_err();
        assert!(matches!(err, InferError::Exec(_)), "{err:?}");
        assert!(
            err.to_string().contains("batch execution failed"),
            "caller must see an explicit batch failure, got: {err}"
        );
        assert!(err.to_string().contains("injected backend failure"), "{err}");
        // Failed batches are not counted as served.
        assert_eq!(stats.requests.load(Ordering::Relaxed), 0);
        assert_eq!(stats.batches.load(Ordering::Relaxed), 0);
        assert_eq!(stats.queue_depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fault_injected_worker_panic_is_supervised_and_survives() {
        // A triggered panic crashes the replica mid-batch: the caller
        // gets the typed `WorkerCrashed` error (not a hang, not a
        // disconnected channel), the supervisor rebuilds the replica,
        // and the next request is served normally. Restart accounting
        // matches the injected panic count exactly.
        let inj = Arc::new(crate::fault::FaultInjector::new(crate::fault::seed_from_env(42)));
        let server = ServerConfig::new(sim_engine(2))
            .workers(1)
            .max_wait(Duration::from_millis(1))
            .faults(inj.clone())
            .start()
            .unwrap();
        let elems = server.handle().image_shape().numel();
        inj.trigger(FaultPoint::WorkerPanic);
        let err = server.handle().try_infer(vec![0.0; elems]).unwrap_err();
        assert!(matches!(err, InferError::WorkerCrashed { worker: 0 }), "{err:?}");
        // The crash was counted before the reply was sent.
        assert_eq!(server.stats.restarts.load(Ordering::Relaxed), 1);
        assert_eq!(server.stats.worker_restarts(), vec![1]);
        assert_eq!(inj.fired(FaultPoint::WorkerPanic), 1);
        // The rebuilt replica serves the retry.
        let out = server.handle().try_infer(vec![0.0; elems]).unwrap();
        assert_eq!(out.shape.batch(), 1);
        assert_eq!(server.stats.requests.load(Ordering::Relaxed), 1);
        server.stop();
    }

    #[test]
    fn fault_crash_during_shutdown_still_drains_cleanly() {
        // Storm-while-stopping: panics on every batch must not lose
        // shutdown tokens (the lost-restart race) — `stop()` joins all
        // workers and every accepted request is answered, all with
        // typed errors. Run a few rounds to give the races air.
        for round in 0..3 {
            let inj = Arc::new(crate::fault::FaultInjector::new(
                crate::fault::seed_from_env(7).wrapping_add(round),
            ));
            inj.set_rate(FaultPoint::WorkerPanic, 1.0);
            let server = ServerConfig::new(sim_engine(2))
                .workers(2)
                .queue_depth(4)
                .max_wait(Duration::from_millis(1))
                .faults(inj)
                .start()
                .unwrap();
            let clients = spawn_requests(&server, 6);
            std::thread::sleep(Duration::from_millis(2));
            server.stop(); // must not hang: tokens survive the crashes
            for c in clients {
                let err = c.join().unwrap().unwrap_err();
                let msg = err.to_string();
                assert!(
                    msg.contains("crashed mid-batch") || msg.contains("server stopped"),
                    "round {round}: unexpected error {msg}"
                );
            }
        }
    }

    #[test]
    fn fault_deadline_expired_in_queue_is_shed_with_typed_error() {
        // One slow worker (paced ~30 ms/batch): the first request
        // occupies it, the second carries a 5 ms deadline and expires
        // in the queue — the worker sheds it without running it.
        let scale = pace_scale_for(1, 0.03);
        let server = ServerConfig::new(sim_engine(1).sim_paced(scale))
            .workers(1)
            .queue_depth(4)
            .max_wait(Duration::from_millis(1))
            .start()
            .unwrap();
        let elems = server.handle().image_shape().numel();
        let running = spawn_requests(&server, 1);
        std::thread::sleep(Duration::from_millis(10)); // worker busy
        let h = server.handle();
        let err = h
            .try_infer_deadline(
                vec![0.0; elems],
                Some(Instant::now() + Duration::from_millis(5)),
            )
            .unwrap_err();
        match err {
            InferError::DeadlineExceeded { waited_ms } => {
                assert!(waited_ms >= 5, "shed before the deadline: {waited_ms} ms")
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(server.stats.deadline_dropped.load(Ordering::Relaxed), 1);
        for c in running {
            assert!(c.join().unwrap().is_ok());
        }
        // The shed request was never executed: one request served.
        assert_eq!(server.stats.requests.load(Ordering::Relaxed), 1);
        server.stop();
    }

    #[test]
    fn fault_expired_deadline_rejected_at_admission() {
        let server = sim_server(2, Duration::from_millis(1));
        let elems = server.handle().image_shape().numel();
        let err = server
            .handle()
            .try_infer_deadline(vec![0.0; elems], Some(Instant::now() - Duration::from_millis(1)))
            .unwrap_err();
        assert!(matches!(err, InferError::DeadlineExceeded { waited_ms: 0 }), "{err:?}");
        assert_eq!(server.stats.deadline_dropped.load(Ordering::Relaxed), 1);
        assert_eq!(server.stats.requests.load(Ordering::Relaxed), 0);
        server.stop();
    }

    #[test]
    fn fault_health_machine_walks_ready_to_draining() {
        let server = sim_server(2, Duration::from_millis(1));
        let stats = server.stats.clone();
        assert_eq!(stats.health.phase(), HealthPhase::Ready);
        assert!(stats.health.is_serving());
        server.stop();
        assert_eq!(stats.health.phase(), HealthPhase::Draining);
        assert!(!stats.health.is_serving());
        // Degraded is derived from the rebuild gauge, and clears.
        let fresh = ServerStats::with_workers(1);
        fresh.health.set_ready();
        fresh.health.rebuild_started();
        assert_eq!(fresh.health.phase(), HealthPhase::Degraded);
        fresh.health.rebuild_finished();
        assert_eq!(fresh.health.phase(), HealthPhase::Ready);
    }

    #[test]
    fn server_obs_records_spans_and_segment_metrics() {
        // Tracing armed: the batch loop records a Request span per
        // served request and a Batch span around execution, both
        // carrying the caller's trace id; the metrics registry picks up
        // one per-segment histogram series per executed segment (the
        // sim backend reports modeled per-layer stats, so this works
        // artifact-free).
        let obs = Arc::new(crate::obs::Obs::default());
        let server = ServerConfig::new(sim_engine(2))
            .max_wait(Duration::from_millis(1))
            .obs(obs.clone())
            .start()
            .unwrap();
        let h = server.handle();
        let elems = h.image_shape().numel();
        let out = h
            .try_infer_deadline_traced(vec![0.0; elems], None, 0xBEEF)
            .unwrap();
        assert_eq!(out.shape.batch(), 1);
        server.stop();
        assert!(obs.metrics.series_count() > 0, "no per-segment series registered");
        let spans = obs.spans.drain();
        let req: Vec<_> = spans
            .iter()
            .filter(|s| s.kind == crate::obs::SpanKind::Request)
            .collect();
        assert_eq!(req.len(), 1, "one served request, one Request span");
        assert_eq!(req[0].trace, 0xBEEF);
        assert!(
            spans
                .iter()
                .any(|s| s.kind == crate::obs::SpanKind::Batch && s.trace == 0xBEEF),
            "batch span missing or unattributed"
        );
    }

    #[test]
    fn untraced_server_still_counts_segment_metrics_but_no_spans() {
        // Default (no `.obs()`): spans stay off — the internal context
        // records none — but the per-segment metric series still fill,
        // so `/v1/metrics` is useful without ever arming tracing.
        let server = ServerConfig::new(sim_engine(2))
            .max_wait(Duration::from_millis(1))
            .start()
            .unwrap();
        let h = server.handle();
        assert!(h.infer(vec![0.0; h.image_shape().numel()]).is_ok());
        let obs = server.obs();
        server.stop();
        assert!(obs.metrics.series_count() > 0);
        assert!(obs.spans.drain().is_empty(), "untraced server recorded spans");
    }

    #[test]
    fn retry_after_scales_with_queue_depth() {
        assert_eq!(suggested_retry_after(0, 64), 1);
        assert_eq!(suggested_retry_after(32, 64), 3);
        assert_eq!(suggested_retry_after(64, 64), 5);
        // Transient over-capacity readings clamp instead of exploding.
        assert_eq!(suggested_retry_after(10_000, 64), 8);
        // Degenerate capacity must not divide by zero.
        assert_eq!(suggested_retry_after(3, 0), 8);
    }
}
