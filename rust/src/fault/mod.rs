//! Deterministic, seeded fault injection for the serving stack.
//!
//! The serving path (dispatch queue → worker pool → engine replica →
//! HTTP front door) has a handful of places where production reality
//! diverges from the happy path: a worker panics mid-batch, a backend
//! stalls, the dispatch queue wedges, a client socket dies mid-read, a
//! kernel write buffer fills and `write` returns short. Each of those
//! is a named [`FaultPoint`] here; the stack consults one shared
//! [`FaultInjector`] at every point and the injector decides — from a
//! fixed seed and a per-point draw counter, never from wall-clock or OS
//! randomness — whether the fault fires.
//!
//! Design constraints:
//!
//! - **Zero-cost when disabled.** Every consumer holds an
//!   `Option<Arc<FaultInjector>>`; the unarmed path is a `None` branch
//!   with no atomics touched and no RNG advanced.
//! - **Deterministic per (seed, point, draw index).** The decision for
//!   draw *k* at point *p* is a pure function of `(seed, p, k)` hashed
//!   through [`crate::rng::splitmix64`], so the multiset of outcomes
//!   over the first *N* draws is identical across runs and thread
//!   interleavings — which is what lets `fig21_fault_recovery` assert
//!   `restarts == fired(WorkerPanic)` exactly.
//! - **Runtime-adjustable.** Rates are `f64` bits in atomics so a bench
//!   can raise a fault storm, then calm it, on a live server.
//! - **Triggerable.** [`FaultInjector::trigger`] queues a one-shot
//!   fire, consumed by the next draw at that point regardless of rate —
//!   the hook behind the `x-brainslug-fault` request header and the
//!   `bench-serve --single` crash drill.
//!
//! The module also carries [`supervisor_protocol`]: the model-checked
//! replica of the worker-supervision restart dance (see
//! [`crate::server`]), explored by `brainslug check --schedules` with a
//! bug switch that re-introduces the lost-shutdown-token restart race.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::json::Json;

/// How long an injected [`FaultPoint::SlowExec`] stalls a worker and an
/// injected [`FaultPoint::QueueStall`] stalls a dequeue. Long enough to
/// be visible in latency percentiles, short enough that a seeded storm
/// in CI stays inside the test budget.
pub const SLOW_EXEC_MS: u64 = 20;

/// A named place in the serving stack where a fault can be injected.
///
/// The discriminant doubles as the index into the injector's per-point
/// counter arrays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPoint {
    /// Panic inside a worker's batch execution (`server::batch_loop`),
    /// after the batch is gathered and before the engine runs.
    WorkerPanic = 0,
    /// Sleep [`SLOW_EXEC_MS`] inside batch execution — a stalled
    /// backend that holds the batch (and its callers) hostage.
    SlowExec = 1,
    /// Sleep [`SLOW_EXEC_MS`] before a worker locks the dispatch queue
    /// — a wedged dequeue that lets the bounded queue fill and exert
    /// backpressure.
    QueueStall = 2,
    /// Drop an accepted HTTP connection before reading the next
    /// request — the client sees a reset/EOF mid-exchange.
    SocketReset = 3,
    /// Route the HTTP response through a writer that chops writes into
    /// short fragments and interleaves `ErrorKind::Interrupted` — the
    /// wire writer must reassemble the full response regardless.
    PartialWrite = 4,
}

const NUM_POINTS: usize = 5;

impl FaultPoint {
    /// Every injection point, in discriminant order.
    pub const ALL: [FaultPoint; NUM_POINTS] = [
        FaultPoint::WorkerPanic,
        FaultPoint::SlowExec,
        FaultPoint::QueueStall,
        FaultPoint::SocketReset,
        FaultPoint::PartialWrite,
    ];

    /// Stable kebab-case name — the `x-brainslug-fault` header value
    /// and the key in the `/v1/stats` `fault_injection` object.
    pub fn name(self) -> &'static str {
        match self {
            FaultPoint::WorkerPanic => "worker-panic",
            FaultPoint::SlowExec => "slow-exec",
            FaultPoint::QueueStall => "queue-stall",
            FaultPoint::SocketReset => "socket-reset",
            FaultPoint::PartialWrite => "partial-write",
        }
    }

    /// Inverse of [`Self::name`].
    pub fn parse(name: &str) -> Option<FaultPoint> {
        FaultPoint::ALL.into_iter().find(|p| p.name() == name)
    }

    /// Per-point salt mixed into the draw hash so two points with the
    /// same seed and draw index decide independently.
    fn salt(self) -> u64 {
        (self as u64 + 1).wrapping_mul(0xA24B_AED4_963E_E407)
    }
}

/// Seeded fault-injection state shared across the serving stack.
///
/// ## Memory-ordering contract (audited)
///
/// Every atomic here is `Ordering::Relaxed`, for the same reasons as
/// the [`crate::server::ServerStats`] contract: each cell is an
/// independent counter (`draws`, `fired`, `pending`) or an
/// independently-read configuration value (`rates`); no reader derives
/// a cross-cell invariant mid-run, and nothing is published *through*
/// these atomics — the fault itself (a panic, a sleep, a dropped
/// socket) is the observable effect, not data guarded by the counter.
/// Cross-thread visibility of final counts is established by the
/// thread joins that precede every assertion on them. `fetch_add` /
/// `fetch_update` are atomic read-modify-writes at every ordering, so
/// draws are never double-assigned and one-shot triggers fire exactly
/// once.
#[derive(Debug)]
pub struct FaultInjector {
    seed: u64,
    /// Per-point firing probability in `[0, 1]`, stored as `f64` bits.
    rates: [AtomicU64; NUM_POINTS],
    /// Per-point count of decisions taken (fired or not).
    draws: [AtomicU64; NUM_POINTS],
    /// Per-point count of decisions that fired.
    fired: [AtomicU64; NUM_POINTS],
    /// Per-point queued one-shot triggers (fire regardless of rate).
    pending: [AtomicU64; NUM_POINTS],
}

impl FaultInjector {
    /// A quiescent injector: armed (consumers will consult it) but with
    /// every rate at zero, so only [`Self::trigger`] fires anything.
    pub fn new(seed: u64) -> Self {
        FaultInjector {
            seed,
            rates: std::array::from_fn(|_| AtomicU64::new(0f64.to_bits())),
            draws: std::array::from_fn(|_| AtomicU64::new(0)),
            fired: std::array::from_fn(|_| AtomicU64::new(0)),
            pending: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Set the firing probability for one point (clamped to `[0, 1]`).
    /// Takes effect for subsequent draws; in-flight draws may use the
    /// old rate (benign — rates are advisory storm knobs).
    pub fn set_rate(&self, point: FaultPoint, rate: f64) {
        let clamped = if rate.is_finite() { rate.clamp(0.0, 1.0) } else { 0.0 };
        self.rates[point as usize].store(clamped.to_bits(), Ordering::Relaxed);
    }

    /// Current firing probability for one point.
    pub fn rate(&self, point: FaultPoint) -> f64 {
        f64::from_bits(self.rates[point as usize].load(Ordering::Relaxed))
    }

    /// Queue a one-shot fire: the next [`Self::fire`] call at `point`
    /// returns `true` regardless of the configured rate.
    pub fn trigger(&self, point: FaultPoint) {
        self.pending[point as usize].fetch_add(1, Ordering::Relaxed);
    }

    /// Decide whether the fault at `point` fires for this visit.
    ///
    /// One-shot triggers are consumed first; otherwise the decision is
    /// the pure hash of `(seed, point, draw index)` compared against
    /// the point's rate, so a fixed seed replays the same outcome
    /// sequence run after run.
    pub fn fire(&self, point: FaultPoint) -> bool {
        let i = point as usize;
        if self.pending[i].load(Ordering::Relaxed) > 0 {
            let took = self.pending[i]
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |p| p.checked_sub(1));
            if took.is_ok() {
                self.fired[i].fetch_add(1, Ordering::Relaxed);
                return true;
            }
        }
        let draw = self.draws[i].fetch_add(1, Ordering::Relaxed);
        let rate = f64::from_bits(self.rates[i].load(Ordering::Relaxed));
        if rate <= 0.0 {
            return false;
        }
        let mut s = self
            .seed
            .wrapping_add(point.salt())
            .wrapping_add(draw.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let unit = (crate::rng::splitmix64(&mut s) >> 11) as f64 / (1u64 << 53) as f64;
        if unit < rate {
            self.fired[i].fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// How many times `point` has fired (rate draws plus one-shots).
    pub fn fired(&self, point: FaultPoint) -> u64 {
        self.fired[point as usize].load(Ordering::Relaxed)
    }

    /// How many rate decisions have been taken at `point`.
    pub fn draws(&self, point: FaultPoint) -> u64 {
        self.draws[point as usize].load(Ordering::Relaxed)
    }

    /// The `fault_injection` object in `GET /v1/stats`: the seed plus
    /// per-point `{rate, draws, fired}`.
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("seed", Json::Num(self.seed as f64));
        let mut points = Json::object();
        for p in FaultPoint::ALL {
            let mut e = Json::object();
            e.set("rate", Json::Num(self.rate(p)));
            e.set("draws", Json::Num(self.draws(p) as f64));
            e.set("fired", Json::Num(self.fired(p) as f64));
            points.set(p.name(), e);
        }
        o.set("points", points);
        o
    }

    /// The injected stall duration for [`FaultPoint::SlowExec`] /
    /// [`FaultPoint::QueueStall`].
    pub fn stall() -> Duration {
        Duration::from_millis(SLOW_EXEC_MS)
    }
}

/// Seed override for the CI fault matrix: `BRAINSLUG_FAULT_SEED` when
/// set and parseable, else `default`. The supervision and recovery
/// guarantees must hold for *every* seed; CI sweeps a few.
pub fn seed_from_env(default: u64) -> u64 {
    std::env::var("BRAINSLUG_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Bug switches for [`supervisor_protocol`]. `Default` (all `false`) is
/// the shipped supervision protocol; each switch re-introduces one
/// pre-fix behavior so the model-check suite can prove the checker
/// still finds it.
#[derive(Debug, Clone, Copy, Default)]
pub struct SupervisorBugs {
    /// Re-introduce the lost-restart race: a worker that crashes on a
    /// batch which also absorbed a shutdown token *forgets* the token
    /// when it restarts. The token is burned, the reborn worker blocks
    /// in `recv` forever, and the supervisor's `join` deadlocks —
    /// BSL050 (model deadlock).
    pub lose_shutdown_on_crash: bool,
    /// Drop the in-flight batch on a crash instead of answering every
    /// gathered request with a typed error: the callers' obligations
    /// stay open at join time — BSL056 (non-quiescent join).
    pub drop_inflight_on_crash: bool,
}

/// Outcome of one supervised worker "life" in the protocol replica —
/// mirrors `server::LoopExit`.
enum Exit {
    /// A shutdown token was consumed (or the queue disconnected).
    Shutdown,
    /// The worker crashed mid-batch. `shutdown_pending` records whether
    /// the crashed batch's gather had already absorbed a shutdown
    /// token — the supervisor must honor it instead of restarting.
    Crashed { shutdown_pending: bool },
}

/// Model-checked replica of the worker-supervision protocol — the sync
/// skeleton of the supervised outer loop in [`crate::server`]: workers
/// gather up to two jobs per batch from the shared bounded queue,
/// "crash" on poison jobs (answering the gathered batch with typed
/// errors, i.e. completing the obligations), and are restarted by the
/// supervisor unless the crashed batch had absorbed a shutdown token.
/// `crashes` poison jobs and `requests` normal jobs race the stop
/// sequence (close gate, send one token per worker, join).
///
/// Explored by `brainslug check --schedules` in the shipped
/// configuration and by the model-check test suite with [`SupervisorBugs`].
pub fn supervisor_protocol(
    workers: usize,
    queue_depth: usize,
    requests: usize,
    crashes: usize,
    bugs: SupervisorBugs,
) {
    use crate::conc::sync::{model, sync_channel_labeled, Gate, Mutex, Receiver};
    use std::sync::Arc;

    struct WorkJob {
        ob: model::Obligation,
        poison: bool,
    }
    enum Job {
        Work(WorkJob),
        Shutdown,
    }

    /// One batch_loop "life": gather, execute-or-crash, repeat until a
    /// token or a crash ends it. Extracted so the supervised outer loop
    /// below reads like `Server`'s worker thread.
    fn life(rx: &Mutex<Receiver<Job>>, bugs: SupervisorBugs) -> Exit {
        loop {
            // Gather under one lock hold, like `batch_loop`: a first
            // job via blocking recv, then at most one more via the
            // batch-window timeout (which the model may fire
            // immediately — both orders are explored).
            let (batch, shutdown_after) = {
                let q = match rx.lock() {
                    Ok(q) => q,
                    Err(_) => return Exit::Shutdown,
                };
                let first = match q.recv() {
                    Ok(Job::Work(j)) => j,
                    Ok(Job::Shutdown) | Err(_) => return Exit::Shutdown,
                };
                let mut batch = vec![first];
                let mut shutdown_after = false;
                match q.recv_timeout(Duration::from_millis(1)) {
                    Ok(Job::Work(j)) => batch.push(j),
                    Ok(Job::Shutdown) => shutdown_after = true,
                    Err(_) => {}
                }
                (batch, shutdown_after)
            };
            // "Execute": a poison job crashes the replica. The fixed
            // protocol still answers every gathered request (completes
            // the obligation) and still honors an absorbed token.
            let crashed = batch.iter().any(|j| j.poison);
            for j in batch {
                if crashed && bugs.drop_inflight_on_crash {
                    drop(j.ob); // bug: callers stranded without a reply
                } else {
                    j.ob.complete();
                }
            }
            if crashed {
                let pending = if bugs.lose_shutdown_on_crash {
                    false // bug: the absorbed token is forgotten
                } else {
                    shutdown_after
                };
                return Exit::Crashed {
                    shutdown_pending: pending,
                };
            }
            if shutdown_after {
                return Exit::Shutdown;
            }
        }
    }

    let gate = Arc::new(Gate::labeled("closed"));
    let (tx, rx) = sync_channel_labeled::<Job>(queue_depth, "dispatch");
    tx.bind_gate(&gate);
    let rx = Arc::new(Mutex::labeled(rx, "dispatch-rx"));

    // Supervised worker pool: each thread is the outer loop of
    // `Server`'s worker — run one life, and on a crash rebuild the
    // replica (modeled as looping) unless the crashed batch had
    // absorbed a shutdown token.
    let mut pool = Vec::with_capacity(workers);
    for w in 0..workers {
        let rx = rx.clone();
        pool.push(model::spawn(&format!("worker-{w}"), move || loop {
            match life(&rx, bugs) {
                Exit::Shutdown | Exit::Crashed {
                    shutdown_pending: true,
                } => return,
                Exit::Crashed {
                    shutdown_pending: false,
                } => {} // restart: next life
            }
        }));
    }

    // Client: gated submissions, poison first so crashes race the stop
    // sequence. Every accepted job opens an obligation the serving (or
    // crashing) worker must complete.
    let client = {
        let gate = gate.clone();
        let tx = tx.clone();
        model::spawn("client", move || {
            for i in 0..crashes + requests {
                match gate.enter() {
                    Some(_admitted) => {
                        let _ = tx.send(Job::Work(WorkJob {
                            ob: model::obligation(&format!("request-{i}")),
                            poison: i < crashes,
                        }));
                    }
                    None => return, // stopped: reject fast, owe nothing
                }
            }
        })
    };

    // Shutdown (`Server::stop`), racing the client and the crashes.
    gate.close();
    for _ in 0..workers {
        let _ = tx.send_token(Job::Shutdown);
    }
    client.join();
    for h in pool {
        h.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_never_fires_and_counts_draws() {
        let inj = FaultInjector::new(seed_from_env(7));
        for _ in 0..100 {
            assert!(!inj.fire(FaultPoint::WorkerPanic));
        }
        assert_eq!(inj.fired(FaultPoint::WorkerPanic), 0);
        assert_eq!(inj.draws(FaultPoint::WorkerPanic), 100);
    }

    #[test]
    fn unit_rate_always_fires() {
        let inj = FaultInjector::new(seed_from_env(7));
        inj.set_rate(FaultPoint::SlowExec, 1.0);
        for _ in 0..50 {
            assert!(inj.fire(FaultPoint::SlowExec));
        }
        assert_eq!(inj.fired(FaultPoint::SlowExec), 50);
    }

    #[test]
    fn fire_sequence_is_deterministic_per_seed() {
        let seed = seed_from_env(42);
        let run = |n: usize| -> Vec<bool> {
            let inj = FaultInjector::new(seed);
            inj.set_rate(FaultPoint::SocketReset, 0.3);
            (0..n).map(|_| inj.fire(FaultPoint::SocketReset)).collect()
        };
        let a = run(200);
        let b = run(200);
        assert_eq!(a, b, "same seed must replay the same outcome sequence");
        let hits = a.iter().filter(|f| **f).count();
        // 0.3 over 200 draws: statistically impossible to miss [20, 100]
        // for any seed (binomial tails < 1e-9).
        assert!((20..=100).contains(&hits), "rate 0.3 fired {hits}/200");
        // A different seed gives a different sequence (for any pair of
        // distinct small seeds this holds; pin one counterexample pair).
        let other = FaultInjector::new(seed ^ 0x5EED);
        other.set_rate(FaultPoint::SocketReset, 0.3);
        let c: Vec<bool> = (0..200).map(|_| other.fire(FaultPoint::SocketReset)).collect();
        assert_ne!(a, c, "distinct seeds should not replay identically");
    }

    #[test]
    fn fired_count_is_interleaving_independent() {
        // The total fired over N draws depends only on (seed, rates, N),
        // not on which thread takes which draw: draw indices are handed
        // out by one atomic counter and each decision is a pure hash.
        let seed = seed_from_env(9);
        let serial = {
            let inj = FaultInjector::new(seed);
            inj.set_rate(FaultPoint::WorkerPanic, 0.25);
            for _ in 0..400 {
                inj.fire(FaultPoint::WorkerPanic);
            }
            inj.fired(FaultPoint::WorkerPanic)
        };
        let inj = std::sync::Arc::new(FaultInjector::new(seed));
        inj.set_rate(FaultPoint::WorkerPanic, 0.25);
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let inj = inj.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        inj.fire(FaultPoint::WorkerPanic);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(inj.fired(FaultPoint::WorkerPanic), serial);
        assert_eq!(inj.draws(FaultPoint::WorkerPanic), 400);
    }

    #[test]
    fn trigger_is_one_shot_and_ignores_rate() {
        let inj = FaultInjector::new(seed_from_env(3));
        inj.trigger(FaultPoint::WorkerPanic);
        assert!(inj.fire(FaultPoint::WorkerPanic), "queued trigger fires");
        assert!(!inj.fire(FaultPoint::WorkerPanic), "trigger is one-shot");
        assert_eq!(inj.fired(FaultPoint::WorkerPanic), 1);
    }

    #[test]
    fn point_names_round_trip() {
        for p in FaultPoint::ALL {
            assert_eq!(FaultPoint::parse(p.name()), Some(p));
        }
        assert_eq!(FaultPoint::parse("nonsense"), None);
    }

    #[test]
    fn stats_json_carries_every_point() {
        let inj = FaultInjector::new(1);
        inj.set_rate(FaultPoint::SlowExec, 0.5);
        inj.trigger(FaultPoint::WorkerPanic);
        inj.fire(FaultPoint::WorkerPanic);
        let j = inj.to_json();
        assert_eq!(j.usize_field("seed").unwrap(), 1);
        let points = j.get("points").unwrap();
        for p in FaultPoint::ALL {
            let e = points.get(p.name()).unwrap();
            assert!(e.f64_field("rate").unwrap().is_finite());
        }
        assert_eq!(
            points.get("worker-panic").unwrap().usize_field("fired").unwrap(),
            1
        );
    }

    #[test]
    fn supervisor_protocol_smoke_outside_the_model() {
        // Outside `brainslug check` the facade is plain std::sync; the
        // protocol must simply terminate with all obligations met.
        supervisor_protocol(2, 2, 2, 1, SupervisorBugs::default());
    }
}
