//! `brainslug` — leader binary of the BrainSlug reproduction.
//!
//! Every command goes through the [`brainslug::engine::Engine`] facade:
//!
//! ```text
//! let mut engine = Engine::builder()
//!     .zoo_small("vgg11_bn", 8)     // network: zoo name or Graph
//!     .brainslug(opts)              // mode: Baseline | BrainSlug
//!     .sim()                        // backend: pjrt (artifacts) | sim
//!     .build()?;
//! let (output, stats) = engine.run(engine.synthetic_input())?;
//! ```
//!
//! Commands:
//! * `emit-requests` — run the optimizer over the experiment set and
//!   write `artifacts/requests.json` for the python AOT path.
//! * `analyze`       — per-network optimizer/memsim report (Table 2).
//! * `simulate`      — paper-scale simulated experiments (Tables 1–2,
//!   Figures 10–15); see the benches for the full harnesses.
//! * `run`           — execute a network (PJRT artifacts or the
//!   artifact-free sim backend), baseline vs BrainSlug, and verify
//!   numerics.
//! * `serve`         — batching-server demo (either backend); with
//!   `--http PORT` it becomes a real HTTP/JSON inference service.
//! * `bench-serve`   — closed/open-loop load harness over real sockets
//!   (Figure 18); `--single` is the CI smoke client.
//! * `trace`         — run a network on the native CPU backend with
//!   span tracing armed, write a Chrome-trace JSON timeline, and
//!   (`--drift`) join measured segments against memsim predictions.
//! * `dot`           — GraphViz dump of a network.
//! * `check`         — static verification: graph lint, plan verifier
//!   and concurrency-topology lint with stable `BSL0xx` codes.

// Same lint posture as the library (see lib.rs). The one unsafe block
// (raw `signal(2)` FFI in `install_signal_handlers`) carries a
// documented `#[allow]`.
#![deny(unsafe_code)]
#![warn(clippy::unwrap_used)]
#![warn(clippy::map_unwrap_or)]
#![warn(clippy::dbg_macro)]

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use brainslug::autotune::{self, ProfileStore, TuneLevel};
use brainslug::bench::{self, fmt_pct, fmt_time, Table};
use brainslug::cli::Args;
use brainslug::device::DeviceSpec;
use brainslug::engine::{BackendKind, Engine, EngineBuilder, Mode};
use brainslug::fault::{FaultInjector, FaultPoint};
use brainslug::graph::graph_to_json;
use brainslug::http::{self, HttpConfig, HttpServer, RetryPolicy};
use brainslug::json::Json;
use brainslug::memsim::{baseline_optimized_time, speedup_pct};
use brainslug::optimizer::CollapseOptions;
use brainslug::runtime::RequestSet;
use brainslug::server::{QueuePolicy, Server, ServerConfig};
use brainslug::zoo;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    let r = match args.command.as_str() {
        "emit-requests" => cmd_emit_requests(&args),
        "analyze" => cmd_analyze(&args),
        "simulate" => cmd_simulate(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "bench-serve" => cmd_bench_serve(&args),
        "tune" => cmd_tune(&args),
        "trace" => cmd_trace(&args),
        "dot" => cmd_dot(&args),
        "check" => cmd_check(&args),
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(anyhow::anyhow!("unknown command '{other}'"))
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "brainslug — depth-first neural network acceleration (paper reproduction)

USAGE: brainslug <command> [flags]

  emit-requests [--out artifacts/requests.json]
  analyze       [--net NAME | --all] [--device paper-cpu|paper-gpu|tpu] [--batch N]
  simulate      --exp table1|table2 [--device ...]
  run           --net NAME [--batch N] [--mode both|baseline|brainslug]
                [--backend pjrt|sim|cpu] [--threads N] [--artifacts DIR]
                [--device PRESET] [--collapse-budget BYTES]
                [--profile-path FILE] [--no-profile] [--trace FILE]
  serve         --net NAME [--batch B] [--requests N] [--brainslug]
                [--backend pjrt|sim|cpu] [--threads N] [--artifacts DIR]
                [--workers N] [--queue-depth D] [--queue-policy block|reject]
                [--pace SCALE] [--device PRESET] [--profile-path FILE]
                [--no-profile] [--http PORT] [--http-threads K]
                [--max-body BYTES] [--fault-seed S] [--fault-rate R]
                [--trace FILE]
  trace         --net NAME [--batch N] [--backend cpu] [--threads N]
                [--runs N] [--out trace.json] [--drift] [--device PRESET]
                [--collapse-budget BYTES]
  bench-serve   [--workers 1,2,4] [--concurrency 2,8] [--batch B]
                [--requests N] [--batch-cost-ms MS]
                [--fault-rate R] [--fault-seed S]
                [--addr HOST:PORT [--single]]
  tune          --net NAME [--batch N] [--backend cpu] [--threads N]
                [--budget fast|full] [--device PRESET] [--profile-path FILE]
  dot           --net NAME [--batch N] [--small] [--json]
  check         [--net NAME | --all-zoo] [--batch N] [--device PRESET]
                [--collapse-budget BYTES] [--deny warnings]
                [--format text|json] [--schedules N] [--seed S]

Network names accept family aliases (vgg, resnet, densenet, squeezenet,
inception). `--backend sim` needs no artifacts directory at all.
`--backend cpu` really computes with native f32 kernels (breadth-first
baseline vs depth-first band walker) — also artifact-free; `--threads N`
spreads independent tile bands over N scoped workers, and the collapse
budget defaults to the host-cpu device model.

`serve` runs a pool of N engine replicas over one bounded dispatch
queue (depth D): when the queue is full, requests block (policy
`block`) or fail fast (`reject`). `--pace SCALE` makes the sim backend
sleep model-time x SCALE per batch, so pool scaling and queueing are
measured against real wall-clock (see benches/fig16_serving_scaling).
With `--http PORT` the pool goes behind a zero-dependency HTTP/1.1
front door (POST /v1/run, GET /v1/stats, GET /healthz; port 0 picks an
ephemeral port) and runs until SIGINT/SIGTERM, then drains gracefully.
A `reject` queue policy surfaces on the wire as 503 + a queue-depth-
aware Retry-After; `x-brainslug-deadline-ms: N` sheds requests that
cannot run within N ms as 504. `--fault-seed S` / `--fault-rate R` arm
the deterministic fault injector (worker panics, slow batches, queue
stalls, socket resets, partial writes — see DESIGN.md §Fault Injection
& Recovery); crashed workers are supervised and rebuilt, with restart
counts in GET /v1/stats. BRAINSLUG_FAULT_SEED overrides the seed.

`bench-serve` load-tests that front door over real sockets: a
closed-loop sweep (workers x concurrency, keep-alive clients) plus one
open-loop overload point per worker count (paced arrivals at ~1.75x
estimated capacity, latency measured from the *scheduled* arrival so
queue build-up is charged to the tail, not hidden). Reports
p50/p95/p99 latency, throughput, and rejected-request rate; writes
BENCH_serve_http.json. `--fault-rate R` (optionally `--fault-seed S`)
storms the in-process sweep through the fault injector while clients
retry with jittered backoff, adding retry/restart counts to each row.
`--addr` points it at an already-running server; with `--single` it
fires one POST /v1/run, one deadline-annotated run, and one
GET /healthz — and, against a fault-armed server, injects a worker
crash and requires a 200 after recovery (the CI smoke).

`tune` searches the collapse-configuration space (budget scale,
band-height caps) on the *real* CPU backend: a memsim cost-model
pre-pass prunes the candidates, the survivors get timed runs (warmup +
median-of-N, early-exit for clear losers), and each per-thread winner
persists to the profile cache (default ~/.brainslug/profiles.json, or
--profile-path). Later `run`/`serve` invocations on the same network,
device, and thread count load the tuned config automatically — tuning
pays once, every later run is faster with zero flags (`--no-profile`
opts out). The cache key includes the batch size (it is part of the
graph), so tune at the batch you will serve: `tune --net X --batch 8`
pairs with `serve --net X --batch 8`.

`trace` arms the zero-overhead span recorder over the native CPU
backend's depth-first hot path and runs the network `--runs` times
(each under a fresh trace id), then writes every recorded
Request/Plan/Segment/Band/Kernel span as a Chrome-trace JSON timeline
(`--out`, default trace.json — load it in Perfetto or
chrome://tracing). `--drift` additionally joins the measured Segment
spans against the memsim cost model's per-segment predictions and
prints a predicted-vs-measured table with a Spearman rank correlation
(see DESIGN.md §Observability and benches/fig22_trace_drift). The same
recorder is reachable from `run --trace FILE` (traced brainslug leg)
and `serve --trace FILE` (spans drained to FILE at graceful shutdown);
without a `--trace` flag no recorder exists and the hot path carries
zero tracing cost. Serving metrics are always on: every `serve --http`
server exposes GET /v1/metrics in the Prometheus text format, and
every response carries an `x-brainslug-trace` id echo (client-supplied
or minted) for span correlation.

`check` is the static verifier: it lints the graph (shape/dtype
inference, BSL001–BSL012), re-proves the optimizer plan's resource
invariants (budget packing, halo back-propagation, skip reservations,
BSL020–BSL029), and lints the runtime's declared thread/channel
topologies (BSL040–BSL045). With `--schedules N` it also *executes*
model-checked replicas of the runtime's drain/queue/pool protocols
under a controlled scheduler — N bounded-preemption schedules plus
seeded random walks per protocol (`--seed S` rotates the stream) —
reporting ordering violations (BSL050–BSL056) with replayable
counterexample schedules. Every finding carries a stable BSL0xx code;
`--deny warnings` makes warnings fail the exit code (CI runs
`check --all-zoo --deny warnings --schedules 256`). The explored suite
covers the server drain, listener drain, band pool, fault-supervisor
restart, and observability span-flush protocols. See DESIGN.md §Static Analysis and
§Schedule Model Checking.

Library quickstart (the whole pipeline is one builder):

  let mut engine = Engine::builder()
      .zoo_small(\"vgg11_bn\", 8)   // zoo name (or .graph(...))
      .brainslug(Default::default())
      .sim()                        // or .artifacts(\"artifacts\")
      .build()?;
  let (out, stats) = engine.run(engine.synthetic_input())?;
"
    );
}

/// `--backend` / `--artifacts` / `--threads` flags → a [`BackendKind`].
/// `--threads 0` (or any non-positive value) is an error, not a silent
/// fall-through to the default.
fn backend_from_args(args: &Args) -> Result<BackendKind> {
    let artifacts = args.get_or("artifacts", bench::ARTIFACT_DIR).to_string();
    let mut backend = BackendKind::parse(args.get_or("backend", "pjrt"), &artifacts)?;
    if let Some(threads) = args.get_positive_usize("threads")? {
        match &mut backend {
            BackendKind::Cpu { threads: t } => *t = threads,
            _ => bail!("--threads only applies to --backend cpu"),
        }
    }
    Ok(backend)
}

/// Optional `--device` preset, defaulting to the measured-mode device.
/// A miss lists the valid preset names.
fn device_from_args(args: &Args, default: DeviceSpec) -> Result<DeviceSpec> {
    match args.get("device") {
        None => Ok(default),
        Some(d) => DeviceSpec::preset(d).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown device preset '{d}' — valid presets: {}",
                DeviceSpec::preset_names()
            )
        }),
    }
}

/// `--profile-path` / `--no-profile` flags → builder profile policy.
fn apply_profile_flags(mut builder: EngineBuilder, args: &Args) -> EngineBuilder {
    let path = args.get("profile-path").map(PathBuf::from);
    if args.get_bool("no-profile") {
        builder = builder.no_profile();
    } else if let Some(p) = path {
        builder = builder.profile_path(p);
    }
    builder
}

/// Optional `--collapse-budget BYTES` (positive) merged into collapse
/// options — budget injection instead of preset-only budgets.
fn collapse_opts_from_args(args: &Args, base: CollapseOptions) -> Result<CollapseOptions> {
    let mut opts = base;
    if let Some(bytes) = args.get_positive_usize("collapse-budget")? {
        opts.budget_bytes = Some(bytes);
    }
    Ok(opts)
}

fn cmd_emit_requests(args: &Args) -> Result<()> {
    let out = args.get_or("out", "artifacts/requests.json").to_string();
    args.reject_unknown()?;

    let mut rs = RequestSet::new();

    // Full networks: baseline + plan executables + oracle per batch. The
    // sim backend resolves the graph and validates the plan without
    // needing the very artifacts this command is emitting requests for.
    for &name in bench::measured_networks() {
        for &batch in bench::measured_batches() {
            let engine = bench::measured_engine(name, batch).sim().build()?;
            let g = engine.graph();
            let plan = engine.plan().expect("measured engines plan");
            rs.add_baseline(g);
            rs.add_plan(g, plan);
            if batch == bench::measured_batches()[0] {
                rs.add_oracle(&format!("{name}_b{batch}"), g, engine.seed());
            }
        }
    }

    // Figure-10 block networks under each collapse strategy.
    for &blocks in bench::fig10_measured_blocks() {
        for (i, (_, opts)) in bench::fig10_strategies().into_iter().enumerate() {
            let engine = bench::block_engine(blocks, 4, 8, 32, opts).sim().build()?;
            if i == 0 {
                rs.add_baseline(engine.graph());
                if blocks == 2 {
                    rs.add_oracle("blocks2_b4", engine.graph(), engine.seed());
                }
            }
            rs.add_plan(engine.graph(), engine.plan().expect("block engines plan"));
        }
    }

    let json = rs.to_json();
    if let Some(dir) = Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, json.to_string_pretty())?;
    println!(
        "wrote {out}: {} layer executables, {} stack executables",
        rs.num_layers(),
        rs.num_stacks()
    );
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let device = device_from_args(args, DeviceSpec::paper_gpu())?;
    let batch = args.get_positive_usize("batch")?.unwrap_or(128);
    let all = args.get_bool("all");
    let one = args.get("net").map(|s| s.to_string());
    args.reject_unknown()?;

    let names: Vec<&str> = match one.as_deref() {
        Some(name) if !all => vec![name],
        _ => zoo::ALL_NETWORKS.to_vec(),
    };

    let mut table = Table::new(&[
        "network", "layers", "opt", "stacks", "uniq", "branches", "opt-speedup", "%time",
        "total-speedup",
    ]);
    for name in names {
        let engine = bench::paper_engine(name, batch, &device).build()?;
        let plan = engine.plan().expect("paper engines plan");
        let base = engine.simulate_baseline();
        let bs = engine.simulate_plan().expect("plan simulation");
        // Like-for-like optimized-portion comparison: `stack_s` includes
        // fused branch joins, so its baseline side must too.
        let opt_base_s = baseline_optimized_time(engine.graph(), plan, engine.device());
        let opt_speedup = speedup_pct(opt_base_s, bs.stack_s);
        let pct_time = opt_base_s / base.total_s * 100.0;
        let total = speedup_pct(base.total_s, bs.total_s);
        table.row(vec![
            engine.graph().name.clone(),
            engine.graph().num_layers().to_string(),
            plan.num_optimized_layers().to_string(),
            plan.num_stacks().to_string(),
            plan.num_unique_stacks().to_string(),
            plan.num_branches().to_string(),
            fmt_pct(opt_speedup),
            format!("{pct_time:.1}"),
            fmt_pct(total),
        ]);
    }
    println!(
        "# Table-2 style analysis — device={} batch={batch} (simulated)",
        device.name
    );
    table.print();
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let exp = args.get_or("exp", "table1").to_string();
    let device = device_from_args(args, DeviceSpec::paper_gpu())?;
    args.reject_unknown()?;
    match exp.as_str() {
        "table1" => {
            let batches = [1usize, 2, 4, 8, 16, 32, 64, 128, 256];
            let mut table = Table::new(&[
                "network", "1", "2", "4", "8", "16", "32", "64", "128", "256",
            ]);
            for name in zoo::ALL_NETWORKS {
                let mut cells = vec![name.to_string()];
                for &b in &batches {
                    let engine = bench::paper_engine(name, b, &device).build()?;
                    let base = engine.simulate_baseline();
                    let bs = engine.simulate_plan().expect("plan simulation");
                    cells.push(fmt_pct(speedup_pct(base.total_s, bs.total_s)));
                }
                table.row(cells);
            }
            println!(
                "# Table 1 — total speed-up, device={} (simulated)",
                device.name
            );
            table.print();
        }
        "table2" => {
            let fwd = Args::parse(
                ["analyze", "--all", "--device", &device.name]
                    .iter()
                    .map(|s| s.to_string()),
            )?;
            return cmd_analyze(&fwd);
        }
        other => bail!("unknown experiment '{other}' (table1|table2)"),
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let name = args
        .get("net")
        .ok_or_else(|| anyhow::anyhow!("--net required"))?
        .to_string();
    let batch = args
        .get_positive_usize("batch")?
        .unwrap_or(bench::measured_batches()[0]);
    let mode = args.get_or("mode", "both").to_string();
    let backend = backend_from_args(args)?;
    // The native backend tiles for the host's cache by default; the
    // other backends keep the measured-mode (TPU-profile) device.
    let default_device = if matches!(backend, BackendKind::Cpu { .. }) {
        DeviceSpec::host_cpu()
    } else {
        bench::measured_device()
    };
    let device = device_from_args(args, default_device)?;
    let opts = collapse_opts_from_args(args, bench::measured_opts())?;
    let engine_mode = match mode.as_str() {
        "baseline" => Mode::Baseline,
        "both" | "brainslug" => Mode::BrainSlug(opts),
        other => bail!("unknown mode '{other}' (both|baseline|brainslug)"),
    };
    let mut builder = apply_profile_flags(
        Engine::builder()
            .zoo_small(&name, batch)
            .device(device)
            .mode(engine_mode)
            .backend(backend)
            .seed(bench::oracle_seed()),
        args,
    );
    // `--trace FILE` arms the span recorder for the brainslug leg
    // (baseline runs are never traced) and writes a Chrome-trace
    // timeline at the end. Without the flag no recorder exists.
    let trace_out = args.get("trace").map(|s| s.to_string());
    let obs = trace_out
        .as_ref()
        .map(|_| Arc::new(brainslug::obs::Obs::default()));
    if let Some(o) = &obs {
        builder = builder.obs(o.clone());
    }
    args.reject_unknown()?;
    let mut engine = builder.build()?;
    let input = engine.synthetic_input();

    println!("{} batch={batch}", engine.describe());
    if let Some(p) = engine.applied_profile() {
        println!("tuned profile: {p}");
    }

    let mut t_base = None;
    let mut t_plan = None;
    let mut out_base = None;
    if mode == "both" || mode == "baseline" {
        let (out, stats) = engine.run_baseline(input.clone())?;
        println!("baseline:  total={}", fmt_time(stats.total_s));
        for (kind, s) in stats.by_kind().iter().take(5) {
            println!("  {kind:<12} {}", fmt_time(*s));
        }
        t_base = Some(stats.total_s);
        out_base = Some(out);
    }
    if mode == "both" || mode == "brainslug" {
        let (out, stats) = engine.run(input.clone())?;
        println!("brainslug: total={}", fmt_time(stats.total_s));
        t_plan = Some(stats.total_s);
        if let Some(b) = &out_base {
            let diff = b.max_abs_diff(&out);
            println!("max |baseline - brainslug| = {diff:.2e}");
            if !b.allclose(&out, 1e-4, 1e-4) {
                bail!("numerics mismatch between baseline and brainslug");
            }
        }
    }
    if let (Some(b), Some(p)) = (t_base, t_plan) {
        println!(
            "speedup (first run, incl. executable compile): {}",
            fmt_pct(speedup_pct(b, p))
        );
    }
    if let (Some(path), Some(obs)) = (&trace_out, &obs) {
        write_trace_file(path, obs)?;
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let name = args
        .get("net")
        .ok_or_else(|| anyhow::anyhow!("--net required"))?
        .to_string();
    let n_requests = args.get_usize("requests", 32)?;
    let brainslug_mode = args.get_bool("brainslug");
    let backend = backend_from_args(args)?;
    let workers = args.get_positive_usize("workers")?.unwrap_or(1);
    let queue_depth = args.get_positive_usize("queue-depth")?.unwrap_or(64);
    let queue_policy = match args.get_or("queue-policy", "block") {
        "block" => QueuePolicy::Block,
        "reject" => QueuePolicy::Reject,
        other => bail!("unknown queue policy '{other}' (block|reject)"),
    };
    let pace: Option<f64> = args.get_f64("pace")?;
    if pace.is_some() && !matches!(backend, BackendKind::Sim) {
        bail!("--pace only applies to the sim backend (add --backend sim)");
    }
    // HTTP front-door flags (port 0 = ephemeral).
    let http_port: Option<u16> = match args.get("http") {
        None => None,
        Some(v) => Some(
            v.parse()
                .map_err(|e| anyhow::anyhow!("--http: bad port '{v}': {e}"))?,
        ),
    };
    let http_threads = args.get_positive_usize("http-threads")?.unwrap_or(8);
    let max_body = args.get_positive_usize("max-body")?;
    // Fault-injection flags: giving either one arms the injector
    // (rates default to zero — `x-brainslug-fault` triggers still work).
    let fault_seed: Option<u64> = match args.get("fault-seed") {
        None => None,
        Some(v) => Some(
            v.parse()
                .map_err(|e| anyhow::anyhow!("--fault-seed: bad seed '{v}': {e}"))?,
        ),
    };
    let fault_rate: Option<f64> = args.get_f64("fault-rate")?;
    if let Some(r) = fault_rate {
        if !(0.0..=1.0).contains(&r) {
            bail!("--fault-rate must be in [0, 1], got {r}");
        }
    }
    let default_device = if matches!(backend, BackendKind::Cpu { .. }) {
        DeviceSpec::host_cpu()
    } else {
        bench::measured_device()
    };
    let device = device_from_args(args, default_device)?;
    // Compiled batch size B. Tuned profiles are keyed by the graph
    // signature (batch included), so serving a tuned config requires
    // tuning at the same batch: `tune --batch N` then `serve --batch N`.
    let batch = args
        .get_positive_usize("batch")?
        .or_else(|| bench::measured_batches().last().copied())
        .unwrap_or(128);
    let mut engine = Engine::builder()
        .zoo_small(&name, batch)
        .device(device)
        .mode(if brainslug_mode {
            Mode::BrainSlug(bench::measured_opts())
        } else {
            Mode::Baseline
        })
        .backend(backend)
        .seed(bench::oracle_seed());
    engine = apply_profile_flags(engine, args);
    // `--trace FILE` arms span tracing across the worker pool; the
    // spans drain to FILE after graceful shutdown.
    let trace_out = args.get("trace").map(|s| s.to_string());
    args.reject_unknown()?;
    if let Some(scale) = pace {
        engine = engine.sim_paced(scale);
    }
    let mut config = ServerConfig::new(engine)
        .workers(workers)
        .queue_depth(queue_depth)
        .queue_policy(queue_policy)
        .max_wait(Duration::from_millis(5));
    let obs = trace_out
        .as_ref()
        .map(|_| Arc::new(brainslug::obs::Obs::default()));
    if let Some(o) = &obs {
        config = config.obs(o.clone());
    }
    if fault_seed.is_some() || fault_rate.is_some() {
        let seed = brainslug::fault::seed_from_env(fault_seed.unwrap_or(0));
        let inj = Arc::new(FaultInjector::new(seed));
        if let Some(r) = fault_rate {
            for p in FaultPoint::ALL {
                inj.set_rate(p, r);
            }
        }
        println!(
            "fault injection armed: seed {seed}, rate {:.3} on every point",
            fault_rate.unwrap_or(0.0)
        );
        config = config.faults(inj);
    }
    let server = config.start()?;
    if let Some(port) = http_port {
        serve_http(server, port, http_threads, max_body)?;
        if let (Some(path), Some(obs)) = (&trace_out, &obs) {
            write_trace_file(path, obs)?;
        }
        return Ok(());
    }
    let handle = server.handle();
    let image_elems = handle.image_shape().numel();

    let t0 = std::time::Instant::now();
    let clients: Vec<_> = (0..n_requests)
        .map(|i| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let img = brainslug::rng::fill_f32(i as u64, image_elems);
                h.infer(img).map(|t| t.data[0])
            })
        })
        .collect();
    let mut ok = 0;
    for c in clients {
        // A panicked client thread counts as a failed request.
        if matches!(c.join(), Ok(Ok(_))) {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {ok}/{n_requests} requests in {} ({:.1} req/s), mean latency {:.2}ms, batch occupancy {:.0}%",
        fmt_time(wall),
        ok as f64 / wall,
        server.stats.mean_latency_ms(),
        server.occupancy() * 100.0
    );
    let (p50, p95, p99) = server.stats.latency_percentiles_ms();
    println!("latency p50 {p50:.2}ms p95 {p95:.2}ms p99 {p99:.2}ms");
    println!(
        "workers={} batches/worker={:?} peak queue depth {} rejected {}",
        server.workers(),
        server.stats.worker_batches(),
        server.stats.queue_peak.load(Ordering::Relaxed),
        server.stats.rejected.load(Ordering::Relaxed)
    );
    server.stop();
    if let (Some(path), Some(obs)) = (&trace_out, &obs) {
        write_trace_file(path, obs)?;
    }
    Ok(())
}

/// Flag set by the SIGINT/SIGTERM handlers; the `serve --http` wait
/// loop polls it. A C signal handler may only touch lock-free statics,
/// hence a process-global rather than the listener's own stop flag.
static SIGNAL_STOP: AtomicBool = AtomicBool::new(false);

/// Point SIGINT (2) and SIGTERM (15) at a flag-setting handler via the
/// raw libc `signal` symbol — the offline toolchain has no `libc`
/// crate, and an atomic store is async-signal-safe.
#[cfg(unix)]
#[allow(unsafe_code)] // raw libc `signal` FFI; no `libc` crate offline
fn install_signal_handlers() {
    extern "C" fn on_signal(_signum: i32) {
        // Ordering: Relaxed — this flag is a pure boolean signal with
        // nothing published through it (the poll loop below reacts by
        // *starting* shutdown, it never reads data the handler wrote),
        // so there is no release/acquire pairing to preserve. Matches
        // the Relaxed poll in `serve_http`.
        SIGNAL_STOP.store(true, Ordering::Relaxed);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    let handler = on_signal as extern "C" fn(i32) as usize;
    unsafe {
        signal(2, handler);
        signal(15, handler);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// `serve --http PORT`: put the worker pool behind the HTTP front door
/// and run until a signal arrives, then drain gracefully (stop
/// accepting → finish in-flight → drain the queue → join).
fn serve_http(server: Server, port: u16, conn_threads: usize, max_body: Option<usize>) -> Result<()> {
    let mut cfg = HttpConfig::new(format!("0.0.0.0:{port}"));
    cfg.conn_threads = conn_threads;
    if let Some(bytes) = max_body {
        cfg.limits.max_body_bytes = bytes;
    }
    let http = HttpServer::start(server, cfg)?;
    println!(
        "serving {} on http://{} — POST /v1/run, GET /v1/stats, GET /healthz (ctrl-c to drain)",
        http.state().model,
        http.addr()
    );
    install_signal_handlers();
    while !SIGNAL_STOP.load(Ordering::Relaxed) {
        std::thread::sleep(Duration::from_millis(100));
    }
    println!("signal received — draining in-flight and queued requests");
    let stats = http.state().stats.clone();
    let batch = http.state().batch;
    http.shutdown();
    let (p50, p95, p99) = stats.latency_percentiles_ms();
    println!(
        "served {} requests ({} rejected), mean latency {:.2}ms, p50 {p50:.2}ms p95 {p95:.2}ms p99 {p99:.2}ms, batch occupancy {:.0}%",
        stats.requests.load(Ordering::Relaxed),
        stats.rejected.load(Ordering::Relaxed),
        stats.mean_latency_ms(),
        stats.occupancy(batch) * 100.0
    );
    Ok(())
}

/// `{"model": ..., "input": [...]}` — the `POST /v1/run` body.
fn run_body_json(model: &str, input: &[f32]) -> String {
    let mut o = Json::object();
    o.set("model", Json::Str(model.to_string()));
    o.set(
        "input",
        Json::Arr(input.iter().map(|v| Json::Num(*v as f64)).collect()),
    );
    o.to_string_compact()
}

/// Ask a running server who it is: (model, image_elems, workers) from
/// `GET /v1/stats`.
fn discover_server(addr: &str) -> Result<(String, usize, usize)> {
    let resp = http::one_shot(addr, "GET", "/v1/stats", None)
        .map_err(|e| anyhow::anyhow!("GET /v1/stats on {addr}: {e}"))?;
    if resp.status != 200 {
        bail!("GET /v1/stats on {addr} returned {}", resp.status);
    }
    let j = brainslug::json::parse(std::str::from_utf8(&resp.body)?)?;
    Ok((
        j.str_field("model")?,
        j.usize_field("image_elems")?,
        j.usize_field("workers")?,
    ))
}

/// Common fields of one `BENCH_serve_http.json` row.
fn serve_row(mode: &str, workers: usize, report: &http::LoadReport) -> Json {
    let mut row = Json::object();
    row.set("bench", Json::Str("serve_http".into()));
    row.set("mode", Json::Str(mode.into()));
    row.set("workers", Json::from_usize(workers));
    row.set("sent", Json::Num(report.sent as f64));
    row.set("ok", Json::Num(report.ok as f64));
    row.set("rejected", Json::Num(report.rejected as f64));
    row.set("errors", Json::Num(report.errors as f64));
    row.set("reject_rate", Json::Num(report.reject_rate()));
    row.set("throughput_rps", Json::Num(report.throughput_rps()));
    row.set("mean_ms", Json::Num(report.mean_ms()));
    row.set("p50_ms", Json::Num(report.p50_ms()));
    row.set("p95_ms", Json::Num(report.p95_ms()));
    row.set("p99_ms", Json::Num(report.p99_ms()));
    row
}

/// One table row for the bench-serve report.
fn serve_table_row(table: &mut Table, mode: &str, workers: usize, load: String, r: &http::LoadReport) {
    table.row(vec![
        mode.to_string(),
        workers.to_string(),
        load,
        r.sent.to_string(),
        r.ok.to_string(),
        r.rejected.to_string(),
        format!("{:.2}", r.reject_rate()),
        format!("{:.0}", r.throughput_rps()),
        format!("{:.2}", r.mean_ms()),
        format!("{:.2}", r.p50_ms()),
        format!("{:.2}", r.p95_ms()),
        format!("{:.2}", r.p99_ms()),
    ]);
}

fn serve_table() -> Table {
    Table::new(&[
        "mode", "workers", "load", "sent", "ok", "rejected", "rej-rate", "req/s", "mean-ms",
        "p50-ms", "p95-ms", "p99-ms",
    ])
}

/// `bench-serve --single --addr H:P`: the CI smoke — one plain
/// `POST /v1/run`, one deadline-annotated run, one `GET /healthz`, and
/// (when the server has fault injection armed) one injected worker
/// crash followed by a recovery probe. Non-zero exit unless every leg
/// behaves.
fn bench_serve_single(addr: &str) -> Result<()> {
    let (model, elems, _) = discover_server(addr)?;
    let body = run_body_json(&model, &brainslug::rng::fill_f32(1, elems));
    let run = http::one_shot(addr, "POST", "/v1/run", Some(body.as_bytes()))
        .map_err(|e| anyhow::anyhow!("POST /v1/run on {addr}: {e}"))?;
    if run.status != 200 {
        bail!(
            "POST /v1/run returned {}: {}",
            run.status,
            String::from_utf8_lossy(&run.body)
        );
    }
    let out = brainslug::json::parse(std::str::from_utf8(&run.body)?)?;
    let n_out = out.arr_field("output")?.len();
    // A generous deadline must not change the outcome.
    let deadlined = http::one_shot_with(
        addr,
        "POST",
        "/v1/run",
        &[("x-brainslug-deadline-ms", "10000")],
        Some(body.as_bytes()),
    )
    .map_err(|e| anyhow::anyhow!("deadline-annotated POST /v1/run on {addr}: {e}"))?;
    if deadlined.status != 200 {
        bail!(
            "deadline-annotated POST /v1/run returned {}: {}",
            deadlined.status,
            String::from_utf8_lossy(&deadlined.body)
        );
    }
    let health = http::one_shot(addr, "GET", "/healthz", None)
        .map_err(|e| anyhow::anyhow!("GET /healthz on {addr}: {e}"))?;
    if health.status != 200 {
        bail!("GET /healthz returned {}", health.status);
    }
    // Metrics leg: the exposition must answer 200 with at least the
    // serving counters, and every sample line must parse as
    // `name{labels} value` with a finite value.
    let metrics = http::one_shot(addr, "GET", "/v1/metrics", None)
        .map_err(|e| anyhow::anyhow!("GET /v1/metrics on {addr}: {e}"))?;
    if metrics.status != 200 {
        bail!("GET /v1/metrics returned {}", metrics.status);
    }
    let text = std::str::from_utf8(&metrics.body)?;
    let mut samples = 0usize;
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let value = line
            .rsplit_once(' ')
            .and_then(|(_, v)| v.parse::<f64>().ok())
            .ok_or_else(|| anyhow::anyhow!("unparseable metrics sample line: {line:?}"))?;
        if !value.is_finite() {
            bail!("non-finite metrics value: {line:?}");
        }
        samples += 1;
    }
    if samples == 0 || !text.contains("brainslug_requests_total") {
        bail!("metrics exposition is missing the serving counters");
    }
    // If the server was started with fault injection armed (the stats
    // block advertises it), crash a worker mid-batch and prove the
    // supervisor brings the replica back.
    let stats = http::one_shot(addr, "GET", "/v1/stats", None)
        .map_err(|e| anyhow::anyhow!("GET /v1/stats on {addr}: {e}"))?;
    let stats_json = brainslug::json::parse(std::str::from_utf8(&stats.body)?)?;
    let mut crash_leg = "fault injection not armed; crash leg skipped";
    if stats_json.get("fault_injection").is_some() {
        let crashed = http::one_shot_with(
            addr,
            "POST",
            "/v1/run",
            &[("x-brainslug-fault", "worker-panic")],
            Some(body.as_bytes()),
        )
        .map_err(|e| anyhow::anyhow!("crash-trigger POST /v1/run on {addr}: {e}"))?;
        // The triggering request rides the crashing batch (503) unless
        // another worker picked it up first (200) — both are healthy.
        if !matches!(crashed.status, 200 | 503) {
            bail!(
                "crash-trigger POST /v1/run returned {}: {}",
                crashed.status,
                String::from_utf8_lossy(&crashed.body)
            );
        }
        // Recovery: the rebuilt replica must answer within ~5 s.
        let mut recovered = false;
        for _ in 0..50 {
            if let Ok(resp) = http::one_shot(addr, "POST", "/v1/run", Some(body.as_bytes())) {
                if resp.status == 200 {
                    recovered = true;
                    break;
                }
            }
            std::thread::sleep(Duration::from_millis(100));
        }
        if !recovered {
            bail!("server did not serve a 200 within 5 s of the injected worker crash");
        }
        crash_leg = "injected worker crash recovered to 200";
    }
    println!(
        "single-shot smoke OK against {addr}: POST /v1/run 200 (model {model}, {n_out} output \
         values), deadline-annotated run 200, GET /healthz 200, GET /v1/metrics 200 \
         ({samples} samples), {crash_leg}"
    );
    Ok(())
}

/// `bench-serve --addr H:P`: closed-loop load against an
/// already-running external server.
fn bench_serve_external(
    addr: &str,
    concurrencies: &[usize],
    reqs_per_client: usize,
) -> Result<()> {
    let (model, elems, workers) = discover_server(addr)?;
    let body = run_body_json(&model, &brainslug::rng::fill_f32(7, elems));
    println!("# bench-serve — external server {addr} (model {model}, {workers} workers)");
    let mut table = serve_table();
    let mut rows = Vec::new();
    for &c in concurrencies {
        let report = http::closed_loop(addr, c, reqs_per_client, body.as_bytes());
        serve_table_row(&mut table, "closed", workers, format!("c={c}"), &report);
        let mut row = serve_row("closed", workers, &report);
        row.set("concurrency", Json::from_usize(c));
        rows.push(row);
    }
    table.print();
    bench::emit_bench_json("serve_http", rows);
    Ok(())
}

/// `brainslug bench-serve`: spin up paced-sim HTTP servers in-process
/// and measure serving tail latency over real sockets — a closed-loop
/// (workers x concurrency) sweep plus one open-loop overload point per
/// worker count. The paced sim makes queueing genuine (a batch costs
/// real wall-clock), so percentiles reflect scheduling, not kernels.
fn cmd_bench_serve(args: &Args) -> Result<()> {
    let addr = args.get("addr").map(|s| s.to_string());
    let single = args.get_bool("single");
    let worker_counts = args.get_usize_list("workers", &[1, 2, 4])?;
    let concurrencies = args.get_usize_list("concurrency", &[2, 8])?;
    let batch = args.get_positive_usize("batch")?.unwrap_or(4);
    let reqs_per_client = args.get_positive_usize("requests")?.unwrap_or(8);
    let batch_cost_ms = args.get_f64("batch-cost-ms")?.unwrap_or(4.0);
    // Fault mode: arm every injection point at this rate on each
    // in-process server and give the clients a retry budget.
    let fault_rate: Option<f64> = args.get_f64("fault-rate")?;
    if let Some(r) = fault_rate {
        if !(0.0..=1.0).contains(&r) {
            bail!("--fault-rate must be in [0, 1], got {r}");
        }
    }
    let fault_seed: Option<u64> = match args.get("fault-seed") {
        None => None,
        Some(v) => Some(
            v.parse()
                .map_err(|e| anyhow::anyhow!("--fault-seed: bad seed '{v}': {e}"))?,
        ),
    };
    args.reject_unknown()?;
    if single {
        let addr = addr.ok_or_else(|| anyhow::anyhow!("--single requires --addr HOST:PORT"))?;
        return bench_serve_single(&addr);
    }
    if let Some(addr) = addr {
        if fault_rate.is_some() || fault_seed.is_some() {
            bail!("--fault-rate/--fault-seed drive the in-process sweep; they cannot reach a server behind --addr");
        }
        return bench_serve_external(&addr, &concurrencies, reqs_per_client);
    }
    let fault_seed = brainslug::fault::seed_from_env(fault_seed.unwrap_or(0));

    // Calibrate the sim pacing so one batch costs ~batch_cost_ms of
    // wall-clock (same scheme as benches/fig16_serving_scaling).
    let batch_cost_s = batch_cost_ms / 1e3;
    let mut probe = bench::serving_engine(batch, 0.0).build()?;
    let input = probe.synthetic_input();
    let (_, st) = probe.run(input)?;
    let scale = batch_cost_s / st.total_s.max(1e-12);

    println!(
        "# bench-serve — HTTP serving tail latency (paced sim, batch={batch}, batch-cost={batch_cost_ms:.1}ms)"
    );
    let mut table = serve_table();
    let mut rows = Vec::new();
    for &w in &worker_counts {
        // Closed loop, Block policy: every request is eventually
        // served; queue wait shows up in the percentiles.
        for &c in &concurrencies {
            let mut config = ServerConfig::new(bench::serving_engine(batch, scale))
                .workers(w)
                .queue_depth(4 * batch)
                .queue_policy(QueuePolicy::Block)
                .max_wait(Duration::from_millis(2));
            let inj = fault_rate.map(|r| {
                let inj = Arc::new(FaultInjector::new(fault_seed));
                for p in FaultPoint::ALL {
                    inj.set_rate(p, r);
                }
                inj
            });
            if let Some(inj) = inj.clone() {
                config = config.faults(inj);
            }
            let server = config.start()?;
            let mut cfg = HttpConfig::new("127.0.0.1:0");
            cfg.conn_threads = c.max(8);
            let http = HttpServer::start(server, cfg)?;
            let state = http.state().clone();
            let body = run_body_json(&state.model, &brainslug::rng::fill_f32(7, state.image_elems));
            let retry = fault_rate.map(|_| RetryPolicy {
                seed: fault_seed,
                ..RetryPolicy::default()
            });
            let report = http::closed_loop_with(
                &http.addr().to_string(),
                c,
                reqs_per_client,
                body.as_bytes(),
                retry,
            );
            let restarts = state.stats.restarts.load(Ordering::Relaxed);
            http.shutdown();
            serve_table_row(&mut table, "closed", w, format!("c={c}"), &report);
            let mut row = serve_row("closed", w, &report);
            row.set("batch", Json::from_usize(batch));
            row.set("concurrency", Json::from_usize(c));
            if let Some(r) = fault_rate {
                row.set("fault_rate", Json::Num(r));
                row.set("fault_seed", Json::Num(fault_seed as f64));
                row.set("retries", Json::Num(report.retries as f64));
                row.set("expired", Json::Num(report.expired as f64));
                row.set("restarts", Json::Num(restarts as f64));
            }
            rows.push(row);
        }
        // Open loop, Reject policy, arrivals at ~1.75x estimated
        // capacity: the overload point. Latency is charged from each
        // request's scheduled arrival, so shed load keeps the tail
        // honest instead of pausing the clock.
        let capacity_rps = w as f64 * batch as f64 / batch_cost_s;
        let rate_rps = 1.75 * capacity_rps;
        let server = ServerConfig::new(bench::serving_engine(batch, scale))
            .workers(w)
            .queue_depth(2 * batch)
            .queue_policy(QueuePolicy::Reject)
            .max_wait(Duration::from_millis(2))
            .start()?;
        let mut cfg = HttpConfig::new("127.0.0.1:0");
        cfg.conn_threads = 16;
        let http = HttpServer::start(server, cfg)?;
        let state = http.state().clone();
        let body = run_body_json(&state.model, &brainslug::rng::fill_f32(7, state.image_elems));
        let report = http::open_loop(&http.addr().to_string(), rate_rps, 1.0, 16, body.as_bytes());
        http.shutdown();
        serve_table_row(&mut table, "open", w, format!("{rate_rps:.0}/s"), &report);
        let mut row = serve_row("open", w, &report);
        row.set("batch", Json::from_usize(batch));
        row.set("rate_rps", Json::Num(rate_rps));
        row.set("pool", Json::from_usize(16));
        rows.push(row);
    }
    table.print();
    // The table's percentiles are raw client-side samples; the server's
    // own /v1/stats percentiles come from fixed histogram buckets
    // (midpoint estimate, within obs::MIDPOINT_REL_ERROR = 12.5 % —
    // see DESIGN.md §Observability and benches/fig18_http_serving).
    println!(
        "note: percentiles above are raw client samples; GET /v1/stats reports \
         histogram-midpoint estimates (within 12.5 %)"
    );
    bench::emit_bench_json("serve_http", rows);
    Ok(())
}

/// `brainslug tune`: search the collapse-configuration space on the
/// real CPU backend and persist the per-thread winners to the profile
/// cache, so later `run`/`serve` invocations auto-load them.
fn cmd_tune(args: &Args) -> Result<()> {
    let name = args
        .get("net")
        .ok_or_else(|| anyhow::anyhow!("--net required"))?
        .to_string();
    let batch = args
        .get_positive_usize("batch")?
        .unwrap_or(bench::measured_batches()[0]);
    let backend_name = args.get_or("backend", "cpu").to_string();
    if !matches!(backend_name.as_str(), "cpu" | "native") {
        bail!(
            "tune measures real execution: only --backend cpu is supported \
             (got '{backend_name}')"
        );
    }
    let level = TuneLevel::parse(args.get_or("budget", "fast"))?;
    let threads = args.get_positive_usize("threads")?;
    let device = device_from_args(args, DeviceSpec::host_cpu())?;
    let profile_path = args
        .get("profile-path")
        .map_or_else(ProfileStore::default_path, PathBuf::from);
    args.reject_unknown()?;

    let resolved = zoo::resolve(&name);
    let graph = zoo::try_build(resolved, zoo::small_config(&name, batch)).ok_or_else(|| {
        anyhow::anyhow!("unknown network '{name}' (see `analyze --all` for the zoo)")
    })?;
    let graph = Arc::new(graph);
    let thread_list: Vec<usize> = match threads {
        Some(t) => vec![t],
        None => autotune::default_thread_sweep(),
    };
    println!(
        "# tune — network={} batch={batch} device={} level={level:?} threads={thread_list:?}",
        graph.name, device.name
    );

    let outcome = autotune::tune(&graph, &device, bench::oracle_seed(), level, &thread_list)?;
    println!(
        "candidates: {} in space, {} measured after the cost-model pre-pass",
        outcome.candidates_total, outcome.candidates_measured
    );
    let mut table = Table::new(&["config", "threads", "predicted", "measured", "note"]);
    for m in &outcome.measured {
        let winner = outcome
            .per_thread
            .iter()
            .any(|tr| tr.threads == m.threads && tr.winner.opts == m.opts && !m.pruned);
        table.row(vec![
            m.label.clone(),
            m.threads.to_string(),
            fmt_time(m.predicted_s),
            fmt_time(m.measured_s),
            if m.pruned {
                "pruned".into()
            } else if winner {
                "winner".into()
            } else {
                String::new()
            },
        ]);
    }
    table.print();

    let mut rows = Vec::new();
    for tr in &outcome.per_thread {
        println!(
            "threads={}: winner `{}` — default {}, tuned {} ({})",
            tr.threads,
            tr.winner.label,
            fmt_time(tr.default_s),
            fmt_time(tr.tuned_s),
            fmt_pct(tr.gain_pct())
        );
        let mut row = Json::object();
        row.set("bench", Json::Str("tune".into()));
        row.set("net", Json::Str(graph.name.clone()));
        row.set("batch", Json::from_usize(batch));
        row.set("threads", Json::from_usize(tr.threads));
        row.set("device", Json::Str(device.name.clone()));
        row.set("config", Json::Str(tr.winner.label.clone()));
        row.set("default_s", Json::Num(tr.default_s));
        row.set("tuned_s", Json::Num(tr.tuned_s));
        row.set("gain_pct", Json::Num(tr.gain_pct()));
        rows.push(row);
    }
    bench::emit_bench_json("tune", rows);

    let mut store = ProfileStore::load(&profile_path);
    for tr in &outcome.per_thread {
        store.insert(tr.profile.clone());
    }
    store.save(&profile_path)?;
    let best = outcome.best();
    // The suggested follow-up must hit the cache key this run wrote:
    // spell out batch and profile path whenever they differ from the
    // `run` defaults (batch is part of the graph signature).
    let mut hint = format!("brainslug run --net {name} --backend cpu --threads {}", best.threads);
    if batch != bench::measured_batches()[0] {
        hint.push_str(&format!(" --batch {batch}"));
    }
    if profile_path != ProfileStore::default_path() {
        hint.push_str(&format!(" --profile-path {}", profile_path.display()));
    }
    println!(
        "wrote {} profile(s) to {} — `{hint}` now auto-loads the tuned config",
        outcome.per_thread.len(),
        profile_path.display()
    );
    Ok(())
}

/// Serialise `doc` to `path`, creating parent directories as needed.
fn write_json_file(path: &str, doc: &Json) -> Result<()> {
    if let Some(dir) = Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, doc.to_string_compact())?;
    Ok(())
}

/// Drain `obs`'s spans into a Chrome-trace file at `path` and report
/// what was captured (used by `run --trace` and `serve --trace`).
fn write_trace_file(path: &str, obs: &brainslug::obs::Obs) -> Result<()> {
    let spans = obs.spans.drain();
    let names = obs.spans.thread_names();
    write_json_file(path, &brainslug::obs::chrome_trace(&spans, &names))?;
    println!(
        "wrote {path}: {} spans over {} thread(s) ({} dropped)",
        spans.len(),
        names.len(),
        obs.spans.dropped()
    );
    Ok(())
}

/// `brainslug trace`: run a network on the native CPU backend with span
/// tracing armed, dump the timeline as Chrome-trace JSON, and
/// optionally (`--drift`) report predicted-vs-measured segment drift.
fn cmd_trace(args: &Args) -> Result<()> {
    let name = args
        .get("net")
        .ok_or_else(|| anyhow::anyhow!("--net required"))?
        .to_string();
    let batch = args.get_positive_usize("batch")?.unwrap_or(1);
    let backend_name = args.get_or("backend", "cpu").to_string();
    if !matches!(backend_name.as_str(), "cpu" | "native") {
        bail!(
            "trace records real execution: only --backend cpu is supported \
             (got '{backend_name}')"
        );
    }
    let threads = args.get_positive_usize("threads")?.unwrap_or(1);
    let runs = args.get_positive_usize("runs")?.unwrap_or(3);
    let out = args.get_or("out", "trace.json").to_string();
    let drift = args.get_bool("drift");
    let device = device_from_args(args, DeviceSpec::host_cpu())?;
    let opts = collapse_opts_from_args(args, bench::measured_opts())?;
    args.reject_unknown()?;

    let obs = Arc::new(brainslug::obs::Obs::default());
    let mut engine = Engine::builder()
        .zoo_small(&name, batch)
        .device(device)
        .mode(Mode::BrainSlug(opts))
        .backend(BackendKind::Cpu { threads })
        .seed(bench::oracle_seed())
        .obs(obs.clone())
        .build()?;
    println!("{} batch={batch} threads={threads}", engine.describe());
    let input = engine.synthetic_input();
    // Fixed seed: trace ids here only need to be distinct per run.
    let id_seed = std::sync::atomic::AtomicU64::new(0x7ACE_0000);
    for run in 0..runs {
        let trace = brainslug::obs::next_trace_id(&id_seed);
        let (_, stats) = engine.run_traced(input.clone(), trace)?;
        println!(
            "run {run}: trace {trace:016x}, total {}",
            fmt_time(stats.total_s)
        );
    }
    let spans = obs.spans.drain();
    let names = obs.spans.thread_names();
    let mut by_kind: std::collections::BTreeMap<&str, usize> = Default::default();
    for s in &spans {
        *by_kind.entry(s.kind.name()).or_default() += 1;
    }
    println!(
        "captured {} spans over {} thread(s) ({} dropped): {}",
        spans.len(),
        names.len(),
        obs.spans.dropped(),
        by_kind
            .iter()
            .map(|(k, n)| format!("{k}={n}"))
            .collect::<Vec<_>>()
            .join(" ")
    );
    write_json_file(&out, &brainslug::obs::chrome_trace(&spans, &names))?;
    println!("wrote {out} — open in Perfetto or chrome://tracing");

    if drift {
        let plan = engine
            .plan()
            .ok_or_else(|| anyhow::anyhow!("--drift needs an optimized plan"))?;
        let predicted =
            brainslug::memsim::predicted_segments(engine.graph(), plan, engine.device());
        let report = brainslug::obs::drift_report(&engine.graph().name, &predicted, &spans);
        let mut table = Table::new(&["segment", "kind", "predicted", "measured", "ratio"]);
        for r in &report.rows {
            table.row(vec![
                r.segment.clone(),
                r.kind.clone(),
                fmt_time(r.predicted_s),
                fmt_time(r.measured_s),
                format!("{:.2}", r.ratio),
            ]);
        }
        println!(
            "# drift — memsim predicted vs measured (min of {runs} runs), network={}",
            report.network
        );
        table.print();
        println!(
            "rank correlation {:.3}, {} unmatched segment(s)",
            report.rank_correlation, report.unmatched
        );
    }
    Ok(())
}

fn cmd_dot(args: &Args) -> Result<()> {
    let name = args
        .get("net")
        .ok_or_else(|| anyhow::anyhow!("--net required"))?
        .to_string();
    let batch = args.get_positive_usize("batch")?.unwrap_or(1);
    let small = args.get_bool("small");
    let json_out = args.get_bool("json");
    args.reject_unknown()?;
    let cfg = if small {
        zoo::small_config(&name, batch)
    } else {
        zoo::paper_config(&name, batch)
    };
    let g = zoo::try_build(&name, cfg)
        .ok_or_else(|| anyhow::anyhow!("unknown network '{name}'"))?;
    if json_out {
        let j: Json = graph_to_json(&g);
        println!("{}", j.to_string_pretty());
    } else {
        println!("{}", g.to_dot());
    }
    Ok(())
}

/// `check`: the static verifier. Lints each requested network's graph,
/// re-proves its optimized plan (structure + resources) against the
/// selected device/budget, then lints the runtime's declared
/// concurrency topologies. With `--schedules N` it additionally runs
/// the schedule model checker over replicas of the real runtime
/// protocols (see `brainslug::conc`). Exit is non-zero on any error,
/// or on any warning under `--deny warnings`.
fn cmd_check(args: &Args) -> Result<()> {
    use brainslug::analysis;
    use brainslug::optimizer::optimize;

    let all = args.get_bool("all-zoo");
    let one = args.get("net").map(|s| s.to_string());
    let batch = args.get_positive_usize("batch")?.unwrap_or(1);
    let device = device_from_args(args, DeviceSpec::paper_cpu())?;
    let opts = collapse_opts_from_args(args, CollapseOptions::default())?;
    let deny_warnings = match args.get("deny") {
        None => false,
        Some("warnings") => true,
        Some(other) => bail!("--deny takes 'warnings', got '{other}'"),
    };
    let format = args.get_or("format", "text").to_string();
    if format != "text" && format != "json" {
        bail!("--format takes text|json, got '{format}'");
    }
    let schedules = args.get_positive_usize("schedules")?;
    let seed = match args.get("seed") {
        None => brainslug::conc::ExploreOptions::default().seed,
        Some(s) => s
            .parse::<u64>()
            .map_err(|_| anyhow::anyhow!("--seed takes a u64, got '{s}'"))?,
    };
    args.reject_unknown()?;

    let names: Vec<String> = match (&one, all) {
        (Some(name), false) => {
            let canon = zoo::resolve(name);
            if zoo::try_build(canon, zoo::small_config(canon, 1)).is_none() {
                bail!("unknown network '{name}'");
            }
            vec![canon.to_string()]
        }
        _ => zoo::ALL_NETWORKS.iter().map(|s| s.to_string()).collect(),
    };

    let mut report = analysis::Report::new();
    for name in &names {
        let g = zoo::build(name, zoo::paper_config(name, batch));
        report.extend(analysis::lint_graph(&g));
        let plan = optimize(&g, &device, &opts);
        report.extend(analysis::verify_plan(&g, &plan, &device, &opts));
    }
    for topo in analysis::standard_topologies() {
        report.extend(analysis::check_topology(&topo));
    }
    // Pass 4 (opt-in, it executes code): schedule model checking of the
    // runtime protocol replicas. N bounds the DFS; the random-walk count
    // scales off it inside `check_protocols`.
    if let Some(n) = schedules {
        report.extend(brainslug::conc::check_protocols(n, seed).diags);
    }

    if format == "json" {
        let mut j = report.to_json();
        j.set(
            "networks",
            Json::Arr(names.iter().map(|n| Json::Str(n.clone())).collect()),
        );
        j.set("device", Json::Str(device.name.clone()));
        if let Some(n) = schedules {
            j.set("schedules", Json::Num(n as f64));
        }
        println!("{}", j.to_string_pretty());
    } else {
        println!(
            "checked {} network(s) on {} + {} concurrency topolog(ies){}",
            names.len(),
            device.name,
            analysis::standard_topologies().len(),
            match schedules {
                Some(n) => format!(" + schedule exploration ({n} DFS executions/protocol)"),
                None => String::new(),
            }
        );
        print!("{}", report.render_text());
    }
    if !report.is_clean(deny_warnings) {
        bail!(
            "check failed: {} error(s), {} warning(s){}",
            report.error_count(),
            report.warning_count(),
            if deny_warnings { " (warnings denied)" } else { "" }
        );
    }
    Ok(())
}
