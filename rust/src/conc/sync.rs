//! Drop-in synchronization facade: `std::sync` in production, modeled
//! primitives under the controlled scheduler during exploration.
//!
//! Every type here mirrors its `std::sync` counterpart's API (same
//! method names, same error types), so porting a protocol is a type
//! swap, not a rewrite. Construction decides the mode once: an object
//! created on a model thread (inside [`crate::conc::explore`]) registers
//! with that execution's scheduler and routes every operation through
//! it; an object created anywhere else carries no model state and every
//! operation is exactly the `std::sync` call — the only production
//! overhead is one thread-local read at construction.
//!
//! The facade adds three things `std::sync` does not have, used by the
//! drain protocols and the checker:
//!
//! - [`Gate`]: the `Arc<RwLock<bool>>` shutdown-gate idiom as a type
//!   (enter under the read side, close under the write side).
//! - [`SyncSender::send_token`]: a send tagged as a *shutdown token*,
//!   so the checker can enforce the gate-before-tokens drain contract
//!   (BSL055) from real traces.
//! - [`model::Obligation`]: accepted work the protocol owes an answer
//!   for; an obligation still open at quiescence is BSL056.

use std::collections::VecDeque;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::{Arc, LockResult, PoisonError};
use std::time::Duration;

use super::sched::{current_ctx, Scheduler, SlotKind};

/// Handle tying a facade object to the scheduler of the execution it
/// was created in.
struct ModelRef {
    sched: Arc<Scheduler>,
    id: usize,
}

impl ModelRef {
    /// Register-if-modeling: `Some` only on a live model thread.
    fn new(register: impl FnOnce(&Scheduler) -> usize) -> Option<ModelRef> {
        current_ctx().map(|(sched, _)| {
            let id = register(&sched);
            ModelRef { sched, id }
        })
    }

    /// The calling thread's tid, when it belongs to the same execution
    /// this object was registered in.
    fn tid(&self) -> Option<usize> {
        match current_ctx() {
            Some((s, tid)) if Arc::ptr_eq(&s, &self.sched) => Some(tid),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------
// Mutex
// ---------------------------------------------------------------------

/// [`std::sync::Mutex`] facade. In model mode the scheduler serializes
/// threads, so the inner std lock is always uncontended; it still
/// provides the `&mut T` access and poison bookkeeping.
pub struct Mutex<T> {
    inner: std::sync::Mutex<T>,
    model: Option<ModelRef>,
}

impl<T> Mutex<T> {
    pub fn new(value: T) -> Mutex<T> {
        Self::labeled(value, "mutex")
    }

    /// Like `new`, with a label used in diagnostics and lock-order
    /// cycle reports.
    pub fn labeled(value: T, label: &str) -> Mutex<T> {
        Mutex {
            inner: std::sync::Mutex::new(value),
            model: ModelRef::new(|s| s.register_mutex(label)),
        }
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        let model_tid = match &self.model {
            Some(m) => match m.tid() {
                Some(tid) => {
                    m.sched.mutex_lock(tid, m.id);
                    Some(tid)
                }
                None => None,
            },
            None => None,
        };
        match self.inner.lock() {
            Ok(g) => Ok(MutexGuard {
                inner: Some(g),
                owner: self,
                model_tid,
            }),
            Err(p) => Err(PoisonError::new(MutexGuard {
                inner: Some(p.into_inner()),
                owner: self,
                model_tid,
            })),
        }
    }
}

/// Guard returned by [`Mutex::lock`]. Releases the real lock first,
/// then reports the logical release to the scheduler (which is a
/// scheduling point), so no thread is ever parked while holding the
/// real lock.
pub struct MutexGuard<'a, T> {
    inner: Option<std::sync::MutexGuard<'a, T>>,
    owner: &'a Mutex<T>,
    model_tid: Option<usize>,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        match &self.inner {
            Some(g) => g,
            None => unreachable!("mutex guard used after release"),
        }
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        match &mut self.inner {
            Some(g) => g,
            None => unreachable!("mutex guard used after release"),
        }
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Real lock first, logical release (a scheduling point) after.
        self.inner = None;
        if let (Some(tid), Some(m)) = (self.model_tid, &self.owner.model) {
            m.sched.mutex_unlock(tid, m.id);
        }
    }
}

// ---------------------------------------------------------------------
// Condvar
// ---------------------------------------------------------------------

/// [`std::sync::Condvar`] facade. A bare [`Condvar::wait`] is flagged
/// BSL052 by the checker; [`Condvar::wait_while`] is the endorsed form.
pub struct Condvar {
    inner: std::sync::Condvar,
    model: Option<ModelRef>,
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl Condvar {
    pub fn new() -> Condvar {
        Self::labeled("condvar")
    }

    pub fn labeled(label: &str) -> Condvar {
        Condvar {
            inner: std::sync::Condvar::new(),
            model: ModelRef::new(|s| s.register_condvar(label)),
        }
    }

    /// Wait without a predicate loop. Works, but the checker flags it
    /// (BSL052): spurious wakeups and lost notifies are on the caller.
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        self.wait_impl(guard, true)
    }

    /// Wait until `condition` returns false (checked under the lock).
    pub fn wait_while<'a, T, F>(
        &self,
        mut guard: MutexGuard<'a, T>,
        mut condition: F,
    ) -> LockResult<MutexGuard<'a, T>>
    where
        F: FnMut(&mut T) -> bool,
    {
        while condition(&mut guard) {
            guard = match self.wait_impl(guard, false) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
        Ok(guard)
    }

    fn wait_impl<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
        bare: bool,
    ) -> LockResult<MutexGuard<'a, T>> {
        let owner = guard.owner;
        if let (Some(cv), Some(mx)) = (&self.model, &owner.model) {
            if let Some(tid) = cv.tid() {
                // Release the real lock, suppress the guard's logical
                // release (condvar_wait performs it atomically with the
                // park), and re-take the real lock once re-admitted.
                guard.inner = None;
                guard.model_tid = None;
                drop(guard);
                cv.sched.condvar_wait(tid, cv.id, mx.id, bare);
                let std_guard = owner.inner.lock().unwrap_or_else(|p| p.into_inner());
                return Ok(MutexGuard {
                    inner: Some(std_guard),
                    owner,
                    model_tid: Some(tid),
                });
            }
        }
        // Production path: plain std wait on the inner guard.
        let std_guard = match guard.inner.take() {
            Some(g) => g,
            None => unreachable!("mutex guard used after release"),
        };
        guard.model_tid = None;
        drop(guard);
        match self.inner.wait(std_guard) {
            Ok(g) => Ok(MutexGuard {
                inner: Some(g),
                owner,
                model_tid: None,
            }),
            Err(p) => Err(PoisonError::new(MutexGuard {
                inner: Some(p.into_inner()),
                owner,
                model_tid: None,
            })),
        }
    }

    pub fn notify_one(&self) {
        if let Some(cv) = &self.model {
            if let Some(tid) = cv.tid() {
                cv.sched.condvar_notify(tid, cv.id, false);
                return;
            }
        }
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        if let Some(cv) = &self.model {
            if let Some(tid) = cv.tid() {
                cv.sched.condvar_notify(tid, cv.id, true);
                return;
            }
        }
        self.inner.notify_all();
    }
}

// ---------------------------------------------------------------------
// Bounded channel
// ---------------------------------------------------------------------

/// Shared state of one modeled channel: the scheduler holds the slot
/// *tags* (value vs token) and the blocking logic; the typed payloads
/// live here. Only the running thread touches either, so the inner
/// mutex is always uncontended.
struct ModelChan<T> {
    values: std::sync::Mutex<VecDeque<T>>,
    model: ModelRef,
}

impl<T> ModelChan<T> {
    fn push(&self, value: T) {
        self.values
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push_back(value);
    }

    fn pop(&self) -> Option<T> {
        self.values
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .pop_front()
    }
}

enum SenderImpl<T> {
    Std(mpsc::SyncSender<T>),
    Model(Arc<ModelChan<T>>),
}

enum ReceiverImpl<T> {
    Std(mpsc::Receiver<T>),
    Model(Arc<ModelChan<T>>),
}

/// [`std::sync::mpsc::SyncSender`] facade.
pub struct SyncSender<T>(SenderImpl<T>);

/// [`std::sync::mpsc::Receiver`] facade.
pub struct Receiver<T>(ReceiverImpl<T>);

/// [`std::sync::mpsc::sync_channel`] facade.
pub fn sync_channel<T>(bound: usize) -> (SyncSender<T>, Receiver<T>) {
    sync_channel_labeled(bound, "channel")
}

/// Like [`sync_channel`], with a label for diagnostics.
pub fn sync_channel_labeled<T>(bound: usize, label: &str) -> (SyncSender<T>, Receiver<T>) {
    if let Some((sched, _)) = current_ctx() {
        let id = sched.register_chan(bound, label);
        let chan = Arc::new(ModelChan {
            values: std::sync::Mutex::new(VecDeque::new()),
            model: ModelRef { sched, id },
        });
        (
            SyncSender(SenderImpl::Model(chan.clone())),
            Receiver(ReceiverImpl::Model(chan)),
        )
    } else {
        let (tx, rx) = mpsc::sync_channel(bound);
        (SyncSender(SenderImpl::Std(tx)), Receiver(ReceiverImpl::Std(rx)))
    }
}

impl<T> SyncSender<T> {
    fn model_send(
        chan: &Arc<ModelChan<T>>,
        value: T,
        kind: SlotKind,
    ) -> Result<(), mpsc::SendError<T>> {
        match chan.model.tid() {
            Some(tid) => {
                if chan.model.sched.chan_send(tid, chan.model.id, kind) {
                    chan.push(value);
                    Ok(())
                } else {
                    Err(mpsc::SendError(value))
                }
            }
            // Misuse escape hatch: a non-model thread touching a model
            // channel bypasses the scheduler (documented, not reached
            // by the protocols under check).
            None => {
                chan.push(value);
                Ok(())
            }
        }
    }

    /// Blocking send (a regular work item).
    pub fn send(&self, value: T) -> Result<(), mpsc::SendError<T>> {
        match &self.0 {
            SenderImpl::Std(tx) => tx.send(value),
            SenderImpl::Model(chan) => Self::model_send(chan, value, SlotKind::Value),
        }
    }

    /// Blocking send of a *shutdown token*. Identical to [`Self::send`]
    /// in production; under the model the slot is tagged so the checker
    /// can enforce the gate-before-tokens drain contract (BSL055).
    pub fn send_token(&self, value: T) -> Result<(), mpsc::SendError<T>> {
        match &self.0 {
            SenderImpl::Std(tx) => tx.send(value),
            SenderImpl::Model(chan) => Self::model_send(chan, value, SlotKind::Token),
        }
    }

    pub fn try_send(&self, value: T) -> Result<(), mpsc::TrySendError<T>> {
        match &self.0 {
            SenderImpl::Std(tx) => tx.try_send(value),
            SenderImpl::Model(chan) => match chan.model.tid() {
                Some(tid) => {
                    match chan.model.sched.chan_try_send(tid, chan.model.id, SlotKind::Value) {
                        Ok(true) => {
                            chan.push(value);
                            Ok(())
                        }
                        Ok(false) => Err(mpsc::TrySendError::Disconnected(value)),
                        Err(()) => Err(mpsc::TrySendError::Full(value)),
                    }
                }
                None => {
                    chan.push(value);
                    Ok(())
                }
            },
        }
    }

    /// Declare that shutdown tokens on this channel are only legal once
    /// `gate` is closed (no-op in production; BSL055 under the model).
    pub fn bind_gate(&self, gate: &Gate) {
        if let (SenderImpl::Model(chan), Some(g)) = (&self.0, &gate.model) {
            chan.model.sched.bind_gate(chan.model.id, g.id);
        }
    }
}

impl<T> Clone for SyncSender<T> {
    fn clone(&self) -> Self {
        match &self.0 {
            SenderImpl::Std(tx) => SyncSender(SenderImpl::Std(tx.clone())),
            SenderImpl::Model(chan) => {
                chan.model.sched.chan_sender_cloned(chan.model.id);
                SyncSender(SenderImpl::Model(chan.clone()))
            }
        }
    }
}

impl<T> Drop for SyncSender<T> {
    fn drop(&mut self) {
        if let SenderImpl::Model(chan) = &self.0 {
            chan.model.sched.chan_sender_dropped(chan.model.id);
        }
    }
}

impl<T> Receiver<T> {
    pub fn recv(&self) -> Result<T, mpsc::RecvError> {
        match &self.0 {
            ReceiverImpl::Std(rx) => rx.recv(),
            ReceiverImpl::Model(chan) => match chan.model.tid() {
                Some(tid) => match chan.model.sched.chan_recv(tid, chan.model.id) {
                    Some(_kind) => chan.pop().ok_or(mpsc::RecvError),
                    None => Err(mpsc::RecvError),
                },
                None => chan.pop().ok_or(mpsc::RecvError),
            },
        }
    }

    /// Timed receive. Under the model, time does not exist: the timeout
    /// may always fire immediately, which over-approximates every real
    /// timing (sound for protocols that treat a timeout as "close the
    /// batch early", never as a synchronization edge).
    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, mpsc::RecvTimeoutError> {
        match &self.0 {
            ReceiverImpl::Std(rx) => rx.recv_timeout(timeout),
            ReceiverImpl::Model(chan) => match chan.model.tid() {
                Some(tid) => match chan.model.sched.chan_recv_timeout(tid, chan.model.id) {
                    Ok(_kind) => chan.pop().ok_or(mpsc::RecvTimeoutError::Disconnected),
                    Err(true) => Err(mpsc::RecvTimeoutError::Disconnected),
                    Err(false) => Err(mpsc::RecvTimeoutError::Timeout),
                },
                None => chan.pop().ok_or(mpsc::RecvTimeoutError::Timeout),
            },
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        if let ReceiverImpl::Model(chan) = &self.0 {
            chan.model.sched.chan_receiver_dropped(chan.model.id);
        }
    }
}

// ---------------------------------------------------------------------
// Gate
// ---------------------------------------------------------------------

/// The shutdown-gate idiom (`Arc<RwLock<bool>>`) as a first-class type:
/// request intake enters under the read side, shutdown closes under the
/// write side. Closing blocks until every admitted enterer has exited,
/// which is exactly the FIFO-ordering fence the drain protocol needs —
/// no request admitted before the close can land behind the shutdown
/// tokens.
pub struct Gate {
    inner: std::sync::RwLock<bool>,
    model: Option<ModelRef>,
}

impl Default for Gate {
    fn default() -> Self {
        Self::new()
    }
}

impl Gate {
    pub fn new() -> Gate {
        Self::labeled("gate")
    }

    pub fn labeled(label: &str) -> Gate {
        Gate {
            inner: std::sync::RwLock::new(false),
            model: ModelRef::new(|s| s.register_gate(label)),
        }
    }

    /// Enter under the read side: `Some(guard)` while open (hold the
    /// guard across the protected action, e.g. the enqueue), `None`
    /// once closed.
    pub fn enter(&self) -> Option<GateGuard<'_>> {
        if let Some(m) = &self.model {
            if let Some(tid) = m.tid() {
                return if m.sched.gate_enter(tid, m.id) {
                    Some(GateGuard {
                        gate: self,
                        std_guard: None,
                        model_tid: Some(tid),
                    })
                } else {
                    None
                };
            }
        }
        let g = self.inner.read().unwrap_or_else(|p| p.into_inner());
        if *g {
            None
        } else {
            Some(GateGuard {
                gate: self,
                std_guard: Some(g),
                model_tid: None,
            })
        }
    }

    /// Close under the write side: blocks until current enterers exit;
    /// afterwards every [`Self::enter`] returns `None`.
    pub fn close(&self) {
        if let Some(m) = &self.model {
            if let Some(tid) = m.tid() {
                m.sched.gate_close(tid, m.id);
                return;
            }
        }
        let mut g = self.inner.write().unwrap_or_else(|p| p.into_inner());
        *g = true;
    }

    /// Non-blocking observation (a scheduling point under the model).
    pub fn is_closed(&self) -> bool {
        if let Some(m) = &self.model {
            if let Some(tid) = m.tid() {
                return m.sched.gate_is_closed(tid, m.id);
            }
        }
        *self.inner.read().unwrap_or_else(|p| p.into_inner())
    }
}

/// Read-side admission ticket from [`Gate::enter`].
pub struct GateGuard<'a> {
    gate: &'a Gate,
    std_guard: Option<std::sync::RwLockReadGuard<'a, bool>>,
    model_tid: Option<usize>,
}

impl Drop for GateGuard<'_> {
    fn drop(&mut self) {
        self.std_guard = None;
        if let (Some(tid), Some(m)) = (self.model_tid, &self.gate.model) {
            m.sched.gate_exit(tid, m.id);
        }
    }
}

// ---------------------------------------------------------------------
// Atomics
// ---------------------------------------------------------------------

/// [`std::sync::atomic::AtomicBool`] facade: loads and stores are
/// scheduling points under the model (flag polling protocols get their
/// interleavings explored), plain atomics in production.
pub struct AtomicBool {
    inner: std::sync::atomic::AtomicBool,
    model: Option<Arc<Scheduler>>,
}

impl AtomicBool {
    pub fn new(value: bool) -> AtomicBool {
        AtomicBool {
            inner: std::sync::atomic::AtomicBool::new(value),
            model: current_ctx().map(|(s, _)| s),
        }
    }

    fn yield_point(&self) {
        if let Some(s) = &self.model {
            if let Some((cur, tid)) = current_ctx() {
                if Arc::ptr_eq(&cur, s) {
                    s.yield_now(tid);
                }
            }
        }
    }

    pub fn load(&self, order: Ordering) -> bool {
        self.yield_point();
        self.inner.load(order)
    }

    pub fn store(&self, value: bool, order: Ordering) {
        self.yield_point();
        self.inner.store(value, order);
    }
}

// ---------------------------------------------------------------------
// Model-thread spawning and obligations
// ---------------------------------------------------------------------

/// Thread spawning and work obligations for protocol bodies. Outside
/// an exploration these fall back to `std::thread` / no-ops, so a
/// protocol replica also runs as a plain test.
pub mod model {
    use super::*;

    enum HandleImpl {
        Std(std::thread::JoinHandle<()>),
        Model { sched: Arc<Scheduler>, tid: usize },
    }

    /// Join handle for a spawned protocol thread.
    pub struct JoinHandle(HandleImpl);

    impl JoinHandle {
        pub fn join(self) {
            match self.0 {
                HandleImpl::Std(h) => {
                    let _ = h.join();
                }
                HandleImpl::Model { sched, tid } => match current_ctx() {
                    Some((cur, me)) if Arc::ptr_eq(&cur, &sched) => {
                        sched.join_thread(me, tid);
                    }
                    _ => {}
                },
            }
        }
    }

    /// Spawn a protocol thread: a model thread under exploration, a
    /// plain `std::thread` otherwise.
    pub fn spawn(label: &str, f: impl FnOnce() + Send + 'static) -> JoinHandle {
        match current_ctx() {
            Some((sched, me)) => {
                let tid = sched.spawn_child(me, label, f);
                JoinHandle(HandleImpl::Model { sched, tid })
            }
            None => JoinHandle(HandleImpl::Std(std::thread::spawn(f))),
        }
    }

    /// True on a model thread of a live exploration.
    pub fn active() -> bool {
        current_ctx().is_some()
    }

    /// Accepted work the protocol owes an answer for. Open it when the
    /// work is admitted, complete it when answered; an obligation alive
    /// at quiescence is a BSL056 violation with the schedule attached.
    /// No-op outside an exploration.
    pub struct Obligation {
        sched: Option<Arc<Scheduler>>,
        id: u64,
    }

    /// Open an obligation on the current model thread.
    pub fn obligation(label: &str) -> Obligation {
        match current_ctx() {
            Some((sched, tid)) => {
                let id = sched.obligation_open(tid, label);
                Obligation {
                    sched: Some(sched),
                    id,
                }
            }
            None => Obligation { sched: None, id: 0 },
        }
    }

    impl Obligation {
        /// The work was answered. Dropping without completing leaves
        /// the obligation open — deliberately: a dropped reply channel
        /// is exactly the bug class this models.
        pub fn complete(self) {
            if let Some(sched) = &self.sched {
                if let Some((cur, tid)) = current_ctx() {
                    if Arc::ptr_eq(&cur, sched) {
                        sched.obligation_complete(tid, self.id);
                    }
                }
            }
        }
    }
}
