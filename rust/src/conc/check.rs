//! The `brainslug check` schedule-exploration pass: run the standard
//! protocol replicas under the controlled scheduler and map everything
//! found onto BSL050–BSL056 diagnostics.
//!
//! The replicas live next to the code they model —
//! [`crate::server::drain_protocol`] (queue + gate + shutdown tokens),
//! [`crate::http::listener::drain_protocol`] (accept → pool handoff →
//! drain ordering), [`crate::cpu::par::pool_protocol`] (scoped band
//! pool) and [`crate::fault::supervisor_protocol`] (worker crash →
//! restart with shutdown-token conservation) — so a change to a runtime
//! protocol lands in the same review as the change to its model. Each
//! replica takes a bug-switch struct whose default is the shipped
//! protocol; the switches re-introduce the historical bugs (the PR 2
//! shutdown-while-queued loss, the PR 6 token-overtakes-request drain
//! race, and the supervisor lost-restart race) so the test suite can
//! prove the checker still catches them.

use std::sync::Arc;

use crate::analysis::{DiagCode, Diagnostic, Report};

use super::sched::{explore, ExploreOptions, ExploreReport, ModelWarning, Violation};

/// How many trailing trace events a counterexample diagnostic carries.
const TRACE_NOTES: usize = 8;

/// Exploration bounds for `brainslug check --schedules N`: `N` caps the
/// DFS pass, with a quarter of `N` seeded random walks for the long
/// tail past the preemption bound.
pub fn options_for(schedules: usize, seed: u64) -> ExploreOptions {
    ExploreOptions {
        dfs_executions: schedules,
        random_schedules: (schedules / 4).max(8),
        seed,
        ..ExploreOptions::default()
    }
}

fn schedule_note(schedule: &[usize]) -> String {
    let tids: Vec<String> = schedule.iter().map(|t| t.to_string()).collect();
    format!(
        "counterexample schedule ({} decisions, one tid each): {}",
        schedule.len(),
        tids.join(" ")
    )
}

/// Map one protocol's exploration outcome onto diagnostics. A clean
/// report maps to no diagnostics; a violation carries its replayable
/// schedule and the tail of the event trace as notes.
pub fn report_to_diags(report: &ExploreReport) -> Vec<Diagnostic> {
    let subject = format!("schedule model '{}'", report.name);
    let mut diags = Vec::new();
    if let Some(finding) = &report.finding {
        let (code, message) = match &finding.violation {
            Violation::Deadlock { blocked } => (
                DiagCode::ModelDeadlock,
                format!(
                    "deadlock after {} executions: {}",
                    report.executions,
                    blocked.join(", ")
                ),
            ),
            Violation::LostNotify { condvar, wasted } => (
                DiagCode::LostNotify,
                format!(
                    "deadlock behind condvar '{condvar}': {wasted} notify(s) fired while \
                     nothing was waiting, then a waiter parked forever"
                ),
            ),
            Violation::GateAfterTokens { channel, gate } => (
                DiagCode::GateAfterTokens,
                format!(
                    "shutdown token entered channel '{channel}' while gate '{gate}' was \
                     still open: a late request can land behind the token and be dropped"
                ),
            ),
            Violation::NonQuiescent { open } => (
                DiagCode::NonQuiescentJoin,
                format!(
                    "protocol finished with unanswered work: {}",
                    open.join(", ")
                ),
            ),
            Violation::LockOrderCycle { cycle } => (
                DiagCode::LockOrderCycle,
                format!("observed acquisition order forms a cycle: {}", cycle.join(" -> ")),
            ),
        };
        let mut d = Diagnostic::new(code, subject.clone(), message)
            .note(schedule_note(&finding.counterexample.schedule))
            .note("replay with ExploreOptions { replay: Some(schedule), .. } to reproduce");
        let tail = finding
            .counterexample
            .events
            .len()
            .saturating_sub(TRACE_NOTES);
        for ev in &finding.counterexample.events[tail..] {
            d = d.note(format!("trace: {ev}"));
        }
        diags.push(d);
    }
    for w in &report.warnings {
        let (code, message) = match w {
            ModelWarning::BareWait { condvar } => (
                DiagCode::BareCondvarWait,
                format!(
                    "condvar '{condvar}' is waited on without a predicate loop; a spurious \
                     wakeup or early notify breaks it (use wait_while)"
                ),
            ),
            ModelWarning::SendAfterClose { channel } => (
                DiagCode::SendAfterClose,
                format!(
                    "send on channel '{channel}' after its receiver was dropped is reachable"
                ),
            ),
        };
        diags.push(Diagnostic::new(code, subject.clone(), message));
    }
    diags
}

/// The protocol suite `brainslug check` explores: the shipped (bug-free)
/// configurations of the five runtime protocols, sized small enough
/// that the DFS pass gets real coverage of the interleaving space.
fn protocol_suite() -> Vec<(&'static str, Arc<dyn Fn() + Send + Sync>)> {
    vec![
        (
            "server-drain",
            Arc::new(|| {
                crate::server::drain_protocol(2, 2, 2, crate::server::DrainBugs::default());
            }) as Arc<dyn Fn() + Send + Sync>,
        ),
        (
            "listener-drain",
            Arc::new(|| {
                crate::http::listener::drain_protocol(
                    2,
                    2,
                    3,
                    crate::http::listener::ListenerBugs::default(),
                );
            }),
        ),
        (
            "cpu-band-pool",
            Arc::new(|| {
                crate::cpu::par::pool_protocol(2, 4);
            }),
        ),
        (
            "fault-supervisor",
            Arc::new(|| {
                crate::fault::supervisor_protocol(
                    2,
                    2,
                    1,
                    1,
                    crate::fault::SupervisorBugs::default(),
                );
            }),
        ),
        (
            "obs-flush",
            Arc::new(|| {
                crate::obs::flush_protocol(2, 2, crate::obs::FlushBugs::default());
            }),
        ),
    ]
}

/// Run the schedule-exploration pass over the standard protocol suite.
/// This is `brainslug check --schedules N` (and the model-check test
/// suite's clean-tree assertion).
pub fn check_protocols(schedules: usize, seed: u64) -> Report {
    let opts = options_for(schedules, seed);
    let mut report = Report::new();
    for (name, body) in protocol_suite() {
        let explored = explore(name, &opts, body);
        report.extend(report_to_diags(&explored));
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conc::sched::{Counterexample, Finding};

    #[test]
    fn violation_maps_to_its_code_with_replayable_schedule() {
        let report = ExploreReport {
            name: "synthetic".into(),
            executions: 12,
            finding: Some(Finding {
                violation: Violation::GateAfterTokens {
                    channel: "dispatch".into(),
                    gate: "closed".into(),
                },
                counterexample: Counterexample {
                    schedule: vec![0, 1, 1, 0],
                    events: vec!["e1".into(), "e2".into()],
                },
            }),
            warnings: vec![ModelWarning::BareWait {
                condvar: "cv".into(),
            }],
        };
        let diags = report_to_diags(&report);
        assert_eq!(diags.len(), 2);
        assert_eq!(diags[0].code, DiagCode::GateAfterTokens);
        assert!(diags[0].notes.iter().any(|n| n.contains("0 1 1 0")));
        assert!(diags[0].notes.iter().any(|n| n.contains("trace: e2")));
        assert_eq!(diags[1].code, DiagCode::BareCondvarWait);
    }

    #[test]
    fn clean_report_maps_to_no_diags() {
        let report = ExploreReport {
            name: "clean".into(),
            executions: 64,
            finding: None,
            warnings: vec![],
        };
        assert!(report_to_diags(&report).is_empty());
    }

    #[test]
    fn shipped_protocol_suite_explores_clean() {
        // The acceptance bar: the unmodified tree, explored with the
        // default CI budget, has zero findings and zero warnings.
        let report = check_protocols(128, 0x5EED_0BB5);
        assert!(
            report.is_clean(true),
            "shipped protocols must model-check clean:\n{}",
            report.render_text()
        );
    }
}
