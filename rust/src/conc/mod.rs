//! Controlled-schedule concurrency model checking (`brainslug check`
//! pass 4, BSL050–BSL056).
//!
//! PR 7's topology lint checks the *declared* shape of the runtime's
//! threads, channels and gates; this module checks their *behavior*, in
//! the spirit of loom/CHESS systematic concurrency testing — with zero
//! dependencies, on stable Rust, against the protocols this repo
//! actually ships.
//!
//! ```text
//!   protocol replica (server::drain_protocol, …)
//!        │ uses
//!        ▼
//!   conc::sync facade ── production path ──▶ std::sync (one TLS read)
//!        │ model path (inside conc::explore)
//!        ▼
//!   conc::sched::Scheduler      real OS threads, but exactly ONE
//!        │                      runnable at a time; every acquire /
//!        │                      release / send / recv / wait / enter
//!        ▼                      is a scheduling point
//!   exploration: bounded-preemption DFS  +  seeded random walks
//!        │                                  (SplitMix64, crate::rng)
//!        ▼
//!   Finding { Violation, Counterexample { schedule, events } }
//!        ▼
//!   BSL050–BSL056 diagnostics with the replayable schedule as notes
//! ```
//!
//! The three layers:
//!
//! - [`sync`] — drop-in `Mutex`/`Condvar`/`sync_channel` facade plus
//!   the [`sync::Gate`] drain-gate type, shutdown-token sends and work
//!   [`sync::model::Obligation`]s. Objects built outside an exploration
//!   compile straight to `std::sync` behavior.
//! - [`sched`] — the deterministic token-passing scheduler and the
//!   [`explore`] driver. Properties checked per execution: global
//!   deadlock (BSL050), lock-order cycles from observed acquisition
//!   traces (BSL051), bare condvar waits (BSL052), lost notifies
//!   (BSL053), sends after receiver teardown (BSL054), shutdown tokens
//!   overtaking the drain gate (BSL055), and non-quiescent completion —
//!   queued work or open obligations at join (BSL056).
//! - [`check`] — maps [`ExploreReport`]s onto [`crate::analysis`]
//!   diagnostics and runs the standard protocol suite for
//!   `brainslug check --schedules N`.
//!
//! Every violation carries a [`Counterexample`]: the exact decision
//! list (one chosen thread id per scheduling point) plus the trailing
//! event trace. Feeding the schedule back through
//! [`ExploreOptions::replay`] reproduces the failure deterministically.
//!
//! ## Model reductions (what the model deliberately is not)
//!
//! - **No time.** `recv_timeout` may always time out immediately; a
//!   timeout is over-approximated as "can fire at any point", which is
//!   sound for protocols that use timeouts to close a batch early and
//!   unsound only for code that uses wall-clock as a synchronization
//!   edge (which the lint would flag anyway).
//! - **Notify wakes all, schedule picks.** `notify_one` moves one
//!   waiter out of the wait-set but every unparked thread re-races for
//!   the mutex under scheduler control, which covers the OS's freedom
//!   in picking the woken thread.
//! - **Bounded exploration.** DFS is capped by executions and a
//!   preemption bound (CHESS-style: most real bugs need ≤ 2 forced
//!   preemptions); the random pass covers the long tail. A clean
//!   report is evidence, not proof.

pub mod check;
pub mod sched;
pub mod sync;

pub use check::{check_protocols, report_to_diags};
pub use sched::{
    explore, Counterexample, ExploreOptions, ExploreReport, Finding, ModelWarning, SlotKind,
    Violation,
};

#[cfg(test)]
mod tests {
    use super::sync::{model, sync_channel_labeled, Condvar, Gate, Mutex};
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn opts(dfs: usize, random: usize) -> ExploreOptions {
        ExploreOptions {
            dfs_executions: dfs,
            random_schedules: random,
            ..ExploreOptions::default()
        }
    }

    #[test]
    fn clean_counter_protocol_explores_clean() {
        let report = explore(
            "counter",
            &opts(64, 16),
            Arc::new(|| {
                let m = Arc::new(Mutex::labeled(0u32, "counter"));
                let hs: Vec<_> = (0..2)
                    .map(|_| {
                        let m = m.clone();
                        model::spawn("inc", move || {
                            let mut g = m.lock().unwrap_or_else(|p| p.into_inner());
                            *g += 1;
                        })
                    })
                    .collect();
                for h in hs {
                    h.join();
                }
                let g = m.lock().unwrap_or_else(|p| p.into_inner());
                assert_eq!(*g, 2);
            }),
        );
        assert!(report.finding.is_none(), "{:?}", report.finding);
        assert!(report.warnings.is_empty());
        assert!(report.executions > 1, "should explore several schedules");
    }

    #[test]
    fn opposite_lock_order_is_found() {
        // The classic AB/BA deadlock: DFS must find either the deadlock
        // itself or the lock-order cycle that proves it possible.
        let report = explore(
            "ab-ba",
            &opts(256, 32),
            Arc::new(|| {
                let a = Arc::new(Mutex::labeled((), "lock-a"));
                let b = Arc::new(Mutex::labeled((), "lock-b"));
                let (a2, b2) = (a.clone(), b.clone());
                let h = model::spawn("ba", move || {
                    let _gb = b2.lock().unwrap_or_else(|p| p.into_inner());
                    let _ga = a2.lock().unwrap_or_else(|p| p.into_inner());
                });
                {
                    let _ga = a.lock().unwrap_or_else(|p| p.into_inner());
                    let _gb = b.lock().unwrap_or_else(|p| p.into_inner());
                }
                h.join();
            }),
        );
        let f = report.finding.expect("AB/BA must not explore clean");
        assert!(
            matches!(
                f.violation,
                Violation::Deadlock { .. } | Violation::LockOrderCycle { .. }
            ),
            "{:?}",
            f.violation
        );
        assert!(!f.counterexample.schedule.is_empty());
    }

    #[test]
    fn counterexample_replays_to_same_violation() {
        let body: Arc<dyn Fn() + Send + Sync> = Arc::new(|| {
            let a = Arc::new(Mutex::labeled((), "ra"));
            let b = Arc::new(Mutex::labeled((), "rb"));
            let (a2, b2) = (a.clone(), b.clone());
            let h = model::spawn("ba", move || {
                let _gb = b2.lock().unwrap_or_else(|p| p.into_inner());
                let _ga = a2.lock().unwrap_or_else(|p| p.into_inner());
            });
            {
                let _ga = a.lock().unwrap_or_else(|p| p.into_inner());
                let _gb = b.lock().unwrap_or_else(|p| p.into_inner());
            }
            h.join();
        });
        let report = explore("replay-src", &opts(256, 32), body.clone());
        let f = report.finding.expect("must find the deadlock");
        if matches!(f.violation, Violation::LockOrderCycle { .. }) {
            // Cycle findings accumulate across runs; only direct
            // per-execution violations replay from one schedule.
            return;
        }
        let replay = explore(
            "replay-dst",
            &ExploreOptions {
                replay: Some(f.counterexample.schedule.clone()),
                ..ExploreOptions::default()
            },
            body,
        );
        assert_eq!(replay.executions, 1);
        let rf = replay.finding.expect("replay must reproduce the violation");
        assert!(
            matches!(
                rf.violation,
                Violation::Deadlock { .. } | Violation::LockOrderCycle { .. }
            ),
            "{:?}",
            rf.violation
        );
    }

    #[test]
    fn bare_wait_is_warned_and_wait_while_is_not() {
        let report = explore(
            "bare-wait",
            &opts(32, 8),
            Arc::new(|| {
                let pair = Arc::new((Mutex::labeled(false, "ready"), Condvar::labeled("cv")));
                let p2 = pair.clone();
                let h = model::spawn("setter", move || {
                    let (m, cv) = &*p2;
                    let mut g = m.lock().unwrap_or_else(|p| p.into_inner());
                    *g = true;
                    cv.notify_one();
                });
                let (m, cv) = &*pair;
                let g = m.lock().unwrap_or_else(|p| p.into_inner());
                if !*g {
                    // Bare wait: no predicate loop. Under schedules where
                    // the setter already ran, we never park — the warning
                    // must still be found on the schedules where we do.
                    let _g = cv.wait(g).unwrap_or_else(|p| p.into_inner());
                } else {
                    drop(g);
                }
                h.join();
            }),
        );
        assert!(
            report
                .warnings
                .iter()
                .any(|w| matches!(w, ModelWarning::BareWait { .. })),
            "{:?}",
            report.warnings
        );

        let report = explore(
            "wait-while",
            &opts(32, 8),
            Arc::new(|| {
                let pair = Arc::new((Mutex::labeled(false, "ready2"), Condvar::labeled("cv2")));
                let p2 = pair.clone();
                let h = model::spawn("setter", move || {
                    let (m, cv) = &*p2;
                    let mut g = m.lock().unwrap_or_else(|p| p.into_inner());
                    *g = true;
                    cv.notify_one();
                });
                let (m, cv) = &*pair;
                let g = m.lock().unwrap_or_else(|p| p.into_inner());
                let _g = cv
                    .wait_while(g, |ready| !*ready)
                    .unwrap_or_else(|p| p.into_inner());
                h.join();
            }),
        );
        assert!(report.finding.is_none(), "{:?}", report.finding);
        assert!(report.warnings.is_empty(), "{:?}", report.warnings);
    }

    #[test]
    fn lost_notify_is_found() {
        // Fire-and-forget notify with no state under the lock: under
        // schedules where the notify fires before the park, the waiter
        // sleeps forever.
        let report = explore(
            "lost-notify",
            &opts(128, 32),
            Arc::new(|| {
                let m = Arc::new(Mutex::labeled((), "flagged"));
                let cv = Arc::new(Condvar::labeled("lost-cv"));
                let cv2 = cv.clone();
                let h = model::spawn("notifier", move || {
                    cv2.notify_one();
                });
                let g = m.lock().unwrap_or_else(|p| p.into_inner());
                let _g = cv.wait(g).unwrap_or_else(|p| p.into_inner());
                h.join();
            }),
        );
        // Depending on the schedule this surfaces as LostNotify (the
        // deadlock classifier sees the wasted notify) — it must not
        // explore clean.
        let f = report.finding.expect("lost notify must be caught");
        assert!(
            matches!(f.violation, Violation::LostNotify { .. }),
            "{:?}",
            f.violation
        );
    }

    #[test]
    fn token_before_gate_close_is_bsl055() {
        let report = explore(
            "token-early",
            &opts(16, 4),
            Arc::new(|| {
                let gate = Gate::labeled("drain-gate");
                let (tx, rx) = sync_channel_labeled::<u32>(4, "dispatch");
                tx.bind_gate(&gate);
                // Buggy drain: token first, gate second.
                tx.send_token(0).ok();
                gate.close();
                drop(tx);
                while rx.recv().is_ok() {}
            }),
        );
        let f = report.finding.expect("early token must be caught");
        assert!(
            matches!(f.violation, Violation::GateAfterTokens { .. }),
            "{:?}",
            f.violation
        );
    }

    #[test]
    fn open_obligation_at_join_is_bsl056() {
        let report = explore(
            "dropped-work",
            &opts(16, 4),
            Arc::new(|| {
                let ob = model::obligation("request #1");
                // Accepted, never answered.
                drop(ob);
            }),
        );
        let f = report.finding.expect("open obligation must be caught");
        assert!(
            matches!(f.violation, Violation::NonQuiescent { .. }),
            "{:?}",
            f.violation
        );
    }

    #[test]
    fn facade_is_std_outside_exploration() {
        // No explore() wrapper: everything must behave as plain std.
        let m = Mutex::new(7u32);
        assert_eq!(*m.lock().unwrap_or_else(|p| p.into_inner()), 7);
        let gate = Gate::new();
        assert!(gate.enter().is_some());
        gate.close();
        assert!(gate.enter().is_none());
        assert!(gate.is_closed());
        let (tx, rx) = sync_channel_labeled::<u8>(2, "plain");
        tx.send(1).expect("std path send");
        tx.send_token(2).expect("std path token send");
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Ok(2));
        let n = Arc::new(AtomicUsize::new(0));
        let n2 = n.clone();
        model::spawn("std-thread", move || {
            n2.fetch_add(1, Ordering::Relaxed);
        })
        .join();
        assert_eq!(n.load(Ordering::Relaxed), 1);
        // Obligations are free no-ops outside the model.
        model::obligation("noop").complete();
    }
}
