//! The controlled scheduler behind [`crate::conc`].
//!
//! Model threads are *real* OS threads serialized by a token: exactly
//! one thread runs between two scheduling points, everyone else parks
//! on the scheduler's condvar. Every operation on a
//! [`crate::conc::sync`] primitive is a scheduling point, so the set of
//! reachable interleavings is exactly the set of schedules this module
//! can enumerate (CHESS/loom-style systematic testing). Two explorers
//! share the execution machinery:
//!
//! - **Bounded-preemption DFS**: enumerate schedule prefixes, forcing a
//!   different runnable thread at one decision and replaying the
//!   deterministic default policy after it. Preemptions (switching away
//!   from a thread that could continue) are bounded, which is where
//!   most real concurrency bugs live with surprisingly small bounds.
//! - **Seeded random walks**: for state spaces too big to enumerate,
//!   pick every decision with the shared SplitMix64 stream
//!   ([`crate::rng`]), so a failing schedule is reproducible from its
//!   seed alone.
//!
//! Every decision of an execution is recorded; a violating execution's
//! decision list (one thread id per scheduling point) *is* the
//! counterexample, replayable bit-for-bit via
//! [`ExploreOptions::replay`].
//!
//! Model reductions (documented, deliberate): `recv_timeout` is modeled
//! as "the timeout may always fire immediately" (a strict superset of
//! real behaviors for our protocols, which never rely on a timeout
//! *not* firing); time and pacing do not exist; mutex handoff wakes all
//! blocked threads and lets the schedule pick the winner.

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, Once};

use crate::rng::splitmix64;

/// Panic payload used to unwind model threads during teardown after a
/// violation. Not an error: the quiet panic hook swallows it.
struct Abort;

/// Suppress the default "thread panicked" spew for teardown unwinds;
/// real panics still reach the previous hook untouched.
fn install_quiet_hook() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<Abort>().is_none() {
                prev(info);
            }
        }));
    });
}

thread_local! {
    /// (scheduler, thread id) of the model thread running on this OS
    /// thread, if any. `None` means production mode: every facade op
    /// takes the plain `std::sync` path.
    static CTX: RefCell<Option<(Arc<Scheduler>, usize)>> = const { RefCell::new(None) };
}

/// The scheduler/tid pair for the calling thread, when it is a model
/// thread of a live exploration.
pub fn current_ctx() -> Option<(Arc<Scheduler>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

/// What a thread is doing, from the scheduler's point of view.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Status {
    Ready,
    /// Parked until the object it waits on is signaled.
    Blocked(usize),
    Finished,
}

#[derive(Debug)]
struct ThreadState {
    status: Status,
    /// Object id other threads block on to join this thread.
    join_obj: usize,
    /// Lock objects currently held, in acquisition order (for the
    /// lock-order graph).
    held: Vec<usize>,
}

/// Payload kind of a queued channel slot. `Value` slots are work the
/// protocol owes an answer for; `Token` slots are shutdown signals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotKind {
    Value,
    Token,
}

/// Modeled state of one synchronization object.
#[derive(Debug)]
enum Obj {
    Mutex {
        owner: Option<usize>,
        label: String,
    },
    Condvar {
        waiters: Vec<usize>,
        /// Notifies that fired into an empty wait-set.
        wasted: u64,
        label: String,
    },
    Chan {
        cap: usize,
        queue: VecDeque<SlotKind>,
        senders: usize,
        recv_alive: bool,
        /// Gate object guarding this channel's intake, if bound.
        gate: Option<usize>,
        label: String,
    },
    Gate {
        closed: bool,
        readers: usize,
        label: String,
    },
    /// Join target for one model thread.
    Thread { label: String },
}

impl Obj {
    fn label(&self) -> &str {
        match self {
            Obj::Mutex { label, .. }
            | Obj::Condvar { label, .. }
            | Obj::Chan { label, .. }
            | Obj::Gate { label, .. }
            | Obj::Thread { label } => label,
        }
    }
}

/// One scheduling decision: which threads were runnable, which ran.
#[derive(Debug, Clone)]
pub struct Choice {
    pub options: Vec<usize>,
    pub chosen: usize,
}

/// A property violation found in one execution.
#[derive(Debug, Clone)]
pub enum Violation {
    /// Every live thread is blocked (or the step budget ran out, which
    /// we treat as a livelock variant of the same failure).
    Deadlock { blocked: Vec<String> },
    /// Deadlock behind a condvar that swallowed notifies while its
    /// wait-set was empty.
    LostNotify { condvar: String, wasted: u64 },
    /// A shutdown token entered a gated channel while the gate was
    /// still open.
    GateAfterTokens { channel: String, gate: String },
    /// Execution finished with queued work or open obligations.
    NonQuiescent { open: Vec<String> },
    /// Cycle in the lock-acquisition-order graph (accumulated across
    /// executions; the counterexample is the run that closed it).
    LockOrderCycle { cycle: Vec<String> },
}

/// Non-fatal suspicious patterns, deduplicated across executions.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub enum ModelWarning {
    /// `Condvar::wait` without a predicate loop.
    BareWait { condvar: String },
    /// A send was attempted on a channel whose receiver is gone.
    SendAfterClose { channel: String },
}

/// Everything recorded about one finished execution.
#[derive(Debug)]
pub struct RunOutcome {
    pub choices: Vec<Choice>,
    pub events: Vec<String>,
    pub violation: Option<Violation>,
    pub warnings: Vec<ModelWarning>,
    /// Observed lock-order edges `(held label, acquired label)`.
    pub lock_edges: Vec<(String, String)>,
    /// Payload of the first non-teardown panic, if any.
    pub panic: Option<String>,
}

enum Mode {
    /// Follow `prefix`, then the deterministic minimal-preemption
    /// default policy.
    Guided,
    /// Pick every decision with a SplitMix64 stream.
    Random(u64),
}

struct Core {
    threads: Vec<ThreadState>,
    objects: Vec<Obj>,
    /// Thread holding the token; `None` between executions / when done.
    running: Option<usize>,
    /// Last thread that held the token (minimal-preemption default).
    last_running: usize,
    live: usize,
    prefix: Vec<usize>,
    mode: Mode,
    choices: Vec<Choice>,
    events: Vec<String>,
    violation: Option<Violation>,
    warnings: BTreeSet<ModelWarning>,
    lock_edges: BTreeSet<(String, String)>,
    /// Open obligations: accepted work that has not been completed.
    obligations: BTreeMap<u64, String>,
    next_obligation: u64,
    steps: usize,
    max_steps: usize,
    aborting: bool,
    panic: Option<String>,
}

/// The per-execution scheduler. One instance drives exactly one
/// execution; the explorer creates a fresh one per schedule.
pub struct Scheduler {
    core: Mutex<Core>,
    cv: Condvar,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// Outcome of one attempt at a modeled operation.
enum Attempt<R> {
    Done(R),
    /// Park until `obj` is signaled, then retry.
    Block(usize),
}

impl Scheduler {
    fn new(prefix: Vec<usize>, mode: Mode, max_steps: usize) -> Scheduler {
        Scheduler {
            core: Mutex::new(Core {
                threads: Vec::new(),
                objects: Vec::new(),
                running: None,
                last_running: 0,
                live: 0,
                prefix,
                mode,
                choices: Vec::new(),
                events: Vec::new(),
                violation: None,
                warnings: BTreeSet::new(),
                lock_edges: BTreeSet::new(),
                obligations: BTreeMap::new(),
                next_obligation: 0,
                steps: 0,
                max_steps,
                aborting: false,
                panic: None,
            }),
            cv: Condvar::new(),
            handles: Mutex::new(Vec::new()),
        }
    }

    fn lock(&self) -> MutexGuard<'_, Core> {
        self.core.lock().unwrap_or_else(|p| p.into_inner())
    }

    // ----- scheduling machinery ------------------------------------

    /// Record one decision and hand the token to the chosen thread.
    /// Must be called by the thread currently holding the token (or
    /// exiting with it).
    fn choice_point(&self, core: &mut Core) {
        if core.aborting {
            return;
        }
        core.steps += 1;
        if core.steps > core.max_steps && core.violation.is_none() {
            core.violation = Some(Violation::Deadlock {
                blocked: vec![format!(
                    "step budget of {} exhausted (livelock?)",
                    core.max_steps
                )],
            });
            self.abort(core);
            return;
        }
        let options: Vec<usize> = core
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| t.status == Status::Ready)
            .map(|(i, _)| i)
            .collect();
        if options.is_empty() {
            if core.live == 0 {
                // Execution complete: quiescence check, then release
                // the explorer.
                self.check_quiescence(core);
                core.running = None;
                self.cv.notify_all();
                return;
            }
            // Every live thread is blocked: deadlock. Classify
            // lost-notify deadlocks by inspecting what they block on.
            let mut blocked = Vec::new();
            let mut lost: Option<(String, u64)> = None;
            for (i, t) in core.threads.iter().enumerate() {
                if let Status::Blocked(obj) = t.status {
                    blocked.push(format!(
                        "thread {i} blocked on {}",
                        core.objects[obj].label()
                    ));
                    if let Obj::Condvar { wasted, label, .. } = &core.objects[obj] {
                        if *wasted > 0 && lost.is_none() {
                            lost = Some((label.clone(), *wasted));
                        }
                    }
                }
            }
            // Keep an earlier violation (e.g. gate-after-tokens) if one
            // was already recorded during this execution.
            if core.violation.is_none() {
                core.violation = Some(match lost {
                    Some((condvar, wasted)) => Violation::LostNotify { condvar, wasted },
                    None => Violation::Deadlock { blocked },
                });
            }
            self.abort(core);
            return;
        }
        let decision = core.choices.len();
        let forced = core.prefix.get(decision).copied();
        let chosen = match forced {
            // Replay/backtrack prefix. A forced tid that is not
            // runnable (possible only if the program changed under the
            // schedule) falls through to the default policy.
            Some(tid) if options.contains(&tid) => tid,
            _ => match &mut core.mode {
                Mode::Random(state) => {
                    let r = splitmix64(state);
                    options[(r % options.len() as u64) as usize]
                }
                Mode::Guided => {
                    if options.contains(&core.last_running) {
                        core.last_running
                    } else {
                        options[0]
                    }
                }
            },
        };
        core.choices.push(Choice { options, chosen });
        core.last_running = chosen;
        core.running = Some(chosen);
        self.cv.notify_all();
    }

    /// Begin teardown: wake everyone; parked model threads unwind with
    /// the `Abort` payload.
    fn abort(&self, core: &mut Core) {
        core.aborting = true;
        core.running = None;
        self.cv.notify_all();
    }

    /// Park until this thread holds the token (status must be `Ready`).
    /// Panics with `Abort` when teardown begins.
    fn wait_for_token<'a>(
        &'a self,
        mut core: MutexGuard<'a, Core>,
        tid: usize,
    ) -> MutexGuard<'a, Core> {
        while core.running != Some(tid) {
            if core.aborting {
                drop(core);
                std::panic::panic_any(Abort);
            }
            core = self
                .cv
                .wait(core)
                .unwrap_or_else(|p| p.into_inner());
        }
        core
    }

    /// Run one modeled operation for thread `tid`: yield (scheduling
    /// point), then attempt; on `Block`, park until signaled and retry.
    /// `attempt` must be idempotent until it commits.
    fn op<R>(&self, tid: usize, mut attempt: impl FnMut(&mut Core) -> Attempt<R>) -> R {
        let mut core = self.lock();
        if core.aborting {
            // Teardown. Release-type ops still reach here from guard
            // drops while other frames unwind with `Abort`; run them
            // inline (they never block) instead of panicking inside a
            // panic. A blocking op here is a fresh frame, safe to
            // unwind.
            loop {
                match attempt(&mut core) {
                    Attempt::Done(r) => return r,
                    Attempt::Block(_) => {
                        drop(core);
                        std::panic::panic_any(Abort);
                    }
                }
            }
        }
        self.choice_point(&mut core);
        core = self.wait_for_token(core, tid);
        loop {
            match attempt(&mut core) {
                Attempt::Done(r) => return r,
                Attempt::Block(obj) => {
                    core.threads[tid].status = Status::Blocked(obj);
                    self.choice_point(&mut core);
                    core = self.wait_for_token(core, tid);
                }
            }
        }
    }

    /// Mark every thread parked on `obj` runnable again; each rechecks
    /// its condition when scheduled.
    fn signal(core: &mut Core, obj: usize) {
        for t in core.threads.iter_mut() {
            if t.status == Status::Blocked(obj) {
                t.status = Status::Ready;
            }
        }
    }

    /// A pure scheduling point with no state change.
    pub fn yield_now(&self, tid: usize) {
        self.op(tid, |_| Attempt::Done(()));
    }

    // ----- threads --------------------------------------------------

    /// Register a new model thread (runnable, not yet started).
    fn register_thread(&self, label: &str) -> usize {
        let mut core = self.lock();
        let join_obj = core.objects.len();
        core.objects.push(Obj::Thread {
            label: format!("thread '{label}'"),
        });
        let tid = core.threads.len();
        core.threads.push(ThreadState {
            status: Status::Ready,
            join_obj,
            held: Vec::new(),
        });
        core.live += 1;
        core.events.push(format!("spawn thread {tid} ('{label}')"));
        tid
    }

    /// OS-spawn the body of a registered model thread. The wrapper
    /// parks until first scheduled, runs the body, and reports exit.
    fn os_spawn(self: &Arc<Self>, tid: usize, body: impl FnOnce() + Send + 'static) {
        let sched = self.clone();
        let handle = std::thread::spawn(move || {
            CTX.with(|c| *c.borrow_mut() = Some((sched.clone(), tid)));
            // The initial park is inside the catch: teardown can begin
            // before this thread ever gets the token.
            let inner = sched.clone();
            let result = catch_unwind(AssertUnwindSafe(move || {
                let core = inner.lock();
                let core = inner.wait_for_token(core, tid);
                drop(core);
                body();
            }));
            CTX.with(|c| *c.borrow_mut() = None);
            sched.thread_exit(tid, result.err());
        });
        self.handles
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push(handle);
    }

    /// Spawn a child model thread from a running model thread and
    /// return its tid. The spawn itself is a scheduling point.
    pub fn spawn_child(
        self: &Arc<Self>,
        parent: usize,
        label: &str,
        body: impl FnOnce() + Send + 'static,
    ) -> usize {
        let tid = self.register_thread(label);
        self.os_spawn(tid, body);
        self.yield_now(parent);
        tid
    }

    /// Block until thread `tid` finishes.
    pub fn join_thread(&self, me: usize, tid: usize) {
        self.op(me, |core| {
            if core.threads[tid].status == Status::Finished {
                Attempt::Done(())
            } else {
                Attempt::Block(core.threads[tid].join_obj)
            }
        });
    }

    fn thread_exit(&self, tid: usize, panic: Option<Box<dyn std::any::Any + Send>>) {
        let mut core = self.lock();
        core.threads[tid].status = Status::Finished;
        core.live -= 1;
        let join_obj = core.threads[tid].join_obj;
        Self::signal(&mut core, join_obj);
        if let Some(payload) = panic {
            if payload.downcast_ref::<Abort>().is_none() {
                // Real panic from protocol code: record it and tear the
                // execution down so the explorer can propagate it.
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                core.events.push(format!("thread {tid} panicked: {msg}"));
                if core.panic.is_none() {
                    core.panic = Some(msg);
                }
                self.abort(&mut core);
                return;
            }
            // Teardown unwind: hand off without recording a decision.
            if core.live == 0 {
                core.running = None;
            }
            self.cv.notify_all();
            return;
        }
        if core.aborting {
            self.cv.notify_all();
            return;
        }
        core.events.push(format!("thread {tid} exits"));
        // Hand the token to the next runnable thread (a real decision:
        // the exiting thread no longer counts among the options).
        self.choice_point(&mut core);
    }

    /// End-of-execution check: channels must hold no unconsumed work
    /// and every opened obligation must have been completed.
    fn check_quiescence(&self, core: &mut Core) {
        if core.violation.is_some() {
            return;
        }
        let mut open: Vec<String> = Vec::new();
        for obj in &core.objects {
            if let Obj::Chan { queue, label, .. } = obj {
                let values = queue.iter().filter(|s| **s == SlotKind::Value).count();
                if values > 0 {
                    open.push(format!(
                        "{values} work item(s) still queued on {label}"
                    ));
                }
            }
        }
        for label in core.obligations.values() {
            open.push(format!("obligation '{label}' opened but never completed"));
        }
        if !open.is_empty() {
            core.violation = Some(Violation::NonQuiescent { open });
        }
    }

    // ----- object registration -------------------------------------

    fn register(&self, obj: Obj) -> usize {
        let mut core = self.lock();
        let id = core.objects.len();
        core.events.push(format!("new {}", obj.label()));
        core.objects.push(obj);
        id
    }

    pub fn register_mutex(&self, label: &str) -> usize {
        self.register(
            Obj::Mutex {
                owner: None,
                label: format!("mutex '{label}'"),
            },
        )
    }

    pub fn register_condvar(&self, label: &str) -> usize {
        self.register(
            Obj::Condvar {
                waiters: Vec::new(),
                wasted: 0,
                label: format!("condvar '{label}'"),
            },
        )
    }

    pub fn register_chan(&self, cap: usize, label: &str) -> usize {
        self.register(
            Obj::Chan {
                cap: cap.max(1),
                queue: VecDeque::new(),
                senders: 1,
                recv_alive: true,
                gate: None,
                label: format!("channel '{label}'"),
            },
        )
    }

    pub fn register_gate(&self, label: &str) -> usize {
        self.register(
            Obj::Gate {
                closed: false,
                readers: 0,
                label: format!("gate '{label}'"),
            },
        )
    }

    /// Declare that tokens on channel `chan` must only be sent after
    /// gate `gate` closed (the drain-ordering contract, BSL055).
    pub fn bind_gate(&self, chan: usize, gate: usize) {
        let mut core = self.lock();
        if let Obj::Chan { gate: g, .. } = &mut core.objects[chan] {
            *g = Some(gate);
        }
    }

    // ----- mutex ----------------------------------------------------

    pub fn mutex_lock(&self, tid: usize, id: usize) {
        self.op(tid, |core| {
            match &core.objects[id] {
                Obj::Mutex { owner: Some(_), .. } => return Attempt::Block(id),
                Obj::Mutex { owner: None, .. } => {}
                _ => return Attempt::Done(()),
            }
            if let Obj::Mutex { owner, .. } = &mut core.objects[id] {
                *owner = Some(tid);
            }
            // Lock-order edges from everything already held.
            let held = core.threads[tid].held.clone();
            let to = core.objects[id].label().to_string();
            for h in held {
                let from = core.objects[h].label().to_string();
                core.lock_edges.insert((from, to.clone()));
            }
            core.threads[tid].held.push(id);
            core.events.push(format!("thread {tid} acquires {to}"));
            Attempt::Done(())
        });
    }

    pub fn mutex_unlock(&self, tid: usize, id: usize) {
        self.op(tid, |core| {
            if let Obj::Mutex { owner, .. } = &mut core.objects[id] {
                *owner = None;
            }
            core.threads[tid].held.retain(|&h| h != id);
            core.events
                .push(format!("thread {tid} releases {}", core.objects[id].label()));
            Self::signal(core, id);
            Attempt::Done(())
        });
    }

    // ----- condvar --------------------------------------------------

    /// Condvar wait: atomically release `mutex`, park on the condvar,
    /// and re-acquire the mutex once notified. `bare` marks a wait used
    /// without a predicate loop (flagged as BSL052).
    pub fn condvar_wait(&self, tid: usize, id: usize, mutex: usize, bare: bool) {
        let mut phase = 0u8;
        self.op(tid, |core| {
            loop {
                match phase {
                    0 => {
                        if bare {
                            let label = core.objects[id].label().to_string();
                            core.warnings.insert(ModelWarning::BareWait { condvar: label });
                        }
                        if let Obj::Mutex { owner, .. } = &mut core.objects[mutex] {
                            *owner = None;
                        }
                        core.threads[tid].held.retain(|&h| h != mutex);
                        Self::signal(core, mutex);
                        if let Obj::Condvar { waiters, .. } = &mut core.objects[id] {
                            waiters.push(tid);
                        }
                        core.events
                            .push(format!("thread {tid} waits on {}", core.objects[id].label()));
                        phase = 1;
                    }
                    1 => {
                        let waiting = match &core.objects[id] {
                            Obj::Condvar { waiters, .. } => waiters.contains(&tid),
                            _ => false,
                        };
                        if waiting {
                            return Attempt::Block(id);
                        }
                        phase = 2;
                    }
                    _ => {
                        if let Obj::Mutex { owner, .. } = &mut core.objects[mutex] {
                            if owner.is_none() {
                                *owner = Some(tid);
                                core.threads[tid].held.push(mutex);
                                return Attempt::Done(());
                            }
                        }
                        return Attempt::Block(mutex);
                    }
                }
            }
        });
    }

    pub fn condvar_notify(&self, tid: usize, id: usize, all: bool) {
        self.op(tid, |core| {
            if let Obj::Condvar { waiters, wasted, .. } = &mut core.objects[id] {
                if waiters.is_empty() {
                    // Correct condvar semantics: a notify with nobody
                    // waiting is lost. Remember it so a later deadlock
                    // on this condvar is classified as lost-notify.
                    *wasted += 1;
                } else if all {
                    waiters.clear();
                } else {
                    waiters.remove(0);
                }
            }
            core.events.push(format!(
                "thread {tid} notifies {}",
                core.objects[id].label()
            ));
            Self::signal(core, id);
            Attempt::Done(())
        });
    }

    // ----- channels -------------------------------------------------

    /// Blocking send. Returns `false` when the receiver is gone (the
    /// facade maps that to `SendError`).
    pub fn chan_send(&self, tid: usize, id: usize, kind: SlotKind) -> bool {
        self.op(tid, |core| Self::try_push(core, tid, id, kind))
    }

    /// Non-blocking send: `Ok(true)` sent, `Ok(false)` disconnected,
    /// `Err(())` full.
    pub fn chan_try_send(&self, tid: usize, id: usize, kind: SlotKind) -> Result<bool, ()> {
        self.op(tid, |core| match Self::try_push(core, tid, id, kind) {
            Attempt::Done(sent) => Attempt::Done(Ok(sent)),
            Attempt::Block(_) => Attempt::Done(Err(())),
        })
    }

    fn try_push(core: &mut Core, tid: usize, id: usize, kind: SlotKind) -> Attempt<bool> {
        let (full, closed, gate) = match &core.objects[id] {
            Obj::Chan {
                cap,
                queue,
                recv_alive,
                gate,
                ..
            } => (queue.len() >= *cap, !*recv_alive, *gate),
            _ => return Attempt::Done(false),
        };
        if closed {
            let label = core.objects[id].label().to_string();
            core.warnings
                .insert(ModelWarning::SendAfterClose { channel: label });
            return Attempt::Done(false);
        }
        if full {
            return Attempt::Block(id);
        }
        // The drain contract: a token on a gated channel is only legal
        // once the gate is closed — otherwise a request admitted under
        // the still-open gate can land FIFO-behind the token and be
        // dropped by the worker that consumed the token.
        if kind == SlotKind::Token {
            if let Some(g) = gate {
                if let Obj::Gate { closed: false, .. } = &core.objects[g] {
                    let channel = core.objects[id].label().to_string();
                    let gate_label = core.objects[g].label().to_string();
                    core.events.push(format!(
                        "thread {tid} sends shutdown token on {channel} while {gate_label} is open"
                    ));
                    if core.violation.is_none() {
                        core.violation = Some(Violation::GateAfterTokens {
                            channel,
                            gate: gate_label,
                        });
                    }
                    // Not recoverable: tear down and report.
                    return Attempt::Done(true);
                }
            }
        }
        if let Obj::Chan { queue, .. } = &mut core.objects[id] {
            queue.push_back(kind);
        }
        core.events.push(format!(
            "thread {tid} sends {:?} on {}",
            kind,
            core.objects[id].label()
        ));
        Self::signal(core, id);
        Attempt::Done(true)
    }

    /// Blocking receive: `Some(kind)` or `None` when empty and all
    /// senders are gone.
    pub fn chan_recv(&self, tid: usize, id: usize) -> Option<SlotKind> {
        self.op(tid, |core| {
            let popped = match &mut core.objects[id] {
                Obj::Chan { queue, senders, .. } => {
                    if let Some(kind) = queue.pop_front() {
                        Ok(Some(kind))
                    } else if *senders == 0 {
                        Ok(None)
                    } else {
                        Err(())
                    }
                }
                _ => Ok(None),
            };
            match popped {
                Ok(Some(kind)) => {
                    let label = core.objects[id].label().to_string();
                    core.events
                        .push(format!("thread {tid} receives {kind:?} from {label}"));
                    Self::signal(core, id);
                    Attempt::Done(Some(kind))
                }
                Ok(None) => Attempt::Done(None),
                Err(()) => Attempt::Block(id),
            }
        })
    }

    /// Timed receive, modeled as "the timeout may fire immediately":
    /// `Ok(kind)`, `Err(true)` disconnected, `Err(false)` timed out.
    pub fn chan_recv_timeout(&self, tid: usize, id: usize) -> Result<SlotKind, bool> {
        self.op(tid, |core| {
            let popped = match &mut core.objects[id] {
                Obj::Chan { queue, senders, .. } => {
                    if let Some(kind) = queue.pop_front() {
                        Ok(kind)
                    } else if *senders == 0 {
                        Err(true)
                    } else {
                        Err(false)
                    }
                }
                _ => Err(true),
            };
            if popped.is_ok() {
                Self::signal(core, id);
            }
            Attempt::Done(popped)
        })
    }

    pub fn chan_sender_cloned(&self, id: usize) {
        let mut core = self.lock();
        if let Obj::Chan { senders, .. } = &mut core.objects[id] {
            *senders += 1;
        }
    }

    pub fn chan_sender_dropped(&self, id: usize) {
        let mut core = self.lock();
        if let Obj::Chan { senders, .. } = &mut core.objects[id] {
            *senders = senders.saturating_sub(1);
            if *senders == 0 {
                Self::signal(&mut core, id);
            }
        }
    }

    pub fn chan_receiver_dropped(&self, id: usize) {
        let mut core = self.lock();
        if let Obj::Chan { recv_alive, .. } = &mut core.objects[id] {
            *recv_alive = false;
        }
        Self::signal(&mut core, id);
    }

    // ----- gate -----------------------------------------------------

    /// Read-acquire the gate: `true` admitted (caller must pair with
    /// [`Self::gate_exit`]), `false` already closed.
    pub fn gate_enter(&self, tid: usize, id: usize) -> bool {
        self.op(tid, |core| match &mut core.objects[id] {
            Obj::Gate { closed, readers, .. } => {
                if *closed {
                    Attempt::Done(false)
                } else {
                    *readers += 1;
                    Attempt::Done(true)
                }
            }
            _ => Attempt::Done(false),
        })
    }

    pub fn gate_exit(&self, tid: usize, id: usize) {
        let mut core = self.lock();
        if let Obj::Gate { readers, .. } = &mut core.objects[id] {
            *readers = readers.saturating_sub(1);
        }
        let _ = tid;
        Self::signal(&mut core, id);
    }

    /// Write-acquire and flip the gate closed; blocks until the last
    /// reader exits (RwLock<bool> semantics of the real drain gate).
    pub fn gate_close(&self, tid: usize, id: usize) {
        self.op(tid, |core| {
            match &core.objects[id] {
                Obj::Gate { readers, .. } if *readers > 0 => return Attempt::Block(id),
                Obj::Gate { .. } => {}
                _ => return Attempt::Done(()),
            }
            if let Obj::Gate { closed, .. } = &mut core.objects[id] {
                *closed = true;
            }
            let label = core.objects[id].label().to_string();
            core.events.push(format!("thread {tid} closes {label}"));
            Self::signal(core, id);
            Attempt::Done(())
        });
    }

    pub fn gate_is_closed(&self, tid: usize, id: usize) -> bool {
        self.op(tid, |core| match &core.objects[id] {
            Obj::Gate { closed, .. } => Attempt::Done(*closed),
            _ => Attempt::Done(false),
        })
    }

    // ----- obligations ---------------------------------------------

    /// Open an obligation: accepted work the protocol owes an answer
    /// for. The execution is non-quiescent (BSL056) until completed.
    pub fn obligation_open(&self, tid: usize, label: &str) -> u64 {
        self.op(tid, |core| {
            let id = core.next_obligation;
            core.next_obligation += 1;
            core.obligations.insert(id, label.to_string());
            core.events
                .push(format!("thread {tid} opens obligation '{label}'"));
            Attempt::Done(id)
        })
    }

    pub fn obligation_complete(&self, tid: usize, id: u64) {
        self.op(tid, |core| {
            if let Some(label) = core.obligations.remove(&id) {
                core.events
                    .push(format!("thread {tid} completes obligation '{label}'"));
            }
            Attempt::Done(())
        });
    }
}

// ---------------------------------------------------------------------
// Exploration
// ---------------------------------------------------------------------

/// Bounds and mode of one [`explore`] call.
#[derive(Debug, Clone)]
pub struct ExploreOptions {
    /// Cap on DFS executions (0 disables the DFS pass).
    pub dfs_executions: usize,
    /// Maximum preemptive context switches per explored schedule.
    pub preemption_bound: usize,
    /// Seeded random schedules to run after the DFS pass.
    pub random_schedules: usize,
    pub seed: u64,
    /// Per-execution scheduling-point budget (overrun = livelock).
    pub max_steps: usize,
    /// Replay exactly this decision list instead of exploring.
    pub replay: Option<Vec<usize>>,
}

impl Default for ExploreOptions {
    fn default() -> Self {
        ExploreOptions {
            dfs_executions: 256,
            preemption_bound: 2,
            random_schedules: 64,
            seed: 0x5EED_0BB5,
            max_steps: 20_000,
            replay: None,
        }
    }
}

/// A violating schedule, replayable via [`ExploreOptions::replay`].
#[derive(Debug, Clone)]
pub struct Counterexample {
    /// One chosen thread id per scheduling point.
    pub schedule: Vec<usize>,
    /// Trailing event trace of the violating execution.
    pub events: Vec<String>,
}

#[derive(Debug)]
pub struct Finding {
    pub violation: Violation,
    pub counterexample: Counterexample,
}

/// Result of exploring one protocol.
#[derive(Debug)]
pub struct ExploreReport {
    pub name: String,
    pub executions: usize,
    pub finding: Option<Finding>,
    pub warnings: Vec<ModelWarning>,
}

/// Run `body` once under a fresh scheduler following `prefix` (or a
/// random walk), and collect the outcome.
fn run_once(
    prefix: Vec<usize>,
    mode: Mode,
    max_steps: usize,
    body: &Arc<dyn Fn() + Send + Sync>,
) -> RunOutcome {
    install_quiet_hook();
    let sched = Arc::new(Scheduler::new(prefix, mode, max_steps));
    let root = sched.register_thread("root");
    {
        let mut core = sched.lock();
        core.running = Some(root);
    }
    let b = body.clone();
    sched.os_spawn(root, move || b());
    // Wait for the execution to finish (all model threads exited). The
    // timeout is a safety valve for scheduler bugs only: a healthy
    // execution ends via choice_point/abort.
    {
        let mut core = sched.lock();
        let mut stalls = 0u32;
        while core.live > 0 {
            let (c, timeout) = sched
                .cv
                .wait_timeout(core, std::time::Duration::from_secs(10))
                .unwrap_or_else(|p| p.into_inner());
            core = c;
            if timeout.timed_out() {
                stalls += 1;
                if stalls >= 3 && !core.aborting {
                    core.violation = Some(Violation::Deadlock {
                        blocked: vec!["execution stalled (scheduler watchdog)".into()],
                    });
                    sched.abort(&mut core);
                }
            }
        }
    }
    for h in sched
        .handles
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .drain(..)
    {
        let _ = h.join();
    }
    let mut core = sched.lock();
    RunOutcome {
        choices: core.choices.drain(..).collect(),
        events: core.events.drain(..).collect(),
        violation: core.violation.take(),
        warnings: core.warnings.iter().cloned().collect(),
        lock_edges: core.lock_edges.iter().cloned().collect(),
        panic: core.panic.take(),
    }
}

/// Number of preemptive switches in a decision list: decisions where
/// the previously running thread was still runnable but another thread
/// was chosen.
fn preemptions(choices: &[Choice]) -> usize {
    let mut count = 0;
    let mut prev = 0usize; // root
    for c in choices {
        if c.chosen != prev && c.options.contains(&prev) {
            count += 1;
        }
        prev = c.chosen;
    }
    count
}

fn make_counterexample(out: &RunOutcome) -> Counterexample {
    const TAIL: usize = 40;
    let schedule: Vec<usize> = out.choices.iter().map(|c| c.chosen).collect();
    let mut events = Vec::new();
    if out.events.len() > TAIL {
        events.push(format!("… {} earlier events", out.events.len() - TAIL));
    }
    let start = out.events.len().saturating_sub(TAIL);
    events.extend(out.events[start..].iter().cloned());
    Counterexample { schedule, events }
}

/// Find a cycle in the accumulated lock-order graph, if any.
fn find_lock_cycle(edges: &BTreeSet<(String, String)>) -> Option<Vec<String>> {
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (a, b) in edges {
        adj.entry(a.as_str()).or_default().push(b.as_str());
    }
    // Iterative DFS with colors; on a back edge, reconstruct the cycle
    // from the active path.
    let mut color: BTreeMap<&str, u8> = BTreeMap::new(); // 1 = on path, 2 = done
    for &start in adj.keys().collect::<Vec<_>>().iter() {
        if color.get(start).copied().unwrap_or(0) != 0 {
            continue;
        }
        let mut path: Vec<&str> = vec![start];
        let mut iters: Vec<usize> = vec![0];
        color.insert(start, 1);
        while let Some(&node) = path.last() {
            let next = adj
                .get(node)
                .and_then(|ns| ns.get(*iters.last().unwrap_or(&0)))
                .copied();
            if let Some(n) = next {
                if let Some(last) = iters.last_mut() {
                    *last += 1;
                }
                match color.get(n).copied().unwrap_or(0) {
                    1 => {
                        // Back edge: slice the cycle out of the path.
                        let pos = path.iter().position(|&p| p == n).unwrap_or(0);
                        let mut cycle: Vec<String> =
                            path[pos..].iter().map(|s| (*s).to_string()).collect();
                        cycle.push(n.to_string());
                        return Some(cycle);
                    }
                    2 => {}
                    _ => {
                        color.insert(n, 1);
                        path.push(n);
                        iters.push(0);
                    }
                }
            } else {
                color.insert(node, 2);
                path.pop();
                iters.pop();
            }
        }
    }
    None
}

/// Systematically explore the schedules of `body` (which must be
/// re-runnable: each execution starts from fresh facade objects created
/// inside it). Returns the first violation found with its replayable
/// counterexample, or a clean report.
pub fn explore(
    name: &str,
    opts: &ExploreOptions,
    body: Arc<dyn Fn() + Send + Sync>,
) -> ExploreReport {
    let mut report = ExploreReport {
        name: name.to_string(),
        executions: 0,
        finding: None,
        warnings: Vec::new(),
    };
    let mut all_edges: BTreeSet<(String, String)> = BTreeSet::new();
    let mut warnings: BTreeSet<ModelWarning> = BTreeSet::new();
    let mut absorb = |report: &mut ExploreReport,
                      out: RunOutcome,
                      all_edges: &mut BTreeSet<(String, String)>,
                      warnings: &mut BTreeSet<ModelWarning>|
     -> bool {
        report.executions += 1;
        warnings.extend(out.warnings.iter().cloned());
        all_edges.extend(out.lock_edges.iter().cloned());
        if let Some(msg) = &out.panic {
            // A protocol assertion failed under this schedule: surface
            // it as a deadlock-class finding with the schedule attached
            // rather than crashing the whole check pass.
            report.finding = Some(Finding {
                violation: Violation::Deadlock {
                    blocked: vec![format!("protocol panicked: {msg}")],
                },
                counterexample: make_counterexample(&out),
            });
            return true;
        }
        if let Some(v) = out.violation {
            report.finding = Some(Finding {
                violation: v,
                counterexample: make_counterexample(&out),
            });
            return true;
        }
        if let Some(cycle) = find_lock_cycle(all_edges) {
            report.finding = Some(Finding {
                violation: Violation::LockOrderCycle { cycle },
                counterexample: make_counterexample(&out),
            });
            return true;
        }
        false
    };

    if let Some(schedule) = &opts.replay {
        let out = run_once(schedule.clone(), Mode::Guided, opts.max_steps, &body);
        absorb(&mut report, out, &mut all_edges, &mut warnings);
        report.warnings = warnings.into_iter().collect();
        return report;
    }

    // Pass 1: bounded-preemption DFS over schedule prefixes.
    let mut stack: Vec<Vec<usize>> = vec![Vec::new()];
    while let Some(prefix) = stack.pop() {
        if report.executions >= opts.dfs_executions {
            break;
        }
        let out = run_once(prefix.clone(), Mode::Guided, opts.max_steps, &body);
        let choices = out.choices.clone();
        if absorb(&mut report, out, &mut all_edges, &mut warnings) {
            report.warnings = warnings.into_iter().collect();
            return report;
        }
        // Branch: at each decision past the forced prefix, try every
        // other runnable thread, keeping the shared prefix up to it.
        let chosen: Vec<usize> = choices.iter().map(|c| c.chosen).collect();
        for i in (prefix.len()..choices.len()).rev() {
            for &alt in &choices[i].options {
                if alt == choices[i].chosen {
                    continue;
                }
                let mut candidate: Vec<Choice> = choices[..i].to_vec();
                candidate.push(Choice {
                    options: choices[i].options.clone(),
                    chosen: alt,
                });
                if preemptions(&candidate) > opts.preemption_bound {
                    continue;
                }
                let mut p: Vec<usize> = chosen[..i].to_vec();
                p.push(alt);
                stack.push(p);
            }
        }
    }

    // Pass 2: seeded random walks for the long tail.
    for k in 0..opts.random_schedules {
        let seed = opts.seed.wrapping_add(k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let out = run_once(Vec::new(), Mode::Random(seed), opts.max_steps, &body);
        if absorb(&mut report, out, &mut all_edges, &mut warnings) {
            break;
        }
    }
    report.warnings = warnings.into_iter().collect();
    report
}
