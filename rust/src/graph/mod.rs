//! Graph IR: tensors, layers, and the network DAG.
//!
//! This is the common abstraction the paper's front-ends produce (§4,
//! Figure 7): every framework-specific network is parsed into this form
//! before the optimizer runs. Here the "front-end" role is played by the
//! model zoo builders ([`crate::zoo`]) and by the python exporter
//! (`python/compile/zoo.py`), which must agree — see the golden-file
//! tests in `rust/tests/`.

pub mod dag;
pub mod json;
pub mod layer;
pub mod shape;

pub use dag::{BranchRegion, Consumers, Graph, GraphError, Node, NodeId};
pub use json::{graph_from_json, graph_to_json, node_param_tags};
pub use layer::{ceil_out_dim, Layer, PoolKind, Window2d};
pub use shape::{conv_out_dim, DType, Shape};
