//! The network DAG: nodes in topological order with shape inference.
//!
//! Graphs are built append-only (every node's inputs must already exist),
//! so the node vector *is* a topological order — the same invariant the
//! paper's Network Analyzer relies on when walking the network layer by
//! layer (§4.1 step 2).

use std::collections::HashMap;

use super::layer::Layer;
use super::shape::Shape;
use crate::analysis::{DiagCode, Severity};

/// Node identifier: index into `Graph::nodes`.
pub type NodeId = usize;

/// Structured graph-validation error: a stable [`DiagCode`], the
/// offending node (id + name when known), and a human-readable reason.
/// `Display` renders one line, so existing `{e}` call sites keep their
/// output; the fields let callers (the JSON loader, `brainslug check`)
/// point at the offending node instead of re-parsing a string.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GraphError {
    /// Stable `BSL0xx` diagnostic code (see `crate::analysis::diag`).
    pub code: DiagCode,
    pub node: Option<NodeId>,
    pub node_name: Option<String>,
    pub reason: String,
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match (self.node, &self.node_name) {
            (Some(id), Some(name)) => write!(
                f,
                "[{}] node {id} ('{name}'): {}",
                self.code.as_str(),
                self.reason
            ),
            (Some(id), _) => write!(f, "[{}] node {id}: {}", self.code.as_str(), self.reason),
            _ => write!(f, "[{}] {}", self.code.as_str(), self.reason),
        }
    }
}

impl std::error::Error for GraphError {}

/// Precomputed consumer adjacency of a graph.
///
/// The planner, the validator, and the executor all ask "who reads this
/// node?". The full O(V+E) map is derived once per pass and threaded
/// through every query site (chain walk, branch-region detection,
/// dangling-node check, remaining-consumer counts) instead of once per
/// site — `benches/optimizer_hotpath.rs` measures what each avoided
/// derivation costs.
#[derive(Debug, Clone)]
pub struct Consumers {
    lists: Vec<Vec<NodeId>>,
}

impl Consumers {
    /// Consumers of `id`, in topological order.
    pub fn of(&self, id: NodeId) -> &[NodeId] {
        &self.lists[id]
    }

    /// Number of consumers of `id`.
    pub fn count(&self, id: NodeId) -> usize {
        self.lists[id].len()
    }

    /// Does `id` have exactly one consumer? (Only then may it sit in the
    /// interior of a stack — fan-out forces materialization.)
    pub fn is_single(&self, id: NodeId) -> bool {
        self.count(id) == 1
    }
}

/// A single-entry/single-exit branch region: a fan-out node (`entry`)
/// whose reconvergence point is an `Add`/`Concat` (`join`), with every
/// arm between them a plain unary chain of single-consumer nodes. An
/// empty arm is the identity skip edge of a residual connection.
///
/// This is the unit the branch-aware planner turns into a
/// [`crate::optimizer::Segment::Branch`]: arms execute depth-first one
/// after another while the entry buffer stays live, and the join fuses
/// with the final arm instead of launching as a standalone kernel.
#[derive(Debug, Clone)]
pub struct BranchRegion {
    /// The fan-out node feeding every arm (not part of the region).
    pub entry: NodeId,
    /// The reconverging `Add`/`Concat` node.
    pub join: NodeId,
    /// Arm bodies in join-input order: `arms[i]` produces
    /// `join.inputs[i]` (an empty arm means the join reads `entry`
    /// directly).
    pub arms: Vec<Vec<NodeId>>,
}

impl BranchRegion {
    /// All arm-body nodes of the region (entry and join excluded).
    pub fn arm_nodes(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.arms.iter().flatten().copied()
    }
}

/// One node of the network DAG.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    /// Human-readable name, e.g. `features.3.conv`.
    pub name: String,
    pub layer: Layer,
    /// Producer nodes, in argument order.
    pub inputs: Vec<NodeId>,
    /// Inferred output shape.
    pub shape: Shape,
}

/// A neural network as a DAG of layers.
#[derive(Debug, Clone)]
pub struct Graph {
    /// Network name, e.g. `resnet18`.
    pub name: String,
    pub nodes: Vec<Node>,
    /// The single output node (all evaluated networks have one output).
    pub output: NodeId,
}

impl Graph {
    /// Start a new graph with an input placeholder node.
    pub fn new(name: impl Into<String>, input_shape: Shape) -> Self {
        let input = Node {
            id: 0,
            name: "input".into(),
            layer: Layer::Input {
                shape: input_shape.clone(),
            },
            inputs: vec![],
            shape: input_shape,
        };
        Graph {
            name: name.into(),
            nodes: vec![input],
            output: 0,
        }
    }

    /// Append a layer consuming `inputs`; returns the new node id and
    /// updates the graph output to it. Panics on malformed nodes — the
    /// zoo builders construct known-good graphs; loaders of untrusted
    /// graphs use [`Self::try_add`].
    pub fn add(&mut self, name: impl Into<String>, layer: Layer, inputs: &[NodeId]) -> NodeId {
        self.try_add(name, layer, inputs)
            .unwrap_or_else(|e| panic!("graph '{}': {e}", self.name))
    }

    /// Non-panicking [`Self::add`]: validates edges, arity, and op
    /// config *before* shape inference (whose window helpers assert on
    /// degenerate windows), returning a [`GraphError`] that names the
    /// offending node.
    pub fn try_add(
        &mut self,
        name: impl Into<String>,
        layer: Layer,
        inputs: &[NodeId],
    ) -> Result<NodeId, GraphError> {
        let id = self.nodes.len();
        let name = name.into();
        let fail = |code: DiagCode, reason: String, name: &str| GraphError {
            code,
            node: Some(id),
            node_name: Some(name.to_string()),
            reason,
        };
        for &i in inputs {
            if i >= id {
                return Err(fail(
                    DiagCode::NonTopologicalEdge,
                    format!("input {i} does not exist yet"),
                    &name,
                ));
            }
        }
        let (min_in, max_in) = layer.arity();
        if inputs.len() < min_in || inputs.len() > max_in {
            return Err(fail(
                DiagCode::ArityMismatch,
                format!(
                    "{} got {} input(s), expects at least {min_in}",
                    layer.kind_name(),
                    inputs.len()
                ),
                &name,
            ));
        }
        let in_shapes: Vec<&Shape> = inputs.iter().map(|&i| &self.nodes[i].shape).collect();
        if let Err(reason) = layer.check_config(&in_shapes) {
            return Err(fail(DiagCode::DegenerateOp, reason, &name));
        }
        let shape = layer.infer_shape(&in_shapes).map_err(|reason| {
            let code = match layer {
                Layer::Add | Layer::Concat => DiagCode::JoinShapeMismatch,
                _ => DiagCode::DegenerateOp,
            };
            fail(code, reason, &name)
        })?;
        self.nodes.push(Node {
            id,
            name,
            layer,
            inputs: inputs.to_vec(),
            shape,
        });
        self.output = id;
        Ok(id)
    }

    /// Convenience: append a unary layer consuming the current output.
    pub fn push(&mut self, name: impl Into<String>, layer: Layer) -> NodeId {
        let prev = self.output;
        self.add(name, layer, &[prev])
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn input_shape(&self) -> &Shape {
        &self.nodes[0].shape
    }

    pub fn output_shape(&self) -> &Shape {
        &self.nodes[self.output].shape
    }

    /// Number of layers excluding the input placeholder (the paper's
    /// "Layers" column counts operations, not the input).
    pub fn num_layers(&self) -> usize {
        self.nodes.len() - 1
    }

    /// Compute the consumer adjacency once; thread the result through a
    /// whole planning/validation/execution pass rather than re-deriving
    /// it per query.
    pub fn consumer_map(&self) -> Consumers {
        let mut lists = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                lists[i].push(n.id);
            }
        }
        Consumers { lists }
    }

    /// Detect every branch region of the graph: for each `Add`/`Concat`
    /// node, walk each input backwards through single-consumer unary
    /// nodes; the region is valid when all walks stop at one shared
    /// fan-out node. Walks that hit a multi-input node (a nested join)
    /// or diverge onto different entries reject the candidate — such
    /// joins stay ordinary segments.
    ///
    /// Arm bodies of different regions are automatically disjoint (a
    /// single-consumer node's chain leads to exactly one join), and a
    /// join is never inside another region's arm (it is multi-input), so
    /// the returned regions never overlap.
    pub fn branch_regions(&self, cons: &Consumers) -> Vec<BranchRegion> {
        self.nodes
            .iter()
            .filter(|n| matches!(n.layer, Layer::Add | Layer::Concat))
            .filter_map(|n| self.trace_region(n, cons))
            .collect()
    }

    /// Trace one join candidate's arms back to a shared entry.
    fn trace_region(&self, join: &Node, cons: &Consumers) -> Option<BranchRegion> {
        if join.inputs.len() < 2 {
            return None;
        }
        let mut arms = Vec::with_capacity(join.inputs.len());
        let mut entry = None;
        for &src in &join.inputs {
            let mut arm = Vec::new();
            let mut cur = src;
            // Walk upstream while the node is exclusively ours; the
            // first shared (fan-out) node is the entry candidate.
            while cons.is_single(cur) {
                let n = self.node(cur);
                if n.inputs.len() != 1 {
                    return None; // nested join or input placeholder
                }
                arm.push(cur);
                cur = n.inputs[0];
            }
            match entry {
                None => entry = Some(cur),
                Some(e) if e == cur => {}
                Some(_) => return None, // arms diverge: no single entry
            }
            arm.reverse();
            arms.push(arm);
        }
        Some(BranchRegion {
            entry: entry.expect("join has >= 2 inputs"),
            join: join.id,
            arms,
        })
    }

    /// Validate structural invariants. Delegates to the full graph lint
    /// (`crate::analysis::lint_graph`) and surfaces the first
    /// `Severity::Error` finding as a structured [`GraphError`];
    /// warnings (e.g. dtype mixes at a concat) do not fail validation —
    /// run `brainslug check` to see them.
    pub fn validate(&self) -> Result<(), GraphError> {
        let first_error = crate::analysis::lint_graph(self)
            .into_iter()
            .find(|d| d.severity == Severity::Error);
        match first_error {
            None => Ok(()),
            Some(d) => Err(GraphError {
                code: d.code,
                node: d.node,
                node_name: d
                    .node
                    .and_then(|id| self.nodes.get(id))
                    .map(|n| n.name.clone()),
                reason: d.message,
            }),
        }
    }

    /// Histogram of layer kinds (for reports and Table 2's layer counts).
    pub fn kind_histogram(&self) -> HashMap<&'static str, usize> {
        let mut h = HashMap::new();
        for n in self.nodes.iter().skip(1) {
            *h.entry(n.layer.kind_name()).or_insert(0) += 1;
        }
        h
    }

    /// Total parameter count of the network.
    pub fn num_params(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| {
                let input = n.inputs.first().map(|&i| &self.nodes[i].shape);
                match input {
                    Some(s) => n.layer.param_shapes(s).iter().map(|p| p.numel()).sum(),
                    None => 0,
                }
            })
            .sum()
    }

    /// Rebuild this graph with a different batch size (shapes re-inferred).
    pub fn with_batch(&self, batch: usize) -> Graph {
        let mut dims = self.input_shape().dims.clone();
        dims[0] = batch;
        let mut g = Graph::new(
            self.name.clone(),
            Shape::new(dims, self.input_shape().dtype),
        );
        for n in self.nodes.iter().skip(1) {
            g.add(n.name.clone(), n.layer.clone(), &n.inputs);
        }
        g.output = self.output;
        g
    }

    /// GraphViz DOT rendering (debug/diagnostics).
    pub fn to_dot(&self) -> String {
        let mut s = format!("digraph \"{}\" {{\n  rankdir=TB;\n", self.name);
        for n in &self.nodes {
            let color = if n.layer.is_optimizable() {
                "lightblue"
            } else {
                "lightgray"
            };
            s.push_str(&format!(
                "  n{} [label=\"{}\\n{}\\n{}\" style=filled fillcolor={}];\n",
                n.id,
                n.name,
                n.layer.kind_name(),
                n.shape,
                color
            ));
            for &i in &n.inputs {
                s.push_str(&format!("  n{} -> n{};\n", i, n.id));
            }
        }
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::{PoolKind, Window2d};

    fn tiny() -> Graph {
        let mut g = Graph::new("tiny", Shape::nchw(1, 3, 8, 8));
        g.push(
            "conv1",
            Layer::Conv2d {
                out_channels: 4,
                window: Window2d::square(3, 1, 1),
                bias: false,
            },
        );
        g.push("bn1", Layer::BatchNorm2d { eps: 1e-5 });
        g.push("relu1", Layer::Relu);
        g.push(
            "pool1",
            Layer::Pool2d {
                kind: PoolKind::Max,
                window: Window2d::square(2, 2, 0),
                ceil_mode: false,
                count_include_pad: true,
            },
        );
        g
    }

    #[test]
    fn build_and_validate() {
        let g = tiny();
        assert_eq!(g.num_layers(), 4);
        assert_eq!(g.output_shape(), &Shape::nchw(1, 4, 4, 4));
        g.validate().unwrap();
    }

    #[test]
    fn residual_add_graph() {
        let mut g = Graph::new("res", Shape::nchw(1, 4, 8, 8));
        let x = g.output;
        let c = g.push(
            "conv",
            Layer::Conv2d {
                out_channels: 4,
                window: Window2d::square(3, 1, 1),
                bias: false,
            },
        );
        g.add("add", Layer::Add, &[c, x]);
        g.push("relu", Layer::Relu);
        g.validate().unwrap();
        let cons = g.consumer_map();
        assert_eq!(cons.of(x), &[c, c + 1]); // input feeds conv and add
    }

    #[test]
    fn single_consumer_flags() {
        let mut g = Graph::new("fan", Shape::nchw(1, 4, 8, 8));
        let x = g.output;
        let a = g.add("relu_a", Layer::Relu, &[x]);
        let b = g.add("relu_b", Layer::Relu, &[x]);
        g.add("add", Layer::Add, &[a, b]);
        let cons = g.consumer_map();
        assert!(!cons.is_single(x)); // two consumers
        assert_eq!(cons.count(x), 2);
        assert!(cons.is_single(a) && cons.is_single(b));
    }

    #[test]
    fn residual_branch_region_detected() {
        // x -> conv -> bn \
        //   \--------------> add -> relu
        let mut g = Graph::new("res", Shape::nchw(1, 4, 8, 8));
        let x = g.output;
        let c = g.push(
            "conv",
            Layer::Conv2d {
                out_channels: 4,
                window: Window2d::square(3, 1, 1),
                bias: false,
            },
        );
        let b = g.push("bn", Layer::BatchNorm2d { eps: 1e-5 });
        g.add("add", Layer::Add, &[b, x]);
        g.push("relu", Layer::Relu);
        let cons = g.consumer_map();
        let regions = g.branch_regions(&cons);
        assert_eq!(regions.len(), 1);
        let r = &regions[0];
        assert_eq!(r.entry, x);
        assert_eq!(g.node(r.join).layer.kind_name(), "add");
        assert_eq!(r.arms, vec![vec![c, b], vec![]]); // identity skip arm
        assert_eq!(r.arm_nodes().count(), 2);
    }

    #[test]
    fn concat_region_with_parallel_arms() {
        // Fire-module shape: s fans out to two conv+relu arms, concat.
        let mut g = Graph::new("fire", Shape::nchw(1, 4, 8, 8));
        let s = g.push("squeeze_relu", Layer::Relu);
        let conv = |oc: usize| Layer::Conv2d {
            out_channels: oc,
            window: Window2d::square(1, 1, 0),
            bias: true,
        };
        let a = g.add("e1", conv(8), &[s]);
        let ar = g.add("e1_relu", Layer::Relu, &[a]);
        let b = g.add("e3", conv(8), &[s]);
        let br = g.add("e3_relu", Layer::Relu, &[b]);
        g.add("cat", Layer::Concat, &[ar, br]);
        let regions = g.branch_regions(&g.consumer_map());
        assert_eq!(regions.len(), 1);
        assert_eq!(regions[0].entry, s);
        assert_eq!(regions[0].arms, vec![vec![a, ar], vec![b, br]]);
    }

    #[test]
    fn nested_join_rejects_outer_region() {
        // inner add reconverges at x; the outer concat's arm contains the
        // inner join (multi-input), so only the inner region is valid.
        let mut g = Graph::new("nest", Shape::nchw(1, 4, 8, 8));
        let x = g.push("relu0", Layer::Relu);
        let a = g.add("bn_a", Layer::BatchNorm2d { eps: 1e-5 }, &[x]);
        let inner = g.add("add", Layer::Add, &[a, x]);
        let c = g.add("relu_c", Layer::Relu, &[inner]);
        g.add("cat", Layer::Concat, &[c, x]);
        let regions = g.branch_regions(&g.consumer_map());
        assert_eq!(regions.len(), 1);
        assert_eq!(regions[0].join, inner);
    }

    #[test]
    fn consumer_map_contents() {
        let g = tiny(); // input -> conv1 -> bn1 -> relu1 -> pool1
        let cons = g.consumer_map();
        for id in 0..g.nodes.len() - 1 {
            assert_eq!(cons.of(id), &[id + 1]);
            assert!(cons.is_single(id));
        }
        assert_eq!(cons.count(g.output), 0);
        assert!(!cons.is_single(g.output));
    }

    #[test]
    fn with_batch_rebuilds_shapes() {
        let g = tiny().with_batch(16);
        assert_eq!(g.input_shape().batch(), 16);
        assert_eq!(g.output_shape(), &Shape::nchw(16, 4, 4, 4));
        g.validate().unwrap();
    }

    #[test]
    fn validate_catches_dangling() {
        let mut g = tiny();
        // Add a node not connected to the output.
        let id = g.add("stray", Layer::Relu, &[1]);
        g.output = id - 1; // restore old output, leaving `stray` dangling...
        assert!(g.validate().is_err());
    }

    #[test]
    fn kind_histogram_counts() {
        let h = tiny().kind_histogram();
        assert_eq!(h["conv2d"], 1);
        assert_eq!(h["batchnorm"], 1);
        assert_eq!(h["relu"], 1);
        assert_eq!(h["maxpool"], 1);
    }

    #[test]
    fn num_params() {
        let g = tiny();
        // conv 4*3*3*3 = 108, bn 4*4 = 16
        assert_eq!(g.num_params(), 108 + 16);
    }

    #[test]
    fn dot_renders() {
        let dot = tiny().to_dot();
        assert!(dot.contains("digraph"));
        assert!(dot.contains("maxpool"));
    }
}
