//! Tensor shapes and dtypes for the graph IR.
//!
//! All activation tensors in the evaluated networks are rank-4 `NCHW`
//! (batch, channels, height, width) or rank-2 `NF` (batch, features)
//! after flattening, so we model shapes as a small owned dim vector with
//! NCHW helpers rather than a general tensor algebra.

/// Element type of a tensor. The paper evaluates f32 end-to-end; bf16 is
/// carried for the TPU-profile VMEM sizing in the collapser/memsim.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    BF16,
}

impl DType {
    /// Size of one element in bytes.
    pub fn bytes(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::BF16 => 2,
        }
    }

    /// Name as used in artifact signatures (stable across rust/python).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::BF16 => "bf16",
        }
    }
}

/// Shape of an activation or parameter tensor.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape {
    pub dims: Vec<usize>,
    pub dtype: DType,
}

impl Shape {
    pub fn new(dims: Vec<usize>, dtype: DType) -> Self {
        Shape { dims, dtype }
    }

    /// Rank-4 NCHW activation shape (f32).
    pub fn nchw(n: usize, c: usize, h: usize, w: usize) -> Self {
        Shape::new(vec![n, c, h, w], DType::F32)
    }

    /// Rank-2 (batch, features) shape (f32).
    pub fn nf(n: usize, f: usize) -> Self {
        Shape::new(vec![n, f], DType::F32)
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Total number of elements.
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// Total size in bytes.
    pub fn bytes(&self) -> usize {
        self.numel() * self.dtype.bytes()
    }

    pub fn batch(&self) -> usize {
        self.dims[0]
    }

    /// Channel count for NCHW, feature count for NF.
    pub fn channels(&self) -> usize {
        assert!(self.rank() >= 2, "channels() on rank-{} shape", self.rank());
        self.dims[1]
    }

    pub fn height(&self) -> usize {
        assert_eq!(self.rank(), 4, "height() on rank-{} shape", self.rank());
        self.dims[2]
    }

    pub fn width(&self) -> usize {
        assert_eq!(self.rank(), 4, "width() on rank-{} shape", self.rank());
        self.dims[3]
    }

    /// Signature fragment used in artifact names: `1x64x32x32f32`.
    pub fn sig(&self) -> String {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        format!("{}{}", dims.join("x"), self.dtype.name())
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.sig())
    }
}

/// Output spatial extent of a conv/pool window:
/// `floor((in + 2*pad - kernel) / stride) + 1`.
///
/// Matches PyTorch's default (floor) mode, which TorchVision networks use.
pub fn conv_out_dim(input: usize, kernel: usize, stride: usize, pad: usize) -> usize {
    assert!(stride > 0, "stride must be positive");
    let padded = input + 2 * pad;
    assert!(
        padded >= kernel,
        "window {kernel} larger than padded input {padded}"
    );
    (padded - kernel) / stride + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_bytes() {
        let s = Shape::nchw(2, 3, 4, 5);
        assert_eq!(s.numel(), 120);
        assert_eq!(s.bytes(), 480);
        let b = Shape::new(vec![2, 3], DType::BF16);
        assert_eq!(b.bytes(), 12);
    }

    #[test]
    fn accessors() {
        let s = Shape::nchw(8, 16, 32, 33);
        assert_eq!(
            (s.batch(), s.channels(), s.height(), s.width()),
            (8, 16, 32, 33)
        );
        let f = Shape::nf(8, 100);
        assert_eq!((f.batch(), f.channels()), (8, 100));
    }

    #[test]
    fn sig_format() {
        assert_eq!(Shape::nchw(1, 64, 32, 32).sig(), "1x64x32x32f32");
        assert_eq!(Shape::new(vec![4, 8], DType::BF16).sig(), "4x8bf16");
    }

    #[test]
    fn conv_out_dims() {
        // 3x3 stride 1 pad 1 keeps size ("same").
        assert_eq!(conv_out_dim(32, 3, 1, 1), 32);
        // 3x3 stride 2 pad 1 halves (ceil).
        assert_eq!(conv_out_dim(32, 3, 2, 1), 16);
        // 2x2 stride 2 pad 0 halves exactly.
        assert_eq!(conv_out_dim(32, 2, 2, 0), 16);
        // AlexNet-style 11x11 stride 4 pad 2 on 224.
        assert_eq!(conv_out_dim(224, 11, 4, 2), 55);
        // floor mode: 7x7 pool on 6+2*0 is invalid; on 7 it's 1.
        assert_eq!(conv_out_dim(7, 7, 1, 0), 1);
    }

    #[test]
    #[should_panic]
    fn conv_out_dim_window_too_large() {
        conv_out_dim(4, 7, 1, 0);
    }
}
