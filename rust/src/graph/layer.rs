//! Layer definitions for the graph IR.
//!
//! The set covers everything the 21 evaluated TorchVision architectures
//! need (AlexNet, VGG±BN, ResNet, DenseNet, SqueezeNet, Inception-V3):
//! convolutions, linear layers, max/avg pooling, batch-norm, ReLU,
//! dropout, flatten, residual add and channel concat.
//!
//! `Layer::is_optimizable` encodes the paper's §3.2 criterion: a layer can
//! join a depth-first stack iff it operates on a local sub-region of its
//! input — element-wise layers (BN, ReLU, dropout) and pooling layers.
//! Convolution and linear layers are explicitly excluded (§7 Limitations),
//! and multi-input joins (add/concat) break stacks structurally.

use super::shape::{conv_out_dim, Shape};

/// 2-D window parameters shared by pooling layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Window2d {
    pub kernel: (usize, usize),
    pub stride: (usize, usize),
    pub pad: (usize, usize),
}

impl Window2d {
    pub fn square(kernel: usize, stride: usize, pad: usize) -> Self {
        Window2d {
            kernel: (kernel, kernel),
            stride: (stride, stride),
            pad: (pad, pad),
        }
    }

    /// Output spatial dims for an input of `(h, w)`.
    pub fn out_hw(&self, h: usize, w: usize, ceil_mode: bool) -> (usize, usize) {
        if ceil_mode {
            (
                ceil_out_dim(h, self.kernel.0, self.stride.0, self.pad.0),
                ceil_out_dim(w, self.kernel.1, self.stride.1, self.pad.1),
            )
        } else {
            (
                conv_out_dim(h, self.kernel.0, self.stride.0, self.pad.0),
                conv_out_dim(w, self.kernel.1, self.stride.1, self.pad.1),
            )
        }
    }

    /// Signature fragment, e.g. `k3x3s1p1`.
    pub fn sig(&self) -> String {
        format!(
            "k{}x{}s{}x{}p{}x{}",
            self.kernel.0, self.kernel.1, self.stride.0, self.stride.1, self.pad.0, self.pad.1
        )
    }
}

/// Ceil-mode output extent (PyTorch `ceil_mode=True`, used by SqueezeNet's
/// max-pools). PyTorch additionally forbids windows that start entirely in
/// the right/bottom padding; that correction is applied here.
pub fn ceil_out_dim(input: usize, kernel: usize, stride: usize, pad: usize) -> usize {
    let padded = input + 2 * pad;
    assert!(padded >= kernel, "window larger than padded input");
    let mut out = (padded - kernel).div_ceil(stride) + 1;
    // Last window must start inside the (left-padded) input.
    if pad > 0 && (out - 1) * stride >= input + pad {
        out -= 1;
    }
    out
}

/// Pooling aggregation kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolKind {
    Max,
    Avg,
}

/// A single layer (graph node operation).
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    /// Input placeholder; carries the network's input shape.
    Input { shape: Shape },
    /// 2-D convolution, NCHW, OIHW weights.
    Conv2d {
        out_channels: usize,
        window: Window2d,
        bias: bool,
    },
    /// Fully-connected layer over flattened features.
    Linear { out_features: usize, bias: bool },
    /// Max or average pooling.
    Pool2d {
        kind: PoolKind,
        window: Window2d,
        /// PyTorch `ceil_mode` (SqueezeNet max-pools use true).
        ceil_mode: bool,
        /// For avg pooling: whether padded zeros count in the divisor
        /// (PyTorch default true).
        count_include_pad: bool,
    },
    /// Adaptive average pooling to a fixed output size (maps onto a plain
    /// avg-pool whose kernel/stride are derived from the input extent).
    AdaptiveAvgPool { out_hw: (usize, usize) },
    /// Inference-mode batch normalization: per-channel affine
    /// `y = (x - mean) / sqrt(var + eps) * gamma + beta`.
    BatchNorm2d { eps: f32 },
    /// Rectified linear unit.
    Relu,
    /// Dropout — identity at inference time; kept in the graph because the
    /// paper's layer counts include it and it participates in stacks.
    Dropout { p: f32 },
    /// Collapse CHW to features.
    Flatten,
    /// Element-wise residual addition of two inputs.
    Add,
    /// Channel-axis concatenation of N inputs.
    Concat,
}

impl Layer {
    /// §3.2: can this layer be absorbed into a depth-first stack?
    pub fn is_optimizable(&self) -> bool {
        matches!(
            self,
            Layer::Pool2d { .. } | Layer::BatchNorm2d { .. } | Layer::Relu | Layer::Dropout { .. }
        )
    }

    /// Element-wise layers never change shape and can always join a step;
    /// pooling is optimizable but *not* element-wise (one per step).
    pub fn is_elementwise(&self) -> bool {
        matches!(
            self,
            Layer::BatchNorm2d { .. } | Layer::Relu | Layer::Dropout { .. }
        )
    }

    /// Does this layer carry learned parameters, and what are their shapes
    /// given the input shape? Order matches the python side (`model.py`).
    pub fn param_shapes(&self, input: &Shape) -> Vec<Shape> {
        match self {
            Layer::Conv2d {
                out_channels,
                window,
                bias,
            } => {
                let mut v = vec![Shape::new(
                    vec![
                        *out_channels,
                        input.channels(),
                        window.kernel.0,
                        window.kernel.1,
                    ],
                    input.dtype,
                )];
                if *bias {
                    v.push(Shape::new(vec![*out_channels], input.dtype));
                }
                v
            }
            Layer::Linear { out_features, bias } => {
                let mut v = vec![Shape::new(
                    vec![input.channels(), *out_features],
                    input.dtype,
                )];
                if *bias {
                    v.push(Shape::new(vec![*out_features], input.dtype));
                }
                v
            }
            Layer::BatchNorm2d { .. } => {
                let c = input.channels();
                // gamma, beta, running_mean, running_var
                (0..4)
                    .map(|_| Shape::new(vec![c], input.dtype))
                    .collect()
            }
            _ => vec![],
        }
    }

    /// Expected input arity as `(min, max)`; `max == usize::MAX` means
    /// unbounded (Concat).
    pub fn arity(&self) -> (usize, usize) {
        match self {
            Layer::Input { .. } => (0, 0),
            Layer::Add => (2, 2),
            Layer::Concat => (2, usize::MAX),
            _ => (1, 1),
        }
    }

    /// Static config sanity for the given inputs: everything that would
    /// make [`Self::infer_shape`]'s window helpers assert (zero stride,
    /// window larger than the padded input) or define a degenerate op
    /// (zero-size kernel, zero output channels/features, zero-size
    /// adaptive target). [`super::Graph::try_add`] and the graph lint
    /// run this *before* `infer_shape`, which panics on these inputs.
    pub fn check_config(&self, inputs: &[&Shape]) -> Result<(), String> {
        fn window_ok(w: &Window2d, input: Option<&&Shape>) -> Result<(), String> {
            if w.kernel.0 == 0 || w.kernel.1 == 0 {
                return Err(format!("zero-size window {}", w.sig()));
            }
            if w.stride.0 == 0 || w.stride.1 == 0 {
                return Err(format!("zero stride in window {}", w.sig()));
            }
            if let Some(i) = input {
                if i.rank() == 4
                    && (i.height() + 2 * w.pad.0 < w.kernel.0
                        || i.width() + 2 * w.pad.1 < w.kernel.1)
                {
                    return Err(format!(
                        "window {} larger than padded input {i}",
                        w.sig()
                    ));
                }
            }
            Ok(())
        }
        match self {
            Layer::Conv2d {
                out_channels,
                window,
                ..
            } => {
                if *out_channels == 0 {
                    return Err("conv2d with zero output channels".into());
                }
                window_ok(window, inputs.first())
            }
            Layer::Linear { out_features, .. } => {
                if *out_features == 0 {
                    return Err("linear with zero output features".into());
                }
                Ok(())
            }
            Layer::Pool2d { window, .. } => window_ok(window, inputs.first()),
            Layer::AdaptiveAvgPool { out_hw } => {
                if out_hw.0 == 0 || out_hw.1 == 0 {
                    return Err("adaptive pool with zero-size target".into());
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }

    /// Infer the output shape from input shapes (most layers are unary).
    pub fn infer_shape(&self, inputs: &[&Shape]) -> Result<Shape, String> {
        let unary = || -> Result<&Shape, String> {
            if inputs.len() == 1 {
                Ok(inputs[0])
            } else {
                Err(format!("{self:?} expects 1 input, got {}", inputs.len()))
            }
        };
        match self {
            Layer::Input { shape } => Ok(shape.clone()),
            Layer::Conv2d {
                out_channels,
                window,
                ..
            } => {
                let i = unary()?;
                if i.rank() != 4 {
                    return Err(format!("conv2d needs rank-4 input, got {i}"));
                }
                let (oh, ow) = window.out_hw(i.height(), i.width(), false);
                Ok(Shape::new(
                    vec![i.batch(), *out_channels, oh, ow],
                    i.dtype,
                ))
            }
            Layer::Linear { out_features, .. } => {
                let i = unary()?;
                if i.rank() != 2 {
                    return Err(format!("linear needs rank-2 input, got {i}"));
                }
                Ok(Shape::new(vec![i.batch(), *out_features], i.dtype))
            }
            Layer::Pool2d {
                window, ceil_mode, ..
            } => {
                let i = unary()?;
                if i.rank() != 4 {
                    return Err(format!("pool2d needs rank-4 input, got {i}"));
                }
                let (oh, ow) = window.out_hw(i.height(), i.width(), *ceil_mode);
                Ok(Shape::new(
                    vec![i.batch(), i.channels(), oh, ow],
                    i.dtype,
                ))
            }
            Layer::AdaptiveAvgPool { out_hw } => {
                let i = unary()?;
                if i.rank() != 4 {
                    return Err(format!("adaptive pool needs rank-4 input, got {i}"));
                }
                if i.height() % out_hw.0 != 0 || i.width() % out_hw.1 != 0 {
                    return Err(format!(
                        "adaptive pool {}x{} does not divide input {}x{}",
                        out_hw.0,
                        out_hw.1,
                        i.height(),
                        i.width()
                    ));
                }
                Ok(Shape::new(
                    vec![i.batch(), i.channels(), out_hw.0, out_hw.1],
                    i.dtype,
                ))
            }
            Layer::BatchNorm2d { .. } | Layer::Relu | Layer::Dropout { .. } => {
                Ok(unary()?.clone())
            }
            Layer::Flatten => {
                let i = unary()?;
                Ok(Shape::new(
                    vec![i.batch(), i.numel() / i.batch()],
                    i.dtype,
                ))
            }
            Layer::Add => {
                if inputs.len() != 2 {
                    return Err(format!("add expects 2 inputs, got {}", inputs.len()));
                }
                if inputs[0] != inputs[1] {
                    return Err(format!(
                        "add shape mismatch: {} vs {}",
                        inputs[0], inputs[1]
                    ));
                }
                Ok(inputs[0].clone())
            }
            Layer::Concat => {
                if inputs.len() < 2 {
                    return Err("concat expects >=2 inputs".into());
                }
                let first = inputs[0];
                let mut channels = 0;
                for i in inputs {
                    if i.rank() != 4 {
                        return Err(format!("concat needs rank-4 inputs, got {i}"));
                    }
                    if i.batch() != first.batch()
                        || i.height() != first.height()
                        || i.width() != first.width()
                    {
                        return Err(format!("concat mismatch: {first} vs {i}"));
                    }
                    channels += i.channels();
                }
                Ok(Shape::new(
                    vec![first.batch(), channels, first.height(), first.width()],
                    first.dtype,
                ))
            }
        }
    }

    /// Short kind tag used in signatures and reports.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Layer::Input { .. } => "input",
            Layer::Conv2d { .. } => "conv2d",
            Layer::Linear { .. } => "linear",
            Layer::Pool2d {
                kind: PoolKind::Max,
                ..
            } => "maxpool",
            Layer::Pool2d {
                kind: PoolKind::Avg,
                ..
            } => "avgpool",
            Layer::AdaptiveAvgPool { .. } => "adaptiveavgpool",
            Layer::BatchNorm2d { .. } => "batchnorm",
            Layer::Relu => "relu",
            Layer::Dropout { .. } => "dropout",
            Layer::Flatten => "flatten",
            Layer::Add => "add",
            Layer::Concat => "concat",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(n: usize, c: usize, h: usize, w: usize) -> Shape {
        Shape::nchw(n, c, h, w)
    }

    #[test]
    fn optimizable_classification() {
        assert!(Layer::Relu.is_optimizable());
        assert!(Layer::BatchNorm2d { eps: 1e-5 }.is_optimizable());
        assert!(Layer::Dropout { p: 0.5 }.is_optimizable());
        let pool = Layer::Pool2d {
            kind: PoolKind::Max,
            window: Window2d::square(3, 1, 1),
            ceil_mode: false,
            count_include_pad: true,
        };
        assert!(pool.is_optimizable());
        assert!(!pool.is_elementwise());
        assert!(Layer::Relu.is_elementwise());
        assert!(!Layer::Conv2d {
            out_channels: 8,
            window: Window2d::square(3, 1, 1),
            bias: true
        }
        .is_optimizable());
        assert!(!Layer::Add.is_optimizable());
        assert!(!Layer::Concat.is_optimizable());
        assert!(!Layer::Flatten.is_optimizable());
    }

    #[test]
    fn conv_shape_inference() {
        let conv = Layer::Conv2d {
            out_channels: 16,
            window: Window2d::square(3, 2, 1),
            bias: false,
        };
        let out = conv.infer_shape(&[&s(4, 3, 32, 32)]).unwrap();
        assert_eq!(out, s(4, 16, 16, 16));
    }

    #[test]
    fn pool_shape_inference_floor_and_ceil() {
        let mk = |ceil| Layer::Pool2d {
            kind: PoolKind::Max,
            window: Window2d::square(3, 2, 0),
            ceil_mode: ceil,
            count_include_pad: true,
        };
        // floor: (13-3)/2+1 = 6 ; ceil: ceil((13-3)/2)+1 = 6? (10/2=5)+1=6 both.
        assert_eq!(mk(false).infer_shape(&[&s(1, 8, 13, 13)]).unwrap(), s(1, 8, 6, 6));
        // 14: floor (11/2=5)+1=6? (14-3)/2+1 = 6 ; ceil = ceil(11/2)+1 = 7.
        assert_eq!(mk(false).infer_shape(&[&s(1, 8, 14, 14)]).unwrap(), s(1, 8, 6, 6));
        assert_eq!(mk(true).infer_shape(&[&s(1, 8, 14, 14)]).unwrap(), s(1, 8, 7, 7));
    }

    #[test]
    fn ceil_mode_pad_correction() {
        // input 4, k2 s2 p1: padded 6, ceil((6-2)/2)+1 = 3, last window
        // starts at 4 >= input+pad=5? no (4 < 5) -> stays 3.
        assert_eq!(ceil_out_dim(4, 2, 2, 1), 3);
        // input 3, k2 s2 p1: padded 5, ceil(3/2)+1 = 3, last start 4 >= 3+1=4
        // -> corrected to 2.
        assert_eq!(ceil_out_dim(3, 2, 2, 1), 2);
    }

    #[test]
    fn add_concat_inference() {
        let a = s(2, 8, 16, 16);
        let b = s(2, 24, 16, 16);
        assert_eq!(Layer::Add.infer_shape(&[&a, &a]).unwrap(), a);
        assert!(Layer::Add.infer_shape(&[&a, &b]).is_err());
        assert_eq!(
            Layer::Concat.infer_shape(&[&a, &b]).unwrap(),
            s(2, 32, 16, 16)
        );
    }

    #[test]
    fn param_shapes() {
        let conv = Layer::Conv2d {
            out_channels: 16,
            window: Window2d::square(3, 1, 1),
            bias: true,
        };
        let ps = conv.param_shapes(&s(1, 8, 32, 32));
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].dims, vec![16, 8, 3, 3]);
        assert_eq!(ps[1].dims, vec![16]);
        let bn = Layer::BatchNorm2d { eps: 1e-5 };
        assert_eq!(bn.param_shapes(&s(1, 8, 32, 32)).len(), 4);
        assert!(Layer::Relu.param_shapes(&s(1, 8, 32, 32)).is_empty());
    }

    #[test]
    fn adaptive_pool() {
        let l = Layer::AdaptiveAvgPool { out_hw: (1, 1) };
        assert_eq!(l.infer_shape(&[&s(2, 64, 8, 8)]).unwrap(), s(2, 64, 1, 1));
        assert!(l.infer_shape(&[&s(2, 64, 8, 8)]).is_ok());
        let l7 = Layer::AdaptiveAvgPool { out_hw: (7, 7) };
        assert!(l7.infer_shape(&[&s(2, 64, 8, 8)]).is_err());
    }

    #[test]
    fn flatten_linear() {
        let f = Layer::Flatten.infer_shape(&[&s(2, 64, 4, 4)]).unwrap();
        assert_eq!(f, Shape::nf(2, 1024));
        let l = Layer::Linear {
            out_features: 10,
            bias: true,
        };
        assert_eq!(l.infer_shape(&[&f]).unwrap(), Shape::nf(2, 10));
    }
}
