//! Graph ⇄ JSON interop with the python compile path.
//!
//! The rust zoo is the single source of truth for network topology: the
//! `brainslug emit-requests` command exports graphs in this JSON form and
//! `python/compile/model.py` *interprets* them as JAX computations — the
//! python side never re-defines an architecture, so the two layers cannot
//! drift. The schema is stable and covered by golden tests on both sides.

use crate::json::Json;

use super::dag::{Graph, Node};
use super::layer::{Layer, PoolKind, Window2d};
use super::shape::{DType, Shape};

fn shape_json(s: &Shape) -> Json {
    let mut o = Json::object();
    o.set(
        "dims",
        Json::Arr(s.dims.iter().map(|&d| Json::from_usize(d)).collect()),
    );
    o.set("dtype", Json::Str(s.dtype.name().to_string()));
    o
}

fn shape_from_json(j: &Json) -> anyhow::Result<Shape> {
    let dims = j.req("dims")?.usize_vec()?;
    let dtype = match j.str_field("dtype")?.as_str() {
        "f32" => DType::F32,
        "bf16" => DType::BF16,
        other => anyhow::bail!("unknown dtype {other}"),
    };
    Ok(Shape::new(dims, dtype))
}

fn pair(j: (usize, usize)) -> Json {
    Json::Arr(vec![Json::from_usize(j.0), Json::from_usize(j.1)])
}

fn pair_from(j: &Json) -> anyhow::Result<(usize, usize)> {
    let v = j.usize_vec()?;
    if v.len() != 2 {
        anyhow::bail!("expected pair, got {} elems", v.len());
    }
    Ok((v[0], v[1]))
}

fn window_json(o: &mut Json, w: &Window2d) {
    o.set("kernel", pair(w.kernel));
    o.set("stride", pair(w.stride));
    o.set("pad", pair(w.pad));
}

fn window_from(j: &Json) -> anyhow::Result<Window2d> {
    Ok(Window2d {
        kernel: pair_from(j.req("kernel")?)?,
        stride: pair_from(j.req("stride")?)?,
        pad: pair_from(j.req("pad")?)?,
    })
}

/// Serialize one layer's kind + parameters into an object (shared by the
/// graph exporter and the compile-request emitter).
pub fn layer_fields_into(o: &mut Json, layer: &Layer) {
    o.set("kind", Json::Str(layer.kind_name().to_string()));
    match layer {
        Layer::Input { shape } => {
            o.set("shape", shape_json(shape));
        }
        Layer::Conv2d {
            out_channels,
            window,
            bias,
        } => {
            o.set("out_channels", Json::from_usize(*out_channels));
            window_json(o, window);
            o.set("bias", Json::Bool(*bias));
        }
        Layer::Linear { out_features, bias } => {
            o.set("out_features", Json::from_usize(*out_features));
            o.set("bias", Json::Bool(*bias));
        }
        Layer::Pool2d {
            kind,
            window,
            ceil_mode,
            count_include_pad,
        } => {
            o.set(
                "pool",
                Json::Str(
                    match kind {
                        PoolKind::Max => "max",
                        PoolKind::Avg => "avg",
                    }
                    .to_string(),
                ),
            );
            window_json(o, window);
            o.set("ceil_mode", Json::Bool(*ceil_mode));
            o.set("count_include_pad", Json::Bool(*count_include_pad));
        }
        Layer::AdaptiveAvgPool { out_hw } => {
            o.set("out_hw", pair(*out_hw));
        }
        Layer::BatchNorm2d { eps } => {
            o.set("eps", Json::Num(*eps as f64));
        }
        Layer::Dropout { p } => {
            o.set("p", Json::Num(*p as f64));
        }
        Layer::Relu | Layer::Flatten | Layer::Add | Layer::Concat => {}
    }
}

fn layer_from_json(j: &Json) -> anyhow::Result<Layer> {
    let kind = j.str_field("kind")?;
    Ok(match kind.as_str() {
        "input" => Layer::Input {
            shape: shape_from_json(j.req("shape")?)?,
        },
        "conv2d" => Layer::Conv2d {
            out_channels: j.usize_field("out_channels")?,
            window: window_from(j)?,
            bias: j.bool_field("bias")?,
        },
        "linear" => Layer::Linear {
            out_features: j.usize_field("out_features")?,
            bias: j.bool_field("bias")?,
        },
        "maxpool" | "avgpool" => Layer::Pool2d {
            kind: match j.str_field("pool")?.as_str() {
                "max" => PoolKind::Max,
                "avg" => PoolKind::Avg,
                other => anyhow::bail!("bad pool kind {other}"),
            },
            window: window_from(j)?,
            ceil_mode: j.bool_field("ceil_mode")?,
            count_include_pad: j.bool_field("count_include_pad")?,
        },
        "adaptiveavgpool" => Layer::AdaptiveAvgPool {
            out_hw: pair_from(j.req("out_hw")?)?,
        },
        "batchnorm" => Layer::BatchNorm2d {
            eps: j.f64_field("eps")? as f32,
        },
        "relu" => Layer::Relu,
        "dropout" => Layer::Dropout {
            p: j.f64_field("p")? as f32,
        },
        "flatten" => Layer::Flatten,
        "add" => Layer::Add,
        "concat" => Layer::Concat,
        other => anyhow::bail!("unknown layer kind {other}"),
    })
}

/// Serialize a graph (topology + shapes) to JSON.
pub fn graph_to_json(g: &Graph) -> Json {
    let mut root = Json::object();
    root.set("name", Json::Str(g.name.clone()));
    root.set("output", Json::from_usize(g.output));
    let nodes: Vec<Json> = g
        .nodes
        .iter()
        .map(|n| {
            let mut o = Json::object();
            o.set("id", Json::from_usize(n.id));
            o.set("name", Json::Str(n.name.clone()));
            o.set(
                "inputs",
                Json::Arr(n.inputs.iter().map(|&i| Json::from_usize(i)).collect()),
            );
            o.set("shape", shape_json(&n.shape));
            layer_fields_into(&mut o, &n.layer);
            o
        })
        .collect();
    root.set("nodes", Json::Arr(nodes));
    root
}

/// Parse a graph back from JSON (shape inference re-checks every node).
pub fn graph_from_json(j: &Json) -> anyhow::Result<Graph> {
    let name = j.str_field("name")?;
    let nodes = j.arr_field("nodes")?;
    if nodes.is_empty() {
        anyhow::bail!("graph has no nodes");
    }
    let first = &nodes[0];
    let input_shape = shape_from_json(first.req("shape")?)?;
    let mut g = Graph::new(name, input_shape);
    for nj in &nodes[1..] {
        let layer = layer_from_json(nj)?;
        let inputs = nj.req("inputs")?.usize_vec()?;
        let node_name = nj.str_field("name")?;
        // try_add (not add): a malformed file must produce an error
        // naming the offending node, never a panic.
        let id = g
            .try_add(node_name, layer, &inputs)
            .map_err(|e| anyhow::anyhow!("malformed graph json: {e}"))?;
        // Cross-check stored shape against inference.
        let stored = shape_from_json(nj.req("shape")?)?;
        if g.node(id).shape != stored {
            anyhow::bail!(
                "malformed graph json: node {id} ('{}'): shape mismatch (stored {}, inferred {})",
                g.node(id).name,
                stored,
                g.node(id).shape
            );
        }
    }
    g.output = j.usize_field("output")?;
    g.validate()
        .map_err(|e| anyhow::anyhow!("malformed graph json: {e}"))?;
    Ok(g)
}

/// Parameter manifest of a node: stable (name, kind, shape) triples the
/// runtime and the python oracle both generate with detrng.
pub fn node_param_tags(graph: &Graph, node: &Node) -> Vec<(String, &'static str, Shape)> {
    let input = match node.inputs.first() {
        Some(&i) => &graph.node(i).shape,
        None => return vec![],
    };
    let shapes = node.layer.param_shapes(input);
    let kinds: Vec<&'static str> = match &node.layer {
        Layer::Conv2d { bias, .. } | Layer::Linear { bias, .. } => {
            if *bias {
                vec!["weight", "bias"]
            } else {
                vec!["weight"]
            }
        }
        Layer::BatchNorm2d { .. } => vec!["bn_gamma", "bn_beta", "bn_mean", "bn_var"],
        _ => vec![],
    };
    assert_eq!(shapes.len(), kinds.len(), "param bookkeeping mismatch");
    kinds
        .into_iter()
        .zip(shapes)
        .map(|(k, s)| (format!("{}:{}", node.name, k), k, s))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn roundtrip_all_zoo_networks() {
        for name in zoo::ALL_NETWORKS {
            let g = zoo::build(name, zoo::small_config(name, 2));
            let j = graph_to_json(&g);
            let back = graph_from_json(&j).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(back.nodes.len(), g.nodes.len(), "{name}");
            assert_eq!(back.output, g.output, "{name}");
            for (a, b) in back.nodes.iter().zip(&g.nodes) {
                assert_eq!(a.layer, b.layer, "{name}: node {}", a.id);
                assert_eq!(a.shape, b.shape, "{name}: node {}", a.id);
                assert_eq!(a.inputs, b.inputs, "{name}: node {}", a.id);
            }
        }
    }

    #[test]
    fn json_is_parseable_text() {
        let g = zoo::build("alexnet", zoo::small_config("alexnet", 1));
        let text = graph_to_json(&g).to_string_pretty();
        let j = crate::json::parse(&text).unwrap();
        graph_from_json(&j).unwrap();
    }

    #[test]
    fn param_tags_stable() {
        let g = zoo::build("vgg11_bn", zoo::small_config("vgg11_bn", 1));
        let conv = g.nodes.iter().find(|n| n.name == "features.0.conv").unwrap();
        let tags = node_param_tags(&g, conv);
        assert_eq!(tags.len(), 2);
        assert_eq!(tags[0].0, "features.0.conv:weight");
        assert_eq!(tags[1].0, "features.0.conv:bias");
        let bn = g.nodes.iter().find(|n| n.name == "features.1.bn").unwrap();
        let tags = node_param_tags(&g, bn);
        assert_eq!(tags.len(), 4);
        assert_eq!(tags[2].1, "bn_mean");
    }

    #[test]
    fn corrupted_json_rejected() {
        let g = zoo::build("alexnet", zoo::small_config("alexnet", 1));
        let mut j = graph_to_json(&g);
        j.set("output", Json::from_usize(99999));
        assert!(graph_from_json(&j).is_err());
    }
}
